//! # AdaPtis — adaptive pipeline parallelism for heterogeneous LLMs
//!
//! Rust + JAX + Pallas reproduction of *AdaPtis: Reducing Pipeline
//! Bubbles with Adaptive Pipeline Parallelism on Heterogeneous Models*
//! (cs.DC 2025).  See DESIGN.md for the architecture and the paper →
//! repo substitution table.
//!
//! The crate is the Layer-3 coordinator: it owns model partition,
//! model placement and workload scheduling (the paper's three phases),
//! the Pipeline Performance Model, the Pipeline Generator and the
//! unified Pipeline Executor.  Compute executes via AOT-compiled XLA
//! artifacts (Layer 2 JAX graphs embedding Layer-1 Pallas kernels)
//! loaded through the PJRT C API — python never runs at training time.
//!
//! Quick tour:
//! - [`config`]: model families (paper Table 5), parallelism, hardware;
//! - [`model`]: layer taxonomy + analytical cost model;
//! - [`profile`]: profiled per-layer data (analytical or measured);
//! - [`partition`], [`placement`], [`schedule`]: the three phases;
//! - [`perfmodel`]: Algorithm 1 — the Pipeline Performance Model
//!   (O(slots·log P) event-driven kernel, fused schedule+simulate
//!   evaluation, and the retained reference oracle — DESIGN.md §3);
//! - [`memory`]: the peak-memory model next to it — per-stage
//!   footprints, per-device capacities, and the reference tracker
//!   (DESIGN.md §6);
//! - [`generator`]: §4.3 co-optimization loop — zero-alloc candidate
//!   search over the fused evaluator, accelerated by analytic bound
//!   pruning, score memoization and a persistent evaluation pool
//!   (DESIGN.md §4);
//! - [`executor`]: §4.4 instruction lowering + comm passes —
//!   single-pass resumable deadlock repair, program well-formedness
//!   validation (DESIGN.md §7);
//! - [`cluster`]: simulated + real (threads & PJRT) clusters — the
//!   timed SimCluster is a differential twin of [`perfmodel`]
//!   (bitwise in matched-assumption mode, DESIGN.md §7); plus
//!   deterministic fault/drift injection (`cluster::fault`);
//! - [`adapt`]: the elastic re-planning loop — runtime monitor
//!   (drift estimation, hysteresis, rollback), warm-started
//!   re-generation, and the fault-scenario harness (DESIGN.md §8);
//! - [`service`]: planner-as-a-service — a long-running daemon with a
//!   cross-request plan cache (exact + near-miss warm starts), a
//!   shared evaluation pool, admission control and request
//!   coalescing, fronted by `adaptis serve` (DESIGN.md §9);
//! - [`runtime`]: PJRT artifact loading/execution;
//! - [`trainer`]: end-to-end pipeline training;
//! - [`figures`]: one harness per paper table/figure.

// Clippy runs with `-D warnings` in CI (scripts/verify.sh).  The
// simulation kernels and aggregators walk many *parallel* per-device /
// per-stage arrays by index — the Algorithm-1 correspondence reads off
// the subscripts, and zip-chains over 4+ vectors obscure it — so the
// index-loop style lint is opted out crate-wide.
#![allow(clippy::needless_range_loop)]

pub mod adapt;
pub mod baselines;
pub mod cluster;
pub mod config;
pub mod executor;
pub mod figures;
pub mod generator;
pub mod ilp;
pub mod memory;
pub mod metrics;
pub mod model;
pub mod partition;
pub mod perfmodel;
pub mod placement;
pub mod profile;
pub mod runtime;
pub mod schedule;
pub mod service;
pub mod trainer;
pub mod util;
