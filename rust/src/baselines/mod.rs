//! Named baseline pipelines (paper §5.1): complete (partition,
//! placement, schedule) triples for S-1F1B, GPipe, I-1F1B, ZB-H1 and
//! the Mist-style balanced-partition method — reimplemented as pure
//! coordination policies (DESIGN.md §Substitutions).

use crate::partition::{balanced, uniform, Partition};
use crate::placement::{interleaved, sequential, wave, Placement};
use crate::profile::ProfiledData;
use crate::schedule::greedy::{greedy_schedule, SchedKnobs};
use crate::schedule::{builders, Schedule};

/// A fully specified pipeline: the object the performance model
/// simulates and the executor runs.
#[derive(Clone, Debug)]
pub struct Pipeline {
    pub name: String,
    pub partition: Partition,
    pub placement: Placement,
    pub schedule: Schedule,
}

/// Baseline method identifiers.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Method {
    GPipe,
    S1F1B,
    I1F1B,
    ZB,
    Mist,
    /// Hanayo-style wave placement (§2.3) with a 1F1B-like schedule.
    Hanayo,
}

impl Method {
    pub fn name(&self) -> &'static str {
        match self {
            Method::GPipe => "GPipe",
            Method::S1F1B => "S-1F1B",
            Method::I1F1B => "I-1F1B",
            Method::ZB => "ZB",
            Method::Mist => "Mist",
            Method::Hanayo => "Hanayo",
        }
    }

    pub fn all() -> [Method; 6] {
        [
            Method::GPipe,
            Method::S1F1B,
            Method::I1F1B,
            Method::ZB,
            Method::Mist,
            Method::Hanayo,
        ]
    }

    /// The four paper baselines (Fig 1 / Fig 8 comparison set).
    pub fn paper_baselines() -> [Method; 4] {
        [Method::S1F1B, Method::I1F1B, Method::ZB, Method::Mist]
    }
}

/// Number of virtual-stage chunks I-1F1B uses (paper default style:
/// small fixed v; Megatron requires layers divisible across chunks).
pub const I1F1B_CHUNKS: usize = 2;

/// Build a baseline pipeline for `method` over `n_layers` layers on
/// `p` devices with `nmb` micro-batches.
pub fn build(
    method: Method,
    profile: &ProfiledData,
    p: usize,
    nmb: usize,
) -> Pipeline {
    let n_layers = profile.n_layers();
    match method {
        Method::GPipe => Pipeline {
            name: method.name().into(),
            partition: uniform(n_layers, p),
            placement: sequential(p),
            schedule: builders::gpipe(p, nmb),
        },
        Method::S1F1B => Pipeline {
            name: method.name().into(),
            partition: uniform(n_layers, p),
            placement: sequential(p),
            schedule: builders::one_f_one_b(p, nmb),
        },
        Method::I1F1B => {
            // Interleaved placement with v chunks; falls back to S-1F1B
            // when nmb isn't divisible by p (the Megatron constraint).
            let v = I1F1B_CHUNKS;
            if nmb % p != 0 || n_layers < p * v {
                let mut pl = build(Method::S1F1B, profile, p, nmb);
                pl.name = method.name().into();
                return pl;
            }
            Pipeline {
                name: method.name().into(),
                partition: uniform(n_layers, p * v),
                placement: interleaved(p, v),
                schedule: builders::interleaved_1f1b(p, v, nmb),
            }
        }
        Method::ZB => Pipeline {
            name: method.name().into(),
            partition: uniform(n_layers, p),
            placement: sequential(p),
            schedule: builders::zb_h1(p, nmb),
        },
        Method::Mist => Pipeline {
            // Mist: compute-balanced partition (memory-parallelism
            // co-opt reduced to its partition contribution), S-1F1B
            // placement + schedule (paper Table 2: partition-only).
            name: method.name().into(),
            partition: balanced(profile, p),
            placement: sequential(p),
            schedule: builders::one_f_one_b(p, nmb),
        },
        Method::Hanayo => {
            // Wave placement with 2 waves; the schedule is the greedy
            // 1F1B-equivalent (fused backward, no W delay, no overlap
            // tuning) built for the wave dependency structure.
            let v = 2;
            if n_layers < p * v {
                let mut pl = build(Method::S1F1B, profile, p, nmb);
                pl.name = method.name().into();
                return pl;
            }
            let partition = uniform(n_layers, p * v);
            let placement = wave(p, v);
            let schedule = greedy_schedule(
                profile,
                &partition,
                &placement,
                nmb,
                SchedKnobs {
                    split_bw: false,
                    w_fill: false,
                    mem_cap_factor: 1.0,
                    overlap_aware: false,
                },
            );
            Pipeline { name: method.name().into(), partition, placement, schedule }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Family, HardwareCfg, ModelCfg, ParallelCfg, Size};
    use crate::model::build_model;
    use crate::perfmodel::simulate;

    fn profile(fam: Family, p: usize, nmb: usize) -> ProfiledData {
        let spec = build_model(&ModelCfg::table5(fam, Size::Small));
        ProfiledData::analytical(
            &spec,
            &HardwareCfg::default(),
            &ParallelCfg::new(p, 2, nmb, 1, 4096),
        )
    }

    #[test]
    fn all_baselines_simulate() {
        let prof = profile(Family::Gemma, 4, 8);
        for m in Method::all() {
            let pl = build(m, &prof, 4, 8);
            pl.schedule
                .validate(&pl.placement)
                .unwrap_or_else(|e| panic!("{}: {e}", m.name()));
            let r = simulate(&prof, &pl.partition, &pl.placement, &pl.schedule, false)
                .unwrap_or_else(|e| panic!("{}: {e}", m.name()));
            assert!(r.total > 0.0);
        }
    }

    #[test]
    fn mist_beats_s1f1b_on_gemma() {
        // Balanced partition must help on the vocab-heavy model.
        let prof = profile(Family::Gemma, 4, 16);
        let s = build(Method::S1F1B, &prof, 4, 16);
        let m = build(Method::Mist, &prof, 4, 16);
        let rs = simulate(&prof, &s.partition, &s.placement, &s.schedule, false).unwrap();
        let rm = simulate(&prof, &m.partition, &m.placement, &m.schedule, false).unwrap();
        assert!(rm.total < rs.total, "mist {:.4} !< s1f1b {:.4}", rm.total, rs.total);
    }

    #[test]
    fn i1f1b_falls_back_when_indivisible() {
        let prof = profile(Family::Gemma, 4, 6);
        let pl = build(Method::I1F1B, &prof, 4, 6);
        assert_eq!(pl.placement.n_stages(), 4); // fell back to sequential
    }
}
