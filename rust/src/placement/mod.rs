//! Model placement: mapping pipeline stages onto devices (paper §2.3),
//! with the three seed policies (sequential / interleaved / wave) and
//! the grouped-permutation tuning move (§4.3 "Model Placement Tuning").

/// Stage → device mapping over `p` pipeline devices.  Multiple stages
/// per device = virtual pipeline stages (I-1F1B / Hanayo style).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Placement {
    pub p: usize,
    /// `device_of[s]` = device executing stage `s`.
    pub device_of: Vec<usize>,
}

impl Placement {
    pub fn n_stages(&self) -> usize {
        self.device_of.len()
    }

    /// Stages hosted by device `d`, in stage order (Alg. 1 `Stages(d)`).
    pub fn stages_of(&self, d: usize) -> Vec<usize> {
        (0..self.n_stages()).filter(|&s| self.device_of[s] == d).collect()
    }

    /// Every device must host ≥ 1 stage; device ids in range.
    /// O(S + P) via a seen-bitmap — this runs inside the generator's
    /// move loop, where the old per-device `contains` scan was O(S·P).
    /// Allocation-free for P ≤ 128 (a u128 mask); larger clusters fall
    /// back to a heap bitmap.
    pub fn is_valid(&self) -> bool {
        if self.p <= 128 {
            let mut seen: u128 = 0;
            for &d in &self.device_of {
                if d >= self.p {
                    return false;
                }
                seen |= 1u128 << d;
            }
            let all = if self.p == 128 { u128::MAX } else { (1u128 << self.p) - 1 };
            seen == all
        } else {
            let mut seen = vec![false; self.p];
            for &d in &self.device_of {
                if d >= self.p {
                    return false;
                }
                seen[d] = true;
            }
            seen.iter().all(|&s| s)
        }
    }

    /// Swap the devices of two stages (a placement tuning move).
    pub fn swap_stages(&mut self, a: usize, b: usize) {
        self.device_of.swap(a, b);
    }
}

/// Sequential: stage `s` on device `s` (requires S == P) — the S-1F1B /
/// DAPPLE / ZB default.
pub fn sequential(p: usize) -> Placement {
    Placement { p, device_of: (0..p).collect() }
}

/// Interleaved (I-1F1B virtual pipeline stages): `v·p` stages laid out
/// round-robin — stage `s` on device `s % p`.  Device 0 gets stages
/// {0, p, 2p, …}: each device hosts `v` *virtual* stages.
pub fn interleaved(p: usize, v: usize) -> Placement {
    Placement { p, device_of: (0..p * v).map(|s| s % p).collect() }
}

/// Wave (Hanayo): like interleaved but alternate rounds reverse
/// direction — stages flow 0,1,…,p-1,p-1,…,1,0,0,1,… producing the
/// "wave" pattern; `v` waves ⇒ `v·p` stages.
pub fn wave(p: usize, v: usize) -> Placement {
    let device_of = (0..p * v)
        .map(|s| {
            let round = s / p;
            let off = s % p;
            if round % 2 == 0 {
                off
            } else {
                p - 1 - off
            }
        })
        .collect();
    Placement { p, device_of }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sequential_identity() {
        let pl = sequential(4);
        assert!(pl.is_valid());
        assert_eq!(pl.device_of, vec![0, 1, 2, 3]);
        assert_eq!(pl.stages_of(2), vec![2]);
    }

    #[test]
    fn interleaved_round_robin() {
        let pl = interleaved(4, 2);
        assert!(pl.is_valid());
        assert_eq!(pl.n_stages(), 8);
        assert_eq!(pl.stages_of(0), vec![0, 4]);
        assert_eq!(pl.stages_of(3), vec![3, 7]);
    }

    #[test]
    fn wave_reverses_alternate_rounds() {
        let pl = wave(4, 2);
        assert_eq!(pl.device_of, vec![0, 1, 2, 3, 3, 2, 1, 0]);
        assert_eq!(pl.stages_of(0), vec![0, 7]);
        assert!(pl.is_valid());
    }

    #[test]
    fn is_valid_rejects_bad_placements() {
        // Device 1 hosts nothing.
        let empty = Placement { p: 2, device_of: vec![0, 0] };
        assert!(!empty.is_valid());
        // Device id out of range.
        let oob = Placement { p: 2, device_of: vec![0, 2] };
        assert!(!oob.is_valid());
        // Both covered.
        let ok = Placement { p: 2, device_of: vec![1, 0] };
        assert!(ok.is_valid());
    }

    #[test]
    fn swap_move() {
        let mut pl = interleaved(2, 2);
        pl.swap_stages(0, 1);
        assert_eq!(pl.device_of, vec![1, 0, 0, 1]);
        assert!(pl.is_valid());
    }
}
