//! Checkpointed mid-step recovery (DESIGN.md §10): turn a
//! [`crate::cluster::sim::StepInterrupt`] into a *minimal* spliced
//! recovery program that re-enters the step at the committed frontier.
//!
//! Three pieces:
//!
//! - [`StepCheckpoint`]: the per-device committed F/B/W microbatch
//!   frontier plus every *live* tensor at the capture instant — the
//!   activation stash (`Act`), the W-retained slice (`ActW`), and the
//!   pending boundary tensors in both directions (`Bound`, `BoundB`) —
//!   with byte cost and capture pause priced from
//!   [`crate::memory::MemoryModel`].
//!
//! - [`plan_recovery`]: the replay-set closure.  Seeds are the
//!   unexecuted computes (the remainder, a per-device *suffix* because
//!   devices execute their lists in order); an already-executed op is
//!   pulled into the replay set only when some remainder op needs state
//!   that lived on the dead device and is not covered by the
//!   checkpoint.  The closure guarantees **minimality**: every replayed
//!   op postdates the checkpoint it recovers from (a
//!   checkpoint-committed microbatch is never replayed) — the invariant
//!   `tests/executor_recovery.rs` pins across a property grid.
//!
//! - Splicing: the recovery schedule (replay prefix on the dead
//!   device's slot, remainder suffixes everywhere) is lowered with the
//!   same comm-insertion rules as [`super::lower`], plus **bare
//!   resends** for frontier-crossing edges whose producing compute
//!   already ran: live producers re-send from their retention buffers,
//!   and the spare re-sends boundary tensors restored from the
//!   checkpoint.  The result is proven sound the same way lowering is —
//!   [`Program::validate`] plus the resumable rendezvous deadlock check
//!   — before it is handed to a cluster.

use std::collections::{HashMap, HashSet};

use super::lower::{check_rendezvous, hoist_receives, repair_deadlocks};
use super::{Instr, Program};
use crate::cluster::sim::OpRecord;
use crate::memory::MemoryModel;
use crate::placement::Placement;
use crate::schedule::{OpKind, Schedule};

/// A compute identity: `(kind, stage, microbatch)` — unique within a
/// step, so frontiers and replay sets are plain sets of these.
pub type OpKey = (OpKind, u32, u32);

fn op_rank(op: OpKind) -> u8 {
    match op {
        OpKind::F => 0,
        OpKind::B => 1,
        OpKind::W => 2,
    }
}

/// One live tensor class at a capture instant (all keyed `(kind, stage,
/// mb)`):
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum CoverKind {
    /// Activation stash of `(stage, mb)`: F done, B pending.
    Act,
    /// W-retained slice: B done, W pending (split backward only).
    ActW,
    /// Pending forward boundary input of `stage`: the producer's F is
    /// done, this stage's F is not — the tensor sits in the producer's
    /// send/retention buffer (or the consumer's inbox).
    Bound,
    /// Pending backward boundary (output-gradient) of `stage`.
    BoundB,
}

pub type CoverKey = (CoverKind, u32, u32);

/// Checkpoint cadence + pricing knobs.
#[derive(Clone, Copy, Debug)]
pub struct CheckpointCfg {
    /// Capture every this many virtual seconds within a step; `None`
    /// disables checkpointing (recovery then replays from step start).
    pub interval_s: Option<f64>,
    /// Capture drain bandwidth (bytes/s) — prices the capture pause.
    pub bw: f64,
    /// Fixed coordination latency per capture.
    pub latency_s: f64,
    /// Restore bandwidth onto the spare.
    pub restore_bw: f64,
}

impl Default for CheckpointCfg {
    fn default() -> CheckpointCfg {
        CheckpointCfg { interval_s: None, bw: 50e9, latency_s: 2e-3, restore_bw: 50e9 }
    }
}

/// The per-device committed F/B/W microbatch frontier plus every live
/// tensor at one capture instant, with its byte cost and capture pause.
#[derive(Clone, Debug)]
pub struct StepCheckpoint {
    /// Capture instant (virtual seconds from step start).
    pub t_s: f64,
    /// Committed frontier: every compute whose record ended by `t_s`.
    pub done: HashSet<OpKey>,
    /// Live tensors at `t_s`, with per-item bytes.
    pub covered: HashMap<CoverKey, f64>,
    /// Total bytes drained by the capture.
    pub bytes: f64,
    /// Pipeline pause charged for the capture (`latency + bytes/bw`).
    pub pause_s: f64,
}

impl StepCheckpoint {
    pub fn covers(&self, k: &CoverKey) -> bool {
        self.covered.contains_key(k)
    }
}

/// Capture the pipeline state at virtual time `t_c`, reconstructed
/// post-hoc from the step's op records (valid because the pre-fault
/// timeline equals the unfaulted timeline — captures are priced
/// *additively* by the harness and never perturb sim-internal clocks,
/// which is what keeps no-fault trajectories bit-identical).
pub fn capture(
    records: &[OpRecord],
    t_c: f64,
    model: &MemoryModel,
    nmb: usize,
    split_bw: bool,
    cfg: &CheckpointCfg,
) -> StepCheckpoint {
    let done: HashSet<OpKey> = records
        .iter()
        .filter(|r| r.end <= t_c)
        .map(|r| (r.op, r.stage, r.mb))
        .collect();
    let s_n = model.n_stages();
    let mut covered: HashMap<CoverKey, f64> = HashMap::new();
    for s in 0..s_n {
        let su = s as u32;
        let fp = &model.stages[s];
        for m in 0..nmb as u32 {
            let f = done.contains(&(OpKind::F, su, m));
            let b = done.contains(&(OpKind::B, su, m));
            if f && !b {
                covered.insert((CoverKind::Act, su, m), fp.act_per_mb);
            }
            if split_bw && b && !done.contains(&(OpKind::W, su, m)) {
                covered.insert((CoverKind::ActW, su, m), fp.act_w_per_mb);
            }
            if s > 0 && done.contains(&(OpKind::F, su - 1, m)) && !f {
                covered.insert((CoverKind::Bound, su, m), model.stages[s - 1].out_bytes);
            }
            if s + 1 < s_n && done.contains(&(OpKind::B, su + 1, m)) && !b {
                covered.insert((CoverKind::BoundB, su, m), fp.out_bytes);
            }
        }
    }
    let bytes: f64 = covered.values().sum();
    StepCheckpoint { t_s: t_c, done, covered, bytes, pause_s: cfg.latency_s + bytes / cfg.bw }
}

/// All captures a step of duration `horizon_s` takes under the cadence
/// (`k · interval` for `k ≥ 1`, strictly inside the step).  Empty when
/// the cadence is off.
pub fn plan_checkpoints(
    records: &[OpRecord],
    horizon_s: f64,
    model: &MemoryModel,
    nmb: usize,
    split_bw: bool,
    cfg: &CheckpointCfg,
) -> Vec<StepCheckpoint> {
    let Some(iv) = cfg.interval_s else { return Vec::new() };
    assert!(iv > 0.0, "checkpoint interval must be positive");
    let mut out = Vec::new();
    let mut t = iv;
    while t < horizon_s {
        out.push(capture(records, t, model, nmb, split_bw, cfg));
        t += iv;
    }
    out
}

/// Result of [`plan_recovery`]: the spliced, soundness-checked program
/// plus the accounting the harness charges.
#[derive(Clone, Debug)]
pub struct Recovery {
    /// The recovery program (remainder suffixes + replay prefix + bare
    /// resends), validated and deadlock-checked.
    pub prog: Program,
    /// Ops re-executed on the spare (⊆ the dead device's committed ops
    /// that postdate the checkpoint).
    pub replay: HashSet<OpKey>,
    /// Checkpoint items restored onto the spare.
    pub restored_items: usize,
    /// Bytes restored onto the spare (priced at `restore_bw`).
    pub restore_bytes: f64,
    /// Bare resend sends spliced in (retention-buffer re-deliveries).
    pub resends: usize,
    /// Every compute the step has executed once recovery completes:
    /// committed ∪ recovery-program computes.  Equals the full
    /// schedule's op set — the differential the tests pin.
    pub final_ops: HashSet<OpKey>,
}

/// Compute the minimal replay set for a kill on logical device `dead`
/// and splice the recovery program (module docs describe the closure
/// and its minimality invariant).  `done` is the per-logical-device
/// committed frontier at the kill; `ckpt` the last usable checkpoint,
/// if any.
///
/// Errors when the spliced program fails [`Program::validate`] or the
/// rendezvous deadlock check — soundness is proven, not assumed.
pub fn plan_recovery(
    schedule: &Schedule,
    placement: &Placement,
    dead: usize,
    done: &[HashSet<OpKey>],
    ckpt: Option<&StepCheckpoint>,
) -> Result<Recovery, String> {
    assert_eq!(done.len(), schedule.p, "one frontier per device");
    assert!(dead < schedule.p);
    let s_last = schedule.n_stages - 1;
    let dev_of = |s: usize| placement.device_of[s];
    let covers = |k: CoverKey| ckpt.is_some_and(|c| c.covers(&k));

    // Remainder: per-device unexecuted suffixes (devices execute their
    // lists in order, so `done` is a prefix of each compute sequence).
    let mut present: HashSet<OpKey> = HashSet::new();
    let mut all_done: HashSet<OpKey> = HashSet::new();
    for (d, slots) in schedule.per_device.iter().enumerate() {
        for sl in slots {
            let k = (sl.op, sl.stage, sl.mb);
            if done[d].contains(&k) {
                all_done.insert(k);
            } else {
                present.insert(k);
            }
        }
    }

    // Replay-set closure: worklist of committed dead-device ops whose
    // outputs some recovery op needs and the checkpoint does not cover.
    let mut replay: HashSet<OpKey> = HashSet::new();
    let mut restored: HashSet<CoverKey> = HashSet::new();
    let mut work: Vec<OpKey> = Vec::new();
    // `need(op)`: op's outputs must exist during recovery.  Returns the
    // replay candidates it forces (producer-side, dead device only).
    macro_rules! need_replay {
        ($op:expr, $s:expr, $m:expr) => {{
            let k = ($op, ($s) as u32, ($m) as u32);
            if !present.contains(&k) {
                debug_assert!(all_done.contains(&k), "need for an op that never ran");
                present.insert(k);
                replay.insert(k);
                work.push(k);
            }
        }};
    }
    // Input edge of F(s, m) when the producing F(s-1, m) is absent.
    macro_rules! input_f {
        ($s:expr, $m:expr) => {{
            if dev_of(($s) - 1) == dead {
                let bk = (CoverKind::Bound, ($s) as u32, ($m) as u32);
                if covers(bk) {
                    restored.insert(bk);
                } else {
                    need_replay!(OpKind::F, ($s) - 1, $m);
                }
            }
            // Live producer: retained in its send buffer; the splice
            // emits a bare resend.
        }};
    }
    // Gradient input of B(s, m) when the producing B(s+1, m) is absent.
    macro_rules! input_b {
        ($s:expr, $m:expr) => {{
            if dev_of(($s) + 1) == dead {
                let bk = (CoverKind::BoundB, ($s) as u32, ($m) as u32);
                if covers(bk) {
                    restored.insert(bk);
                } else {
                    need_replay!(OpKind::B, ($s) + 1, $m);
                }
            }
        }};
    }

    // Seed from every remainder op, then drain the worklist (replayed
    // ops have the same needs as remainder ops).
    let mut seeds: Vec<OpKey> = present.iter().copied().collect();
    seeds.sort_by_key(|&(op, s, m)| (s, m, op_rank(op)));
    let mut i = 0;
    while i < seeds.len() || !work.is_empty() {
        let (op, su, mu) = if let Some(k) = work.pop() { k } else { i += 1; seeds[i - 1] };
        let (s, m) = (su as usize, mu as usize);
        match op {
            OpKind::F => {
                if s > 0 && !present.contains(&(OpKind::F, su - 1, mu)) {
                    input_f!(s, m);
                }
            }
            OpKind::B => {
                if !present.contains(&(OpKind::F, su, mu)) && dev_of(s) == dead {
                    // The activation stash was lost with the device.
                    let ak = (CoverKind::Act, su, mu);
                    if covers(ak) {
                        restored.insert(ak);
                    } else {
                        need_replay!(OpKind::F, s, m);
                    }
                }
                if s < s_last && !present.contains(&(OpKind::B, su + 1, mu)) {
                    input_b!(s, m);
                }
            }
            OpKind::W => {
                if !present.contains(&(OpKind::B, su, mu)) && dev_of(s) == dead {
                    let wk = (CoverKind::ActW, su, mu);
                    let ak = (CoverKind::Act, su, mu);
                    if covers(wk) {
                        restored.insert(wk);
                    } else if covers(ak) {
                        // The full stash subsumes the W slice, but the
                        // param-grad inputs B computed are gone: re-run
                        // B from the restored stash.
                        restored.insert(ak);
                        need_replay!(OpKind::B, s, m);
                    } else {
                        need_replay!(OpKind::B, s, m);
                    }
                }
            }
        }
    }

    // Recovery schedule: replay prefix (original order) + remainder
    // suffix on the dead device's logical slot; remainder suffixes on
    // live devices.
    let mut per_slots: Vec<Vec<crate::schedule::Slot>> = vec![Vec::new(); schedule.p];
    for (d, slots) in schedule.per_device.iter().enumerate() {
        for sl in slots {
            let k = (sl.op, sl.stage, sl.mb);
            let in_remainder = !done[d].contains(&k);
            if in_remainder || (d == dead && replay.contains(&k)) {
                per_slots[d].push(*sl);
            }
        }
    }
    // The replay prefix must precede the remainder on the dead device:
    // replay ⊆ the done-prefix, so stable-partitioning by replay
    // membership restores a dataflow-consistent subsequence.
    {
        let (pre, post): (Vec<_>, Vec<_>) = per_slots[dead]
            .iter()
            .copied()
            .partition(|sl| replay.contains(&(sl.op, sl.stage, sl.mb)));
        per_slots[dead] = pre.into_iter().chain(post).collect();
    }

    // Lower with the §4.4 comm-insertion rules, adding bare resends
    // where the producing compute already ran.  A comm pair is needed
    // exactly when `Program::validate` will demand a Wait: the producer
    // stage is on another device, or has no computes left at all (its
    // retained/restored tensor is re-delivered — possibly to the same
    // device, a self-channel priced as a local copy).
    let stage_live: HashSet<u32> = present.iter().map(|&(_, s, _)| s).collect();
    let mut per_device: Vec<Vec<Instr>> = vec![Vec::new(); schedule.p];
    let mut resend_head: Vec<Vec<Instr>> = vec![Vec::new(); schedule.p];
    let mut resends = 0usize;
    for (d, slots) in per_slots.iter().enumerate() {
        for sl in slots {
            let (mb, s) = (sl.mb, sl.stage);
            let su = s as usize;
            match sl.op {
                OpKind::F => {
                    if su > 0 {
                        let pd = dev_of(su - 1);
                        if pd != d || !stage_live.contains(&(s - 1)) {
                            per_device[d].push(Instr::RecvF { mb, stage: s, from_stage: s - 1 });
                            per_device[d].push(Instr::WaitF { mb, stage: s });
                            if !present.contains(&(OpKind::F, s - 1, mb)) {
                                resend_head[pd].push(Instr::SendF {
                                    mb,
                                    stage: s - 1,
                                    to_stage: s,
                                });
                                resends += 1;
                            }
                        }
                    }
                    per_device[d].push(Instr::Compute { op: OpKind::F, mb, stage: s });
                    if su < s_last {
                        let cd = dev_of(su + 1);
                        let needed = present.contains(&(OpKind::F, s + 1, mb))
                            && (cd != d || !stage_live.contains(&s));
                        if needed {
                            per_device[d].push(Instr::SendF { mb, stage: s, to_stage: s + 1 });
                        }
                    }
                }
                OpKind::B => {
                    if su < s_last {
                        let pd = dev_of(su + 1);
                        if pd != d || !stage_live.contains(&(s + 1)) {
                            per_device[d].push(Instr::RecvB { mb, stage: s, from_stage: s + 1 });
                            per_device[d].push(Instr::WaitB { mb, stage: s });
                            if !present.contains(&(OpKind::B, s + 1, mb)) {
                                resend_head[pd].push(Instr::SendB {
                                    mb,
                                    stage: s + 1,
                                    to_stage: s,
                                });
                                resends += 1;
                            }
                        }
                    }
                    per_device[d].push(Instr::Compute { op: OpKind::B, mb, stage: s });
                    if su > 0 {
                        let cd = dev_of(su - 1);
                        let needed = present.contains(&(OpKind::B, s - 1, mb))
                            && (cd != d || !stage_live.contains(&s));
                        if needed {
                            per_device[d].push(Instr::SendB { mb, stage: s, to_stage: s - 1 });
                        }
                    }
                }
                OpKind::W => {
                    per_device[d].push(Instr::Compute { op: OpKind::W, mb, stage: s });
                }
            }
        }
    }
    for (d, head) in resend_head.into_iter().enumerate() {
        // Retention resends are ready immediately: prepend them so the
        // producer device services them before its own remainder.
        let tail = std::mem::take(&mut per_device[d]);
        per_device[d] = head.into_iter().chain(tail).collect();
    }

    let mut prog = Program {
        p: schedule.p,
        nmb: schedule.nmb,
        n_stages: schedule.n_stages,
        split_bw: schedule.split_bw,
        overlap_aware: schedule.overlap_aware,
        per_device,
    };
    if schedule.overlap_aware {
        hoist_receives(&mut prog, usize::MAX);
    }
    repair_deadlocks(&mut prog);
    prog.validate().map_err(|e| format!("recovery program invalid: {e}"))?;
    check_rendezvous(&prog).map_err(|(d, pc)| {
        format!("recovery program deadlocks at device {d} pc {pc}")
    })?;

    // The self-consistency the whole construction promises: committed ∪
    // recovery computes = the full schedule, each op exactly once
    // (replayed ops were lost with the device, so they are not double-
    // counted — their first execution's effects never escaped).
    let mut final_ops = all_done.clone();
    final_ops.extend(present.iter().copied());
    let restore_bytes: f64 = restored
        .iter()
        .map(|k| ckpt.map_or(0.0, |c| c.covered.get(k).copied().unwrap_or(0.0)))
        .sum();
    Ok(Recovery {
        prog,
        replay,
        restored_items: restored.len(),
        restore_bytes,
        resends,
        final_ops,
    })
}

/// Order-independent digest of a compute set — the "final pipeline
/// state" the differential recovery tests compare (recover vs restart
/// vs unfaulted must agree bitwise).  FNV-1a over the sorted keys.
pub fn state_digest(ops: &HashSet<OpKey>) -> u64 {
    let mut keys: Vec<(u32, u32, u8)> =
        ops.iter().map(|&(op, s, m)| (s, m, op_rank(op))).collect();
    keys.sort_unstable();
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for (s, m, o) in keys {
        for b in s.to_le_bytes().into_iter().chain(m.to_le_bytes()).chain([o]) {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }
    h
}

/// Every compute identity in a schedule (the unfaulted final state).
pub fn schedule_ops(schedule: &Schedule) -> HashSet<OpKey> {
    schedule
        .per_device
        .iter()
        .flatten()
        .map(|sl| (sl.op, sl.stage, sl.mb))
        .collect()
}

/// Seconds to roll back / re-install the dead device's optimizer state
/// on the spare — charged when a kill lands after the optimizer update
/// began (the update is not transactional across devices).
pub fn optimizer_rollback_s(model: &MemoryModel, dead: usize, cfg: &CheckpointCfg) -> f64 {
    cfg.latency_s + model.optimizer_bytes(dead) / cfg.restore_bw
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::fault::{RetryPolicy, StepFaults};
    use crate::cluster::sim::{run_timed_midstep, MidstepOutcome, SimOptions};
    use crate::config::{Family, HardwareCfg, ModelCfg, ParallelCfg, Size};
    use crate::executor::lower::{lower, LowerOptions};
    use crate::model::build_model;
    use crate::partition::uniform;
    use crate::placement::sequential;
    use crate::profile::ProfiledData;
    use crate::schedule::builders::one_f_one_b;

    fn setup() -> (ProfiledData, crate::partition::Partition) {
        let spec = build_model(&ModelCfg::table5(Family::Gemma, Size::Small));
        let prof = ProfiledData::analytical(
            &spec,
            &HardwareCfg::default(),
            &ParallelCfg::new(4, 2, 8, 1, 4096),
        );
        let part = uniform(prof.n_layers(), 4);
        (prof, part)
    }

    #[test]
    fn capture_covers_exactly_the_live_tensors() {
        let (prof, part) = setup();
        let pl = sequential(4);
        let mut sch = one_f_one_b(4, 8);
        sch.overlap_aware = true;
        let prog = lower(&sch, &pl, LowerOptions::default());
        let out = run_timed_midstep(
            &prof,
            &part,
            &prog,
            SimOptions::matched(),
            None,
            &StepFaults::none(),
            &RetryPolicy::default(),
        )
        .unwrap();
        let MidstepOutcome::Completed { run, records } = out else { panic!() };
        let mm = MemoryModel::build(&prof, &part, &pl);
        let cfg = CheckpointCfg { interval_s: Some(run.makespan / 3.0), ..Default::default() };
        let cks = plan_checkpoints(&records, run.makespan, &mm, 8, sch.split_bw, &cfg);
        assert_eq!(cks.len(), 2, "two interior captures at makespan/3 cadence");
        for ck in &cks {
            assert!(ck.bytes > 0.0 && ck.pause_s > cfg.latency_s);
            for (&(kind, s, m), _) in &ck.covered {
                let f = ck.done.contains(&(OpKind::F, s, m));
                let b = ck.done.contains(&(OpKind::B, s, m));
                match kind {
                    CoverKind::Act => assert!(f && !b),
                    CoverKind::ActW => assert!(b && !ck.done.contains(&(OpKind::W, s, m))),
                    CoverKind::Bound => {
                        assert!(ck.done.contains(&(OpKind::F, s - 1, m)) && !f)
                    }
                    CoverKind::BoundB => {
                        assert!(ck.done.contains(&(OpKind::B, s + 1, m)) && !b)
                    }
                }
            }
        }
        // Later captures sit at a later frontier.
        assert!(cks[1].done.len() > cks[0].done.len());
        // An end-of-step capture has no live per-mb tensors left.
        let fin = capture(&records, run.makespan + 1.0, &mm, 8, sch.split_bw, &cfg);
        assert!(fin.covered.is_empty(), "{:?}", fin.covered);
    }

    #[test]
    fn full_restart_recovery_covers_the_whole_schedule() {
        // Degenerate splice: nothing done anywhere ⇒ the recovery
        // program is the whole step again and must match plain lowering
        // in compute content.
        let (_, _) = setup();
        let pl = sequential(4);
        let sch = one_f_one_b(4, 8);
        let done: Vec<HashSet<OpKey>> = vec![HashSet::new(); 4];
        let rec = plan_recovery(&sch, &pl, 1, &done, None).unwrap();
        assert!(rec.replay.is_empty());
        assert_eq!(rec.resends, 0);
        assert_eq!(rec.final_ops, schedule_ops(&sch));
        assert_eq!(
            state_digest(&rec.final_ops),
            state_digest(&schedule_ops(&sch)),
            "digest is content-addressed"
        );
    }
}
