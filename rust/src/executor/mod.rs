//! Unified Pipeline Executor (paper §4.4): lowers a workload schedule
//! into per-device **instruction lists** (paper Table 4) and applies
//! the two communication passes:
//!
//! 1. comm insertion (Fig 7 Step 2): a `Recv`+`Wait` before every
//!    compute that consumes a remote tensor, a `Send` right after every
//!    compute that produces one;
//! 2. deadlock repair (Fig 7 Step 3): under rendezvous send semantics
//!    (NCCL-style), mismatched send/recv orderings between device pairs
//!    are detected and repaired by hoisting the blocking `Recv` — one
//!    resumable abstract execution repairs every deadlock in a single
//!    forward pass (see [`lower::repair_deadlocks`]);
//! 3. overlap hoisting (Fig 7 Step 4): each `Recv` is moved to the
//!    earliest dependency-free position so the transfer proceeds under
//!    compute.
//!
//! The same [`Program`] runs on the discrete-event [`crate::cluster`]
//! SimCluster (virtual time; a *differential twin* of the performance
//! model — see `cluster::sim`) and the RealCluster (OS threads +
//! channels + PJRT executables — the actual trainer).
//!
//! [`Program::validate`] is the executor-level counterpart of
//! `Schedule::validate`: structural well-formedness of the instruction
//! lists (channel 1:1 matching, recv-before-wait, in-range stage refs),
//! asserted after every pass in the executor test suites.

pub mod lower;
pub mod recover;

use std::collections::HashMap;

use crate::schedule::OpKind;

/// Logical channel id shared by a matched `Send`/`Recv`/`Wait` triple:
/// `(micro-batch, producer stage, consumer stage, kind)`.  The same key
/// tags RealCluster messages (`cluster::real::ChannelKey`).
pub type Chan = (u32, u32, u32, OpKind);

/// Pipeline execution instructions (paper Table 4).
///
/// `Recv*`/`Wait*` split asynchronous receives: `Recv` posts the
/// receive (build P2P comm), `Wait` blocks until the data arrived —
/// mirroring `receive_F|B_start` / `wait_F|B_receive`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Instr {
    /// compute_F|B|W(C_F|B|W)
    Compute { op: OpKind, mb: u32, stage: u32 },
    /// send_F_start: ship stage's F output to the device of `to_stage`.
    SendF { mb: u32, stage: u32, to_stage: u32 },
    /// send_B_start: ship stage's input-gradient to `to_stage`.
    SendB { mb: u32, stage: u32, to_stage: u32 },
    /// receive_F_start: post receive for F input of `stage` (produced
    /// by `from_stage`).
    RecvF { mb: u32, stage: u32, from_stage: u32 },
    /// receive_B_start: post receive for the output-gradient of `stage`.
    RecvB { mb: u32, stage: u32, from_stage: u32 },
    /// wait_F_receive.
    WaitF { mb: u32, stage: u32 },
    /// wait_B_receive.
    WaitB { mb: u32, stage: u32 },
}

/// Behavioural classification of an [`Instr`] with its channel resolved
/// — **complete**, so rendezvous logic (the abstract repair executor
/// and the timed SimCluster) matches on four arms with no
/// `unreachable!`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Step {
    Compute { op: OpKind, mb: u32, stage: u32 },
    Send(Chan),
    Recv(Chan),
    Wait(Chan),
}

impl Instr {
    /// Classify the instruction, resolving `Wait`s to the channel they
    /// block on (a `WaitF` at stage `s` waits for `s-1 → s`; requires
    /// in-range stage refs — guaranteed by [`Program::validate`]).
    pub fn step(&self) -> Step {
        match *self {
            Instr::Compute { op, mb, stage } => Step::Compute { op, mb, stage },
            Instr::SendF { mb, stage, to_stage } => {
                Step::Send((mb, stage, to_stage, OpKind::F))
            }
            Instr::SendB { mb, stage, to_stage } => {
                Step::Send((mb, stage, to_stage, OpKind::B))
            }
            Instr::RecvF { mb, stage, from_stage } => {
                Step::Recv((mb, from_stage, stage, OpKind::F))
            }
            Instr::RecvB { mb, stage, from_stage } => {
                Step::Recv((mb, from_stage, stage, OpKind::B))
            }
            Instr::WaitF { mb, stage } => Step::Wait((mb, stage - 1, stage, OpKind::F)),
            Instr::WaitB { mb, stage } => Step::Wait((mb, stage + 1, stage, OpKind::B)),
        }
    }

    /// Channel key (mb, producer stage, consumer stage, kind) shared by
    /// a matched send/recv pair (`None` for computes and waits — waits
    /// resolve their channel via [`Instr::step`]).
    pub fn channel(&self) -> Option<Chan> {
        match self.step() {
            Step::Send(c) | Step::Recv(c) => Some(c),
            _ => None,
        }
    }

    pub fn is_send(&self) -> bool {
        matches!(self, Instr::SendF { .. } | Instr::SendB { .. })
    }

    pub fn is_recv(&self) -> bool {
        matches!(self, Instr::RecvF { .. } | Instr::RecvB { .. })
    }
}

/// A lowered pipeline program.
#[derive(Clone, Debug)]
pub struct Program {
    pub p: usize,
    pub nmb: usize,
    pub n_stages: usize,
    pub split_bw: bool,
    /// Comm-overlap assumption the program was scheduled under (copied
    /// from `Schedule::overlap_aware`); the matched-assumption timed
    /// run prices waits with the same expression shape.
    pub overlap_aware: bool,
    pub per_device: Vec<Vec<Instr>>,
}

impl Program {
    pub fn total_instrs(&self) -> usize {
        self.per_device.iter().map(|v| v.len()).sum()
    }

    /// Count of communication instructions (sends + recvs).
    pub fn comm_instrs(&self) -> usize {
        self.per_device
            .iter()
            .flatten()
            .filter(|i| i.is_send() || i.is_recv())
            .count()
    }

    /// Structural well-formedness (executor-level counterpart of
    /// `Schedule::validate`):
    ///
    /// 1. every stage/mb reference is in range, channel endpoints are
    ///    stage-adjacent, and `W` computes appear iff `split_bw`;
    /// 2. each stage's computes live on a single device (the inferred
    ///    stage→device map);
    /// 3. send/recv channels are 1:1, sends on the producer's device,
    ///    recvs on the consumer's;
    /// 4. every `Wait` has its `Recv` earlier on the same device, and
    ///    every cross-device compute input has a `Wait` before the
    ///    consuming compute.
    ///
    /// Asserted after lowering, hoisting and repair in the executor
    /// test suites — all three passes must preserve it.
    pub fn validate(&self) -> Result<(), String> {
        let s_n = self.n_stages as u32;
        let nmb = self.nmb as u32;
        // Pass 1: range checks + per-instruction classification.
        let mut sends: HashMap<Chan, (usize, usize)> = HashMap::new(); // dev, count
        let mut recvs: HashMap<Chan, (usize, usize, usize)> = HashMap::new(); // dev, idx, count
        let mut device_of: Vec<Option<usize>> = vec![None; self.n_stages];
        for (d, list) in self.per_device.iter().enumerate() {
            for (i, ins) in list.iter().enumerate() {
                let (mb, stage) = match *ins {
                    Instr::Compute { mb, stage, .. }
                    | Instr::SendF { mb, stage, .. }
                    | Instr::SendB { mb, stage, .. }
                    | Instr::RecvF { mb, stage, .. }
                    | Instr::RecvB { mb, stage, .. }
                    | Instr::WaitF { mb, stage }
                    | Instr::WaitB { mb, stage } => (mb, stage),
                };
                if stage >= s_n || mb >= nmb {
                    return Err(format!("dev {d}[{i}]: {ins:?} out of range"));
                }
                match *ins {
                    Instr::Compute { op: OpKind::W, .. } if !self.split_bw => {
                        return Err(format!("dev {d}[{i}]: W compute in fused program"));
                    }
                    Instr::Compute { stage, .. } => {
                        let s = stage as usize;
                        match device_of[s] {
                            None => device_of[s] = Some(d),
                            Some(prev) if prev != d => {
                                return Err(format!(
                                    "stage {s} computes on devices {prev} and {d}"
                                ));
                            }
                            _ => {}
                        }
                    }
                    Instr::SendF { stage, to_stage, .. }
                        if to_stage != stage + 1 || to_stage >= s_n =>
                    {
                        return Err(format!("dev {d}[{i}]: non-adjacent SendF"));
                    }
                    Instr::SendB { stage, to_stage, .. }
                        if stage == 0 || to_stage != stage - 1 =>
                    {
                        return Err(format!("dev {d}[{i}]: non-adjacent SendB"));
                    }
                    Instr::RecvF { stage, from_stage, .. }
                        if stage == 0 || from_stage != stage - 1 =>
                    {
                        return Err(format!("dev {d}[{i}]: non-adjacent RecvF"));
                    }
                    Instr::RecvB { stage, from_stage, .. }
                        if from_stage != stage + 1 || from_stage >= s_n =>
                    {
                        return Err(format!("dev {d}[{i}]: non-adjacent RecvB"));
                    }
                    Instr::WaitF { stage, .. } if stage == 0 => {
                        return Err(format!("dev {d}[{i}]: WaitF at stage 0"));
                    }
                    Instr::WaitB { stage, .. } if stage + 1 >= s_n => {
                        return Err(format!("dev {d}[{i}]: WaitB at last stage"));
                    }
                    _ => {}
                }
                // Range-checked instructions classify safely now.
                match ins.step() {
                    Step::Send(c) => {
                        let e = sends.entry(c).or_insert((d, 0));
                        e.1 += 1;
                    }
                    Step::Recv(c) => {
                        let e = recvs.entry(c).or_insert((d, i, 0));
                        e.2 += 1;
                    }
                    Step::Compute { .. } | Step::Wait(_) => {}
                }
            }
        }
        // Pass 2: channel matching + wait/compute ordering.
        for (c, &(_, n)) in &sends {
            if n != 1 {
                return Err(format!("channel {c:?}: {n} sends"));
            }
            match recvs.get(c) {
                None => return Err(format!("send {c:?} has no matching recv")),
                Some(&(_, _, n)) if n != 1 => {
                    return Err(format!("channel {c:?}: {n} recvs"));
                }
                Some(&(rd, _, _)) => {
                    let consumer = c.2 as usize;
                    if device_of[consumer].is_some_and(|cd| cd != rd) {
                        return Err(format!("recv {c:?} not on the consumer's device"));
                    }
                }
            }
            let producer = c.1 as usize;
            let sd = sends[c].0;
            if device_of[producer].is_some_and(|pd| pd != sd) {
                return Err(format!("send {c:?} not on the producer's device"));
            }
        }
        for c in recvs.keys() {
            if !sends.contains_key(c) {
                return Err(format!("recv {c:?} has no matching send"));
            }
        }
        for (d, list) in self.per_device.iter().enumerate() {
            for (i, ins) in list.iter().enumerate() {
                match ins.step() {
                    Step::Wait(c) => match recvs.get(&c) {
                        None => return Err(format!("dev {d}[{i}]: wait {c:?} has no recv")),
                        Some(&(rd, ri, _)) if rd != d || ri >= i => {
                            return Err(format!(
                                "dev {d}[{i}]: recv for {c:?} does not precede its wait"
                            ));
                        }
                        _ => {}
                    },
                    Step::Compute { op, mb, stage } => {
                        // Cross-device inputs must be waited for.
                        let s = stage as usize;
                        let needed = match op {
                            OpKind::F if s > 0 => {
                                (device_of[s - 1] != device_of[s])
                                    .then_some((mb, stage - 1, stage, OpKind::F))
                            }
                            OpKind::B if s + 1 < self.n_stages => {
                                (device_of[s + 1] != device_of[s])
                                    .then_some((mb, stage + 1, stage, OpKind::B))
                            }
                            _ => None,
                        };
                        if let Some(c) = needed {
                            let waited = list[..i]
                                .iter()
                                .any(|w| matches!(w.step(), Step::Wait(wc) if wc == c));
                            if !waited {
                                return Err(format!(
                                    "dev {d}[{i}]: {ins:?} consumes remote input without a wait"
                                ));
                            }
                        }
                    }
                    Step::Send(_) | Step::Recv(_) => {}
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn channel_keys_match() {
        let s = Instr::SendF { mb: 1, stage: 2, to_stage: 3 };
        let r = Instr::RecvF { mb: 1, stage: 3, from_stage: 2 };
        assert_eq!(s.channel(), r.channel());
        let sb = Instr::SendB { mb: 0, stage: 3, to_stage: 2 };
        let rb = Instr::RecvB { mb: 0, stage: 2, from_stage: 3 };
        assert_eq!(sb.channel(), rb.channel());
        assert_ne!(s.channel(), sb.channel());
    }

    #[test]
    fn waits_resolve_their_channel() {
        let w = Instr::WaitF { mb: 1, stage: 3 };
        assert_eq!(w.step(), Step::Wait((1, 2, 3, OpKind::F)));
        assert_eq!(w.channel(), None);
        let w = Instr::WaitB { mb: 0, stage: 2 };
        assert_eq!(w.step(), Step::Wait((0, 3, 2, OpKind::B)));
    }
}
