//! Unified Pipeline Executor (paper §4.4): lowers a workload schedule
//! into per-device **instruction lists** (paper Table 4) and applies
//! the two communication passes:
//!
//! 1. comm insertion (Fig 7 Step 2): a `Recv`+`Wait` before every
//!    compute that consumes a remote tensor, a `Send` right after every
//!    compute that produces one;
//! 2. deadlock repair (Fig 7 Step 3): under rendezvous send semantics
//!    (NCCL-style), mismatched send/recv orderings between device pairs
//!    are detected and repaired by hoisting the blocking `Recv`;
//! 3. overlap hoisting (Fig 7 Step 4): each `Recv` is moved to the
//!    earliest dependency-free position so the transfer proceeds under
//!    compute.
//!
//! The same [`Program`] runs on the discrete-event [`crate::cluster`]
//! SimCluster (virtual time, rendezvous semantics — validates the
//! passes) and the RealCluster (OS threads + channels + PJRT
//! executables — the actual trainer).

pub mod lower;

use crate::schedule::OpKind;

/// Pipeline execution instructions (paper Table 4).
///
/// `Recv*`/`Wait*` split asynchronous receives: `Recv` posts the
/// receive (build P2P comm), `Wait` blocks until the data arrived —
/// mirroring `receive_F|B_start` / `wait_F|B_receive`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Instr {
    /// compute_F|B|W(C_F|B|W)
    Compute { op: OpKind, mb: u32, stage: u32 },
    /// send_F_start: ship stage's F output to the device of `to_stage`.
    SendF { mb: u32, stage: u32, to_stage: u32 },
    /// send_B_start: ship stage's input-gradient to `to_stage`.
    SendB { mb: u32, stage: u32, to_stage: u32 },
    /// receive_F_start: post receive for F input of `stage` (produced
    /// by `from_stage`).
    RecvF { mb: u32, stage: u32, from_stage: u32 },
    /// receive_B_start: post receive for the output-gradient of `stage`.
    RecvB { mb: u32, stage: u32, from_stage: u32 },
    /// wait_F_receive.
    WaitF { mb: u32, stage: u32 },
    /// wait_B_receive.
    WaitB { mb: u32, stage: u32 },
}

impl Instr {
    /// Channel key (mb, producer stage, consumer stage, kind) shared by
    /// a matched send/recv pair.
    pub fn channel(&self) -> Option<(u32, u32, u32, OpKind)> {
        match *self {
            Instr::SendF { mb, stage, to_stage } => Some((mb, stage, to_stage, OpKind::F)),
            Instr::RecvF { mb, stage, from_stage } => {
                Some((mb, from_stage, stage, OpKind::F))
            }
            Instr::SendB { mb, stage, to_stage } => Some((mb, stage, to_stage, OpKind::B)),
            Instr::RecvB { mb, stage, from_stage } => {
                Some((mb, from_stage, stage, OpKind::B))
            }
            _ => None,
        }
    }

    pub fn is_send(&self) -> bool {
        matches!(self, Instr::SendF { .. } | Instr::SendB { .. })
    }

    pub fn is_recv(&self) -> bool {
        matches!(self, Instr::RecvF { .. } | Instr::RecvB { .. })
    }
}

/// A lowered pipeline program.
#[derive(Clone, Debug)]
pub struct Program {
    pub p: usize,
    pub nmb: usize,
    pub n_stages: usize,
    pub split_bw: bool,
    pub per_device: Vec<Vec<Instr>>,
}

impl Program {
    pub fn total_instrs(&self) -> usize {
        self.per_device.iter().map(|v| v.len()).sum()
    }

    /// Count of communication instructions (sends + recvs).
    pub fn comm_instrs(&self) -> usize {
        self.per_device
            .iter()
            .flatten()
            .filter(|i| i.is_send() || i.is_recv())
            .count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn channel_keys_match() {
        let s = Instr::SendF { mb: 1, stage: 2, to_stage: 3 };
        let r = Instr::RecvF { mb: 1, stage: 3, from_stage: 2 };
        assert_eq!(s.channel(), r.channel());
        let sb = Instr::SendB { mb: 0, stage: 3, to_stage: 2 };
        let rb = Instr::RecvB { mb: 0, stage: 2, from_stage: 3 };
        assert_eq!(sb.channel(), rb.channel());
        assert_ne!(s.channel(), sb.channel());
    }
}
