//! Schedule → instruction-list lowering and the §4.4 communication
//! passes (comm insertion, deadlock repair, overlap hoisting).

use std::collections::HashMap;

use super::{Instr, Program};
use crate::placement::Placement;
use crate::schedule::{OpKind, Schedule};

/// Lowering options.
#[derive(Clone, Copy, Debug)]
pub struct LowerOptions {
    /// Run the deadlock-repair pass (Fig 7 Step 3).  Disabling it is
    /// only useful for tests/ablations that want to observe deadlocks.
    pub repair_deadlocks: bool,
    /// Hoist receives up to this many instructions earlier for overlap
    /// (Fig 7 Step 4); 0 disables the pass.
    pub hoist_window: usize,
}

impl Default for LowerOptions {
    fn default() -> Self {
        // A deep hoist window lets receives start as soon as their
        // producer finishes — the timed executor then matches the
        // performance model's overlap assumption exactly (validated in
        // the Fig 12 harness: window 3 → ~12% gap, window 16 → 0%).
        LowerOptions { repair_deadlocks: true, hoist_window: 16 }
    }
}

/// Lower a schedule into a per-device instruction program.
pub fn lower(schedule: &Schedule, placement: &Placement, opts: LowerOptions) -> Program {
    let s_n = schedule.n_stages;
    let dev = |s: u32| placement.device_of[s as usize] as u32;
    let mut per_device: Vec<Vec<Instr>> = vec![Vec::new(); schedule.p];

    // Step 1+2: computation lists with comm instructions inserted.
    for (d, slots) in schedule.per_device.iter().enumerate() {
        let list = &mut per_device[d];
        for sl in slots {
            let (mb, s) = (sl.mb, sl.stage);
            match sl.op {
                OpKind::F => {
                    if s > 0 && dev(s - 1) != d as u32 {
                        list.push(Instr::RecvF { mb, stage: s, from_stage: s - 1 });
                        list.push(Instr::WaitF { mb, stage: s });
                    }
                    list.push(Instr::Compute { op: OpKind::F, mb, stage: s });
                    if (s as usize) < s_n - 1 && dev(s + 1) != d as u32 {
                        list.push(Instr::SendF { mb, stage: s, to_stage: s + 1 });
                    }
                }
                OpKind::B => {
                    if (s as usize) < s_n - 1 && dev(s + 1) != d as u32 {
                        list.push(Instr::RecvB { mb, stage: s, from_stage: s + 1 });
                        list.push(Instr::WaitB { mb, stage: s });
                    }
                    list.push(Instr::Compute { op: OpKind::B, mb, stage: s });
                    if s > 0 && dev(s - 1) != d as u32 {
                        list.push(Instr::SendB { mb, stage: s, to_stage: s - 1 });
                    }
                }
                OpKind::W => {
                    list.push(Instr::Compute { op: OpKind::W, mb, stage: s });
                }
            }
        }
    }

    let mut prog = Program {
        p: schedule.p,
        nmb: schedule.nmb,
        n_stages: s_n,
        split_bw: schedule.split_bw,
        per_device,
    };

    // Step 4 first: overlap hoisting (it can also *create* the
    // mismatches Step 3 must fix, so repair runs last).
    if opts.hoist_window > 0 && schedule.overlap_aware {
        hoist_receives(&mut prog, opts.hoist_window);
    }

    // Step 3: deadlock repair under rendezvous send semantics.
    if opts.repair_deadlocks {
        repair_deadlocks(&mut prog);
    }

    prog
}

/// Move each `Recv` up to `window` instructions earlier (receives have
/// no data dependencies — only their `Wait` does), enabling transfer /
/// compute overlap.
fn hoist_receives(prog: &mut Program, window: usize) {
    for list in &mut prog.per_device {
        let mut i = 0;
        while i < list.len() {
            if list[i].is_recv() {
                let mut j = i;
                let mut moved = 0;
                while j > 0 && moved < window && !list[j - 1].is_recv() {
                    list.swap(j - 1, j);
                    j -= 1;
                    moved += 1;
                }
            }
            i += 1;
        }
    }
}

/// Abstract rendezvous execution: sends block until the matching recv
/// is posted; waits block until the matching send executed.  Returns
/// the device/pc of the first blocked instruction if the program
/// cannot complete.
pub fn check_rendezvous(prog: &Program) -> Result<(), (usize, usize)> {
    let mut pc = vec![0usize; prog.p];
    let mut recv_posted: HashMap<(u32, u32, u32, OpKind), bool> = HashMap::new();
    let mut sent: HashMap<(u32, u32, u32, OpKind), bool> = HashMap::new();
    loop {
        let mut progressed = false;
        let mut all_done = true;
        for d in 0..prog.p {
            loop {
                let Some(ins) = prog.per_device[d].get(pc[d]) else { break };
                all_done = false;
                match ins {
                    Instr::Compute { .. } => {}
                    i if i.is_recv() => {
                        recv_posted.insert(i.channel().unwrap(), true);
                    }
                    i if i.is_send() => {
                        let key = i.channel().unwrap();
                        if !recv_posted.get(&key).copied().unwrap_or(false) {
                            break; // rendezvous: peer hasn't posted
                        }
                        sent.insert(key, true);
                    }
                    Instr::WaitF { mb, stage } => {
                        let key = (*mb, *stage - 1, *stage, OpKind::F);
                        if !sent.get(&key).copied().unwrap_or(false) {
                            break;
                        }
                    }
                    Instr::WaitB { mb, stage } => {
                        let key = (*mb, *stage + 1, *stage, OpKind::B);
                        if !sent.get(&key).copied().unwrap_or(false) {
                            break;
                        }
                    }
                    _ => unreachable!(),
                }
                pc[d] += 1;
                progressed = true;
            }
        }
        if all_done && pc.iter().enumerate().all(|(d, &p)| p >= prog.per_device[d].len())
        {
            return Ok(());
        }
        if !progressed {
            let d = (0..prog.p).find(|&d| pc[d] < prog.per_device[d].len()).unwrap();
            return Err((d, pc[d]));
        }
    }
}

/// Detect rendezvous deadlocks and repair them by hoisting the missing
/// `Recv` on the peer device directly before its blocking instruction
/// (paper: "reorders them to ensure deadlock-free execution").
pub fn repair_deadlocks(prog: &mut Program) {
    let mut guard = 0usize;
    let limit = prog.total_instrs() * 4 + 64;
    while let Err((d0, at0)) = check_rendezvous(prog) {
        guard += 1;
        assert!(
            guard < limit,
            "deadlock repair did not converge (blocked at dev {d0} pc {at0})"
        );
        // The reported device may be blocked on a Wait whose *sender*
        // is the repairable root: find any device stuck at a Send.
        let pcs = stuck_pcs(prog);
        let (d, at) = (0..prog.p)
            .filter_map(|d| {
                let pc = pcs[d];
                prog.per_device[d]
                    .get(pc)
                    .filter(|i| i.is_send())
                    .map(|_| (d, pc))
            })
            .next()
            .unwrap_or_else(|| {
                panic!(
                    "unrepairable deadlock: no blocked send (dev {d0} pc {at0}: {:?}) — schedule invalid?",
                    prog.per_device[d0][at0]
                )
            });
        let blocked = prog.per_device[d][at];
        let key = blocked.channel().unwrap();
        // Find the matching Recv on the consumer device and hoist it to
        // the consumer's current blocking point.
        let consumer = consumer_device(prog, key);
        let list = &mut prog.per_device[consumer];
        let rpos = list
            .iter()
            .position(|i| i.is_recv() && i.channel() == Some(key))
            .unwrap_or_else(|| panic!("send {key:?} has no matching recv"));
        // Hoist before the consumer's first blocking comm instruction
        // at or before rpos (conservatively: to the front of the
        // consumer's unexecuted region — position of its own pc).
        let target = blocking_point(prog, consumer, rpos);
        let list = &mut prog.per_device[consumer];
        let ins = list.remove(rpos);
        list.insert(target, ins);
    }
}

/// Program counters at the stuck point of the abstract execution.
fn stuck_pcs(prog: &Program) -> Vec<usize> {
    let mut pc = vec![0usize; prog.p];
    let mut recv_posted: HashMap<(u32, u32, u32, OpKind), bool> = HashMap::new();
    let mut sent: HashMap<(u32, u32, u32, OpKind), bool> = HashMap::new();
    loop {
        let mut progressed = false;
        for d in 0..prog.p {
            loop {
                let Some(ins) = prog.per_device[d].get(pc[d]) else { break };
                let ok = match ins {
                    Instr::Compute { .. } => true,
                    i if i.is_recv() => {
                        recv_posted.insert(i.channel().unwrap(), true);
                        true
                    }
                    i if i.is_send() => {
                        let key = i.channel().unwrap();
                        recv_posted.get(&key).copied().unwrap_or(false) && {
                            sent.insert(key, true);
                            true
                        }
                    }
                    Instr::WaitF { mb, stage } => sent
                        .get(&(*mb, *stage - 1, *stage, OpKind::F))
                        .copied()
                        .unwrap_or(false),
                    Instr::WaitB { mb, stage } => sent
                        .get(&(*mb, *stage + 1, *stage, OpKind::B))
                        .copied()
                        .unwrap_or(false),
                    _ => unreachable!(),
                };
                if !ok {
                    break;
                }
                pc[d] += 1;
                progressed = true;
            }
        }
        if !progressed {
            return pc;
        }
    }
}

fn consumer_device(prog: &Program, key: (u32, u32, u32, OpKind)) -> usize {
    for (d, list) in prog.per_device.iter().enumerate() {
        if list.iter().any(|i| i.is_recv() && i.channel() == Some(key)) {
            return d;
        }
    }
    panic!("no consumer for channel {key:?}");
}

/// Where to re-insert the hoisted recv: the consumer's current stuck
/// position (its pc in the abstract execution) — guaranteed ≤ rpos.
fn blocking_point(prog: &Program, consumer: usize, rpos: usize) -> usize {
    stuck_pcs(prog)[consumer].min(rpos)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::placement::sequential;
    use crate::schedule::builders::{one_f_one_b, zb_h1};
    use crate::schedule::Slot;

    #[test]
    fn lowering_inserts_matched_comm() {
        let sch = one_f_one_b(4, 8);
        let prog = lower(&sch, &sequential(4), LowerOptions::default());
        // Every send has exactly one matching recv.
        let mut sends = HashMap::new();
        let mut recvs = HashMap::new();
        for i in prog.per_device.iter().flatten() {
            if i.is_send() {
                *sends.entry(i.channel().unwrap()).or_insert(0) += 1;
            }
            if i.is_recv() {
                *recvs.entry(i.channel().unwrap()).or_insert(0) += 1;
            }
        }
        assert_eq!(sends, recvs);
        assert!(sends.values().all(|&c| c == 1));
        // 3 boundaries × 8 mb × 2 directions.
        assert_eq!(sends.len(), 3 * 8 * 2);
    }

    #[test]
    fn lowered_1f1b_is_deadlock_free() {
        for p in [2, 4, 8] {
            for nmb in [2, 8, 16] {
                let sch = one_f_one_b(p, nmb);
                let prog = lower(&sch, &sequential(p), LowerOptions::default());
                check_rendezvous(&prog).unwrap_or_else(|(d, pc)| {
                    panic!("p={p} nmb={nmb}: blocked at dev {d} pc {pc}")
                });
            }
        }
    }

    #[test]
    fn zb_h1_is_deadlock_free_after_repair() {
        for p in [2, 4] {
            let sch = zb_h1(p, 8);
            let prog = lower(&sch, &sequential(p), LowerOptions::default());
            check_rendezvous(&prog).unwrap();
        }
    }

    #[test]
    fn crafted_deadlock_is_repaired() {
        // Classic cross-send (paper Fig 7): dev0 sends F before posting
        // its recv for B; dev1 sends B before posting its recv for F.
        use crate::schedule::{OpKind, Schedule};
        let sch = Schedule {
            p: 2,
            nmb: 1,
            n_stages: 2,
            split_bw: false,
            overlap_aware: false,
            per_device: vec![
                vec![Slot::new(OpKind::F, 0, 0), Slot::new(OpKind::B, 0, 0)],
                vec![Slot::new(OpKind::F, 0, 1), Slot::new(OpKind::B, 0, 1)],
            ],
        };
        // Without repair the naive lowering deadlocks… (dev0's SendF
        // rendezvouses fine here since dev1 posts RecvF first; craft the
        // real cycle by hoisting dev1's compute order via zero-window)
        let raw = lower(
            &sch,
            &sequential(2),
            LowerOptions { repair_deadlocks: false, hoist_window: 0 },
        );
        // dev0: [C_F0, S_F, R_B, W_B, C_B]; dev1: [R_F, W_F, C_F, C_B, S_B]
        // This particular case is fine; force the cycle by swapping
        // dev0's S_F after its R_B removal… instead directly verify the
        // repair pass fixes a manually broken program.
        let mut broken = raw.clone();
        // Remove dev0's RecvB and re-append it at the very end.
        let d0 = &mut broken.per_device[0];
        let rpos = d0.iter().position(|i| i.is_recv()).unwrap();
        let r = d0.remove(rpos);
        d0.push(r);
        // dev0 now waits (W_B) before posting R_B ⇒ blocked forever.
        assert!(check_rendezvous(&broken).is_err());
        repair_deadlocks(&mut broken);
        check_rendezvous(&broken).unwrap();
    }

    #[test]
    fn hoisting_moves_recvs_earlier() {
        let mut sch = one_f_one_b(2, 4);
        sch.overlap_aware = true;
        let hoisted = lower(
            &sch,
            &sequential(2),
            LowerOptions { repair_deadlocks: true, hoist_window: 3 },
        );
        let plain = lower(
            &sch,
            &sequential(2),
            LowerOptions { repair_deadlocks: true, hoist_window: 0 },
        );
        let pos_sum = |prog: &Program| -> usize {
            prog.per_device[1]
                .iter()
                .enumerate()
                .filter(|(_, i)| i.is_recv())
                .map(|(k, _)| k)
                .sum()
        };
        assert!(pos_sum(&hoisted) <= pos_sum(&plain));
        check_rendezvous(&hoisted).unwrap();
    }
}
