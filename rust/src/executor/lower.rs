//! Schedule → instruction-list lowering and the §4.4 communication
//! passes (comm insertion, deadlock repair, overlap hoisting).
//!
//! Comm insertion keys on **stage adjacency** (a `Recv`+`Wait` wherever
//! stage `s`'s input is produced on another *device*, whatever the
//! placement shape), so interleaved, V-shape/wave and arbitrary
//! generator placements all lower through the same path.
//!
//! Deadlock repair is a single resumable abstract execution
//! ([`AbstractExec`]): the rendezvous fixpoint runs forward once, and
//! at each stuck point every blocked `Send`'s missing `Recv` is hoisted
//! to its consumer's current program counter, then the *same* execution
//! resumes — O(total instrs + repairs · scan) instead of the former
//! three-full-simulations-per-repair O(n²–n³) retry loop.

use std::collections::{HashMap, HashSet};

use super::{Chan, Instr, Program, Step};
use crate::placement::Placement;
use crate::schedule::{OpKind, Schedule};

/// Lowering options.
#[derive(Clone, Copy, Debug)]
pub struct LowerOptions {
    /// Run the deadlock-repair pass (Fig 7 Step 3).  Disabling it is
    /// only useful for tests/ablations that want to observe deadlocks.
    pub repair_deadlocks: bool,
    /// Hoist receives up to this many instructions earlier for overlap
    /// (Fig 7 Step 4); 0 disables the pass, `usize::MAX` posts every
    /// receive as early as possible.
    pub hoist_window: usize,
}

impl Default for LowerOptions {
    fn default() -> Self {
        // Unbounded hoisting posts every receive at the earliest
        // dependency-free point — the performance model's overlap
        // assumption, and what the RealCluster's buffered transport
        // does anyway.  This is the matched-assumption default under
        // which the timed SimCluster agrees with `perfmodel::simulate`
        // bitwise (tests/executor_differential.rs).
        LowerOptions { repair_deadlocks: true, hoist_window: usize::MAX }
    }
}

/// Lower a schedule into a per-device instruction program.
pub fn lower(schedule: &Schedule, placement: &Placement, opts: LowerOptions) -> Program {
    let s_n = schedule.n_stages;
    let dev = |s: u32| placement.device_of[s as usize] as u32;
    let mut per_device: Vec<Vec<Instr>> = vec![Vec::new(); schedule.p];

    // Step 1+2: computation lists with comm instructions inserted.
    for (d, slots) in schedule.per_device.iter().enumerate() {
        let list = &mut per_device[d];
        for sl in slots {
            let (mb, s) = (sl.mb, sl.stage);
            match sl.op {
                OpKind::F => {
                    if s > 0 && dev(s - 1) != d as u32 {
                        list.push(Instr::RecvF { mb, stage: s, from_stage: s - 1 });
                        list.push(Instr::WaitF { mb, stage: s });
                    }
                    list.push(Instr::Compute { op: OpKind::F, mb, stage: s });
                    if (s as usize) < s_n - 1 && dev(s + 1) != d as u32 {
                        list.push(Instr::SendF { mb, stage: s, to_stage: s + 1 });
                    }
                }
                OpKind::B => {
                    if (s as usize) < s_n - 1 && dev(s + 1) != d as u32 {
                        list.push(Instr::RecvB { mb, stage: s, from_stage: s + 1 });
                        list.push(Instr::WaitB { mb, stage: s });
                    }
                    list.push(Instr::Compute { op: OpKind::B, mb, stage: s });
                    if s > 0 && dev(s - 1) != d as u32 {
                        list.push(Instr::SendB { mb, stage: s, to_stage: s - 1 });
                    }
                }
                OpKind::W => {
                    list.push(Instr::Compute { op: OpKind::W, mb, stage: s });
                }
            }
        }
    }

    let mut prog = Program {
        p: schedule.p,
        nmb: schedule.nmb,
        n_stages: s_n,
        split_bw: schedule.split_bw,
        overlap_aware: schedule.overlap_aware,
        per_device,
    };

    // Step 4 first: overlap hoisting (it can also *create* the
    // mismatches Step 3 must fix, so repair runs last).
    if opts.hoist_window > 0 && schedule.overlap_aware {
        hoist_receives(&mut prog, opts.hoist_window);
    }

    // Step 3: deadlock repair under rendezvous send semantics.
    if opts.repair_deadlocks {
        repair_deadlocks(&mut prog);
    }

    prog
}

/// Move each `Recv` up to `window` instructions earlier (receives have
/// no data dependencies — only their `Wait` does), enabling transfer /
/// compute overlap.  Crate-visible so [`super::recover`] applies the
/// same pass to spliced recovery programs.
pub(crate) fn hoist_receives(prog: &mut Program, window: usize) {
    for list in &mut prog.per_device {
        let mut i = 0;
        while i < list.len() {
            if list[i].is_recv() {
                let mut j = i;
                let mut moved = 0;
                while j > 0 && moved < window && !list[j - 1].is_recv() {
                    list.swap(j - 1, j);
                    j -= 1;
                    moved += 1;
                }
            }
            i += 1;
        }
    }
}

/// Resumable abstract rendezvous execution: `Send`s block until the
/// matching recv is posted, `Wait`s until the matching send executed.
/// The fixpoint can be re-entered after the program is mutated *at or
/// after* the stuck program counters — the repair pass exploits this to
/// fix every deadlock in one forward pass.
struct AbstractExec {
    pc: Vec<usize>,
    recv_posted: HashSet<Chan>,
    sent: HashSet<Chan>,
}

impl AbstractExec {
    fn new(p: usize) -> AbstractExec {
        AbstractExec { pc: vec![0; p], recv_posted: HashSet::new(), sent: HashSet::new() }
    }

    /// Run (or resume) the fixpoint; `true` iff every device completed.
    fn run(&mut self, prog: &Program) -> bool {
        loop {
            let mut progressed = false;
            for d in 0..prog.p {
                while let Some(ins) = prog.per_device[d].get(self.pc[d]) {
                    match ins.step() {
                        Step::Compute { .. } => {}
                        Step::Recv(c) => {
                            self.recv_posted.insert(c);
                        }
                        Step::Send(c) => {
                            if !self.recv_posted.contains(&c) {
                                break; // rendezvous: peer hasn't posted
                            }
                            self.sent.insert(c);
                        }
                        Step::Wait(c) => {
                            if !self.sent.contains(&c) {
                                break;
                            }
                        }
                    }
                    self.pc[d] += 1;
                    progressed = true;
                }
            }
            if (0..prog.p).all(|d| self.pc[d] >= prog.per_device[d].len()) {
                return true;
            }
            if !progressed {
                return false;
            }
        }
    }

    /// First device still short of its list end (only valid when stuck).
    fn first_blocked(&self, prog: &Program) -> (usize, usize) {
        (0..prog.p)
            .find(|&d| self.pc[d] < prog.per_device[d].len())
            .map(|d| (d, self.pc[d]))
            .expect("not stuck")
    }
}

/// Abstract rendezvous execution: sends block until the matching recv
/// is posted; waits block until the matching send executed.  Returns
/// the device/pc of the first blocked instruction if the program
/// cannot complete.
pub fn check_rendezvous(prog: &Program) -> Result<(), (usize, usize)> {
    let mut ex = AbstractExec::new(prog.p);
    if ex.run(prog) {
        Ok(())
    } else {
        Err(ex.first_blocked(prog))
    }
}

/// Detect rendezvous deadlocks and repair them by hoisting the missing
/// `Recv` on the peer device directly before its blocking instruction
/// (paper: "reorders them to ensure deadlock-free execution").
///
/// One resumable [`AbstractExec`] drives the whole pass: at each stuck
/// point, every device blocked at a `Send` gets its channel's `Recv`
/// hoisted to the consumer's current pc (the recv provably sits at or
/// after it — otherwise it would already be posted), then the same
/// execution resumes; nothing already executed is ever re-simulated.
/// Returns the number of hoisted receives.
///
/// Panics on unrepairable deadlocks (a cycle through compute/wait
/// dependencies, i.e. an invalid schedule rather than a send/recv
/// ordering mismatch — recv hoisting cannot fix those).
pub fn repair_deadlocks(prog: &mut Program) -> usize {
    // Consumer device per channel (recvs never change device).
    let mut recv_dev: HashMap<Chan, usize> = HashMap::new();
    for (d, list) in prog.per_device.iter().enumerate() {
        for ins in list {
            if let Step::Recv(c) = ins.step() {
                recv_dev.insert(c, d);
            }
        }
    }
    let mut ex = AbstractExec::new(prog.p);
    let mut repairs = 0usize;
    loop {
        if ex.run(prog) {
            return repairs;
        }
        let mut repaired = false;
        for d in 0..prog.p {
            let Some(ins) = prog.per_device[d].get(ex.pc[d]) else { continue };
            let Step::Send(chan) = ins.step() else { continue };
            if ex.recv_posted.contains(&chan) {
                continue;
            }
            let consumer = *recv_dev
                .get(&chan)
                .unwrap_or_else(|| panic!("send {chan:?} has no matching recv"));
            let at = ex.pc[consumer];
            let list = &mut prog.per_device[consumer];
            let rpos = (at..list.len())
                .find(|&i| matches!(list[i].step(), Step::Recv(c) if c == chan))
                .expect("unposted recv must sit at or after the consumer's pc");
            let r = list.remove(rpos);
            list.insert(at, r);
            repaired = true;
            repairs += 1;
        }
        if !repaired {
            let (d, at) = ex.first_blocked(prog);
            panic!(
                "unrepairable deadlock: no blocked send (dev {d} pc {at}: {:?}) — schedule invalid?",
                prog.per_device[d][at]
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::placement::sequential;
    use crate::schedule::builders::{one_f_one_b, zb_h1};
    use crate::schedule::Slot;

    #[test]
    fn lowering_inserts_matched_comm() {
        let sch = one_f_one_b(4, 8);
        let prog = lower(&sch, &sequential(4), LowerOptions::default());
        prog.validate().unwrap();
        // Every send has exactly one matching recv.
        let mut sends = HashMap::new();
        let mut recvs = HashMap::new();
        for i in prog.per_device.iter().flatten() {
            if i.is_send() {
                *sends.entry(i.channel().unwrap()).or_insert(0) += 1;
            }
            if i.is_recv() {
                *recvs.entry(i.channel().unwrap()).or_insert(0) += 1;
            }
        }
        assert_eq!(sends, recvs);
        assert!(sends.values().all(|&c| c == 1));
        // 3 boundaries × 8 mb × 2 directions.
        assert_eq!(sends.len(), 3 * 8 * 2);
    }

    #[test]
    fn lowered_1f1b_is_deadlock_free() {
        for p in [2, 4, 8] {
            for nmb in [2, 8, 16] {
                let sch = one_f_one_b(p, nmb);
                let prog = lower(&sch, &sequential(p), LowerOptions::default());
                prog.validate().unwrap();
                check_rendezvous(&prog).unwrap_or_else(|(d, pc)| {
                    panic!("p={p} nmb={nmb}: blocked at dev {d} pc {pc}")
                });
            }
        }
    }

    #[test]
    fn zb_h1_is_deadlock_free_after_repair() {
        for p in [2, 4] {
            let sch = zb_h1(p, 8);
            let prog = lower(&sch, &sequential(p), LowerOptions::default());
            prog.validate().unwrap();
            check_rendezvous(&prog).unwrap();
        }
    }

    #[test]
    fn crafted_deadlock_is_repaired() {
        // Classic cross-send (paper Fig 7): dev0 sends F before posting
        // its recv for B; dev1 sends B before posting its recv for F.
        use crate::schedule::{OpKind, Schedule};
        let sch = Schedule {
            p: 2,
            nmb: 1,
            n_stages: 2,
            split_bw: false,
            overlap_aware: false,
            per_device: vec![
                vec![Slot::new(OpKind::F, 0, 0), Slot::new(OpKind::B, 0, 0)],
                vec![Slot::new(OpKind::F, 0, 1), Slot::new(OpKind::B, 0, 1)],
            ],
        };
        // Without repair the naive lowering deadlocks… (dev0's SendF
        // rendezvouses fine here since dev1 posts RecvF first; craft the
        // real cycle by hoisting dev1's compute order via zero-window)
        let raw = lower(
            &sch,
            &sequential(2),
            LowerOptions { repair_deadlocks: false, hoist_window: 0 },
        );
        // dev0: [C_F0, S_F, R_B, W_B, C_B]; dev1: [R_F, W_F, C_F, C_B, S_B]
        // This particular case is fine; force the cycle by swapping
        // dev0's S_F after its R_B removal… instead directly verify the
        // repair pass fixes a manually broken program.
        let mut broken = raw.clone();
        // Remove dev0's RecvB and re-append it at the very end.
        let d0 = &mut broken.per_device[0];
        let rpos = d0.iter().position(|i| i.is_recv()).unwrap();
        let r = d0.remove(rpos);
        d0.push(r);
        // dev0 now waits (W_B) before posting R_B ⇒ blocked forever.
        assert!(check_rendezvous(&broken).is_err());
        let repairs = repair_deadlocks(&mut broken);
        assert!(repairs >= 1);
        check_rendezvous(&broken).unwrap();
        broken.validate().unwrap();
    }

    #[test]
    fn hoisting_moves_recvs_earlier() {
        let mut sch = one_f_one_b(2, 4);
        sch.overlap_aware = true;
        let hoisted = lower(
            &sch,
            &sequential(2),
            LowerOptions { repair_deadlocks: true, hoist_window: 3 },
        );
        let plain = lower(
            &sch,
            &sequential(2),
            LowerOptions { repair_deadlocks: true, hoist_window: 0 },
        );
        let pos_sum = |prog: &Program| -> usize {
            prog.per_device[1]
                .iter()
                .enumerate()
                .filter(|(_, i)| i.is_recv())
                .map(|(k, _)| k)
                .sum()
        };
        assert!(pos_sum(&hoisted) <= pos_sum(&plain));
        hoisted.validate().unwrap();
        plain.validate().unwrap();
        check_rendezvous(&hoisted).unwrap();
    }

    #[test]
    fn unbounded_hoist_posts_all_recvs_first() {
        let mut sch = one_f_one_b(4, 8);
        sch.overlap_aware = true;
        let prog = lower(&sch, &sequential(4), LowerOptions::default());
        prog.validate().unwrap();
        for list in &prog.per_device {
            let n_recvs = list.iter().filter(|i| i.is_recv()).count();
            assert!(
                list[..n_recvs].iter().all(|i| i.is_recv()),
                "unbounded hoist must move every recv to the list head"
            );
        }
        // With every recv pre-posted no send can block: repair is a
        // no-op on fully hoisted programs.
        let mut clone = prog.clone();
        assert_eq!(repair_deadlocks(&mut clone), 0);
    }
}
