//! Whole-request identity and the near-miss metric (DESIGN.md §9).
//!
//! The per-candidate transposition table keys on `CandKey` and scopes
//! entries to one evaluation context via `search_fingerprint`
//! (`generator/cache.rs`).  The planner service generalizes both to
//! whole requests:
//!
//! - [`ReqKey`] is the **exact** structural identity of a plan
//!   request: layer-kind sequence, every per-layer cost component *to
//!   the bit* (f64 bit patterns, so a single flipped cost bit is a
//!   different request), link parameters, per-device caps, `nmb`,
//!   rates, iteration/time budgets.  It is a real `Eq + Hash` key —
//!   a hash collision falls back to structural equality, never to
//!   serving someone else's plan.  Identical `ReqKey`s are what the
//!   service coalesces and answers from the plan cache.
//! - [`Sketch`] is the request's **geometry** for near-miss reuse:
//!   the same components as *values* rather than bits, minus the
//!   knobs that a cached plan transfers across trivially (`nmb` and
//!   budgets — a pipeline plan is a (partition, placement, knobs)
//!   triple, none of which encode the micro-batch count).
//!   [`near_miss_distance`] compares two sketches: incompatible
//!   shapes (different layer-kind sequences, device counts) never
//!   match; compatible ones score the worst relative drift over every
//!   component.  The metric is symmetric (`rel` is) and zero iff the
//!   sketches are value-identical.
//!
//! A near-miss hit only *seeds* the search ([`crate::generator::GenOptions::incumbent`])
//! — acceptance still goes through the Evaluator — so a wrong notion
//! of "near" can cost time, never correctness.

use crate::cluster::ClusterSpec;
use crate::model::{LayerCost, LayerKind};
use crate::profile::ProfiledData;

use super::PlanRequest;

/// Exact request identity; see module docs.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct ReqKey {
    kinds: Vec<LayerKind>,
    /// Per-layer cost components, 7 per layer, as f64 bit patterns.
    cost_bits: Vec<u64>,
    /// `link_latency`, `link_bw`, `mem_capacity` bit patterns.
    link_bits: [u64; 3],
    /// Per-device capacity bit patterns (cluster order).
    cap_bits: Vec<u64>,
    /// Per-device rate multipliers (empty = healthy/unit).
    rate_bits: Vec<u64>,
    nmb: u64,
    max_iters: u64,
    /// `u64::MAX` encodes "no wall-clock budget".
    budget_bits: u64,
    /// `u64::MAX` encodes "no deadline".  Part of the exact identity:
    /// a deadlined request must not be answered from (or coalesced
    /// with) an un-deadlined one whose search it could not afford.
    deadline_bits: u64,
    /// Block-search knob: `0` off, `1` on without a stash hint,
    /// `2 + k` on with stash budget `k`.  Part of the exact identity —
    /// requests differing only in the block knob search different
    /// spaces and must never coalesce or share a cached plan.
    block_bits: u64,
}

impl ReqKey {
    pub fn of(req: &PlanRequest) -> ReqKey {
        let mut cost_bits = Vec::with_capacity(req.profile.layers.len() * 7);
        for l in &req.profile.layers {
            for v in [l.f, l.b, l.w, l.mem_static, l.mem_act, l.mem_act_w, l.comm_bytes] {
                cost_bits.push(v.to_bits());
            }
        }
        ReqKey {
            kinds: req.kinds.clone(),
            cost_bits,
            link_bits: [
                req.profile.link_latency.to_bits(),
                req.profile.link_bw.to_bits(),
                req.profile.mem_capacity.to_bits(),
            ],
            cap_bits: req.cluster.devices.iter().map(|d| d.mem_bytes.to_bits()).collect(),
            rate_bits: req.rates.iter().map(|r| r.to_bits()).collect(),
            nmb: req.nmb as u64,
            max_iters: req.max_iters as u64,
            budget_bits: req.budget_s.map_or(u64::MAX, f64::to_bits),
            deadline_bits: req.deadline_s.map_or(u64::MAX, f64::to_bits),
            block_bits: block_bits_of(req.block_search, req.block_stash),
        }
    }

    /// 64-bit digest for logs and the wire protocol (FNV-1a, stable
    /// across runs).  Identity decisions never use this — they compare
    /// whole keys.
    pub fn fingerprint(&self) -> u64 {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        let mut mix = |v: u64| {
            for b in v.to_le_bytes() {
                h ^= b as u64;
                h = h.wrapping_mul(0x1000_0000_01b3);
            }
        };
        for k in &self.kinds {
            mix(*k as u64);
        }
        mix(u64::MAX); // section separators guard against concatenation aliasing
        for &b in &self.cost_bits {
            mix(b);
        }
        for &b in &self.link_bits {
            mix(b);
        }
        mix(u64::MAX);
        for &b in &self.cap_bits {
            mix(b);
        }
        mix(u64::MAX);
        for &b in &self.rate_bits {
            mix(b);
        }
        mix(self.nmb);
        mix(self.max_iters);
        mix(self.budget_bits);
        mix(self.deadline_bits);
        mix(self.block_bits);
        h
    }

    /// Journal wire form (little-endian, length-prefixed sections).
    /// The layout is the field order of the struct; [`ReqKey::from_bytes`]
    /// inverts it exactly.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut b = Vec::with_capacity(
            16 + self.kinds.len()
                + 8 * (self.cost_bits.len() + 3 + self.cap_bits.len() + self.rate_bits.len())
                + 4 * 4
                + 8 * 4,
        );
        put_u32(&mut b, self.kinds.len() as u32);
        for k in &self.kinds {
            b.push(kind_tag(*k));
        }
        put_u32(&mut b, self.cost_bits.len() as u32);
        for &v in &self.cost_bits {
            put_u64(&mut b, v);
        }
        for &v in &self.link_bits {
            put_u64(&mut b, v);
        }
        put_u32(&mut b, self.cap_bits.len() as u32);
        for &v in &self.cap_bits {
            put_u64(&mut b, v);
        }
        put_u32(&mut b, self.rate_bits.len() as u32);
        for &v in &self.rate_bits {
            put_u64(&mut b, v);
        }
        put_u64(&mut b, self.nmb);
        put_u64(&mut b, self.max_iters);
        put_u64(&mut b, self.budget_bits);
        put_u64(&mut b, self.deadline_bits);
        put_u64(&mut b, self.block_bits);
        b
    }

    /// Inverse of [`ReqKey::to_bytes`].  `None` on any structural
    /// violation (short buffer, trailing bytes, unknown kind tag,
    /// inconsistent section lengths) — the journal treats that as a
    /// corrupt record, never a panic.
    pub fn from_bytes(bytes: &[u8]) -> Option<ReqKey> {
        let mut r = ByteReader::new(bytes);
        let n_kinds = r.u32()? as usize;
        if n_kinds == 0 || n_kinds > 1 << 20 {
            return None;
        }
        let mut kinds = Vec::with_capacity(n_kinds);
        for _ in 0..n_kinds {
            kinds.push(kind_of_tag(r.u8()?)?);
        }
        let n_cost = r.u32()? as usize;
        if n_cost != n_kinds * 7 {
            return None;
        }
        let mut cost_bits = Vec::with_capacity(n_cost);
        for _ in 0..n_cost {
            cost_bits.push(r.u64()?);
        }
        let link_bits = [r.u64()?, r.u64()?, r.u64()?];
        let n_caps = r.u32()? as usize;
        if n_caps == 0 || n_caps > 1 << 16 {
            return None;
        }
        let mut cap_bits = Vec::with_capacity(n_caps);
        for _ in 0..n_caps {
            cap_bits.push(r.u64()?);
        }
        let n_rates = r.u32()? as usize;
        if n_rates != 0 && n_rates != n_caps {
            return None;
        }
        let mut rate_bits = Vec::with_capacity(n_rates);
        for _ in 0..n_rates {
            rate_bits.push(r.u64()?);
        }
        let nmb = r.u64()?;
        let max_iters = r.u64()?;
        let budget_bits = r.u64()?;
        let deadline_bits = r.u64()?;
        let block_bits = r.u64()?;
        if nmb == 0 || !r.done() {
            return None;
        }
        Some(ReqKey {
            kinds,
            cost_bits,
            link_bits,
            cap_bits,
            rate_bits,
            nmb,
            max_iters,
            budget_bits,
            deadline_bits,
            block_bits,
        })
    }

    /// Rebuild the full [`PlanRequest`] this key identifies.  Exact by
    /// construction: `ReqKey::of(&key.materialize()) == key`, which is
    /// what lets the journal store keys instead of requests and still
    /// re-derive (and verify) a replayed plan's schedule.
    pub fn materialize(&self) -> PlanRequest {
        let layers: Vec<LayerCost> = self
            .cost_bits
            .chunks_exact(7)
            .map(|c| LayerCost {
                f: f64::from_bits(c[0]),
                b: f64::from_bits(c[1]),
                w: f64::from_bits(c[2]),
                mem_static: f64::from_bits(c[3]),
                mem_act: f64::from_bits(c[4]),
                mem_act_w: f64::from_bits(c[5]),
                comm_bytes: f64::from_bits(c[6]),
            })
            .collect();
        let profile = ProfiledData::from_measured(
            layers,
            f64::from_bits(self.link_bits[0]),
            f64::from_bits(self.link_bits[1]),
            f64::from_bits(self.link_bits[2]),
        );
        let cluster = ClusterSpec::with_caps(
            self.cap_bits.iter().map(|&b| f64::from_bits(b)).collect(),
        );
        PlanRequest {
            kinds: self.kinds.clone(),
            profile,
            cluster,
            nmb: self.nmb as usize,
            rates: self.rate_bits.iter().map(|&b| f64::from_bits(b)).collect(),
            budget_s: (self.budget_bits != u64::MAX)
                .then(|| f64::from_bits(self.budget_bits)),
            max_iters: self.max_iters as usize,
            deadline_s: (self.deadline_bits != u64::MAX)
                .then(|| f64::from_bits(self.deadline_bits)),
            block_search: self.block_bits >= 1,
            block_stash: self.block_bits.checked_sub(2).map(|k| k as u32),
        }
    }
}

/// Encode the block knob pair into one identity word: `0` off, `1` on
/// without a stash hint, `2 + k` on with stash budget `k`.  Injective
/// over the meaningful settings (`block_stash` is ignored by the
/// generator when `block_search` is off, and `k` is well below the
/// `u64` range).
fn block_bits_of(block_search: bool, block_stash: Option<u32>) -> u64 {
    match (block_search, block_stash) {
        (false, _) => 0,
        (true, None) => 1,
        (true, Some(k)) => 2 + k as u64,
    }
}

/// Stable on-disk tags for [`LayerKind`] — explicit, so reordering the
/// enum can never silently re-interpret an old journal.
fn kind_tag(k: LayerKind) -> u8 {
    match k {
        LayerKind::Embed => 0,
        LayerKind::Sa => 1,
        LayerKind::Mla => 2,
        LayerKind::Mamba => 3,
        LayerKind::Ffn => 4,
        LayerKind::Moe => 5,
        LayerKind::Head => 6,
    }
}

fn kind_of_tag(t: u8) -> Option<LayerKind> {
    Some(match t {
        0 => LayerKind::Embed,
        1 => LayerKind::Sa,
        2 => LayerKind::Mla,
        3 => LayerKind::Mamba,
        4 => LayerKind::Ffn,
        5 => LayerKind::Moe,
        6 => LayerKind::Head,
        _ => return None,
    })
}

fn put_u32(b: &mut Vec<u8>, v: u32) {
    b.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(b: &mut Vec<u8>, v: u64) {
    b.extend_from_slice(&v.to_le_bytes());
}

/// Bounds-checked little-endian cursor shared with the journal
/// decoder; every read is `Option`al so corrupt bytes can never panic.
pub(crate) struct ByteReader<'a> {
    bytes: &'a [u8],
    at: usize,
}

impl<'a> ByteReader<'a> {
    pub(crate) fn new(bytes: &'a [u8]) -> ByteReader<'a> {
        ByteReader { bytes, at: 0 }
    }

    pub(crate) fn take(&mut self, n: usize) -> Option<&'a [u8]> {
        let end = self.at.checked_add(n)?;
        let s = self.bytes.get(self.at..end)?;
        self.at = end;
        Some(s)
    }

    pub(crate) fn u8(&mut self) -> Option<u8> {
        self.take(1).map(|s| s[0])
    }

    pub(crate) fn u32(&mut self) -> Option<u32> {
        self.take(4).map(|s| u32::from_le_bytes(s.try_into().unwrap()))
    }

    pub(crate) fn u64(&mut self) -> Option<u64> {
        self.take(8).map(|s| u64::from_le_bytes(s.try_into().unwrap()))
    }

    /// True iff the whole buffer was consumed.
    pub(crate) fn done(&self) -> bool {
        self.at == self.bytes.len()
    }
}

/// Request geometry for near-miss reuse; see module docs.
#[derive(Clone, Debug, PartialEq)]
pub struct Sketch {
    pub kinds: Vec<LayerKind>,
    pub p: usize,
    /// Flattened per-layer cost components (7 per layer, layer order).
    pub costs: Vec<f64>,
    /// `link_latency`, `link_bw`, `mem_capacity`.
    pub link: [f64; 3],
    /// Per-device capacities.
    pub caps: Vec<f64>,
    /// Per-device rates, expanded to length `p` (unit when the request
    /// carries none) so healthy and explicitly-rated requests stay
    /// comparable.
    pub rates: Vec<f64>,
    /// Block-knob word (same encoding as the exact key): requests in
    /// different block families search different plan spaces, so a
    /// cached plan from one is a structurally wrong seed for the other
    /// — never a near miss.
    pub block: u64,
}

impl Sketch {
    pub fn of(req: &PlanRequest) -> Sketch {
        let mut costs = Vec::with_capacity(req.profile.layers.len() * 7);
        for l in &req.profile.layers {
            costs.extend_from_slice(&[
                l.f,
                l.b,
                l.w,
                l.mem_static,
                l.mem_act,
                l.mem_act_w,
                l.comm_bytes,
            ]);
        }
        let p = req.cluster.p();
        let rates =
            if req.rates.is_empty() { vec![1.0; p] } else { req.rates.clone() };
        Sketch {
            kinds: req.kinds.clone(),
            p,
            costs,
            link: [req.profile.link_latency, req.profile.link_bw, req.profile.mem_capacity],
            caps: req.cluster.devices.iter().map(|d| d.mem_bytes).collect(),
            rates,
            block: block_bits_of(req.block_search, req.block_stash),
        }
    }
}

/// Symmetric relative drift of one component: 0 for bitwise-equal
/// values (including equal infinities — unbounded caps), else
/// `|x−y| / max(|x|,|y|)`; any one-sided non-finite pair is infinitely
/// far.
fn rel(x: f64, y: f64) -> f64 {
    if x == y {
        0.0
    } else if !x.is_finite() || !y.is_finite() {
        f64::INFINITY
    } else {
        (x - y).abs() / x.abs().max(y.abs())
    }
}

/// Distance between two request geometries: `None` when structurally
/// incompatible (a cached plan could not even seed the search), else
/// the worst per-component relative drift.  Symmetric; zero iff the
/// sketches carry identical values.
pub fn near_miss_distance(a: &Sketch, b: &Sketch) -> Option<f64> {
    if a.kinds != b.kinds || a.p != b.p || a.rates.len() != b.rates.len() {
        return None;
    }
    if a.block != b.block {
        return None; // different block families: structurally incompatible
    }
    debug_assert_eq!(a.costs.len(), b.costs.len());
    debug_assert_eq!(a.caps.len(), b.caps.len());
    let mut d: f64 = 0.0;
    for (x, y) in a.costs.iter().zip(&b.costs) {
        d = d.max(rel(*x, *y));
    }
    for (x, y) in a.link.iter().zip(&b.link) {
        d = d.max(rel(*x, *y));
    }
    for (x, y) in a.caps.iter().zip(&b.caps) {
        d = d.max(rel(*x, *y));
    }
    for (x, y) in a.rates.iter().zip(&b.rates) {
        d = d.max(rel(*x, *y));
    }
    Some(d)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Family, ParallelCfg, Size};

    #[test]
    fn key_round_trips_through_bytes_and_materialize() {
        let mut req = PlanRequest::table5(
            Family::Gemma,
            Size::Small,
            &ParallelCfg::new(4, 2, 8, 1, 4096),
        );
        req.rates = vec![1.0, 0.5, 1.0, 1.0];
        req.budget_s = Some(0.25);
        req.deadline_s = Some(1.5);
        let key = req.key();
        let decoded = ReqKey::from_bytes(&key.to_bytes()).expect("wire form decodes");
        assert_eq!(decoded, key, "byte round trip is exact");
        assert_eq!(decoded.fingerprint(), key.fingerprint());
        assert_eq!(
            ReqKey::of(&key.materialize()),
            key,
            "materialize() rebuilds the identical request identity"
        );

        // Deadline is part of the exact identity…
        let mut other = req.clone();
        other.deadline_s = None;
        assert_ne!(other.key(), key);
        // …but not of the reuse geometry.
        assert_eq!(
            near_miss_distance(&other.sketch(), &req.sketch()),
            Some(0.0)
        );

        // Corrupt bytes degrade to None, never a panic.
        let mut bytes = key.to_bytes();
        assert!(ReqKey::from_bytes(&bytes[..bytes.len() - 1]).is_none(), "truncated");
        bytes[4] = 250; // unknown layer-kind tag
        assert!(ReqKey::from_bytes(&bytes).is_none(), "unknown tag");
        assert!(ReqKey::from_bytes(&[]).is_none(), "empty");
    }

    /// Satellite regression (ISSUE 9): the block knob is part of the
    /// exact request identity AND the reuse geometry — requests
    /// differing only in block parameters must get distinct keys,
    /// distinct fingerprints, survive the wire round trip, and never
    /// near-miss each other.
    #[test]
    fn block_knob_is_part_of_request_identity() {
        let base = PlanRequest::table5(
            Family::Gemma,
            Size::Small,
            &ParallelCfg::new(4, 2, 8, 1, 4096),
        );
        let mut on = base.clone();
        on.block_search = true;
        let mut stashed = on.clone();
        stashed.block_stash = Some(3);
        let mut stashed4 = on.clone();
        stashed4.block_stash = Some(4);

        let keys = [base.key(), on.key(), stashed.key(), stashed4.key()];
        for (i, a) in keys.iter().enumerate() {
            for b in &keys[i + 1..] {
                assert_ne!(a, b, "block settings must yield distinct ReqKeys");
                assert_ne!(a.fingerprint(), b.fingerprint());
            }
        }
        for (key, req) in keys.iter().zip([&base, &on, &stashed, &stashed4]) {
            let decoded = ReqKey::from_bytes(&key.to_bytes()).expect("decodes");
            assert_eq!(&decoded, key, "wire round trip keeps the block word");
            let back = key.materialize();
            assert_eq!(back.block_search, req.block_search);
            assert_eq!(back.block_stash.filter(|_| back.block_search), {
                req.block_stash.filter(|_| req.block_search)
            });
            assert_eq!(&ReqKey::of(&back), key);
        }

        // Near-miss: identical geometry except the block family ⇒ no
        // reuse at all, not merely a large distance.
        assert_eq!(near_miss_distance(&base.sketch(), &base.sketch()), Some(0.0));
        assert_eq!(near_miss_distance(&base.sketch(), &on.sketch()), None);
        assert_eq!(near_miss_distance(&on.sketch(), &stashed.sketch()), None);
        assert_eq!(near_miss_distance(&stashed.sketch(), &stashed4.sketch()), None);
        assert_eq!(near_miss_distance(&on.sketch(), &on.sketch()), Some(0.0));
    }

    #[test]
    fn rel_is_symmetric_and_scale_free() {
        assert_eq!(rel(1.0, 1.0), 0.0);
        assert_eq!(rel(f64::INFINITY, f64::INFINITY), 0.0);
        assert_eq!(rel(1.0, f64::INFINITY), f64::INFINITY);
        let d = rel(1.0, 1.25);
        assert_eq!(d, rel(1.25, 1.0));
        assert!((d - 0.2).abs() < 1e-15, "drift is relative to the larger value");
        assert_eq!(rel(2.0, 2.5), d, "scale-free");
    }
}
