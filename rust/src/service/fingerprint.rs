//! Whole-request identity and the near-miss metric (DESIGN.md §8).
//!
//! The per-candidate transposition table keys on `CandKey` and scopes
//! entries to one evaluation context via `search_fingerprint`
//! (`generator/cache.rs`).  The planner service generalizes both to
//! whole requests:
//!
//! - [`ReqKey`] is the **exact** structural identity of a plan
//!   request: layer-kind sequence, every per-layer cost component *to
//!   the bit* (f64 bit patterns, so a single flipped cost bit is a
//!   different request), link parameters, per-device caps, `nmb`,
//!   rates, iteration/time budgets.  It is a real `Eq + Hash` key —
//!   a hash collision falls back to structural equality, never to
//!   serving someone else's plan.  Identical `ReqKey`s are what the
//!   service coalesces and answers from the plan cache.
//! - [`Sketch`] is the request's **geometry** for near-miss reuse:
//!   the same components as *values* rather than bits, minus the
//!   knobs that a cached plan transfers across trivially (`nmb` and
//!   budgets — a pipeline plan is a (partition, placement, knobs)
//!   triple, none of which encode the micro-batch count).
//!   [`near_miss_distance`] compares two sketches: incompatible
//!   shapes (different layer-kind sequences, device counts) never
//!   match; compatible ones score the worst relative drift over every
//!   component.  The metric is symmetric (`rel` is) and zero iff the
//!   sketches are value-identical.
//!
//! A near-miss hit only *seeds* the search ([`crate::generator::GenOptions::incumbent`])
//! — acceptance still goes through the Evaluator — so a wrong notion
//! of "near" can cost time, never correctness.

use crate::model::LayerKind;

use super::PlanRequest;

/// Exact request identity; see module docs.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct ReqKey {
    kinds: Vec<LayerKind>,
    /// Per-layer cost components, 7 per layer, as f64 bit patterns.
    cost_bits: Vec<u64>,
    /// `link_latency`, `link_bw`, `mem_capacity` bit patterns.
    link_bits: [u64; 3],
    /// Per-device capacity bit patterns (cluster order).
    cap_bits: Vec<u64>,
    /// Per-device rate multipliers (empty = healthy/unit).
    rate_bits: Vec<u64>,
    nmb: u64,
    max_iters: u64,
    /// `u64::MAX` encodes "no wall-clock budget".
    budget_bits: u64,
}

impl ReqKey {
    pub fn of(req: &PlanRequest) -> ReqKey {
        let mut cost_bits = Vec::with_capacity(req.profile.layers.len() * 7);
        for l in &req.profile.layers {
            for v in [l.f, l.b, l.w, l.mem_static, l.mem_act, l.mem_act_w, l.comm_bytes] {
                cost_bits.push(v.to_bits());
            }
        }
        ReqKey {
            kinds: req.kinds.clone(),
            cost_bits,
            link_bits: [
                req.profile.link_latency.to_bits(),
                req.profile.link_bw.to_bits(),
                req.profile.mem_capacity.to_bits(),
            ],
            cap_bits: req.cluster.devices.iter().map(|d| d.mem_bytes.to_bits()).collect(),
            rate_bits: req.rates.iter().map(|r| r.to_bits()).collect(),
            nmb: req.nmb as u64,
            max_iters: req.max_iters as u64,
            budget_bits: req.budget_s.map_or(u64::MAX, f64::to_bits),
        }
    }

    /// 64-bit digest for logs and the wire protocol (FNV-1a, stable
    /// across runs).  Identity decisions never use this — they compare
    /// whole keys.
    pub fn fingerprint(&self) -> u64 {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        let mut mix = |v: u64| {
            for b in v.to_le_bytes() {
                h ^= b as u64;
                h = h.wrapping_mul(0x1000_0000_01b3);
            }
        };
        for k in &self.kinds {
            mix(*k as u64);
        }
        mix(u64::MAX); // section separators guard against concatenation aliasing
        for &b in &self.cost_bits {
            mix(b);
        }
        for &b in &self.link_bits {
            mix(b);
        }
        mix(u64::MAX);
        for &b in &self.cap_bits {
            mix(b);
        }
        mix(u64::MAX);
        for &b in &self.rate_bits {
            mix(b);
        }
        mix(self.nmb);
        mix(self.max_iters);
        mix(self.budget_bits);
        h
    }
}

/// Request geometry for near-miss reuse; see module docs.
#[derive(Clone, Debug, PartialEq)]
pub struct Sketch {
    pub kinds: Vec<LayerKind>,
    pub p: usize,
    /// Flattened per-layer cost components (7 per layer, layer order).
    pub costs: Vec<f64>,
    /// `link_latency`, `link_bw`, `mem_capacity`.
    pub link: [f64; 3],
    /// Per-device capacities.
    pub caps: Vec<f64>,
    /// Per-device rates, expanded to length `p` (unit when the request
    /// carries none) so healthy and explicitly-rated requests stay
    /// comparable.
    pub rates: Vec<f64>,
}

impl Sketch {
    pub fn of(req: &PlanRequest) -> Sketch {
        let mut costs = Vec::with_capacity(req.profile.layers.len() * 7);
        for l in &req.profile.layers {
            costs.extend_from_slice(&[
                l.f,
                l.b,
                l.w,
                l.mem_static,
                l.mem_act,
                l.mem_act_w,
                l.comm_bytes,
            ]);
        }
        let p = req.cluster.p();
        let rates =
            if req.rates.is_empty() { vec![1.0; p] } else { req.rates.clone() };
        Sketch {
            kinds: req.kinds.clone(),
            p,
            costs,
            link: [req.profile.link_latency, req.profile.link_bw, req.profile.mem_capacity],
            caps: req.cluster.devices.iter().map(|d| d.mem_bytes).collect(),
            rates,
        }
    }
}

/// Symmetric relative drift of one component: 0 for bitwise-equal
/// values (including equal infinities — unbounded caps), else
/// `|x−y| / max(|x|,|y|)`; any one-sided non-finite pair is infinitely
/// far.
fn rel(x: f64, y: f64) -> f64 {
    if x == y {
        0.0
    } else if !x.is_finite() || !y.is_finite() {
        f64::INFINITY
    } else {
        (x - y).abs() / x.abs().max(y.abs())
    }
}

/// Distance between two request geometries: `None` when structurally
/// incompatible (a cached plan could not even seed the search), else
/// the worst per-component relative drift.  Symmetric; zero iff the
/// sketches carry identical values.
pub fn near_miss_distance(a: &Sketch, b: &Sketch) -> Option<f64> {
    if a.kinds != b.kinds || a.p != b.p || a.rates.len() != b.rates.len() {
        return None;
    }
    debug_assert_eq!(a.costs.len(), b.costs.len());
    debug_assert_eq!(a.caps.len(), b.caps.len());
    let mut d: f64 = 0.0;
    for (x, y) in a.costs.iter().zip(&b.costs) {
        d = d.max(rel(*x, *y));
    }
    for (x, y) in a.link.iter().zip(&b.link) {
        d = d.max(rel(*x, *y));
    }
    for (x, y) in a.caps.iter().zip(&b.caps) {
        d = d.max(rel(*x, *y));
    }
    for (x, y) in a.rates.iter().zip(&b.rates) {
        d = d.max(rel(*x, *y));
    }
    Some(d)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rel_is_symmetric_and_scale_free() {
        assert_eq!(rel(1.0, 1.0), 0.0);
        assert_eq!(rel(f64::INFINITY, f64::INFINITY), 0.0);
        assert_eq!(rel(1.0, f64::INFINITY), f64::INFINITY);
        let d = rel(1.0, 1.25);
        assert_eq!(d, rel(1.25, 1.0));
        assert!((d - 0.2).abs() < 1e-15, "drift is relative to the larger value");
        assert_eq!(rel(2.0, 2.5), d, "scale-free");
    }
}
