//! Newline-delimited-JSON front end for the planner service
//! (DESIGN.md §8) — what `adaptis serve` speaks over
//! stdin/stdout.
//!
//! One request per input line, one response per output line (compact
//! [`Json::to_string_compact`] framing; responses may arrive out of
//! request order and are correlated by the echoed `id`):
//!
//! ```text
//! {"id":"r1","model":"gemma","size":"small","p":4,"t":2,"nmb":16,
//!  "seq":4096,"budget_s":0.5,"iters":64,
//!  "rates":[1,1,1.5,1],"mem_caps":[8e10,8e10,8e10,8e10],
//!  "cost_scale":[{"layer":3,"f":1.1,"b":1.05}]}
//! ```
//!
//! `model` is required; everything else defaults (`size` small, `p` 4,
//! `t` 2, `nmb` 8, `seq` 4096).  `cost_scale` multiplies per-layer
//! profiled costs (keys `f`, `b`, `w`, `comm_bytes`), which is how a
//! client expresses "the same model, measured a little differently" —
//! the near-miss reuse path.  Responses:
//!
//! ```text
//! {"id":"r1","ok":true,"provenance":"cold","fingerprint":"ab12…",
//!  "makespan_s":…,"headroom_bytes":…,"bubble_ratio":…,
//!  "near_miss_distance":null,"partition":[…],"placement":[…],
//!  "knobs":{…},"evals":…,"iters":…,"budget_exhausted":false,
//!  "search_s":…}
//! {"id":"r9","ok":false,"error":"overloaded","retry_after_s":0.2,"queue_len":64}
//! {"id":"","ok":false,"error":"parse: …"}
//! ```

use std::collections::HashMap;
use std::io::{BufRead, Write};
use std::sync::mpsc::channel;
use std::sync::{Arc, Mutex};

use crate::cluster::ClusterSpec;
use crate::config::{Family, ParallelCfg, Size};
use crate::util::json::{arr, num, obj, s, Json};

use super::{PlanRequest, PlanResponse, Rejected, Service};

/// A request line the service cannot act on; `id` is best-effort.
#[derive(Clone, Debug)]
pub struct ParseErr {
    pub id: String,
    pub msg: String,
}

pub fn parse_family(name: &str) -> Result<Family, String> {
    match name.to_lowercase().as_str() {
        "gemma" => Ok(Family::Gemma),
        "deepseek" => Ok(Family::DeepSeek),
        "nemotron" | "nemotron-h" | "nemotronh" => Ok(Family::NemotronH),
        "llama2" | "llama-2" | "llama" => Ok(Family::Llama2),
        other => Err(format!("unknown model family {other:?}")),
    }
}

pub fn parse_size(name: &str) -> Result<Size, String> {
    match name.to_lowercase().as_str() {
        "small" | "s" => Ok(Size::Small),
        "medium" | "m" => Ok(Size::Medium),
        "large" | "l" => Ok(Size::Large),
        other => Err(format!("unknown size {other:?}")),
    }
}

fn f64_list(v: &Json, what: &str) -> Result<Vec<f64>, String> {
    let items = v.as_arr().ok_or_else(|| format!("{what} must be an array"))?;
    items
        .iter()
        .map(|x| x.as_f64().ok_or_else(|| format!("{what} entries must be numbers")))
        .collect()
}

/// Parse one request line.  See the module docs for the schema.
pub fn parse_request(line: &str) -> Result<(String, PlanRequest), ParseErr> {
    let v = Json::parse(line)
        .map_err(|e| ParseErr { id: String::new(), msg: format!("parse: {e}") })?;
    let id = v.get("id").and_then(Json::as_str).unwrap_or("").to_string();
    let fail = |msg: String| ParseErr { id: id.clone(), msg };
    if v.as_obj().is_none() {
        return Err(fail("request must be a JSON object".into()));
    }
    let family = parse_family(
        v.get("model")
            .and_then(Json::as_str)
            .ok_or_else(|| fail("missing \"model\"".into()))?,
    )
    .map_err(&fail)?;
    let size =
        parse_size(v.get("size").and_then(Json::as_str).unwrap_or("small")).map_err(&fail)?;
    let p = v.get("p").and_then(Json::as_usize).unwrap_or(4);
    let t = v.get("t").and_then(Json::as_usize).unwrap_or(2);
    let nmb = v.get("nmb").and_then(Json::as_usize).unwrap_or(8);
    let seq = v.get("seq").and_then(Json::as_usize).unwrap_or(4096);
    if p < 1 || nmb < 1 {
        return Err(fail("\"p\" and \"nmb\" must be ≥ 1".into()));
    }
    let mut req = PlanRequest::table5(family, size, &ParallelCfg::new(p, t, nmb, 1, seq));
    if let Some(caps) = v.get("mem_caps") {
        let caps = f64_list(caps, "\"mem_caps\"").map_err(&fail)?;
        if caps.len() != p {
            return Err(fail(format!("\"mem_caps\" needs {p} entries")));
        }
        req.cluster = ClusterSpec::with_caps(caps);
    }
    if let Some(rates) = v.get("rates") {
        let rates = f64_list(rates, "\"rates\"").map_err(&fail)?;
        if rates.len() != p {
            return Err(fail(format!("\"rates\" needs {p} entries")));
        }
        // An all-healthy vector is the same request as no vector.
        if rates.iter().any(|&r| r != 1.0) {
            req.rates = rates;
        }
    }
    if let Some(b) = v.get("budget_s").and_then(Json::as_f64) {
        req.budget_s = Some(b);
    }
    if let Some(iters) = v.get("iters").and_then(Json::as_usize) {
        req.max_iters = iters;
    }
    if let Some(scales) = v.get("cost_scale") {
        let entries =
            scales.as_arr().ok_or_else(|| fail("\"cost_scale\" must be an array".into()))?;
        for e in entries {
            let layer = e
                .get("layer")
                .and_then(Json::as_usize)
                .ok_or_else(|| fail("cost_scale entry needs \"layer\"".into()))?;
            if layer >= req.profile.n_layers() {
                return Err(fail(format!("cost_scale layer {layer} out of range")));
            }
            let l = &mut req.profile.layers[layer];
            for (key, slot) in [
                ("f", &mut l.f),
                ("b", &mut l.b),
                ("w", &mut l.w),
                ("comm_bytes", &mut l.comm_bytes),
            ] {
                if let Some(factor) = e.get(key).and_then(Json::as_f64) {
                    *slot *= factor;
                }
            }
        }
        req.profile.rebuild_table();
    }
    Ok((id, req))
}

/// One successful response line (no trailing newline).
pub fn response_line(id: &str, resp: &PlanResponse) -> String {
    let out = &resp.outcome;
    obj(vec![
        ("id", s(id)),
        ("ok", Json::Bool(true)),
        ("provenance", s(resp.provenance.name())),
        ("fingerprint", s(&format!("{:016x}", out.fingerprint))),
        ("makespan_s", num(out.makespan)),
        ("headroom_bytes", num(out.headroom)),
        ("bubble_ratio", num(out.bubble_ratio)),
        (
            "near_miss_distance",
            out.near_miss_distance.map_or(Json::Null, num),
        ),
        (
            "partition",
            arr(out.pipeline.partition.bounds.iter().map(|&b| num(b as f64)).collect()),
        ),
        (
            "placement",
            arr(out.pipeline.placement.device_of.iter().map(|&d| num(d as f64)).collect()),
        ),
        (
            "knobs",
            obj(vec![
                ("split_bw", Json::Bool(out.knobs.split_bw)),
                ("w_fill", Json::Bool(out.knobs.w_fill)),
                ("mem_cap_factor", num(out.knobs.mem_cap_factor)),
                ("overlap_aware", Json::Bool(out.knobs.overlap_aware)),
            ]),
        ),
        ("evals", num(out.evals as f64)),
        ("iters", num(out.iters as f64)),
        ("budget_exhausted", Json::Bool(out.budget_exhausted)),
        ("search_s", num(out.search_s)),
    ])
    .to_string_compact()
}

/// One admission-control rejection line.
pub fn rejected_line(id: &str, rej: &Rejected) -> String {
    obj(vec![
        ("id", s(id)),
        ("ok", Json::Bool(false)),
        ("error", s("overloaded")),
        ("retry_after_s", num(rej.retry_after_s)),
        ("queue_len", num(rej.queue_len as f64)),
    ])
    .to_string_compact()
}

/// One malformed-request line.
pub fn error_line(err: &ParseErr) -> String {
    obj(vec![
        ("id", s(&err.id)),
        ("ok", Json::Bool(false)),
        ("error", s(&err.msg)),
    ])
    .to_string_compact()
}

/// Run the request/response loop until `input` is exhausted, then
/// wait for every in-flight response to be written.  Responses are
/// written by a dedicated thread as searches complete (out of order
/// under concurrency — correlate by `id`); rejections and parse
/// errors are written inline.  Generic over the streams so tests can
/// drive it without a process boundary.
pub fn serve<R, W>(
    service: &Service,
    input: R,
    output: &Arc<Mutex<W>>,
) -> std::io::Result<()>
where
    R: BufRead,
    W: Write + Send + 'static,
{
    let (tx, rx) = channel::<(u64, PlanResponse)>();
    let ids: Arc<Mutex<HashMap<u64, String>>> = Arc::new(Mutex::new(HashMap::new()));
    let writer = {
        let out = Arc::clone(output);
        let ids = Arc::clone(&ids);
        std::thread::spawn(move || {
            for (tag, resp) in rx {
                let id = ids.lock().unwrap().remove(&tag).unwrap_or_default();
                let mut w = out.lock().unwrap();
                let _ = writeln!(w, "{}", response_line(&id, &resp));
                let _ = w.flush();
            }
        })
    };
    let mut tag = 0u64;
    for line in input.lines() {
        let line = line?;
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        match parse_request(line) {
            Err(e) => {
                let mut w = output.lock().unwrap();
                writeln!(w, "{}", error_line(&e))?;
                w.flush()?;
            }
            Ok((id, req)) => {
                tag += 1;
                ids.lock().unwrap().insert(tag, id.clone());
                if let Err(rej) = service.submit_tagged(req, tag, tx.clone()) {
                    ids.lock().unwrap().remove(&tag);
                    let mut w = output.lock().unwrap();
                    writeln!(w, "{}", rejected_line(&id, &rej))?;
                    w.flush()?;
                }
            }
        }
    }
    // In-flight waiters hold sender clones; once the last response is
    // fanned out the channel closes and the writer drains and exits.
    drop(tx);
    let _ = writer.join();
    Ok(())
}
