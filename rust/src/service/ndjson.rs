//! Newline-delimited-JSON front end for the planner service
//! (DESIGN.md §9) — what `adaptis serve` speaks over
//! stdin/stdout.
//!
//! One request per input line, one response per output line (compact
//! [`Json::to_string_compact`] framing; responses may arrive out of
//! request order and are correlated by the echoed `id`):
//!
//! ```text
//! {"id":"r1","model":"gemma","size":"small","p":4,"t":2,"nmb":16,
//!  "seq":4096,"budget_s":0.5,"deadline_s":2.0,"iters":64,
//!  "rates":[1,1,1.5,1],"mem_caps":[8e10,8e10,8e10,8e10],
//!  "cost_scale":[{"layer":3,"f":1.1,"b":1.05}]}
//! ```
//!
//! `model` is required; everything else defaults (`size` small, `p` 4,
//! `t` 2, `nmb` 8, `seq` 4096).  `cost_scale` multiplies per-layer
//! profiled costs (keys `f`, `b`, `w`, `comm_bytes`), which is how a
//! client expresses "the same model, measured a little differently" —
//! the near-miss reuse path.  `deadline_s` bounds the *response* time:
//! an expired deadline returns the best plan so far
//! (`"deadline_hit":true`) or the deterministic fallback
//! (`"provenance":"degraded"`) — never an error.  Responses:
//!
//! ```text
//! {"id":"r1","ok":true,"provenance":"cold","fingerprint":"ab12…",
//!  "makespan_s":…,"headroom_bytes":…,"bubble_ratio":…,
//!  "near_miss_distance":null,"partition":[…],"placement":[…],
//!  "knobs":{…},"evals":…,"iters":…,"budget_exhausted":false,
//!  "deadline_hit":false,"search_s":…}
//! {"id":"r9","ok":false,"error":"overloaded","retry_after_s":0.2,"queue_len":64}
//! {"id":"r4","ok":false,"error":"worker_lost","detail":"…"}
//! {"id":"","ok":false,"error":"parse: …"}
//! ```
//!
//! **Robustness contract** (exercised by `tests/service_fuzz.rs`): any
//! byte sequence on a line — invalid UTF-8, megabyte blobs, truncated
//! JSON, duplicate/missing fields, NaN/Inf numbers, absurd sizes —
//! yields exactly one `"ok":false` line and never panics or kills the
//! loop.  Numeric fields are bounds-checked here so a hostile line
//! cannot make the *search* allocate absurdly either.
//!
//! **Shutdown**: [`serve`] stops admitting on stdin EOF or when the
//! caller's shutdown flag flips (SIGTERM in `adaptis serve`), finishes
//! every in-flight request, writes its responses, drains the service,
//! and flushes + fsyncs the journal before returning.

use std::collections::HashMap;
use std::io::{BufRead, Write};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, RecvTimeoutError};
use std::sync::{Arc, Mutex, PoisonError};
use std::time::Duration;

use crate::cluster::ClusterSpec;
use crate::config::{Family, ParallelCfg, Size};
use crate::util::json::{arr, num, obj, s, Json};

use super::{PlanRequest, PlanResponse, Rejected, Service, ServiceError};

/// Input bounds: a request outside these is a parse error, not an
/// allocation.  Generous relative to every real configuration in the
/// repo (benches top out at p=16, nmb=256).
const MAX_P: usize = 64;
const MAX_NMB: usize = 4096;
const MAX_ITERS: usize = 100_000;
const MAX_SEQ: usize = 1_000_000;
const MAX_T: usize = 64;

/// A request line the service cannot act on; `id` is best-effort.
#[derive(Clone, Debug)]
pub struct ParseErr {
    pub id: String,
    pub msg: String,
}

pub fn parse_family(name: &str) -> Result<Family, String> {
    match name.to_lowercase().as_str() {
        "gemma" => Ok(Family::Gemma),
        "deepseek" => Ok(Family::DeepSeek),
        "nemotron" | "nemotron-h" | "nemotronh" => Ok(Family::NemotronH),
        "llama2" | "llama-2" | "llama" => Ok(Family::Llama2),
        other => Err(format!("unknown model family {other:?}")),
    }
}

pub fn parse_size(name: &str) -> Result<Size, String> {
    match name.to_lowercase().as_str() {
        "small" | "s" => Ok(Size::Small),
        "medium" | "m" => Ok(Size::Medium),
        "large" | "l" => Ok(Size::Large),
        other => Err(format!("unknown size {other:?}")),
    }
}

fn f64_list(v: &Json, what: &str) -> Result<Vec<f64>, String> {
    let items = v.as_arr().ok_or_else(|| format!("{what} must be an array"))?;
    items
        .iter()
        .map(|x| x.as_f64().ok_or_else(|| format!("{what} entries must be numbers")))
        .collect()
}

/// Parse one request line.  See the module docs for the schema and
/// bounds.
pub fn parse_request(line: &str) -> Result<(String, PlanRequest), ParseErr> {
    let v = Json::parse(line)
        .map_err(|e| ParseErr { id: String::new(), msg: format!("parse: {e}") })?;
    let id = v.get("id").and_then(Json::as_str).unwrap_or("").to_string();
    let fail = |msg: String| ParseErr { id: id.clone(), msg };
    if v.as_obj().is_none() {
        return Err(fail("request must be a JSON object".into()));
    }
    let family = parse_family(
        v.get("model")
            .and_then(Json::as_str)
            .ok_or_else(|| fail("missing \"model\"".into()))?,
    )
    .map_err(&fail)?;
    let size =
        parse_size(v.get("size").and_then(Json::as_str).unwrap_or("small")).map_err(&fail)?;
    let p = v.get("p").and_then(Json::as_usize).unwrap_or(4);
    let t = v.get("t").and_then(Json::as_usize).unwrap_or(2);
    let nmb = v.get("nmb").and_then(Json::as_usize).unwrap_or(8);
    let seq = v.get("seq").and_then(Json::as_usize).unwrap_or(4096);
    if p < 1 || p > MAX_P {
        return Err(fail(format!("\"p\" must be in 1..={MAX_P}")));
    }
    if nmb < 1 || nmb > MAX_NMB {
        return Err(fail(format!("\"nmb\" must be in 1..={MAX_NMB}")));
    }
    if t < 1 || t > MAX_T {
        return Err(fail(format!("\"t\" must be in 1..={MAX_T}")));
    }
    if seq < 1 || seq > MAX_SEQ {
        return Err(fail(format!("\"seq\" must be in 1..={MAX_SEQ}")));
    }
    let mut req = PlanRequest::table5(family, size, &ParallelCfg::new(p, t, nmb, 1, seq));
    if req.profile.n_layers() < p {
        return Err(fail(format!(
            "\"p\" = {p} exceeds the model's {} layers",
            req.profile.n_layers()
        )));
    }
    if let Some(caps) = v.get("mem_caps") {
        let caps = f64_list(caps, "\"mem_caps\"").map_err(&fail)?;
        if caps.len() != p {
            return Err(fail(format!("\"mem_caps\" needs {p} entries")));
        }
        // +∞ = unbounded device is legal; NaN / non-positive is not.
        if caps.iter().any(|&c| c.is_nan() || c <= 0.0) {
            return Err(fail("\"mem_caps\" entries must be > 0".into()));
        }
        req.cluster = ClusterSpec::with_caps(caps);
    }
    if let Some(rates) = v.get("rates") {
        let rates = f64_list(rates, "\"rates\"").map_err(&fail)?;
        if rates.len() != p {
            return Err(fail(format!("\"rates\" needs {p} entries")));
        }
        if rates.iter().any(|&r| !r.is_finite() || r <= 0.0) {
            return Err(fail("\"rates\" entries must be finite and > 0".into()));
        }
        // An all-healthy vector is the same request as no vector.
        if rates.iter().any(|&r| r != 1.0) {
            req.rates = rates;
        }
    }
    if let Some(b) = v.get("budget_s").and_then(Json::as_f64) {
        if !b.is_finite() || b <= 0.0 {
            return Err(fail("\"budget_s\" must be finite and > 0".into()));
        }
        req.budget_s = Some(b);
    }
    if let Some(d) = v.get("deadline_s").and_then(Json::as_f64) {
        if !d.is_finite() || d < 0.0 {
            return Err(fail("\"deadline_s\" must be finite and ≥ 0".into()));
        }
        req.deadline_s = Some(d);
    }
    if let Some(iters) = v.get("iters").and_then(Json::as_usize) {
        if iters > MAX_ITERS {
            return Err(fail(format!("\"iters\" must be ≤ {MAX_ITERS}")));
        }
        req.max_iters = iters;
    }
    if let Some(bs) = v.get("block_search") {
        req.block_search = bs
            .as_bool()
            .ok_or_else(|| fail("\"block_search\" must be a boolean".into()))?;
    }
    if let Some(k) = v.get("block_stash") {
        let k = k
            .as_usize()
            .filter(|&k| k >= 1 && k <= MAX_NMB)
            .ok_or_else(|| fail(format!("\"block_stash\" must be in 1..={MAX_NMB}")))?;
        req.block_stash = Some(k as u32);
    }
    if let Some(scales) = v.get("cost_scale") {
        let entries =
            scales.as_arr().ok_or_else(|| fail("\"cost_scale\" must be an array".into()))?;
        for e in entries {
            let layer = e
                .get("layer")
                .and_then(Json::as_usize)
                .ok_or_else(|| fail("cost_scale entry needs \"layer\"".into()))?;
            if layer >= req.profile.n_layers() {
                return Err(fail(format!("cost_scale layer {layer} out of range")));
            }
            let l = &mut req.profile.layers[layer];
            for (key, slot) in [
                ("f", &mut l.f),
                ("b", &mut l.b),
                ("w", &mut l.w),
                ("comm_bytes", &mut l.comm_bytes),
            ] {
                if let Some(factor) = e.get(key).and_then(Json::as_f64) {
                    if !factor.is_finite() || factor <= 0.0 {
                        return Err(fail(format!(
                            "cost_scale \"{key}\" must be finite and > 0"
                        )));
                    }
                    *slot *= factor;
                }
            }
        }
        req.profile.rebuild_table();
    }
    Ok((id, req))
}

/// One successful response line (no trailing newline).
pub fn response_line(id: &str, resp: &PlanResponse) -> String {
    let out = &resp.outcome;
    obj(vec![
        ("id", s(id)),
        ("ok", Json::Bool(true)),
        ("provenance", s(resp.provenance.name())),
        ("fingerprint", s(&format!("{:016x}", out.fingerprint))),
        ("makespan_s", num(out.makespan)),
        ("headroom_bytes", num(out.headroom)),
        ("bubble_ratio", num(out.bubble_ratio)),
        (
            "near_miss_distance",
            out.near_miss_distance.map_or(Json::Null, num),
        ),
        (
            "partition",
            arr(out.pipeline.partition.bounds.iter().map(|&b| num(b as f64)).collect()),
        ),
        (
            "placement",
            arr(out.pipeline.placement.device_of.iter().map(|&d| num(d as f64)).collect()),
        ),
        (
            "knobs",
            obj(vec![
                ("split_bw", Json::Bool(out.knobs.split_bw)),
                ("w_fill", Json::Bool(out.knobs.w_fill)),
                ("mem_cap_factor", num(out.knobs.mem_cap_factor)),
                ("overlap_aware", Json::Bool(out.knobs.overlap_aware)),
            ]),
        ),
        ("evals", num(out.evals as f64)),
        ("iters", num(out.iters as f64)),
        ("budget_exhausted", Json::Bool(out.budget_exhausted)),
        ("deadline_hit", Json::Bool(out.deadline_hit)),
        ("search_s", num(out.search_s)),
    ])
    .to_string_compact()
}

/// One admission-control rejection line.
pub fn rejected_line(id: &str, rej: &Rejected) -> String {
    obj(vec![
        ("id", s(id)),
        ("ok", Json::Bool(false)),
        ("error", s("overloaded")),
        ("retry_after_s", num(rej.retry_after_s)),
        ("queue_len", num(rej.queue_len as f64)),
    ])
    .to_string_compact()
}

/// One structured-failure line ([`ServiceError`] taxonomy).
pub fn failure_line(id: &str, err: &ServiceError) -> String {
    match err {
        ServiceError::Overloaded(rej) => rejected_line(id, rej),
        ServiceError::WorkerLost(detail) => obj(vec![
            ("id", s(id)),
            ("ok", Json::Bool(false)),
            ("error", s("worker_lost")),
            ("detail", s(detail)),
        ])
        .to_string_compact(),
        ServiceError::SearchPanicked(detail) => obj(vec![
            ("id", s(id)),
            ("ok", Json::Bool(false)),
            ("error", s("search_panicked")),
            ("detail", s(detail)),
        ])
        .to_string_compact(),
        ServiceError::Shutdown => obj(vec![
            ("id", s(id)),
            ("ok", Json::Bool(false)),
            ("error", s("shutdown")),
        ])
        .to_string_compact(),
    }
}

/// One malformed-request line.
pub fn error_line(err: &ParseErr) -> String {
    obj(vec![
        ("id", s(&err.id)),
        ("ok", Json::Bool(false)),
        ("error", s(&err.msg)),
    ])
    .to_string_compact()
}

/// Poison-tolerant lock (same argument as `service::lock`: the guarded
/// sections are short, straight-line writes).
fn plock<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Run the request/response loop until `input` is exhausted or
/// `shutdown` flips, then finish in-flight work, write its responses,
/// drain the service, and flush + fsync the journal.  Responses are
/// written by a dedicated thread as searches complete (out of order
/// under concurrency — correlate by `id`); rejections and parse errors
/// are written inline.  Input is read on its own thread so a shutdown
/// signal interrupts the loop even while a read blocks; invalid UTF-8
/// is replaced lossily and then rejected as a parse error rather than
/// killing the stream.  Generic over the streams so tests can drive it
/// without a process boundary.
pub fn serve<R, W>(
    service: &Service,
    input: R,
    output: &Arc<Mutex<W>>,
    shutdown: Option<&AtomicBool>,
) -> std::io::Result<()>
where
    R: BufRead + Send + 'static,
    W: Write + Send + 'static,
{
    let (tx, rx) = channel::<(u64, Result<PlanResponse, ServiceError>)>();
    let ids: Arc<Mutex<HashMap<u64, String>>> = Arc::new(Mutex::new(HashMap::new()));
    let writer = {
        let out = Arc::clone(output);
        let ids = Arc::clone(&ids);
        std::thread::spawn(move || {
            for (tag, resp) in rx {
                let id = plock(&ids).remove(&tag).unwrap_or_default();
                let line = match &resp {
                    Ok(resp) => response_line(&id, resp),
                    Err(err) => failure_line(&id, err),
                };
                let mut w = plock(&out);
                let _ = writeln!(w, "{line}");
                let _ = w.flush();
            }
        })
    };
    // Reader thread: `read_until` keeps raw bytes (no UTF-8 gate on
    // the transport), and decoupling it from the admission loop lets a
    // SIGTERM take effect while a read blocks.  The handle is dropped
    // (detached) on the signal path for the same reason.
    let (line_tx, line_rx) = channel::<std::io::Result<Vec<u8>>>();
    let _reader = std::thread::spawn(move || {
        let mut input = input;
        loop {
            let mut raw = Vec::new();
            match input.read_until(b'\n', &mut raw) {
                Ok(0) => break,
                Ok(_) => {
                    if line_tx.send(Ok(raw)).is_err() {
                        break;
                    }
                }
                Err(e) => {
                    let _ = line_tx.send(Err(e));
                    break;
                }
            }
        }
    });
    let mut tag = 0u64;
    let mut io_err: Option<std::io::Error> = None;
    loop {
        if shutdown.is_some_and(|f| f.load(Ordering::SeqCst)) {
            break;
        }
        let raw = match line_rx.recv_timeout(Duration::from_millis(25)) {
            Ok(Ok(raw)) => raw,
            Ok(Err(e)) => {
                io_err = Some(e);
                break;
            }
            Err(RecvTimeoutError::Timeout) => continue,
            Err(RecvTimeoutError::Disconnected) => break, // EOF
        };
        let line = String::from_utf8_lossy(&raw);
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        match parse_request(line) {
            Err(e) => {
                let mut w = plock(output);
                writeln!(w, "{}", error_line(&e))?;
                w.flush()?;
            }
            Ok((id, req)) => {
                tag += 1;
                plock(&ids).insert(tag, id.clone());
                if let Err(rej) = service.submit_tagged(req, tag, tx.clone()) {
                    plock(&ids).remove(&tag);
                    let mut w = plock(output);
                    writeln!(w, "{}", rejected_line(&id, &rej))?;
                    w.flush()?;
                }
            }
        }
    }
    // Graceful drain: no new admissions past this point.  In-flight
    // waiters hold sender clones; once the last response is fanned out
    // the channel closes and the writer drains and exits — so joining
    // it *is* waiting for in-flight work.
    drop(tx);
    let _ = writer.join();
    service.drain();
    service.flush_journal();
    match io_err {
        Some(e) => Err(e),
        None => Ok(()),
    }
}
