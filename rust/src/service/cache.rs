//! The cross-request plan cache (DESIGN.md §9).
//!
//! [`PlanCache`] maps exact request identities ([`ReqKey`]) to
//! completed search outcomes, with the same bounded-FIFO discipline as
//! the candidate-level `EvalCache` (insertion-order eviction, never
//! hash-map iteration order, so a replayed request stream evicts —
//! and therefore hits — identically every run).
//!
//! On an exact miss, [`PlanCache::nearest`] scans entries in insertion
//! order for the structurally-compatible outcome with the smallest
//! [`near_miss_distance`], tie-broken toward the *oldest* entry —
//! both rules exist for replay determinism, not quality.  A hit under
//! the caller's drift bound warm-starts the new search from the
//! cached plan; it never short-circuits it.

use std::collections::{HashMap, VecDeque};
use std::sync::Arc;

use super::fingerprint::{near_miss_distance, ReqKey, Sketch};
use super::PlanOutcome;

/// Lifetime traffic counters for one [`PlanCache`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PlanCacheStats {
    /// Exact-key hits (request answered without any search).
    pub hits: u64,
    /// Near-miss hits (search ran, warm-started).
    pub near_hits: u64,
    /// Exact-key misses.
    pub misses: u64,
    pub inserts: u64,
    pub evictions: u64,
}

/// Bounded exact-plus-nearest plan store; see module docs.
pub struct PlanCache {
    map: HashMap<ReqKey, Arc<PlanOutcome>>,
    /// Insertion-order queue: FIFO eviction *and* the deterministic
    /// scan order for `nearest`.
    queue: VecDeque<ReqKey>,
    capacity: usize,
    stats: PlanCacheStats,
}

impl PlanCache {
    pub fn new(capacity: usize) -> PlanCache {
        assert!(capacity >= 1);
        PlanCache {
            map: HashMap::new(),
            queue: VecDeque::new(),
            capacity,
            stats: PlanCacheStats::default(),
        }
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn stats(&self) -> PlanCacheStats {
        self.stats
    }

    /// Exact lookup (counted).
    pub fn get(&mut self, key: &ReqKey) -> Option<Arc<PlanOutcome>> {
        match self.map.get(key) {
            Some(out) => {
                self.stats.hits += 1;
                Some(Arc::clone(out))
            }
            None => {
                self.stats.misses += 1;
                None
            }
        }
    }

    /// Nearest structurally-compatible outcome within `max_drift`
    /// (counted as a near-hit when found).  Insertion-order scan with
    /// strict-less selection ⇒ oldest entry wins ties, so a replayed
    /// stream warm-starts from the same donor every run.
    pub fn nearest(
        &mut self,
        sketch: &Sketch,
        max_drift: f64,
    ) -> Option<(Arc<PlanOutcome>, f64)> {
        let mut best: Option<(&ReqKey, f64)> = None;
        for key in &self.queue {
            let Some(out) = self.map.get(key) else { continue };
            let Some(d) = near_miss_distance(sketch, &out.sketch) else { continue };
            if d <= max_drift && best.is_none_or(|(_, bd)| d < bd) {
                best = Some((key, d));
            }
        }
        let (key, d) = best?;
        let out = Arc::clone(&self.map[key]);
        self.stats.near_hits += 1;
        Some((out, d))
    }

    /// Insert a completed search outcome.  Re-inserting an existing
    /// key keeps the original entry (deterministic searches can only
    /// re-derive the same outcome) and does not evict.
    pub fn insert(&mut self, key: ReqKey, outcome: Arc<PlanOutcome>) {
        if self.map.contains_key(&key) {
            return;
        }
        while self.map.len() >= self.capacity {
            let oldest = self.queue.pop_front().expect("queue tracks every entry");
            self.map.remove(&oldest);
            self.stats.evictions += 1;
        }
        self.queue.push_back(key.clone());
        self.map.insert(key, outcome);
        self.stats.inserts += 1;
    }
}
