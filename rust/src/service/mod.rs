//! Planner-as-a-service (DESIGN.md §9).
//!
//! PRs 1–6 made one search fast; this subsystem makes *many* searches
//! a long-running daemon.  A request is the full search input —
//! `(layer kinds, profiled costs, ClusterSpec, nmb, rates, budget)` —
//! and a response is `(plan, predicted makespan, headroom,
//! provenance)`.  Five pieces:
//!
//! - **[`cache::PlanCache`]** — a bounded cross-request plan store.
//!   Exact hits ([`fingerprint::ReqKey`]) answer without any search;
//!   near-miss hits ([`fingerprint::near_miss_distance`] within
//!   [`ServiceCfg::near_miss_max_drift`]) warm-start the search from
//!   the cached plan via [`GenOptions::incumbent`].  Warm starts only
//!   *seed* the incumbent — every candidate still goes through the
//!   Evaluator's acceptance gates — so reuse can save time, never
//!   change correctness.
//! - **shared [`EvalPool`]** — one process-wide worker pool
//!   multiplexes every concurrent search's move batches with fair
//!   round-robin interleaving (`generator/pool.rs`).
//! - **admission control + coalescing** — a bounded request queue
//!   rejects with a retry-after estimate when full; a request
//!   identical to one already in flight attaches to that search and
//!   the result fans out to every waiter.
//! - **fault tolerance** (DESIGN.md §9, "Fault tolerance") — requests
//!   carry deadlines ([`PlanRequest::deadline_s`]) enforced by a
//!   [`CancelToken`] at the generator's exact budget-check boundaries
//!   (bitwise-identical prefix; best-so-far result); a deadline that
//!   expires before any candidate is accepted returns a deterministic
//!   fallback plan tagged [`Provenance::Degraded`], never an error; a
//!   dead evaluation worker fails exactly one request with
//!   [`ServiceError::WorkerLost`] while the pool respawns the thread;
//!   every mutex-poison path recovers; and an optional [`journal`]
//!   makes cache commits crash-safe.
//! - **front ends** — the in-process [`Service`] API (used by
//!   `benches/service.rs`) and the newline-delimited-JSON loop in
//!   [`ndjson`] behind `adaptis serve`.
//!
//! **Determinism.**  Searches are pure functions of their requests
//! (scores merge positionally whatever the pool does), and every
//! cache/coalesce/provenance decision happens at *submission* time
//! under one lock — never at completion time — so a scripted stream
//! submitted in waves ([`Service::hold`] / [`Service::release`] /
//! [`Service::drain`]) replays bitwise: same plans, same provenance
//! counters, run after run.  Each search gets a fresh per-search
//! `EvalCache` (an exact repeat would have hit the plan cache
//! instead), keeping even eval counts replayable.  Degraded and
//! deadline-cut outcomes are never cached or journaled — what a
//! deadline truncates depends on wall clock, so keeping it out of the
//! cache keeps the *cache* a pure function of the request stream.

pub mod cache;
pub mod fingerprint;
pub mod journal;
pub mod ndjson;

use std::collections::{HashMap, VecDeque};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::Path;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, PoisonError};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::baselines::Pipeline;
use crate::config::{Family, HardwareCfg, ModelCfg, ParallelCfg, Size};
use crate::cluster::ClusterSpec;
use crate::generator::cache::EvalCache;
use crate::generator::pool::{EvalAborted, EvalPool};
use crate::generator::{generate_with_cache, CancelToken, GenOptions, Incumbent};
use crate::model::{build_model, LayerKind};
use crate::partition::uniform;
use crate::perfmodel::{simulate_in, SimArena, StageTable};
use crate::placement::sequential;
use crate::profile::ProfiledData;
use crate::schedule::greedy::{greedy_schedule_in, SchedKnobs};

use cache::{PlanCache, PlanCacheStats};
use fingerprint::{ReqKey, Sketch};
use journal::Journal;

/// One plan request: everything a cold search reads.
#[derive(Clone, Debug)]
pub struct PlanRequest {
    /// Layer-kind sequence (the model's structural fingerprint; the
    /// near-miss metric only ever matches identical sequences).
    pub kinds: Vec<LayerKind>,
    /// Per-layer costs + link parameters.
    pub profile: ProfiledData,
    /// Devices and their memory capacities.
    pub cluster: ClusterSpec,
    /// Micro-batches per step.
    pub nmb: usize,
    /// Per-device rate multipliers (empty = healthy cluster).
    pub rates: Vec<f64>,
    /// Wall-clock search budget; `None` falls back to
    /// [`ServiceCfg::default_budget_s`].
    pub budget_s: Option<f64>,
    /// Tuning-iteration cap (the generator default is 64).
    pub max_iters: usize,
    /// Response deadline in seconds from submission; `None` falls back
    /// to [`ServiceCfg::default_deadline_s`].  The absolute instant is
    /// fixed at submission (coalesced waiters share the first
    /// submission's deadline).  When it passes mid-search the best
    /// plan so far comes back with [`PlanOutcome::deadline_hit`] set;
    /// when it passes before any candidate was accepted, the
    /// deterministic fallback plan comes back as
    /// [`Provenance::Degraded`] — a deadline is never an error.
    pub deadline_s: Option<f64>,
    /// Enable the Generator's block-synthesis knob
    /// ([`GenOptions::block_search`]); off by default — an off request
    /// searches exactly as before the knob existed.
    pub block_search: bool,
    /// Stash-budget hint for block moves
    /// ([`GenOptions::block_stash`]).
    pub block_stash: Option<u32>,
}

impl PlanRequest {
    pub fn new(
        kinds: Vec<LayerKind>,
        profile: ProfiledData,
        cluster: ClusterSpec,
        nmb: usize,
    ) -> PlanRequest {
        assert_eq!(kinds.len(), profile.n_layers(), "one kind per profiled layer");
        assert!(nmb >= 1);
        PlanRequest {
            kinds,
            profile,
            cluster,
            nmb,
            rates: Vec::new(),
            budget_s: None,
            max_iters: 64,
            deadline_s: None,
            block_search: false,
            block_stash: None,
        }
    }

    /// Convenience: an analytically-profiled Table-5 model on a
    /// homogeneous cluster of `par.p` devices.
    pub fn table5(family: Family, size: Size, par: &ParallelCfg) -> PlanRequest {
        let hw = HardwareCfg::default();
        let spec = build_model(&ModelCfg::table5(family, size));
        let profile = ProfiledData::analytical(&spec, &hw, par);
        let cluster = ClusterSpec::uniform(par.p, &hw);
        PlanRequest::new(spec.layers, profile, cluster, par.nmb)
    }

    /// Exact identity (cache key, coalescing key).
    pub fn key(&self) -> ReqKey {
        ReqKey::of(self)
    }

    /// Geometry for near-miss matching.
    pub fn sketch(&self) -> Sketch {
        Sketch::of(self)
    }
}

/// How a response was produced, per *requester*.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Provenance {
    /// A search ran from the seed grid.
    Cold,
    /// A search ran, warm-started from a near-miss cached plan.
    Warm,
    /// Served from the plan cache; no search ran.
    Cached,
    /// Attached to an identical in-flight request's search.
    Coalesced,
    /// The deadline expired with zero accepted candidates: the
    /// deterministic heuristic fallback (uniform partition, sequential
    /// placement, 1F1B knobs), not a searched plan.  Never cached.
    Degraded,
}

impl Provenance {
    pub fn name(&self) -> &'static str {
        match self {
            Provenance::Cold => "cold",
            Provenance::Warm => "warm",
            Provenance::Cached => "cached",
            Provenance::Coalesced => "coalesced",
            Provenance::Degraded => "degraded",
        }
    }
}

/// A completed search, shared by every waiter and the plan cache.
#[derive(Clone, Debug)]
pub struct PlanOutcome {
    pub pipeline: Pipeline,
    pub knobs: SchedKnobs,
    /// Predicted per-step makespan (seconds).
    pub makespan: f64,
    /// Worst per-device memory headroom (bytes; negative = OOM).
    pub headroom: f64,
    pub bubble_ratio: f64,
    /// [`Provenance::Cold`], [`Provenance::Warm`] or
    /// [`Provenance::Degraded`] — how the *plan* was produced (waiters
    /// may still see `Cached`/`Coalesced`).
    pub searched: Provenance,
    /// Drift to the warm-start donor (`None` for cold searches).
    pub near_miss_distance: Option<f64>,
    pub evals: usize,
    pub iters: usize,
    pub budget_exhausted: bool,
    /// True iff the request's deadline cut the tuning loop short (the
    /// plan is the best found so far) or forced the degraded fallback.
    /// Such outcomes are never cached or journaled.
    pub deadline_hit: bool,
    /// Generator wall time (seconds).
    pub search_s: f64,
    /// Request digest, echoed on the wire.
    pub fingerprint: u64,
    /// The request geometry — future requests match against this.
    pub sketch: Sketch,
}

impl PlanOutcome {
    /// Package this plan as a warm-start seed.
    pub fn incumbent(&self) -> Incumbent {
        Incumbent {
            partition: self.pipeline.partition.clone(),
            placement: self.pipeline.placement.clone(),
            knobs: self.knobs,
        }
    }
}

/// What a waiter receives: the shared outcome plus this requester's
/// own provenance.
#[derive(Clone, Debug)]
pub struct PlanResponse {
    pub outcome: Arc<PlanOutcome>,
    pub provenance: Provenance,
}

/// Admission-control rejection: the request queue is full.
#[derive(Clone, Copy, Debug)]
pub struct Rejected {
    pub queue_len: usize,
    /// Estimated seconds until a slot frees up (mean recent search
    /// time × backlog / workers).
    pub retry_after_s: f64,
}

/// Structured failure taxonomy for [`Ticket::wait`] /
/// [`Service::call`].  Deadlines are deliberately *not* here — an
/// expired deadline returns a degraded or best-so-far plan, never an
/// error (see [`Provenance::Degraded`]).
#[derive(Clone, Debug)]
pub enum ServiceError {
    /// Admission control turned the request away; retry later.
    Overloaded(Rejected),
    /// An evaluation worker thread died mid-search.  The pool
    /// respawned the worker; only this request failed, and an
    /// immediate resubmission will run on the restored pool.
    WorkerLost(String),
    /// The search itself panicked (a planner bug); contained to this
    /// request, with the payload's message preserved.
    SearchPanicked(String),
    /// The service was dropped with this request still pending.
    Shutdown,
}

impl std::fmt::Display for ServiceError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServiceError::Overloaded(r) => write!(
                f,
                "overloaded: queue_len {} retry_after_s {:.3}",
                r.queue_len, r.retry_after_s
            ),
            ServiceError::WorkerLost(m) => write!(f, "evaluation worker lost: {m}"),
            ServiceError::SearchPanicked(m) => write!(f, "search panicked: {m}"),
            ServiceError::Shutdown => {
                write!(f, "service shut down with the request pending")
            }
        }
    }
}

impl std::error::Error for ServiceError {}

/// Disconnect detector for an abandoned [`Ticket`]: dropping the
/// ticket without waiting decrements its flight's live-waiter count
/// and, at zero, fires the flight's [`CancelToken`] — a search nobody
/// is waiting for stops at the next phase boundary (or is skipped
/// entirely if still queued).  The epoch check makes a stale guard
/// (same key, later flight) a no-op.
struct AbandonGuard {
    inner: Arc<Inner>,
    key: ReqKey,
    epoch: u64,
    armed: bool,
}

impl Drop for AbandonGuard {
    fn drop(&mut self) {
        if !self.armed {
            return;
        }
        let mut st = lock(&self.inner.m);
        if let Some(fl) = st.inflight.get_mut(&self.key) {
            if fl.epoch == self.epoch {
                fl.live = fl.live.saturating_sub(1);
                if fl.live == 0 {
                    fl.cancel.cancel();
                }
            }
        }
    }
}

/// Claim on an admitted request; [`Ticket::wait`] blocks for the
/// response.  Dropping the ticket unwaited counts as a client
/// disconnect and cooperatively cancels the search once *every*
/// waiter for it is gone.
pub struct Ticket {
    rx: Receiver<Result<PlanResponse, ServiceError>>,
    /// `None` when the response was already delivered at submission
    /// (cache hit) — nothing in flight to abandon.
    guard: Option<AbandonGuard>,
}

impl Ticket {
    /// Block until the response arrives (or the request fails with a
    /// structured [`ServiceError`] — never a panic, never a hang: a
    /// dead worker fails the request, and service drop fails pending
    /// tickets with [`ServiceError::Shutdown`]).
    pub fn wait(mut self) -> Result<PlanResponse, ServiceError> {
        let resp = self.rx.recv().unwrap_or(Err(ServiceError::Shutdown));
        if let Some(g) = self.guard.as_mut() {
            g.armed = false;
        }
        resp
    }
}

/// Service configuration.
#[derive(Clone, Copy, Debug)]
pub struct ServiceCfg {
    /// Concurrent searches (each drives the shared pool via its own
    /// client).
    pub search_workers: usize,
    /// Evaluation threads in the shared [`EvalPool`].
    pub pool_threads: usize,
    /// Admission bound: queued-but-unstarted requests beyond this are
    /// rejected.  Coalesced attaches and cache hits never occupy a
    /// slot.
    pub queue_capacity: usize,
    /// Plan-cache entries ([`cache::PlanCache`] FIFO bound).
    pub cache_capacity: usize,
    /// Near-miss warm-start threshold (worst-component relative
    /// drift); `0.0` disables warm starts entirely.
    pub near_miss_max_drift: f64,
    /// Search budget for requests that don't carry their own.
    pub default_budget_s: Option<f64>,
    /// Deadline for requests that don't carry their own `deadline_s`
    /// (see [`PlanRequest::deadline_s`]); `None` = no deadline.
    pub default_deadline_s: Option<f64>,
    /// Start with dequeueing held (see [`Service::hold`]) — lets a
    /// deterministic harness script its first wave before any search
    /// starts.
    pub hold: bool,
}

impl Default for ServiceCfg {
    fn default() -> ServiceCfg {
        ServiceCfg {
            search_workers: 2,
            pool_threads: std::thread::available_parallelism()
                .map(|v| v.get())
                .unwrap_or(1),
            queue_capacity: 64,
            cache_capacity: 256,
            near_miss_max_drift: 0.25,
            default_budget_s: None,
            default_deadline_s: None,
            hold: false,
        }
    }
}

/// Lifetime request counters (all monotone).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ServiceStats {
    /// Every submission, including rejected ones.
    pub requests: u64,
    /// Admitted as a fresh cold search.
    pub cold: u64,
    /// Admitted as a warm-started search.
    pub warm: u64,
    /// Answered from the plan cache without a search.
    pub cached: u64,
    /// Attached to an identical in-flight search.
    pub coalesced: u64,
    /// Turned away by admission control.
    pub rejected: u64,
    /// Searches completed (excludes degraded fallbacks, which run no
    /// search).
    pub searches: u64,
    /// Requests answered with the deterministic degraded fallback.
    pub degraded: u64,
    /// Requests whose deadline fired (degraded fallbacks *and*
    /// best-so-far cuts).
    pub deadline_hits: u64,
    /// Requests failed with a structured [`ServiceError`]
    /// (worker lost / search panicked).
    pub failed: u64,
    /// Requests discarded because every waiter disconnected before the
    /// response was ready.
    pub abandoned: u64,
    /// Plans replayed from the journal at startup.
    pub journal_recovered: u64,
    /// Torn/corrupt journal tail records dropped at startup.
    pub journal_torn: u64,
    /// Journal append/sync IO failures (the service keeps running;
    /// durability of the affected commits is lost).
    pub journal_errors: u64,
}

enum WaiterTx {
    Plain(Sender<Result<PlanResponse, ServiceError>>),
    /// `(tag, shared channel)` — the NDJSON loop multiplexes every
    /// response onto one channel.
    Tagged(u64, Sender<(u64, Result<PlanResponse, ServiceError>)>),
}

impl WaiterTx {
    fn send(self, resp: Result<PlanResponse, ServiceError>) {
        // A vanished waiter (dropped ticket / closed connection) is
        // not the service's problem.
        match self {
            WaiterTx::Plain(tx) => drop(tx.send(resp)),
            WaiterTx::Tagged(tag, tx) => drop(tx.send((tag, resp))),
        }
    }
}

struct Waiter {
    tx: WaiterTx,
    provenance: Provenance,
}

/// One admitted request's waiters plus its cancellation state.
struct Flight {
    waiters: Vec<Waiter>,
    /// Shared with the queued job; fires on deadline expiry or when
    /// `live` reaches zero.
    cancel: CancelToken,
    /// Waiters that can still abandon (plain tickets; tagged NDJSON
    /// waiters are torn down by `serve`'s drain instead).
    live: usize,
    /// Guards against a stale [`AbandonGuard`] touching a *later*
    /// flight for the same key.
    epoch: u64,
}

struct QueuedReq {
    key: ReqKey,
    req: PlanRequest,
    /// Warm-start seed + its near-miss distance (decided at
    /// submission, under the lock — see module docs).
    warm: Option<(Incumbent, f64)>,
    /// The flight's token (deadline fixed at submission).
    cancel: CancelToken,
}

struct State {
    queue: VecDeque<QueuedReq>,
    /// Key → flight of the search that will serve it.  An entry exists
    /// from admission to completion; identical submissions attach
    /// here.
    inflight: HashMap<ReqKey, Flight>,
    cache: PlanCache,
    stats: ServiceStats,
    /// Crash-safe commit log mirroring `cache` inserts (optional).
    journal: Option<Journal>,
    next_epoch: u64,
    held: bool,
    shutdown: bool,
    /// Searches currently running on workers.
    active: usize,
    /// Recent search wall times (seconds) for retry-after estimates.
    recent_s: VecDeque<f64>,
}

struct Inner {
    cfg: ServiceCfg,
    m: Mutex<State>,
    /// Work available / released / shutdown.
    work_cv: Condvar,
    /// A search completed (drain listens here).
    idle_cv: Condvar,
}

/// Poison-tolerant state lock: every critical section is a short,
/// straight-line queue/map edit that cannot be observed half-done, so
/// a thread that panics while holding the lock leaves `State`
/// consistent — poisoning downgrades to "take the data as is" instead
/// of wedging every subsequent request.
fn lock(m: &Mutex<State>) -> MutexGuard<'_, State> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// The long-running planner daemon; see module docs.
pub struct Service {
    inner: Arc<Inner>,
    pool: Arc<EvalPool>,
    workers: Vec<JoinHandle<()>>,
}

impl Service {
    pub fn new(cfg: ServiceCfg) -> Service {
        Service::build(cfg, None).expect("journal-less construction does no IO")
    }

    /// [`Service::new`] plus a crash-safe plan journal at `path`:
    /// committed records are replayed into the plan cache (in commit
    /// order, so contents *and* FIFO/eviction state are bitwise-equal
    /// to the pre-crash committed state), torn or corrupt tail records
    /// are dropped and counted ([`ServiceStats::journal_torn`]), and
    /// every future cache commit is appended before the response fans
    /// out.
    pub fn with_journal(cfg: ServiceCfg, path: &Path) -> std::io::Result<Service> {
        Service::build(cfg, Some(path))
    }

    fn build(cfg: ServiceCfg, journal_path: Option<&Path>) -> std::io::Result<Service> {
        assert!(cfg.search_workers >= 1);
        assert!(cfg.queue_capacity >= 1);
        assert!(cfg.near_miss_max_drift >= 0.0);
        let mut cache = PlanCache::new(cfg.cache_capacity);
        let mut stats = ServiceStats::default();
        let journal = match journal_path {
            Some(path) => {
                let (journal, entries, replay) = Journal::open(path)?;
                for (key, outcome) in entries {
                    cache.insert(key, Arc::new(outcome));
                }
                stats.journal_recovered = replay.recovered as u64;
                stats.journal_torn = replay.torn as u64;
                Some(journal)
            }
            None => None,
        };
        let pool = Arc::new(EvalPool::new(cfg.pool_threads.max(1)));
        let inner = Arc::new(Inner {
            cfg,
            m: Mutex::new(State {
                queue: VecDeque::new(),
                inflight: HashMap::new(),
                cache,
                stats,
                journal,
                next_epoch: 0,
                held: cfg.hold,
                shutdown: false,
                active: 0,
                recent_s: VecDeque::new(),
            }),
            work_cv: Condvar::new(),
            idle_cv: Condvar::new(),
        });
        let workers = (0..cfg.search_workers)
            .map(|_| {
                let inner = Arc::clone(&inner);
                let pool = Arc::clone(&pool);
                std::thread::spawn(move || worker(&inner, &pool))
            })
            .collect();
        Ok(Service { inner, pool, workers })
    }

    /// Submit a request; `Ok` is a claim on exactly one response.
    pub fn submit(&self, req: PlanRequest) -> Result<Ticket, Rejected> {
        let (tx, rx) = channel();
        let guard = self.enqueue(req, WaiterTx::Plain(tx))?.map(|(key, epoch)| {
            AbandonGuard { inner: Arc::clone(&self.inner), key, epoch, armed: true }
        });
        Ok(Ticket { rx, guard })
    }

    /// Submit with the response routed to a shared channel under
    /// `tag` — the NDJSON front end's many-requests-one-writer shape.
    /// Tagged waiters never abandon (the NDJSON loop drains instead).
    pub fn submit_tagged(
        &self,
        req: PlanRequest,
        tag: u64,
        tx: Sender<(u64, Result<PlanResponse, ServiceError>)>,
    ) -> Result<(), Rejected> {
        self.enqueue(req, WaiterTx::Tagged(tag, tx)).map(|_| ())
    }

    /// Submit and block for the response.
    pub fn call(&self, req: PlanRequest) -> Result<PlanResponse, ServiceError> {
        match self.submit(req) {
            Ok(ticket) => ticket.wait(),
            Err(rej) => Err(ServiceError::Overloaded(rej)),
        }
    }

    /// Returns the admitted request's `(key, epoch)` for disconnect
    /// tracking, or `None` when the response was already delivered
    /// from the cache.
    fn enqueue(
        &self,
        req: PlanRequest,
        tx: WaiterTx,
    ) -> Result<Option<(ReqKey, u64)>, Rejected> {
        assert_eq!(req.kinds.len(), req.profile.n_layers());
        assert!(req.nmb >= 1 && req.cluster.p() >= 1);
        assert!(
            req.rates.is_empty() || req.rates.len() == req.cluster.p(),
            "one rate per device"
        );
        let key = req.key();
        let mut guard = lock(&self.inner.m);
        let st = &mut *guard;
        st.stats.requests += 1;
        // Fast path: an identical request already completed.
        if let Some(out) = st.cache.get(&key) {
            st.stats.cached += 1;
            drop(guard);
            tx.send(Ok(PlanResponse { outcome: out, provenance: Provenance::Cached }));
            return Ok(None);
        }
        // Coalesce: an identical request is already being searched
        // (or queued) — attach, occupying no queue slot.  The flight
        // keeps its original deadline.
        if let Some(fl) = st.inflight.get_mut(&key) {
            st.stats.coalesced += 1;
            fl.waiters.push(Waiter { tx, provenance: Provenance::Coalesced });
            fl.live += 1;
            return Ok(Some((key, fl.epoch)));
        }
        // Admission control.
        if st.queue.len() >= self.inner.cfg.queue_capacity {
            st.stats.rejected += 1;
            return Err(Rejected {
                queue_len: st.queue.len(),
                retry_after_s: retry_after(st, &self.inner.cfg),
            });
        }
        // Near-miss probe — decided here, against the cache as of
        // submission, so provenance is a pure function of the stream.
        let warm = if self.inner.cfg.near_miss_max_drift > 0.0 {
            st.cache
                .nearest(&req.sketch(), self.inner.cfg.near_miss_max_drift)
                .map(|(out, d)| (out.incumbent(), d))
        } else {
            None
        };
        let provenance = if warm.is_some() {
            st.stats.warm += 1;
            Provenance::Warm
        } else {
            st.stats.cold += 1;
            Provenance::Cold
        };
        // Deadline → absolute instant, fixed now.  Non-finite or
        // negative values never panic the service: they just mean "no
        // deadline" / "already expired" respectively; huge values are
        // clamped below `Duration::from_secs_f64`'s overflow.
        let deadline_s = req.deadline_s.or(self.inner.cfg.default_deadline_s);
        let cancel = match deadline_s {
            Some(d) if d.is_finite() && d >= 0.0 => CancelToken::with_deadline(
                Instant::now() + Duration::from_secs_f64(d.min(1e9)),
            ),
            _ => CancelToken::new(),
        };
        let epoch = st.next_epoch;
        st.next_epoch += 1;
        st.inflight.insert(
            key.clone(),
            Flight {
                waiters: vec![Waiter { tx, provenance }],
                cancel: cancel.clone(),
                live: 1,
                epoch,
            },
        );
        st.queue.push_back(QueuedReq { key: key.clone(), req, warm, cancel });
        drop(guard);
        self.inner.work_cv.notify_one();
        Ok(Some((key, epoch)))
    }

    /// Pause dequeueing: admitted requests queue up but no new search
    /// starts.  With [`Service::release`] this makes wave-structured
    /// streams fully deterministic (every submission in a wave sees
    /// the same cache/in-flight state on every replay).
    pub fn hold(&self) {
        lock(&self.inner.m).held = true;
    }

    /// Resume dequeueing.
    pub fn release(&self) {
        lock(&self.inner.m).held = false;
        self.inner.work_cv.notify_all();
    }

    /// Block until no request is queued or in flight.  Call
    /// [`Service::release`] first — draining a held queue would wait
    /// forever, so that is a panic, not a hang.
    pub fn drain(&self) {
        let mut st = lock(&self.inner.m);
        while !(st.queue.is_empty() && st.inflight.is_empty()) {
            assert!(
                !(st.held && !st.queue.is_empty()),
                "drain() on a held service with queued work"
            );
            st = self
                .inner
                .idle_cv
                .wait(st)
                .unwrap_or_else(PoisonError::into_inner);
        }
    }

    /// Flush + fsync the journal; `true` on success (trivially so
    /// without a journal).  Failures are also counted in
    /// [`ServiceStats::journal_errors`].
    pub fn flush_journal(&self) -> bool {
        let mut st = lock(&self.inner.m);
        match st.journal.as_mut() {
            Some(j) => match j.sync() {
                Ok(()) => true,
                Err(_) => {
                    st.stats.journal_errors += 1;
                    false
                }
            },
            None => true,
        }
    }

    pub fn stats(&self) -> ServiceStats {
        lock(&self.inner.m).stats
    }

    pub fn plan_cache_stats(&self) -> PlanCacheStats {
        lock(&self.inner.m).cache.stats()
    }

    /// Entries currently in the plan cache (recovery accounting).
    pub fn plan_cache_len(&self) -> usize {
        lock(&self.inner.m).cache.len()
    }

    /// Evaluation threads backing every search.
    pub fn pool_threads(&self) -> usize {
        self.pool.threads()
    }

    /// Test hook: hard-abort the next `n` evaluation-worker dequeues
    /// (see `EvalPool::inject_worker_abort`).
    #[doc(hidden)]
    pub fn inject_eval_abort(&self, n: usize) {
        self.pool.inject_worker_abort(n);
    }

    /// Evaluation workers lost (and respawned) so far.
    pub fn eval_workers_lost(&self) -> u64 {
        self.pool.workers_lost()
    }
}

impl Drop for Service {
    fn drop(&mut self) {
        {
            let mut st = lock(&self.inner.m);
            st.shutdown = true;
        }
        self.inner.work_cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        // Never strand a pending `Ticket::wait`: fail whatever was
        // still queued or in flight, then make the journal durable.
        let mut st = lock(&self.inner.m);
        st.queue.clear();
        for (_, fl) in st.inflight.drain() {
            for w in fl.waiters {
                w.tx.send(Err(ServiceError::Shutdown));
            }
        }
        if let Some(j) = st.journal.as_mut() {
            let _ = j.sync();
        }
    }
}

/// Backlog-proportional retry estimate, floored so callers never busy
/// spin on a zero.
fn retry_after(st: &State, cfg: &ServiceCfg) -> f64 {
    let mean_s = if st.recent_s.is_empty() {
        0.05
    } else {
        st.recent_s.iter().sum::<f64>() / st.recent_s.len() as f64
    };
    let backlog = (st.queue.len() + st.active + 1) as f64;
    (mean_s * backlog / cfg.search_workers as f64).max(1e-3)
}

/// Map a caught search panic to the error taxonomy: the typed
/// [`EvalAborted`] payload (raised by the generator when a pooled
/// evaluation is lost) becomes [`ServiceError::WorkerLost`]; anything
/// else is a planner bug, surfaced with its message.
fn classify_panic(payload: &(dyn std::any::Any + Send)) -> ServiceError {
    if payload.downcast_ref::<EvalAborted>().is_some() {
        return ServiceError::WorkerLost(
            "pooled evaluation lost (worker thread died or the evaluation panicked)"
                .into(),
        );
    }
    let msg = payload
        .downcast_ref::<&'static str>()
        .map(|s| s.to_string())
        .or_else(|| payload.downcast_ref::<String>().cloned())
        .unwrap_or_else(|| "non-string panic payload".into());
    ServiceError::SearchPanicked(msg)
}

fn worker(inner: &Inner, pool: &Arc<EvalPool>) {
    loop {
        let job = {
            let mut st = lock(&inner.m);
            loop {
                if st.shutdown {
                    return;
                }
                if !st.held {
                    if let Some(job) = st.queue.pop_front() {
                        // Every waiter already disconnected: skip the
                        // search entirely.
                        if !st.inflight.get(&job.key).is_some_and(|fl| fl.live > 0) {
                            st.inflight.remove(&job.key);
                            st.stats.abandoned += 1;
                            inner.idle_cv.notify_all();
                            continue;
                        }
                        st.active += 1;
                        break job;
                    }
                }
                st = inner
                    .work_cv
                    .wait(st)
                    .unwrap_or_else(PoisonError::into_inner);
            }
        };
        let t0 = Instant::now();
        // Panic containment: the service worker thread itself never
        // dies.  A panicking search (dead eval worker, planner bug, a
        // degenerate request the fallback cannot schedule) fails
        // exactly this request with a structured error.
        let result: Result<Arc<PlanOutcome>, ServiceError> =
            catch_unwind(AssertUnwindSafe(|| {
                if job.cancel.deadline_expired() {
                    // Expired before any candidate could be accepted:
                    // deterministic fallback, never an error.
                    degraded_outcome(&job)
                } else {
                    run_search(&job, &inner.cfg, pool)
                }
            }))
            .map(Arc::new)
            .map_err(|p| classify_panic(p.as_ref()));
        let wall_s = t0.elapsed().as_secs_f64();
        {
            let mut st = lock(&inner.m);
            st.active -= 1;
            st.recent_s.push_back(wall_s);
            if st.recent_s.len() > 32 {
                st.recent_s.pop_front();
            }
            // Everything below happens under the same lock as the
            // cache insert, so a late identical submission either
            // attaches here or hits the cache — there is no window
            // where it would start a duplicate search.
            let fl = st.inflight.remove(&job.key).expect("admitted ⇒ in flight");
            if fl.live == 0 {
                // Abandoned mid-search: the (possibly cancel-cut)
                // outcome must not reach the cache, and there is
                // nobody to send it to.
                st.stats.abandoned += 1;
            } else {
                match &result {
                    Ok(out) => {
                        let degraded = out.searched == Provenance::Degraded;
                        st.stats.searches += u64::from(!degraded);
                        st.stats.degraded += u64::from(degraded);
                        st.stats.deadline_hits += u64::from(out.deadline_hit);
                        // Deadline-dependent outcomes are wall-clock
                        // functions, not request functions — caching
                        // them would make cache contents timing-
                        // dependent (journal commit mirrors cache).
                        if !out.deadline_hit {
                            st.cache.insert(job.key.clone(), Arc::clone(out));
                            if let Some(j) = st.journal.as_mut() {
                                if j.append(&job.key, out).is_err() {
                                    st.stats.journal_errors += 1;
                                }
                            }
                        }
                    }
                    Err(_) => st.stats.failed += 1,
                }
                for w in fl.waiters {
                    let resp = match &result {
                        Ok(out) => Ok(PlanResponse {
                            outcome: Arc::clone(out),
                            provenance: if out.searched == Provenance::Degraded {
                                Provenance::Degraded
                            } else {
                                w.provenance
                            },
                        }),
                        Err(e) => Err(e.clone()),
                    };
                    w.tx.send(resp);
                }
            }
        }
        inner.idle_cv.notify_all();
    }
}

/// One search, exactly as the batch CLI would run it — plus the
/// shared pool, the request's cancel token, and (for warm requests)
/// the near-miss incumbent seed.
fn run_search(job: &QueuedReq, cfg: &ServiceCfg, pool: &Arc<EvalPool>) -> PlanOutcome {
    let req = &job.req;
    let caps = req.cluster.mem_caps();
    let mut opts = GenOptions::new(caps.p(), req.nmb);
    opts.max_iters = req.max_iters;
    opts.mem_caps = Some(caps);
    if !req.rates.is_empty() {
        opts.rates = Some(req.rates.clone());
    }
    opts.time_budget_s = req.budget_s.or(cfg.default_budget_s);
    opts.block_search = req.block_search;
    opts.block_stash = req.block_stash;
    opts.shared_pool = Some(Arc::clone(pool));
    opts.cancel = Some(job.cancel.clone());
    if let Some((inc, _)) = &job.warm {
        // Seed only — no migration pricing: a plan request is for a
        // job that is not running yet, so nothing would migrate.
        opts.incumbent = Some(inc.clone());
    }
    // Fresh per-search EvalCache: cross-request memoization would only
    // ever help exact repeats, and those hit the plan cache instead.
    let mut ecache = EvalCache::new();
    let res = generate_with_cache(&req.profile, &opts, &mut ecache);
    PlanOutcome {
        makespan: res.report.total,
        headroom: res.report.min_headroom(),
        bubble_ratio: res.report.bubble_ratio(),
        knobs: res.knobs,
        pipeline: res.pipeline,
        searched: if job.warm.is_some() { Provenance::Warm } else { Provenance::Cold },
        near_miss_distance: job.warm.as_ref().map(|(_, d)| *d),
        evals: res.evals,
        iters: res.iters,
        budget_exhausted: res.budget_exhausted,
        // Explicitly-cancelled (abandoned) outcomes are discarded at
        // completion, so an observable `deadline_hit` always means the
        // deadline fired.
        deadline_hit: res.cancelled,
        search_s: res.elapsed_s,
        fingerprint: job.key.fingerprint(),
        sketch: req.sketch(),
    }
}

/// Deterministic heuristic fallback for a deadline that expired with
/// zero accepted candidates: uniform partition over sequential
/// devices, scheduled 1F1B-style (no B/W split, no W-fill, no overlap
/// awareness).  Pure arithmetic — no search, no wall-clock reads — so
/// every degraded response for a given request is bitwise identical.
fn degraded_outcome(job: &QueuedReq) -> PlanOutcome {
    let req = &job.req;
    let caps = req.cluster.mem_caps();
    let p = caps.p();
    let partition = uniform(req.profile.n_layers(), p);
    let placement = sequential(p);
    let knobs = SchedKnobs {
        split_bw: false,
        w_fill: false,
        mem_cap_factor: 1.0,
        overlap_aware: false,
    };
    let table = StageTable::build_rated(&req.profile, &partition, &placement, &req.rates);
    let mut arena = SimArena::new();
    let schedule = greedy_schedule_in(&mut arena, &table, &caps, req.nmb, knobs);
    let report = simulate_in(&mut arena, &table, &caps, &schedule, false)
        .expect("fallback pipeline must simulate");
    PlanOutcome {
        pipeline: Pipeline {
            name: "AdaPtis-fallback".into(),
            partition,
            placement,
            schedule,
        },
        knobs,
        makespan: report.total,
        headroom: report.min_headroom(),
        bubble_ratio: report.bubble_ratio(),
        searched: Provenance::Degraded,
        near_miss_distance: None,
        evals: 0,
        iters: 0,
        budget_exhausted: false,
        deadline_hit: true,
        search_s: 0.0,
        fingerprint: job.key.fingerprint(),
        sketch: req.sketch(),
    }
}
