//! Planner-as-a-service (DESIGN.md §8).
//!
//! PRs 1–6 made one search fast; this subsystem makes *many* searches
//! a long-running daemon.  A request is the full search input —
//! `(layer kinds, profiled costs, ClusterSpec, nmb, rates, budget)` —
//! and a response is `(plan, predicted makespan, headroom,
//! provenance)`.  Four pieces:
//!
//! - **[`cache::PlanCache`]** — a bounded cross-request plan store.
//!   Exact hits ([`fingerprint::ReqKey`]) answer without any search;
//!   near-miss hits ([`fingerprint::near_miss_distance`] within
//!   [`ServiceCfg::near_miss_max_drift`]) warm-start the search from
//!   the cached plan via [`GenOptions::incumbent`].  Warm starts only
//!   *seed* the incumbent — every candidate still goes through the
//!   Evaluator's acceptance gates — so reuse can save time, never
//!   change correctness.
//! - **shared [`EvalPool`]** — one process-wide worker pool
//!   multiplexes every concurrent search's move batches with fair
//!   round-robin interleaving (`generator/pool.rs`).
//! - **admission control + coalescing** — a bounded request queue
//!   rejects with a retry-after estimate when full; a request
//!   identical to one already in flight attaches to that search and
//!   the result fans out to every waiter.
//! - **front ends** — the in-process [`Service`] API (used by
//!   `benches/service.rs`) and the newline-delimited-JSON loop in
//!   [`ndjson`] behind `adaptis serve`.
//!
//! **Determinism.**  Searches are pure functions of their requests
//! (scores merge positionally whatever the pool does), and every
//! cache/coalesce/provenance decision happens at *submission* time
//! under one lock — never at completion time — so a scripted stream
//! submitted in waves ([`Service::hold`] / [`Service::release`] /
//! [`Service::drain`]) replays bitwise: same plans, same provenance
//! counters, run after run.  Each search gets a fresh per-search
//! `EvalCache` (an exact repeat would have hit the plan cache
//! instead), keeping even eval counts replayable.

pub mod cache;
pub mod fingerprint;
pub mod ndjson;

use std::collections::{HashMap, VecDeque};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use crate::baselines::Pipeline;
use crate::config::{Family, HardwareCfg, ModelCfg, ParallelCfg, Size};
use crate::cluster::ClusterSpec;
use crate::generator::cache::EvalCache;
use crate::generator::pool::EvalPool;
use crate::generator::{generate_with_cache, GenOptions, Incumbent};
use crate::model::{build_model, LayerKind};
use crate::profile::ProfiledData;
use crate::schedule::greedy::SchedKnobs;

use cache::{PlanCache, PlanCacheStats};
use fingerprint::{ReqKey, Sketch};

/// One plan request: everything a cold search reads.
#[derive(Clone, Debug)]
pub struct PlanRequest {
    /// Layer-kind sequence (the model's structural fingerprint; the
    /// near-miss metric only ever matches identical sequences).
    pub kinds: Vec<LayerKind>,
    /// Per-layer costs + link parameters.
    pub profile: ProfiledData,
    /// Devices and their memory capacities.
    pub cluster: ClusterSpec,
    /// Micro-batches per step.
    pub nmb: usize,
    /// Per-device rate multipliers (empty = healthy cluster).
    pub rates: Vec<f64>,
    /// Wall-clock search budget; `None` falls back to
    /// [`ServiceCfg::default_budget_s`].
    pub budget_s: Option<f64>,
    /// Tuning-iteration cap (the generator default is 64).
    pub max_iters: usize,
}

impl PlanRequest {
    pub fn new(
        kinds: Vec<LayerKind>,
        profile: ProfiledData,
        cluster: ClusterSpec,
        nmb: usize,
    ) -> PlanRequest {
        assert_eq!(kinds.len(), profile.n_layers(), "one kind per profiled layer");
        assert!(nmb >= 1);
        PlanRequest {
            kinds,
            profile,
            cluster,
            nmb,
            rates: Vec::new(),
            budget_s: None,
            max_iters: 64,
        }
    }

    /// Convenience: an analytically-profiled Table-5 model on a
    /// homogeneous cluster of `par.p` devices.
    pub fn table5(family: Family, size: Size, par: &ParallelCfg) -> PlanRequest {
        let hw = HardwareCfg::default();
        let spec = build_model(&ModelCfg::table5(family, size));
        let profile = ProfiledData::analytical(&spec, &hw, par);
        let cluster = ClusterSpec::uniform(par.p, &hw);
        PlanRequest::new(spec.layers, profile, cluster, par.nmb)
    }

    /// Exact identity (cache key, coalescing key).
    pub fn key(&self) -> ReqKey {
        ReqKey::of(self)
    }

    /// Geometry for near-miss matching.
    pub fn sketch(&self) -> Sketch {
        Sketch::of(self)
    }
}

/// How a response was produced, per *requester*.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Provenance {
    /// A search ran from the seed grid.
    Cold,
    /// A search ran, warm-started from a near-miss cached plan.
    Warm,
    /// Served from the plan cache; no search ran.
    Cached,
    /// Attached to an identical in-flight request's search.
    Coalesced,
}

impl Provenance {
    pub fn name(&self) -> &'static str {
        match self {
            Provenance::Cold => "cold",
            Provenance::Warm => "warm",
            Provenance::Cached => "cached",
            Provenance::Coalesced => "coalesced",
        }
    }
}

/// A completed search, shared by every waiter and the plan cache.
#[derive(Clone, Debug)]
pub struct PlanOutcome {
    pub pipeline: Pipeline,
    pub knobs: SchedKnobs,
    /// Predicted per-step makespan (seconds).
    pub makespan: f64,
    /// Worst per-device memory headroom (bytes; negative = OOM).
    pub headroom: f64,
    pub bubble_ratio: f64,
    /// [`Provenance::Cold`] or [`Provenance::Warm`] — how the
    /// *search* started (waiters may still see `Cached`/`Coalesced`).
    pub searched: Provenance,
    /// Drift to the warm-start donor (`None` for cold searches).
    pub near_miss_distance: Option<f64>,
    pub evals: usize,
    pub iters: usize,
    pub budget_exhausted: bool,
    /// Generator wall time (seconds).
    pub search_s: f64,
    /// Request digest, echoed on the wire.
    pub fingerprint: u64,
    /// The request geometry — future requests match against this.
    pub sketch: Sketch,
}

impl PlanOutcome {
    /// Package this plan as a warm-start seed.
    pub fn incumbent(&self) -> Incumbent {
        Incumbent {
            partition: self.pipeline.partition.clone(),
            placement: self.pipeline.placement.clone(),
            knobs: self.knobs,
        }
    }
}

/// What a waiter receives: the shared outcome plus this requester's
/// own provenance.
#[derive(Clone, Debug)]
pub struct PlanResponse {
    pub outcome: Arc<PlanOutcome>,
    pub provenance: Provenance,
}

/// Admission-control rejection: the request queue is full.
#[derive(Clone, Copy, Debug)]
pub struct Rejected {
    pub queue_len: usize,
    /// Estimated seconds until a slot frees up (mean recent search
    /// time × backlog / workers).
    pub retry_after_s: f64,
}

/// Claim on an admitted request; [`Ticket::wait`] blocks for the
/// response.
pub struct Ticket {
    rx: Receiver<PlanResponse>,
}

impl Ticket {
    /// Block until the response arrives.  Panics if the service is
    /// dropped with this request still pending (drain first).
    pub fn wait(self) -> PlanResponse {
        self.rx.recv().expect("service delivers one response per admitted request")
    }
}

/// Service configuration.
#[derive(Clone, Copy, Debug)]
pub struct ServiceCfg {
    /// Concurrent searches (each drives the shared pool via its own
    /// client).
    pub search_workers: usize,
    /// Evaluation threads in the shared [`EvalPool`].
    pub pool_threads: usize,
    /// Admission bound: queued-but-unstarted requests beyond this are
    /// rejected.  Coalesced attaches and cache hits never occupy a
    /// slot.
    pub queue_capacity: usize,
    /// Plan-cache entries ([`cache::PlanCache`] FIFO bound).
    pub cache_capacity: usize,
    /// Near-miss warm-start threshold (worst-component relative
    /// drift); `0.0` disables warm starts entirely.
    pub near_miss_max_drift: f64,
    /// Search budget for requests that don't carry their own.
    pub default_budget_s: Option<f64>,
    /// Start with dequeueing held (see [`Service::hold`]) — lets a
    /// deterministic harness script its first wave before any search
    /// starts.
    pub hold: bool,
}

impl Default for ServiceCfg {
    fn default() -> ServiceCfg {
        ServiceCfg {
            search_workers: 2,
            pool_threads: std::thread::available_parallelism()
                .map(|v| v.get())
                .unwrap_or(1),
            queue_capacity: 64,
            cache_capacity: 256,
            near_miss_max_drift: 0.25,
            default_budget_s: None,
            hold: false,
        }
    }
}

/// Lifetime request counters (all monotone).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ServiceStats {
    /// Every submission, including rejected ones.
    pub requests: u64,
    /// Admitted as a fresh cold search.
    pub cold: u64,
    /// Admitted as a warm-started search.
    pub warm: u64,
    /// Answered from the plan cache without a search.
    pub cached: u64,
    /// Attached to an identical in-flight search.
    pub coalesced: u64,
    /// Turned away by admission control.
    pub rejected: u64,
    /// Searches completed.
    pub searches: u64,
}

enum WaiterTx {
    Plain(Sender<PlanResponse>),
    /// `(tag, shared channel)` — the NDJSON loop multiplexes every
    /// response onto one channel.
    Tagged(u64, Sender<(u64, PlanResponse)>),
}

impl WaiterTx {
    fn send(self, resp: PlanResponse) {
        // A vanished waiter (dropped ticket / closed connection) is
        // not the service's problem.
        match self {
            WaiterTx::Plain(tx) => drop(tx.send(resp)),
            WaiterTx::Tagged(tag, tx) => drop(tx.send((tag, resp))),
        }
    }
}

struct Waiter {
    tx: WaiterTx,
    provenance: Provenance,
}

struct QueuedReq {
    key: ReqKey,
    req: PlanRequest,
    /// Warm-start seed + its near-miss distance (decided at
    /// submission, under the lock — see module docs).
    warm: Option<(Incumbent, f64)>,
}

struct State {
    queue: VecDeque<QueuedReq>,
    /// Key → waiters of the search that will serve them.  An entry
    /// exists from admission to completion; identical submissions
    /// attach here.
    inflight: HashMap<ReqKey, Vec<Waiter>>,
    cache: PlanCache,
    stats: ServiceStats,
    held: bool,
    shutdown: bool,
    /// Searches currently running on workers.
    active: usize,
    /// Recent search wall times (seconds) for retry-after estimates.
    recent_s: VecDeque<f64>,
}

struct Inner {
    cfg: ServiceCfg,
    m: Mutex<State>,
    /// Work available / released / shutdown.
    work_cv: Condvar,
    /// A search completed (drain listens here).
    idle_cv: Condvar,
}

/// The long-running planner daemon; see module docs.
pub struct Service {
    inner: Arc<Inner>,
    pool: Arc<EvalPool>,
    workers: Vec<JoinHandle<()>>,
}

impl Service {
    pub fn new(cfg: ServiceCfg) -> Service {
        assert!(cfg.search_workers >= 1);
        assert!(cfg.queue_capacity >= 1);
        assert!(cfg.near_miss_max_drift >= 0.0);
        let pool = Arc::new(EvalPool::new(cfg.pool_threads.max(1)));
        let inner = Arc::new(Inner {
            cfg,
            m: Mutex::new(State {
                queue: VecDeque::new(),
                inflight: HashMap::new(),
                cache: PlanCache::new(cfg.cache_capacity),
                stats: ServiceStats::default(),
                held: cfg.hold,
                shutdown: false,
                active: 0,
                recent_s: VecDeque::new(),
            }),
            work_cv: Condvar::new(),
            idle_cv: Condvar::new(),
        });
        let workers = (0..cfg.search_workers)
            .map(|_| {
                let inner = Arc::clone(&inner);
                let pool = Arc::clone(&pool);
                std::thread::spawn(move || worker(&inner, &pool))
            })
            .collect();
        Service { inner, pool, workers }
    }

    /// Submit a request; `Ok` is a claim on exactly one response.
    pub fn submit(&self, req: PlanRequest) -> Result<Ticket, Rejected> {
        let (tx, rx) = channel();
        self.enqueue(req, WaiterTx::Plain(tx))?;
        Ok(Ticket { rx })
    }

    /// Submit with the response routed to a shared channel under
    /// `tag` — the NDJSON front end's many-requests-one-writer shape.
    pub fn submit_tagged(
        &self,
        req: PlanRequest,
        tag: u64,
        tx: Sender<(u64, PlanResponse)>,
    ) -> Result<(), Rejected> {
        self.enqueue(req, WaiterTx::Tagged(tag, tx))
    }

    /// Submit and block for the response (rejections pass through).
    pub fn call(&self, req: PlanRequest) -> Result<PlanResponse, Rejected> {
        self.submit(req).map(Ticket::wait)
    }

    fn enqueue(&self, req: PlanRequest, tx: WaiterTx) -> Result<(), Rejected> {
        assert_eq!(req.kinds.len(), req.profile.n_layers());
        assert!(req.nmb >= 1 && req.cluster.p() >= 1);
        assert!(
            req.rates.is_empty() || req.rates.len() == req.cluster.p(),
            "one rate per device"
        );
        let key = req.key();
        let mut guard = self.inner.m.lock().unwrap();
        let st = &mut *guard;
        st.stats.requests += 1;
        // Fast path: an identical request already completed.
        if let Some(out) = st.cache.get(&key) {
            st.stats.cached += 1;
            drop(guard);
            tx.send(PlanResponse { outcome: out, provenance: Provenance::Cached });
            return Ok(());
        }
        // Coalesce: an identical request is already being searched
        // (or queued) — attach, occupying no queue slot.
        if let Some(waiters) = st.inflight.get_mut(&key) {
            st.stats.coalesced += 1;
            waiters.push(Waiter { tx, provenance: Provenance::Coalesced });
            return Ok(());
        }
        // Admission control.
        if st.queue.len() >= self.inner.cfg.queue_capacity {
            st.stats.rejected += 1;
            return Err(Rejected {
                queue_len: st.queue.len(),
                retry_after_s: retry_after(st, &self.inner.cfg),
            });
        }
        // Near-miss probe — decided here, against the cache as of
        // submission, so provenance is a pure function of the stream.
        let warm = if self.inner.cfg.near_miss_max_drift > 0.0 {
            st.cache
                .nearest(&req.sketch(), self.inner.cfg.near_miss_max_drift)
                .map(|(out, d)| (out.incumbent(), d))
        } else {
            None
        };
        let provenance = if warm.is_some() {
            st.stats.warm += 1;
            Provenance::Warm
        } else {
            st.stats.cold += 1;
            Provenance::Cold
        };
        st.inflight.insert(key.clone(), vec![Waiter { tx, provenance }]);
        st.queue.push_back(QueuedReq { key, req, warm });
        drop(guard);
        self.inner.work_cv.notify_one();
        Ok(())
    }

    /// Pause dequeueing: admitted requests queue up but no new search
    /// starts.  With [`Service::release`] this makes wave-structured
    /// streams fully deterministic (every submission in a wave sees
    /// the same cache/in-flight state on every replay).
    pub fn hold(&self) {
        self.inner.m.lock().unwrap().held = true;
    }

    /// Resume dequeueing.
    pub fn release(&self) {
        self.inner.m.lock().unwrap().held = false;
        self.inner.work_cv.notify_all();
    }

    /// Block until no request is queued or in flight.  Call
    /// [`Service::release`] first — draining a held queue would wait
    /// forever, so that is a panic, not a hang.
    pub fn drain(&self) {
        let mut st = self.inner.m.lock().unwrap();
        while !(st.queue.is_empty() && st.inflight.is_empty()) {
            assert!(
                !(st.held && !st.queue.is_empty()),
                "drain() on a held service with queued work"
            );
            st = self.inner.idle_cv.wait(st).unwrap();
        }
    }

    pub fn stats(&self) -> ServiceStats {
        self.inner.m.lock().unwrap().stats
    }

    pub fn plan_cache_stats(&self) -> PlanCacheStats {
        self.inner.m.lock().unwrap().cache.stats()
    }

    /// Evaluation threads backing every search.
    pub fn pool_threads(&self) -> usize {
        self.pool.threads()
    }
}

impl Drop for Service {
    fn drop(&mut self) {
        {
            let mut st = self.inner.m.lock().unwrap();
            st.shutdown = true;
        }
        self.inner.work_cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Backlog-proportional retry estimate, floored so callers never busy
/// spin on a zero.
fn retry_after(st: &State, cfg: &ServiceCfg) -> f64 {
    let mean_s = if st.recent_s.is_empty() {
        0.05
    } else {
        st.recent_s.iter().sum::<f64>() / st.recent_s.len() as f64
    };
    let backlog = (st.queue.len() + st.active + 1) as f64;
    (mean_s * backlog / cfg.search_workers as f64).max(1e-3)
}

fn worker(inner: &Inner, pool: &Arc<EvalPool>) {
    loop {
        let job = {
            let mut st = inner.m.lock().unwrap();
            loop {
                if st.shutdown {
                    return;
                }
                if !st.held {
                    if let Some(job) = st.queue.pop_front() {
                        st.active += 1;
                        break job;
                    }
                }
                st = inner.work_cv.wait(st).unwrap();
            }
        };
        let t0 = Instant::now();
        let outcome = Arc::new(run_search(&job, &inner.cfg, pool));
        let wall_s = t0.elapsed().as_secs_f64();
        {
            let mut st = inner.m.lock().unwrap();
            st.cache.insert(job.key.clone(), Arc::clone(&outcome));
            st.stats.searches += 1;
            st.active -= 1;
            st.recent_s.push_back(wall_s);
            if st.recent_s.len() > 32 {
                st.recent_s.pop_front();
            }
            // Everything below happens under the same lock as the
            // cache insert, so a late identical submission either
            // attaches here or hits the cache — there is no window
            // where it would start a duplicate search.
            let waiters = st.inflight.remove(&job.key).expect("admitted ⇒ in flight");
            for w in waiters {
                w.tx.send(PlanResponse {
                    outcome: Arc::clone(&outcome),
                    provenance: w.provenance,
                });
            }
        }
        inner.idle_cv.notify_all();
    }
}

/// One search, exactly as the batch CLI would run it — plus the
/// shared pool and (for warm requests) the near-miss incumbent seed.
fn run_search(job: &QueuedReq, cfg: &ServiceCfg, pool: &Arc<EvalPool>) -> PlanOutcome {
    let req = &job.req;
    let caps = req.cluster.mem_caps();
    let mut opts = GenOptions::new(caps.p(), req.nmb);
    opts.max_iters = req.max_iters;
    opts.mem_caps = Some(caps);
    if !req.rates.is_empty() {
        opts.rates = Some(req.rates.clone());
    }
    opts.time_budget_s = req.budget_s.or(cfg.default_budget_s);
    opts.shared_pool = Some(Arc::clone(pool));
    if let Some((inc, _)) = &job.warm {
        // Seed only — no migration pricing: a plan request is for a
        // job that is not running yet, so nothing would migrate.
        opts.incumbent = Some(inc.clone());
    }
    // Fresh per-search EvalCache: cross-request memoization would only
    // ever help exact repeats, and those hit the plan cache instead.
    let mut ecache = EvalCache::new();
    let res = generate_with_cache(&req.profile, &opts, &mut ecache);
    PlanOutcome {
        makespan: res.report.total,
        headroom: res.report.min_headroom(),
        bubble_ratio: res.report.bubble_ratio(),
        knobs: res.knobs,
        pipeline: res.pipeline,
        searched: if job.warm.is_some() { Provenance::Warm } else { Provenance::Cold },
        near_miss_distance: job.warm.as_ref().map(|(_, d)| *d),
        evals: res.evals,
        iters: res.iters,
        budget_exhausted: res.budget_exhausted,
        search_s: res.elapsed_s,
        fingerprint: job.key.fingerprint(),
        sketch: req.sketch(),
    }
}
