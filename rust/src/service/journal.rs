//! Crash-safe plan journal (DESIGN.md §9, "Fault tolerance").
//!
//! An append-only log of committed plan-cache entries.  Every cache
//! insert appends one record; a restarted service replays the log and
//! starts with a [`super::cache::PlanCache`] bitwise-equal to the
//! pre-crash committed state (same entries, same FIFO/eviction order —
//! replay re-runs the exact insert sequence through the same
//! deterministic FIFO policy).
//!
//! ## File format
//!
//! ```text
//! magic  "ADPTJNL1"                                      (8 bytes)
//! record u32 payload_len | payload | u64 fnv1a(payload)  (repeated)
//! ```
//!
//! Everything is little-endian.  The payload is:
//!
//! ```text
//! u32 key_len | ReqKey::to_bytes            request identity
//! u32 name_len | UTF-8 pipeline name
//! u32 n_bounds | u64 …                      partition stage bounds
//! u32 p | u32 n_stages | u32 …              placement device_of
//! u8  knob bits (split_bw|w_fill<<1|overlap_aware<<2) | u64 mem_cap_factor
//! u8  searched (0=Cold, 1=Warm)
//! u8  near-miss flag | [u64 distance]
//! u64 evals | u64 iters | u8 flags (budget_exhausted)
//! u64 search_s | u64 makespan | u64 headroom | u64 bubble_ratio
//! u64 fingerprint
//! ```
//!
//! The plan's **schedule is not stored**: `(partition, placement,
//! knobs)` plus the materialized request re-derive it exactly
//! (`greedy_schedule_in` is deterministic — the same derivation the
//! generator's final-build step uses), and the recomputed makespan /
//! headroom / bubble-ratio **bit patterns must equal the stored ones**
//! or the record is rejected.  That turns the simulator into an
//! end-to-end checksum of the whole decode.
//!
//! ## Recovery rules
//!
//! Records are validated in order; the first failure — short header,
//! oversized length, checksum mismatch, undecodable payload, or
//! re-simulation mismatch — ends the committed prefix.  Whatever
//! follows is a torn tail from a mid-append crash: it is counted
//! ([`Replayed::torn`]), the file is truncated back to the last good
//! record, and appending resumes from there.  Degraded and
//! deadline-cut outcomes are never journaled (see `service::worker`),
//! so a replayed cache is a pure function of the committed request
//! stream, exactly like the live cache.

use std::fs::OpenOptions;
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::Path;

use crate::baselines::Pipeline;
use crate::partition::Partition;
use crate::perfmodel::{simulate_in, SimArena, StageTable};
use crate::placement::Placement;
use crate::schedule::greedy::{greedy_schedule_in, SchedKnobs};

use super::fingerprint::{ByteReader, ReqKey};
use super::{PlanOutcome, Provenance};

const MAGIC: &[u8; 8] = b"ADPTJNL1";
/// Sanity bound on one record's payload — far above any real plan,
/// far below anything that could OOM replay on garbage lengths.
const MAX_PAYLOAD: u32 = 64 << 20;

/// Replay outcome counters, surfaced as
/// `ServiceStats::{journal_recovered, journal_torn}`.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Replayed {
    /// Records replayed into the cache.
    pub recovered: usize,
    /// 1 if a torn/corrupt tail was dropped, else 0 (append-only logs
    /// tear only at the end; everything after the first bad byte is
    /// one tail).
    pub torn: usize,
}

/// Open handle to the journal file; see module docs.
#[derive(Debug)]
pub struct Journal {
    file: std::fs::File,
}

impl Journal {
    /// Open (or create) the journal at `path`, replay its committed
    /// prefix, truncate any torn tail, and leave the handle positioned
    /// for appending.
    pub fn open(path: &Path) -> std::io::Result<(Journal, Vec<(ReqKey, PlanOutcome)>, Replayed)> {
        let mut file = OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .truncate(false)
            .open(path)?;
        let mut bytes = Vec::new();
        file.read_to_end(&mut bytes)?;

        let mut entries = Vec::new();
        let mut replay = Replayed::default();
        let mut good_end: u64;
        if bytes.len() < MAGIC.len() || &bytes[..MAGIC.len()] != MAGIC {
            // Empty file, or a crash before even the magic landed:
            // (re)initialize.  A non-empty unrecognized prefix counts
            // as torn.
            replay.torn = usize::from(!bytes.is_empty());
            file.set_len(0)?;
            file.seek(SeekFrom::Start(0))?;
            file.write_all(MAGIC)?;
            good_end = MAGIC.len() as u64;
        } else {
            let mut at = MAGIC.len();
            good_end = at as u64;
            loop {
                let Some((record, next)) = split_record(&bytes, at) else {
                    replay.torn = usize::from(at < bytes.len());
                    break;
                };
                let Some(entry) = decode_record(record) else {
                    replay.torn = 1;
                    break;
                };
                entries.push(entry);
                replay.recovered += 1;
                at = next;
                good_end = at as u64;
            }
            file.set_len(good_end)?;
        }
        file.seek(SeekFrom::Start(good_end))?;
        Ok((Journal { file }, entries, replay))
    }

    /// Append one committed cache entry.  The record is assembled in
    /// memory and written with a single `write_all`, so a crash leaves
    /// either the whole record or a (detectable, truncatable) torn
    /// tail — never a silently half-applied commit.
    pub fn append(&mut self, key: &ReqKey, out: &PlanOutcome) -> std::io::Result<()> {
        let payload = encode_record(key, out);
        let mut rec = Vec::with_capacity(payload.len() + 12);
        rec.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        rec.extend_from_slice(&payload);
        rec.extend_from_slice(&fnv1a(&payload).to_le_bytes());
        self.file.write_all(&rec)?;
        self.file.flush()
    }

    /// Force the journal to stable storage (fsync).
    pub fn sync(&mut self) -> std::io::Result<()> {
        self.file.sync_all()
    }
}

/// FNV-1a over raw bytes — the same constants as
/// [`ReqKey::fingerprint`], applied per record.
fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    h
}

/// Carve `(payload, next_offset)` for the record starting at `at`,
/// verifying length sanity and checksum.  `None` = torn tail.
fn split_record(bytes: &[u8], at: usize) -> Option<(&[u8], usize)> {
    let len_bytes = bytes.get(at..at + 4)?;
    let len = u32::from_le_bytes(len_bytes.try_into().unwrap());
    if len == 0 || len > MAX_PAYLOAD {
        return None;
    }
    let body = at + 4;
    let payload = bytes.get(body..body + len as usize)?;
    let sum_bytes = bytes.get(body + len as usize..body + len as usize + 8)?;
    if u64::from_le_bytes(sum_bytes.try_into().unwrap()) != fnv1a(payload) {
        return None;
    }
    Some((payload, body + len as usize + 8))
}

fn encode_record(key: &ReqKey, out: &PlanOutcome) -> Vec<u8> {
    debug_assert!(
        out.searched != Provenance::Degraded && !out.deadline_hit,
        "degraded/deadline-cut outcomes are never journaled"
    );
    let mut b = Vec::with_capacity(256);
    let key_bytes = key.to_bytes();
    b.extend_from_slice(&(key_bytes.len() as u32).to_le_bytes());
    b.extend_from_slice(&key_bytes);
    let name = out.pipeline.name.as_bytes();
    b.extend_from_slice(&(name.len() as u32).to_le_bytes());
    b.extend_from_slice(name);
    let bounds = &out.pipeline.partition.bounds;
    b.extend_from_slice(&(bounds.len() as u32).to_le_bytes());
    for &v in bounds {
        b.extend_from_slice(&(v as u64).to_le_bytes());
    }
    b.extend_from_slice(&(out.pipeline.placement.p as u32).to_le_bytes());
    let device_of = &out.pipeline.placement.device_of;
    b.extend_from_slice(&(device_of.len() as u32).to_le_bytes());
    for &d in device_of {
        b.extend_from_slice(&(d as u32).to_le_bytes());
    }
    b.push(
        u8::from(out.knobs.split_bw)
            | u8::from(out.knobs.w_fill) << 1
            | u8::from(out.knobs.overlap_aware) << 2,
    );
    b.extend_from_slice(&out.knobs.mem_cap_factor.to_bits().to_le_bytes());
    b.push(match out.searched {
        Provenance::Warm => 1,
        _ => 0,
    });
    match out.near_miss_distance {
        Some(d) => {
            b.push(1);
            b.extend_from_slice(&d.to_bits().to_le_bytes());
        }
        None => b.push(0),
    }
    b.extend_from_slice(&(out.evals as u64).to_le_bytes());
    b.extend_from_slice(&(out.iters as u64).to_le_bytes());
    b.push(u8::from(out.budget_exhausted));
    for v in [out.search_s, out.makespan, out.headroom, out.bubble_ratio] {
        b.extend_from_slice(&v.to_bits().to_le_bytes());
    }
    b.extend_from_slice(&out.fingerprint.to_le_bytes());
    b
}

/// Decode + verify one checksummed payload.  `None` on any structural
/// or semantic violation — including the re-simulation cross-check —
/// never a panic.
fn decode_record(payload: &[u8]) -> Option<(ReqKey, PlanOutcome)> {
    let mut r = ByteReader::new(payload);
    let key_len = r.u32()? as usize;
    let key = ReqKey::from_bytes(r.take(key_len)?)?;
    let name_len = r.u32()? as usize;
    if name_len > 1 << 10 {
        return None;
    }
    let name = std::str::from_utf8(r.take(name_len)?).ok()?.to_string();
    let n_bounds = r.u32()? as usize;
    if n_bounds < 2 || n_bounds > 1 << 20 {
        return None;
    }
    let mut bounds = Vec::with_capacity(n_bounds);
    for _ in 0..n_bounds {
        bounds.push(usize::try_from(r.u64()?).ok()?);
    }
    let p = r.u32()? as usize;
    let n_stages = r.u32()? as usize;
    if p == 0 || n_stages == 0 || n_stages > 1 << 20 {
        return None;
    }
    let mut device_of = Vec::with_capacity(n_stages);
    for _ in 0..n_stages {
        device_of.push(r.u32()? as usize);
    }
    let knob_bits = r.u8()?;
    if knob_bits > 0b111 {
        return None;
    }
    let mem_cap_factor = f64::from_bits(r.u64()?);
    let searched = match r.u8()? {
        0 => Provenance::Cold,
        1 => Provenance::Warm,
        _ => return None,
    };
    let near_miss_distance = match r.u8()? {
        0 => None,
        1 => Some(f64::from_bits(r.u64()?)),
        _ => return None,
    };
    let evals = usize::try_from(r.u64()?).ok()?;
    let iters = usize::try_from(r.u64()?).ok()?;
    let flags = r.u8()?;
    if flags > 1 {
        return None;
    }
    let budget_exhausted = flags & 1 != 0;
    let search_s = f64::from_bits(r.u64()?);
    let makespan_bits = r.u64()?;
    let headroom_bits = r.u64()?;
    let bubble_bits = r.u64()?;
    let fingerprint = r.u64()?;
    if !r.done() {
        return None;
    }

    // Semantic validation before touching the scheduler: every panic
    // an adversarial-but-checksummed record could trigger is a reject
    // here instead.
    if fingerprint != key.fingerprint() {
        return None;
    }
    if !(mem_cap_factor.is_finite() && mem_cap_factor > 0.0 && mem_cap_factor <= 1.0) {
        return None;
    }
    let req = key.materialize();
    let partition = Partition { bounds };
    let placement = Placement { p, device_of };
    if !partition.is_valid()
        || partition.n_layers() != req.profile.n_layers()
        || !placement.is_valid()
        || placement.n_stages() != partition.n_stages()
        || placement.p != req.cluster.p()
    {
        return None;
    }
    if !req.rates.is_empty()
        && (req.rates.len() != req.cluster.p()
            || req.rates.iter().any(|v| !v.is_finite() || *v <= 0.0))
    {
        return None;
    }

    // Re-derive the schedule exactly as the generator's final-build
    // step does, then demand bit-equality with the stored metrics —
    // the simulator acts as a semantic checksum over the whole record.
    let caps = req.cluster.mem_caps();
    let knobs = SchedKnobs {
        split_bw: knob_bits & 1 != 0,
        w_fill: knob_bits & 2 != 0,
        mem_cap_factor,
        overlap_aware: knob_bits & 4 != 0,
    };
    let table = StageTable::build_rated(&req.profile, &partition, &placement, &req.rates);
    let mut arena = SimArena::new();
    let schedule = greedy_schedule_in(&mut arena, &table, &caps, req.nmb, knobs);
    let report = simulate_in(&mut arena, &table, &caps, &schedule, false).ok()?;
    if report.total.to_bits() != makespan_bits
        || report.min_headroom().to_bits() != headroom_bits
        || report.bubble_ratio().to_bits() != bubble_bits
    {
        return None;
    }

    let sketch = req.sketch();
    let outcome = PlanOutcome {
        pipeline: Pipeline { name, partition, placement, schedule },
        knobs,
        makespan: f64::from_bits(makespan_bits),
        headroom: f64::from_bits(headroom_bits),
        bubble_ratio: f64::from_bits(bubble_bits),
        searched,
        near_miss_distance,
        evals,
        iters,
        budget_exhausted,
        deadline_hit: false,
        search_s,
        fingerprint,
        sketch,
    };
    Some((key, outcome))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Family, ParallelCfg, Size};
    use crate::partition::uniform;
    use crate::placement::sequential;
    use crate::service::PlanRequest;

    fn fixture() -> (ReqKey, PlanOutcome) {
        let req = PlanRequest::table5(
            Family::Gemma,
            Size::Small,
            &ParallelCfg::new(4, 2, 8, 1, 4096),
        );
        let key = req.key();
        let caps = req.cluster.mem_caps();
        let partition = uniform(req.profile.n_layers(), 4);
        let placement = sequential(4);
        let knobs = SchedKnobs {
            split_bw: true,
            w_fill: true,
            mem_cap_factor: 1.0,
            overlap_aware: false,
        };
        let table =
            StageTable::build_rated(&req.profile, &partition, &placement, &req.rates);
        let mut arena = SimArena::new();
        let schedule = greedy_schedule_in(&mut arena, &table, &caps, req.nmb, knobs);
        let report =
            simulate_in(&mut arena, &table, &caps, &schedule, false).expect("simulates");
        let outcome = PlanOutcome {
            pipeline: Pipeline {
                name: "AdaPtis".into(),
                partition,
                placement,
                schedule,
            },
            knobs,
            makespan: report.total,
            headroom: report.min_headroom(),
            bubble_ratio: report.bubble_ratio(),
            searched: Provenance::Cold,
            near_miss_distance: None,
            evals: 17,
            iters: 3,
            budget_exhausted: false,
            deadline_hit: false,
            search_s: 0.125,
            fingerprint: key.fingerprint(),
            sketch: req.sketch(),
        };
        (key, outcome)
    }

    #[test]
    fn record_round_trips_bitwise() {
        let (key, out) = fixture();
        let payload = encode_record(&key, &out);
        let (dkey, dout) = decode_record(&payload).expect("decodes");
        assert_eq!(dkey, key);
        assert_eq!(dout.pipeline.partition, out.pipeline.partition);
        assert_eq!(dout.pipeline.placement, out.pipeline.placement);
        assert_eq!(dout.pipeline.name, out.pipeline.name);
        assert_eq!(dout.knobs, out.knobs);
        assert_eq!(dout.makespan.to_bits(), out.makespan.to_bits());
        assert_eq!(dout.headroom.to_bits(), out.headroom.to_bits());
        assert_eq!(dout.bubble_ratio.to_bits(), out.bubble_ratio.to_bits());
        assert_eq!((dout.evals, dout.iters), (out.evals, out.iters));
        assert_eq!(dout.search_s.to_bits(), out.search_s.to_bits());
        assert_eq!(dout.fingerprint, out.fingerprint);
        assert_eq!(dout.sketch, out.sketch);
        // The re-derived schedule simulates to the same bits, which is
        // the definition of equality the cache consumers rely on.
        assert_eq!(
            format!("{:?}", dout.pipeline.schedule),
            format!("{:?}", out.pipeline.schedule)
        );
    }

    #[test]
    fn corrupt_payloads_are_rejected_not_panics() {
        let (key, out) = fixture();
        let payload = encode_record(&key, &out);
        assert!(decode_record(&payload[..payload.len() - 1]).is_none(), "truncated");
        let mut flipped = payload.clone();
        let mid = flipped.len() / 2;
        flipped[mid] ^= 0xff;
        // A mid-payload flip either fails structural decode or the
        // re-simulation cross-check — never panics.  (It cannot decode
        // to a *different valid* plan: metrics bits would mismatch.)
        let _ = decode_record(&flipped);
        assert!(decode_record(&[]).is_none(), "empty");
    }

    #[test]
    fn open_replays_and_truncates_torn_tail() {
        let path = std::env::temp_dir()
            .join(format!("adaptis-journal-unit-{}.jnl", std::process::id()));
        let _ = std::fs::remove_file(&path);
        let (key, out) = fixture();
        {
            let (mut j, entries, replay) = Journal::open(&path).expect("create");
            assert!(entries.is_empty());
            assert_eq!(replay, Replayed::default());
            j.append(&key, &out).expect("append 1");
            j.append(&key, &out).expect("append 2");
            j.append(&key, &out).expect("append 3");
            j.sync().expect("fsync");
        }
        // Simulate a crash mid-append: tear the last record.
        let len = std::fs::metadata(&path).expect("meta").len();
        let f = OpenOptions::new().write(true).open(&path).expect("reopen");
        f.set_len(len - 3).expect("tear");
        drop(f);
        {
            let (_j, entries, replay) = Journal::open(&path).expect("recover");
            assert_eq!(replay, Replayed { recovered: 2, torn: 1 });
            assert_eq!(entries.len(), 2);
            assert_eq!(entries[0].0, key);
            assert_eq!(entries[0].1.makespan.to_bits(), out.makespan.to_bits());
        }
        // The torn tail was truncated away: a third open is clean.
        {
            let (_j, entries, replay) = Journal::open(&path).expect("clean reopen");
            assert_eq!(replay, Replayed { recovered: 2, torn: 0 });
            assert_eq!(entries.len(), 2);
        }
        let _ = std::fs::remove_file(&path);
    }
}
