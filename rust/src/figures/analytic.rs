//! SimCluster / performance-model figure harnesses (Figs 1, 3, 4, 8,
//! 9, 10, 13, 14, 15 and Table 5).  All use the H800-calibrated
//! analytical profile (DESIGN.md §Substitutions).

use std::fmt::Write as _;

use super::Ctx;
use crate::baselines::{self, Method};
use crate::config::{Family, ModelCfg, ParallelCfg, Size};
use crate::generator::{generate, searchspace, GenOptions, PhaseMask};
use crate::ilp;
use crate::metrics::{cluster_throughput, scaling_pct, Table};
use crate::model::build_model;
use crate::perfmodel::{simulate, PerfReport};
use crate::profile::ProfiledData;
use crate::util::stats::fit_exponential;

/// A method under evaluation: the four baselines + AdaPtis.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Algo {
    Base(Method),
    AdaPtis,
}

impl Algo {
    pub fn name(&self) -> String {
        match self {
            Algo::Base(m) => m.name().to_string(),
            Algo::AdaPtis => "AdaPtis".to_string(),
        }
    }

    pub fn paper_set() -> Vec<Algo> {
        let mut v: Vec<Algo> =
            Method::paper_baselines().iter().map(|&m| Algo::Base(m)).collect();
        v.push(Algo::AdaPtis);
        v
    }
}

/// Evaluate one algo on one configuration.  Returns None on OOM /
/// invalid pipelines.
pub fn eval(
    profile: &ProfiledData,
    algo: Algo,
    p: usize,
    nmb: usize,
    gen_iters: usize,
) -> Option<PerfReport> {
    match algo {
        Algo::Base(m) => {
            let pl = baselines::build(m, profile, p, nmb);
            simulate(profile, &pl.partition, &pl.placement, &pl.schedule, false)
                .ok()
                .filter(|r| !r.oom)
        }
        Algo::AdaPtis => {
            let mut opts = GenOptions::new(p, nmb);
            opts.max_iters = gen_iters;
            let g = generate(profile, &opts);
            (!g.report.oom).then_some(g.report)
        }
    }
}

fn profile_for(cfg: &ModelCfg, par: &ParallelCfg, ctx: &Ctx) -> ProfiledData {
    ProfiledData::analytical(&build_model(cfg), &ctx.hw, par)
}

// ---------------------------------------------------------------------------
// Fig 1: bubble ratios of PP methods on the four model families.
// ---------------------------------------------------------------------------

pub fn fig1(ctx: &Ctx) -> String {
    // Paper setting: L=32, P=4, T=2, G=16, nmb=16 on 8 GPUs (d=1).
    let par = ParallelCfg { p: 4, t: 2, d: 1, e: 1, nmb: 16, mbs: 1, seq: 4096 };
    let mut t = Table::new(&["Model", "S-1F1B", "I-1F1B", "ZB", "Mist"]);
    for fam in [Family::Llama2, Family::Gemma, Family::DeepSeek, Family::NemotronH] {
        let mut cfg = ModelCfg::table5(fam, Size::Small);
        cfg.blocks = 32; // the figure pins L=32 for all families
        let prof = profile_for(&cfg, &par, ctx);
        let mut cells = vec![fam.name().to_string()];
        for m in Method::paper_baselines() {
            let r = eval(&prof, Algo::Base(m), par.p, par.nmb, 0)
                .map(|r| format!("{:.1}%", 100.0 * r.bubble_ratio()))
                .unwrap_or_else(|| "OOM".into());
            cells.push(r);
        }
        t.row(cells);
    }
    format!(
        "## Fig 1 — bubble ratios (L=32, P=4, T=2, nmb=16, 8 GPUs)\n\n{}\n\
         Expected shape: LLaMA-2 lowest; heterogeneous models (Gemma/DeepSeek/\n\
         Nemotron-H) substantially higher, with partially-adaptive methods giving\n\
         limited or negative relief.\n",
        t.render()
    )
}

// ---------------------------------------------------------------------------
// Fig 3: the motivation case study (staged co-optimization speedups).
// ---------------------------------------------------------------------------

pub fn fig3(ctx: &Ctx) -> String {
    // Gemma-like with a large vocabulary, L=32, P=4, nmb=4.
    let par = ParallelCfg { p: 4, t: 1, d: 1, e: 1, nmb: 4, mbs: 1, seq: 4096 };
    let mut cfg = ModelCfg::table5(Family::Gemma, Size::Small);
    cfg.blocks = 32;
    let prof = profile_for(&cfg, &par, ctx);
    let base = eval(&prof, Algo::Base(Method::S1F1B), 4, 4, 0).unwrap();

    let run_masked = |partition: bool, placement: bool, schedule: bool| -> PerfReport {
        let mut opts = GenOptions::new(4, 4);
        opts.phases = PhaseMask { partition, placement, schedule };
        opts.seed_s1f1b_only = true;
        generate(&prof, &opts).report
    };
    let opt1 = run_masked(false, false, true);
    let opt2 = run_masked(true, false, true);
    let opt3 = run_masked(true, true, true);

    let mut t = Table::new(&["Pipeline", "step time", "speedup"]);
    let mut row = |name: &str, r: &PerfReport| {
        t.row(vec![
            name.into(),
            format!("{:.1} ms", r.total * 1e3),
            format!("{:.2}x", base.total / r.total),
        ]);
    };
    row("Baseline (S-1F1B)", &base);
    row("Opt 1: tune scheduling", &opt1);
    row("Opt 2: + tune partition", &opt2);
    row("Opt 3: + tune placement", &opt3);
    format!(
        "## Fig 3 — co-optimization case study (Gemma-like, L=32, P=4, nmb=4)\n\n{}\n\
         Paper reports 1.28x / 1.49x / 1.74x for the three stages.\n",
        t.render()
    )
}

// ---------------------------------------------------------------------------
// Fig 4: search-space growth.
// ---------------------------------------------------------------------------

pub fn fig4(_ctx: &Ctx) -> String {
    let mut out = String::from("## Fig 4 — search-space sizes (log10)\n\n");
    let mut t = Table::new(&["axis", "value", "log10(count)"]);
    for layers in [16u64, 32, 64, 128, 256] {
        t.row(vec![
            "partitions (S=8)".into(),
            layers.to_string(),
            format!("{:.1}", searchspace::log10_partitions(layers, 8)),
        ]);
    }
    for stages in [8u64, 16, 32, 64] {
        t.row(vec![
            "placements (P=8)".into(),
            stages.to_string(),
            format!("{:.1}", searchspace::log10_placements(stages, 8)),
        ]);
    }
    for nmb in [4u64, 8, 16, 32, 64] {
        t.row(vec![
            "schedules (P=8)".into(),
            nmb.to_string(),
            format!("{:.1}", searchspace::log10_schedules(nmb, 8)),
        ]);
    }
    let _ = write!(out, "{}", t.render());
    out.push_str("Exponential growth on every axis motivates phase-by-phase tuning.\n");
    out
}

// ---------------------------------------------------------------------------
// Table 5: model parameter configurations.
// ---------------------------------------------------------------------------

pub fn table5(_ctx: &Ctx) -> String {
    let mut t = Table::new(&["Model", "Size", "L", "V", "H", "FFN type", "Attn type"]);
    for cfg in ModelCfg::all_table5() {
        let (ffn, attn) = match cfg.family {
            Family::Gemma => ("FFN", "SA"),
            Family::DeepSeek => ("FFN+MoE", "MLA"),
            Family::NemotronH => ("FFN", "SA+Mamba"),
            Family::Llama2 => ("FFN", "SA"),
        };
        t.row(vec![
            cfg.family.name().into(),
            cfg.size.name().into(),
            cfg.blocks.to_string(),
            format!("{}K", cfg.vocab >> 10),
            cfg.hidden.to_string(),
            ffn.into(),
            attn.into(),
        ]);
    }
    format!("## Table 5 — model parameter configurations\n\n{}", t.render())
}

// ---------------------------------------------------------------------------
// Fig 8: end-to-end throughput across models, sizes and seq lengths.
// ---------------------------------------------------------------------------

pub fn fig8(ctx: &Ctx) -> String {
    let gpus = if ctx.fast { 16 } else { 32 };
    let sizes: &[Size] =
        if ctx.fast { &[Size::Small] } else { &[Size::Small, Size::Medium, Size::Large] };
    let seqs: &[usize] = if ctx.fast { &[4096] } else { &[2048, 4096] };
    let g_seqs = 128usize; // global batch (sequences)

    let mut t = Table::new(&[
        "Model", "Seq", "S-1F1B", "I-1F1B", "ZB", "Mist", "AdaPtis", "speedup",
    ]);
    for fam in [Family::Gemma, Family::DeepSeek, Family::NemotronH] {
        for &size in sizes {
            let cfg = ModelCfg::table5(fam, size);
            for &seq in seqs {
                let mut best: Vec<Option<f64>> = vec![None; 5];
                // Grid search over (P, T) like the paper (§5.1).
                for p in [4usize, 8, 16] {
                    for tpar in [1usize, 2, 4] {
                        if p * tpar > gpus || build_model(&cfg).n_layers() < p * 2 {
                            continue;
                        }
                        let d = gpus / (p * tpar);
                        let nmb = (g_seqs / d).max(p);
                        let par = ParallelCfg { p, t: tpar, d, e: 1, nmb, mbs: 1, seq };
                        let prof = profile_for(&cfg, &par, ctx);
                        for (i, algo) in Algo::paper_set().iter().enumerate() {
                            let iters = if ctx.fast { 8 } else { 16 };
                            if let Some(r) = eval(&prof, *algo, p, nmb, iters) {
                                let ts = cluster_throughput(&r, &par, &ctx.hw);
                                if best[i].is_none_or(|b| ts > b) {
                                    best[i] = Some(ts);
                                }
                            }
                        }
                    }
                }
                let fmt = |o: Option<f64>| {
                    o.map(|x| crate::util::fmt_si(x)).unwrap_or_else(|| "-".into())
                };
                let speedup = match (best[0], best[4]) {
                    (Some(b), Some(a)) => format!("{:.2}x", a / b),
                    _ => "-".into(),
                };
                t.row(vec![
                    cfg.label(),
                    format!("{}K", seq / 1024),
                    fmt(best[0]),
                    fmt(best[1]),
                    fmt(best[2]),
                    fmt(best[3]),
                    fmt(best[4]),
                    speedup,
                ]);
            }
        }
    }
    format!(
        "## Fig 8 — E2E training throughput (tokens/s, {gpus} GPUs, best (P,T) per method)\n\n{}\
         speedup = AdaPtis vs S-1F1B.  Paper: avg 1.34x, up to 1.54x.\n",
        t.render()
    )
}

// ---------------------------------------------------------------------------
// Fig 9: throughput across sequence lengths (Nemotron-H Large).
// ---------------------------------------------------------------------------

pub fn fig9(ctx: &Ctx) -> String {
    let cfg = ModelCfg::table5(Family::NemotronH, Size::Large);
    let seqs: &[usize] = if ctx.fast {
        &[1024, 4096, 16384]
    } else {
        &[1024, 2048, 4096, 8192, 16384, 32768]
    };
    let mut t =
        Table::new(&["Seq", "S-1F1B", "I-1F1B", "ZB", "Mist", "AdaPtis", "speedup"]);
    for &seq in seqs {
        // Paper: P=8, T=4, G=64, nmb=64.
        let par = ParallelCfg { p: 8, t: 4, d: 1, e: 1, nmb: 64, mbs: 1, seq };
        let prof = profile_for(&cfg, &par, ctx);
        let ts: Vec<Option<f64>> = Algo::paper_set()
            .iter()
            .map(|&a| {
                eval(&prof, a, par.p, par.nmb, if ctx.fast { 8 } else { 16 })
                    .map(|r| cluster_throughput(&r, &par, &ctx.hw))
            })
            .collect();
        let fmt =
            |o: &Option<f64>| o.map(crate::util::fmt_si).unwrap_or_else(|| "-".into());
        let speedup = match (ts[0], ts[4]) {
            (Some(b), Some(a)) => format!("{:.2}x", a / b),
            _ => "-".into(),
        };
        t.row(vec![
            format!("{}K", seq / 1024),
            fmt(&ts[0]),
            fmt(&ts[1]),
            fmt(&ts[2]),
            fmt(&ts[3]),
            fmt(&ts[4]),
            speedup,
        ]);
    }
    format!(
        "## Fig 9 — throughput vs sequence length (Nemotron-H Large, P=8, T=4, nmb=64)\n\n{}",
        t.render()
    )
}

// ---------------------------------------------------------------------------
// Fig 10: ablation of pipeline co-optimization.
// ---------------------------------------------------------------------------

pub fn fig10(ctx: &Ctx) -> String {
    let par = ParallelCfg { p: 8, t: 2, d: 1, e: 1, nmb: 16, mbs: 1, seq: 4096 };
    let mut t = Table::new(&[
        "Model",
        "placement only",
        "schedule only",
        "partition only",
        "co-opt (all)",
    ]);
    for fam in [Family::Gemma, Family::DeepSeek, Family::NemotronH] {
        let cfg = ModelCfg::table5(fam, if ctx.fast { Size::Small } else { Size::Medium });
        let prof = profile_for(&cfg, &par, ctx);
        let base = eval(&prof, Algo::Base(Method::S1F1B), par.p, par.nmb, 0).unwrap();
        let run_masked = |pa: bool, pl: bool, sc: bool| -> f64 {
            let mut opts = GenOptions::new(par.p, par.nmb);
            opts.phases = PhaseMask { partition: pa, placement: pl, schedule: sc };
            opts.seed_s1f1b_only = true;
            let r = generate(&prof, &opts).report;
            base.total / r.total
        };
        t.row(vec![
            fam.name().into(),
            format!("{:.2}x", run_masked(false, true, false)),
            format!("{:.2}x", run_masked(false, false, true)),
            format!("{:.2}x", run_masked(true, false, false)),
            format!("{:.2}x", run_masked(true, true, true)),
        ]);
    }
    format!(
        "## Fig 10 — ablation (speedup over S-1F1B; single phase vs co-optimization)\n\n{}\
         Paper: co-opt 1.32-1.37x; single-phase marginal (placement-only can slow down).\n",
        t.render()
    )
}

// ---------------------------------------------------------------------------
// Fig 13: pipeline generation time (exact solver vs AdaPtis).
// ---------------------------------------------------------------------------

pub fn fig13(ctx: &Ctx) -> String {
    let mut out = String::from("## Fig 13 — pipeline generation time\n\n");
    let mut t = Table::new(&[
        "Model",
        "P",
        "nmb",
        "exact nodes",
        "exact time",
        "(extrapolated)",
        "AdaPtis time",
    ]);
    let sizes: &[(Size, usize, usize)] = if ctx.fast {
        &[(Size::Small, 4, 64)]
    } else {
        &[(Size::Small, 4, 64), (Size::Medium, 8, 128), (Size::Large, 16, 256)]
    };
    for &(size, p, nmb) in sizes {
        let cfg = ModelCfg::table5(Family::NemotronH, size);
        let par = ParallelCfg { p, t: 2, d: 1, e: 1, nmb, mbs: 1, seq: 4096 };
        let prof = profile_for(&cfg, &par, ctx);

        // Exact search on shrunken instances (P=2, the largest depth
        // where the B&B still completes), then extrapolate to the
        // target nmb — the paper's curve_fit approach (§5.6).
        let (part, plac) = ilp::default_setup(&prof, 2);
        let budget = if ctx.fast { 2.0 } else { 8.0 };
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        let mut measured = String::new();
        let mut nodes = 0u64;
        for small_nmb in 2..=6 {
            let r = ilp::exact_schedule(&prof, &part, &plac, small_nmb, budget);
            if !r.complete {
                break;
            }
            xs.push(small_nmb as f64);
            ys.push(r.elapsed_s.max(1e-7));
            nodes = nodes.max(r.nodes);
            measured = format!("{:.4}s @nmb={}", r.elapsed_s, small_nmb);
        }
        let extrapolated = if xs.len() >= 2 {
            let (a, b) = fit_exponential(&xs, &ys);
            let est = a * (b * nmb as f64).exp();
            if est.is_finite() {
                format!("{:.1e} s @nmb={nmb}", est)
            } else {
                format!(">1e300 s @nmb={nmb}")
            }
        } else {
            "n/a (exact infeasible beyond nmb=2)".into()
        };

        let mut opts = GenOptions::new(p, nmb);
        opts.max_iters = 32;
        let g = generate(&prof, &opts);
        t.row(vec![
            format!("Nemotron-H ({})", size.name()),
            p.to_string(),
            nmb.to_string(),
            crate::util::fmt_si(nodes as f64),
            measured,
            extrapolated,
            {
                // "Candidates" counts pruned/cached elisions too, so the
                // figure stays comparable across engines and PRs.
                let cands = g.evals + g.evals_pruned + g.evals_cached;
                format!(
                    "{:.2} s ({} cands, {} simulated, {:.0} cands/s)",
                    g.elapsed_s,
                    cands,
                    g.evals,
                    cands as f64 / g.elapsed_s.max(1e-9)
                )
            },
        ]);
    }
    let _ = write!(out, "{}", t.render());
    out.push_str(
        "Exact JSSP search explodes exponentially in nmb; AdaPtis stays in seconds\n\
         even at paper-scale instances (<100 s in the paper).\n",
    );
    out
}

// ---------------------------------------------------------------------------
// Fig 14/15: strong and weak scaling.
// ---------------------------------------------------------------------------

fn scaling(ctx: &Ctx, weak: bool) -> String {
    let cfg = ModelCfg::table5(Family::NemotronH, Size::Large);
    let mut t = Table::new(&[
        "GPUs", "S-1F1B", "I-1F1B", "ZB", "Mist", "AdaPtis", "AdaPtis scaling",
    ]);
    let gpu_counts: &[usize] = if ctx.fast { &[8, 32] } else { &[8, 16, 32, 64, 128] };
    let mut ref_tput = None;
    for &gpus in gpu_counts {
        let p = 8usize;
        let tpar = 1usize;
        let d = gpus / (p * tpar);
        // Strong: fixed global batch G=64 split over more replicas.
        // Weak: G grows with the cluster (32 → 512).
        let g_seqs = if weak { 32 * gpus / 8 } else { 64 };
        let nmb = (g_seqs / d).max(1);
        let par = ParallelCfg { p, t: tpar, d, e: 1, nmb, mbs: 1, seq: 4096 };
        let prof = profile_for(&cfg, &par, ctx);
        let ts: Vec<Option<f64>> = Algo::paper_set()
            .iter()
            .map(|&a| {
                eval(&prof, a, p, nmb, if ctx.fast { 8 } else { 16 })
                    .map(|r| cluster_throughput(&r, &par, &ctx.hw))
            })
            .collect();
        let fmt =
            |o: &Option<f64>| o.map(crate::util::fmt_si).unwrap_or_else(|| "-".into());
        let ada = ts[4];
        if ref_tput.is_none() {
            ref_tput = ada;
        }
        let scale = match (ada, ref_tput) {
            (Some(a), Some(r)) => format!("{:.0}%", scaling_pct(a, r)),
            _ => "-".into(),
        };
        t.row(vec![
            gpus.to_string(),
            fmt(&ts[0]),
            fmt(&ts[1]),
            fmt(&ts[2]),
            fmt(&ts[3]),
            fmt(&ts[4]),
            scale,
        ]);
    }
    let (id, kind, paper) = if weak {
        ("Fig 15", "weak", "519% at 128 GPUs")
    } else {
        ("Fig 14", "strong", "534% at 128 GPUs (Mist 514%)")
    };
    format!(
        "## {id} — {kind} scaling (Nemotron-H Large, seq 4K, P=8)\n\n{}\
         Paper: AdaPtis {paper}.\n",
        t.render()
    )
}

pub fn fig14(ctx: &Ctx) -> String {
    scaling(ctx, false)
}

pub fn fig15(ctx: &Ctx) -> String {
    scaling(ctx, true)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fast_ctx() -> Ctx {
        Ctx { fast: true, ..Ctx::default() }
    }

    #[test]
    fn fig1_shows_heterogeneity_gap() {
        let s = fig1(&fast_ctx());
        assert!(s.contains("LLaMA-2") && s.contains("Nemotron-H"));
        // LLaMA-2's S-1F1B bubble must be the smallest in its column.
        let ratios: Vec<f64> = s
            .lines()
            .filter(|l| l.starts_with('|') && l.contains('%'))
            .map(|l| {
                let cell = l.split('|').nth(2).unwrap().trim();
                cell.trim_end_matches('%').parse::<f64>().unwrap()
            })
            .collect();
        assert_eq!(ratios.len(), 4);
        assert!(ratios[0] < ratios[1] && ratios[0] < ratios[3], "{ratios:?}");
    }

    #[test]
    fn fig3_monotone_speedups() {
        let s = fig3(&fast_ctx());
        let speedups: Vec<f64> = s
            .lines()
            .filter(|l| l.contains('x') && l.starts_with('|'))
            .filter_map(|l| {
                l.split('|')
                    .nth(3)
                    .and_then(|c| c.trim().trim_end_matches('x').parse::<f64>().ok())
            })
            .collect();
        assert_eq!(speedups.len(), 4, "{s}");
        assert!(speedups.windows(2).all(|w| w[1] >= w[0] - 1e-9), "{speedups:?}");
        assert!(*speedups.last().unwrap() > 1.15, "{speedups:?}");
    }

    #[test]
    fn table5_matches_paper_rows() {
        let s = table5(&fast_ctx());
        assert!(s.contains("| Gemma") && s.contains("1024K"));
        assert!(s.contains("| Nemotron-H | Large  | 112"));
    }
}
