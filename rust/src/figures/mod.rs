//! One harness per paper table/figure (DESIGN.md §12 experiment index).
//!
//! Each harness regenerates the rows/series of its figure from this
//! repo's implementations and returns a markdown report; the CLI
//! (`adaptis figures <id>`) prints it and optionally writes JSON +
//! chrome traces to an output directory.

pub mod ablations;
pub mod analytic;
pub mod fidelity;

use std::path::PathBuf;

use anyhow::{anyhow, Result};

use crate::config::HardwareCfg;

/// Harness context.
#[derive(Clone, Debug)]
pub struct Ctx {
    pub hw: HardwareCfg,
    /// Reduced sweeps for CI / smoke runs.
    pub fast: bool,
    /// Where to drop machine-readable outputs (traces, JSON).
    pub out_dir: Option<PathBuf>,
    /// Artifact root for the RealCluster figures (fig11/fig12).
    pub artifacts: PathBuf,
}

impl Default for Ctx {
    fn default() -> Self {
        Ctx {
            hw: HardwareCfg::default(),
            fast: false,
            out_dir: None,
            artifacts: PathBuf::from("artifacts"),
        }
    }
}

/// All figure ids, in paper order.
pub const ALL: &[&str] = &[
    "fig1", "fig3", "fig4", "table5", "fig8", "fig9", "fig10", "fig11", "fig12",
    "fig13", "fig14", "fig15", "ablations",
];

/// Run one harness by id ("all" runs everything).
pub fn run_figure(id: &str, ctx: &Ctx) -> Result<String> {
    match id {
        "fig1" => Ok(analytic::fig1(ctx)),
        "fig3" => Ok(analytic::fig3(ctx)),
        "fig4" => Ok(analytic::fig4(ctx)),
        "table5" => Ok(analytic::table5(ctx)),
        "fig8" => Ok(analytic::fig8(ctx)),
        "fig9" => Ok(analytic::fig9(ctx)),
        "fig10" => Ok(analytic::fig10(ctx)),
        "fig11" => fidelity::fig11(ctx),
        "fig12" => fidelity::fig12(ctx),
        "fig13" => Ok(analytic::fig13(ctx)),
        "fig14" => Ok(analytic::fig14(ctx)),
        "fig15" => Ok(analytic::fig15(ctx)),
        "ablations" => Ok(ablations::ablations(ctx)),
        "all" => {
            let mut out = String::new();
            for f in ALL {
                out.push_str(&run_figure(f, ctx)?);
                out.push('\n');
            }
            Ok(out)
        }
        _ => Err(anyhow!("unknown figure {id:?}; known: {ALL:?} or 'all'")),
    }
}

/// Write a side artifact if an output dir was requested.
pub fn write_artifact(ctx: &Ctx, name: &str, contents: &str) -> Result<()> {
    if let Some(dir) = &ctx.out_dir {
        std::fs::create_dir_all(dir)?;
        std::fs::write(dir.join(name), contents)?;
    }
    Ok(())
}
