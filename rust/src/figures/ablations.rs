//! Design-choice ablations (DESIGN.md §14) — beyond the paper's own
//! figures, these quantify the executor/generator mechanisms this repo
//! implements:
//!
//! - overlap-aware scheduling + receive hoisting on/off;
//! - deadlock-repair pass (validity, not speed — repaired programs must
//!   execute; unrepaired ones stall);
//! - ZB-style B/W split vs fused backward, including the block IR's
//!   ZB-V and memory-lean V instances (shapes the list scheduler
//!   cannot express);
//! - placement granularity (virtual-stage chunks v = 1, 2, 4);
//! - bottleneck-phase tuning vs exhaustive per-iteration move search.

use std::fmt::Write as _;

use super::Ctx;
use crate::cluster::sim::{run_timed, run_timed_with, SimOptions};
use crate::config::{Family, ModelCfg, ParallelCfg, Size};
use crate::executor::lower::{check_rendezvous, lower, LowerOptions};
use crate::generator::{generate, GenOptions};
use crate::metrics::Table;
use crate::model::build_model;
use crate::partition::uniform;
use crate::placement::{interleaved, sequential};
use crate::perfmodel::simulate;
use crate::profile::ProfiledData;
use crate::schedule::block::{v_mem, v_placement, zb_v};
use crate::schedule::greedy::{greedy_schedule, SchedKnobs};

pub fn ablations(ctx: &Ctx) -> String {
    let mut out = String::from("## Ablations (design choices, DESIGN.md §14)\n\n");
    let par = ParallelCfg { p: 4, t: 2, d: 1, e: 1, nmb: 16, mbs: 1, seq: 4096 };
    let cfg = ModelCfg::table5(Family::NemotronH, Size::Small);
    let prof = ProfiledData::analytical(&build_model(&cfg), &ctx.hw, &par);
    let part = uniform(prof.n_layers(), 4);
    let plac = sequential(4);

    // --- overlap-aware scheduling + hoisting --------------------------------
    let mut t = Table::new(&["configuration", "makespan (ms)", "vs best"]);
    let mut rows: Vec<(String, f64)> = Vec::new();
    for (name, overlap, window) in [
        ("serial comm, no hoist", false, 0usize),
        ("overlap-aware, no hoist", true, 0),
        ("overlap-aware, hoist w=3", true, 3),
        ("overlap-aware, hoist w=16", true, 16),
        ("overlap-aware, hoist unbounded", true, usize::MAX),
    ] {
        let knobs = SchedKnobs { overlap_aware: overlap, ..SchedKnobs::default() };
        let sch = greedy_schedule(&prof, &part, &plac, par.nmb, knobs);
        let prog = lower(&sch, &plac, LowerOptions { repair_deadlocks: true, hoist_window: window });
        let r = run_timed(&prof, &part, &prog, false).unwrap();
        rows.push((name.to_string(), r.makespan));
    }
    // The matched-assumption twin prices the same program with the perf
    // model's exact expression shapes — the floor rendezvous timing
    // approaches as hoisting deepens and contention stays unbound.
    {
        let sch = greedy_schedule(&prof, &part, &plac, par.nmb, SchedKnobs::default());
        let prog = lower(&sch, &plac, LowerOptions::default());
        let r = run_timed_with(&prof, &part, &prog, SimOptions::matched()).unwrap();
        rows.push(("matched-assumption twin (= perf model)".into(), r.makespan));
    }
    let best = rows.iter().map(|r| r.1).fold(f64::INFINITY, f64::min);
    for (name, ms) in rows {
        t.row(vec![name, format!("{:.2}", ms * 1e3), format!("{:+.1}%", 100.0 * (ms / best - 1.0))]);
    }
    let _ = write!(out, "### Communication overlap & receive hoisting\n\n{}\n", t.render());

    // --- B/W split vs fused backward ---------------------------------------
    let mut t = Table::new(&["backward", "makespan (ms)", "peak mem (GB)"]);
    for (name, split) in [("fused B+W", false), ("split B/W (ZB)", true)] {
        let knobs = SchedKnobs { split_bw: split, ..SchedKnobs::default() };
        let sch = greedy_schedule(&prof, &part, &plac, par.nmb, knobs);
        let r = simulate(&prof, &part, &plac, &sch, false).unwrap();
        t.row(vec![
            name.into(),
            format!("{:.2}", r.total * 1e3),
            format!("{:.1}", r.peak_mem() / 1e9),
        ]);
    }
    // The block IR's V family (DESIGN.md §5): split-backward shapes the
    // greedy list scheduler cannot express — ZB-V's depth-(2p−1) warmup
    // over the wave(p, 2) placement, and the memory-controllable
    // lifespan-1 variant that trades its bubbles back for stash.
    {
        let plac_v = v_placement(par.p);
        let part_v = crate::partition::balanced(&prof, plac_v.n_stages());
        for (name, block) in [
            ("ZB-V block (v_mem, lifespan 2p)", zb_v(par.p, par.nmb)),
            ("V block (v_mem, lifespan 1)", v_mem(par.p, par.nmb, 1)),
        ] {
            let (sch, _) = block
                .compile_on(&plac_v.device_of, par.p, par.nmb)
                .expect("the V family compiles at any (p, nmb)");
            let r = simulate(&prof, &part_v, &plac_v, &sch, false).unwrap();
            t.row(vec![
                name.into(),
                format!("{:.2}", r.total * 1e3),
                format!("{:.1}", r.peak_mem() / 1e9),
            ]);
        }
    }
    let _ = write!(out, "### Backward splitting\n\n{}\n", t.render());

    // --- memory caps: the throughput/memory frontier -------------------------
    // Tightening the per-device capacity forces the generator onto
    // memory-leaner plans: makespan may rise, peak memory must fall
    // under the cap (memory/ feasibility gate).
    let mut t = Table::new(&["cap (× free peak)", "step (ms)", "peak mem (GB)", "headroom (GB)"]);
    let free = {
        let mut opts = GenOptions::new(par.p, par.nmb);
        opts.max_iters = if ctx.fast { 4 } else { 12 };
        generate(&prof, &opts)
    };
    let free_peak = free.report.peak_mem();
    for frac in [1.0f64, 0.9, 0.8] {
        let mut opts = GenOptions::new(par.p, par.nmb);
        opts.max_iters = if ctx.fast { 4 } else { 12 };
        opts.mem_caps = Some(crate::memory::MemCaps::uniform(par.p, free_peak * frac));
        let g = generate(&prof, &opts);
        let peak = g.report.peak_mem();
        t.row(vec![
            format!("{frac:.2}{}", if g.report.oom { " [infeasible]" } else { "" }),
            format!("{:.2}", g.report.total * 1e3),
            format!("{:.2}", peak / 1e9),
            format!("{:.2}", g.report.min_headroom() / 1e9),
        ]);
    }
    let _ = write!(out, "### Memory caps (generator feasibility gate)\n\n{}\n", t.render());

    // --- placement granularity ----------------------------------------------
    let mut t = Table::new(&["virtual stages/device", "makespan (ms)", "bubble"]);
    for v in [1usize, 2, 4] {
        let plac_v = if v == 1 { sequential(4) } else { interleaved(4, v) };
        let part_v = crate::partition::balanced(&prof, plac_v.n_stages());
        let sch = greedy_schedule(&prof, &part_v, &plac_v, par.nmb, SchedKnobs::default());
        let r = simulate(&prof, &part_v, &plac_v, &sch, false).unwrap();
        t.row(vec![
            v.to_string(),
            format!("{:.2}", r.total * 1e3),
            format!("{:.1}%", 100.0 * r.bubble_ratio()),
        ]);
    }
    let _ = write!(out, "### Placement granularity (grouped permutation depth)\n\n{}\n", t.render());

    // --- deadlock repair -----------------------------------------------------
    let sch = greedy_schedule(&prof, &part, &plac, par.nmb, SchedKnobs::default());
    let unrepaired =
        lower(&sch, &plac, LowerOptions { repair_deadlocks: false, hoist_window: 16 });
    let mut fixed = unrepaired.clone();
    let repairs = crate::executor::lower::repair_deadlocks(&mut fixed);
    let _ = write!(
        out,
        "### Deadlock repair\n\nunrepaired program executes: {}; after one \
         resumable repair pass ({repairs} recv hoists): {}\n\n",
        check_rendezvous(&unrepaired).is_ok(),
        check_rendezvous(&fixed).is_ok()
    );

    // --- generator budget ----------------------------------------------------
    let mut t =
        Table::new(&["max iters", "step time (ms)", "gen time", "candidates", "simulated"]);
    for iters in [1usize, 4, 16, 64] {
        let mut opts = GenOptions::new(par.p, par.nmb);
        opts.max_iters = iters;
        let g = generate(&prof, &opts);
        t.row(vec![
            iters.to_string(),
            format!("{:.2}", g.report.total * 1e3),
            crate::util::fmt_time(g.elapsed_s),
            // Candidates considered (incl. pruned/cached) vs actually
            // simulated — the gap is the search-acceleration win.
            (g.evals + g.evals_pruned + g.evals_cached).to_string(),
            g.evals.to_string(),
        ]);
    }
    let _ = write!(out, "### Generator tuning budget\n\n{}", t.render());
    out
}
