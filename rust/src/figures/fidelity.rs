//! RealCluster fidelity figures (Fig 11 traces, Fig 12 performance-model
//! accuracy).
//!
//! Host caveat (DESIGN.md §Substitutions): this testbed exposes a
//! SINGLE CPU core, so RealCluster "devices" (OS threads) are
//! time-sliced — wall-clock cannot exhibit pipeline concurrency.  The
//! fidelity experiments therefore split into:
//!
//! 1. **model-vs-executor** (the paper's Fig 12 claim): the Pipeline
//!    Performance Model (schedule-level, Algorithm 1) against the
//!    *instruction-level* timed executor (`cluster::sim::run_timed`,
//!    rendezvous comm) — two independently implemented engines — on
//!    per-layer costs *measured* from the real PJRT artifacts;
//! 2. **wall-clock check**: on one core the real step time must equal
//!    the serialized work Σ_d C_d (+ dispatch overhead); this validates
//!    the measured per-op costs against reality.
//!
//! Fig 11 renders three trace pairs per method: real (wall-clock,
//! serialized), instruction-level virtual time, and the performance
//! model's simulated trace.

use std::sync::Arc;

use anyhow::{anyhow, Result};

use super::{write_artifact, Ctx};
use crate::baselines::Method;
use crate::cluster::sim::{run_timed, run_timed_with, SimOptions};
use crate::executor::lower::{lower, LowerOptions};
use crate::metrics::Table;
use crate::perfmodel::simulate;
use crate::runtime::ArtifactStore;
use crate::trainer::{self, train, TrainMethod, TrainOptions};
use crate::util::stats::mean;
use crate::util::trace::{ascii_timeline, to_chrome_trace};

const TAG: &str = "fidelity";

fn open_store(ctx: &Ctx) -> Result<Arc<ArtifactStore>> {
    let dir = ctx.artifacts.join(TAG);
    ArtifactStore::open(&dir).map(Arc::new).map_err(|e| {
        anyhow!(
            "{e}\nfig11/fig12 need the `{TAG}` artifacts — run `make artifacts` first"
        )
    })
}

fn methods() -> Vec<(String, TrainMethod)> {
    vec![
        ("S-1F1B".into(), TrainMethod::Baseline(Method::S1F1B)),
        ("ZB".into(), TrainMethod::Baseline(Method::ZB)),
        ("Mist".into(), TrainMethod::Baseline(Method::Mist)),
        ("AdaPtis".into(), TrainMethod::AdaPtis),
    ]
}

/// Fig 11: real vs simulated pipeline traces.
pub fn fig11(ctx: &Ctx) -> Result<String> {
    let store = open_store(ctx)?;
    let kinds = trainer::demo_model(TAG);
    let mut out = String::from(
        "## Fig 11 — real vs simulated traces (fidelity model, P=4)\n\n\
         Host note: single-core testbed ⇒ the real (wall-clock) trace is\n\
         time-sliced; compare its *order* with the simulated traces, and\n\
         the two virtual-time traces with each other.\n\n",
    );
    for (name, method) in methods() {
        if name == "ZB" {
            continue; // Fig 11 shows S-1F1B / Mist / AdaPtis, like the paper
        }
        let opts = TrainOptions {
            p: 4,
            nmb: if ctx.fast { 4 } else { 8 },
            steps: 3,
            lr: 0.05,
            seed: 0,
            method,
            collect_trace: true,
            live_log: false,
            monitor: None,
        };
        let r = train(store.clone(), &kinds, &opts)?;
        // Performance-model simulated trace (measured profile).
        let sim = simulate(
            &r.profile,
            &r.pipeline.partition,
            &r.pipeline.placement,
            &r.pipeline.schedule,
            true,
        )
        .map_err(|e| anyhow!("{e}"))?;
        // Instruction-level virtual-time trace.
        let prog =
            lower(&r.pipeline.schedule, &r.pipeline.placement, LowerOptions::default());
        let exec = run_timed(&r.profile, &r.pipeline.partition, &prog, true)
            .map_err(|e| anyhow!("{e}"))?;
        out.push_str(&format!("### {name}\nreal (wall-clock, time-sliced core):\n"));
        out.push_str(&ascii_timeline(&r.trace, opts.p, 100));
        out.push_str("instruction-level executor (virtual time):\n");
        out.push_str(&ascii_timeline(&exec.events, opts.p, 100));
        out.push_str("performance model (simulated):\n");
        out.push_str(&ascii_timeline(&sim.events, opts.p, 100));
        out.push('\n');
        write_artifact(ctx, &format!("fig11_{name}_real.trace.json"), &to_chrome_trace(&r.trace))?;
        write_artifact(ctx, &format!("fig11_{name}_exec.trace.json"), &to_chrome_trace(&exec.events))?;
        write_artifact(ctx, &format!("fig11_{name}_sim.trace.json"), &to_chrome_trace(&sim.events))?;
    }
    out.push_str("Chrome traces written next to this report when --out is given.\n");
    Ok(out)
}

/// Fig 12: performance-model fidelity.
pub fn fig12(ctx: &Ctx) -> Result<String> {
    let store = open_store(ctx)?;
    let kinds = trainer::demo_model(TAG);
    let mut t = Table::new(&[
        "Method",
        "perfmodel (ms)",
        "executor (ms)",
        "model err",
        "matched gap",
        "serial pred (ms)",
        "wall-clock (ms)",
        "wall err",
    ]);
    let mut model_errs = Vec::new();
    let mut wall_errs = Vec::new();
    for (name, method) in methods() {
        let opts = TrainOptions {
            p: 4,
            nmb: if ctx.fast { 4 } else { 8 },
            steps: if ctx.fast { 4 } else { 6 },
            lr: 0.05,
            seed: 0,
            method,
            collect_trace: false,
            live_log: false,
            monitor: None,
        };
        let r = train(store.clone(), &kinds, &opts)?;
        let pm = simulate(
            &r.profile,
            &r.pipeline.partition,
            &r.pipeline.placement,
            &r.pipeline.schedule,
            false,
        )
        .map_err(|e| anyhow!("{e}"))?;
        let prog =
            lower(&r.pipeline.schedule, &r.pipeline.placement, LowerOptions::default());
        // Rendezvous timing (link contention, post-gated transfers).
        let exec = run_timed(&r.profile, &r.pipeline.partition, &prog, false)
            .map_err(|e| anyhow!("{e}"))?;
        // Matched-assumption twin: must agree with the model bitwise.
        let exec_m =
            run_timed_with(&r.profile, &r.pipeline.partition, &prog, SimOptions::matched())
                .map_err(|e| anyhow!("{e}"))?;
        let matched_gap = 100.0 * (pm.total - exec_m.makespan).abs() / pm.total;
        // (1) model vs instruction-level executor, virtual time.
        let model_err = 100.0 * (pm.total - exec.makespan).abs() / exec.makespan;
        model_errs.push(model_err);
        // (2) single-core wall clock vs serialized compute prediction.
        let serial_pred: f64 = pm.busy_d.iter().sum();
        let wall = mean(&r.step_times[1..]);
        let wall_err = 100.0 * (serial_pred - wall).abs() / wall;
        wall_errs.push(wall_err);
        t.row(vec![
            name,
            format!("{:.2}", pm.total * 1e3),
            format!("{:.2}", exec.makespan * 1e3),
            format!("{:.1}%", model_err),
            format!("{:.2}%", matched_gap),
            format!("{:.1}", serial_pred * 1e3),
            format!("{:.1}", wall * 1e3),
            format!("{:.1}%", wall_err),
        ]);
    }
    Ok(format!(
        "## Fig 12 — performance-model fidelity (fidelity model)\n\n{}\
         model-vs-executor mean error: {:.2}% (paper: 2.12% avg, ≤6.6% max);\n\
         matched-assumption twin gap is identically 0 (bitwise, pinned by\n\
         tests/executor_differential.rs);\n\
         wall-clock (single-core serialization) mean error: {:.2}%.\n",
        t.render(),
        mean(&model_errs),
        mean(&wall_errs)
    ))
}
