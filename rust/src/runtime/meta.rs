//! Parsed form of `artifacts/<tag>/meta.json` — the calling convention
//! contract between `python/compile/aot.py` and the rust runtime.

use std::collections::BTreeMap;

use crate::util::json::Json;

/// Element dtype of an artifact tensor.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
}

/// One input/output slot of an executable.
#[derive(Clone, Debug)]
pub struct TensorSig {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: Dtype,
    /// "param" | "act" | "ids" | "targets" | "gy" | "gx" | "grad" |
    /// "loss" | "lr".
    pub role: String,
}

impl TensorSig {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Signature of one executable (kind × op).
#[derive(Clone, Debug)]
pub struct OpSig {
    pub file: String,
    pub inputs: Vec<TensorSig>,
    pub outputs: Vec<TensorSig>,
}

/// Static dims of the artifact family (mirrors python dims.ModelDims).
#[derive(Clone, Debug)]
pub struct Dims {
    pub tag: String,
    pub vocab: usize,
    pub hidden: usize,
    pub ffn_hidden: usize,
    pub heads: usize,
    pub head_dim: usize,
    pub kv_latent: usize,
    pub ssm_state: usize,
    pub experts: usize,
    pub moe_hidden: usize,
    pub seq: usize,
    pub microbatch: usize,
}

/// Whole-family metadata.
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub dims: Dims,
    /// kind -> op -> signature.
    pub kinds: BTreeMap<String, BTreeMap<String, OpSig>>,
    /// kind -> ordered (param name, shape).
    pub params: BTreeMap<String, Vec<(String, Vec<usize>)>>,
    /// kind -> parameter count.
    pub param_counts: BTreeMap<String, usize>,
}

impl ArtifactMeta {
    pub fn parse(text: &str) -> Result<ArtifactMeta, String> {
        let v = Json::parse(text)?;
        let dims_o = v.get("dims").ok_or("missing dims")?;
        let gd = |k: &str| -> Result<usize, String> {
            dims_o.get(k).and_then(Json::as_usize).ok_or(format!("dims.{k} missing"))
        };
        let dims = Dims {
            tag: dims_o
                .get("tag")
                .and_then(Json::as_str)
                .unwrap_or_default()
                .to_string(),
            vocab: gd("vocab")?,
            hidden: gd("hidden")?,
            ffn_hidden: gd("ffn_hidden")?,
            heads: gd("heads")?,
            head_dim: gd("head_dim")?,
            kv_latent: gd("kv_latent")?,
            ssm_state: gd("ssm_state")?,
            experts: gd("experts")?,
            moe_hidden: gd("moe_hidden")?,
            seq: gd("seq")?,
            microbatch: gd("microbatch")?,
        };
        let mut kinds = BTreeMap::new();
        let mut params = BTreeMap::new();
        let kv = v.get("kinds").and_then(Json::as_obj).ok_or("missing kinds")?;
        for (kind, ko) in kv {
            let mut ops = BTreeMap::new();
            let ops_o = ko.get("ops").and_then(Json::as_obj).ok_or("missing ops")?;
            for (op, oo) in ops_o {
                ops.insert(op.clone(), parse_op(oo)?);
            }
            kinds.insert(kind.clone(), ops);
            let ps = ko.get("params").and_then(Json::as_arr).ok_or("missing params")?;
            let plist = ps
                .iter()
                .map(|e| {
                    let name = e.at(&["0"]).and_then(Json::as_str).ok_or("param name")?;
                    let shape = e
                        .at(&["1"])
                        .and_then(Json::as_arr)
                        .ok_or("param shape")?
                        .iter()
                        .map(|x| x.as_usize().unwrap_or(0))
                        .collect();
                    Ok((name.to_string(), shape))
                })
                .collect::<Result<Vec<_>, String>>()?;
            params.insert(kind.clone(), plist);
        }
        let mut param_counts = BTreeMap::new();
        if let Some(pc) = v.get("param_counts").and_then(Json::as_obj) {
            for (k, n) in pc {
                param_counts.insert(k.clone(), n.as_usize().unwrap_or(0));
            }
        }
        Ok(ArtifactMeta { dims, kinds, params, param_counts })
    }

    pub fn op(&self, kind: &str, op: &str) -> Option<&OpSig> {
        self.kinds.get(kind)?.get(op)
    }

    pub fn ops_of(&self, kind: &str) -> Option<&BTreeMap<String, OpSig>> {
        self.kinds.get(kind)
    }

    /// Ordered parameter specs of a layer kind.
    pub fn params_of(&self, kind: &str) -> &[(String, Vec<usize>)] {
        self.params.get(kind).map(|v| v.as_slice()).unwrap_or(&[])
    }
}

fn parse_op(o: &Json) -> Result<OpSig, String> {
    let file =
        o.get("file").and_then(Json::as_str).ok_or("op.file missing")?.to_string();
    let sigs = |key: &str| -> Result<Vec<TensorSig>, String> {
        o.get(key)
            .and_then(Json::as_arr)
            .ok_or(format!("op.{key} missing"))?
            .iter()
            .map(parse_sig)
            .collect()
    };
    Ok(OpSig { file, inputs: sigs("inputs")?, outputs: sigs("outputs")? })
}

fn parse_sig(o: &Json) -> Result<TensorSig, String> {
    Ok(TensorSig {
        name: o.get("name").and_then(Json::as_str).ok_or("sig.name")?.to_string(),
        shape: o
            .get("shape")
            .and_then(Json::as_arr)
            .ok_or("sig.shape")?
            .iter()
            .map(|x| x.as_usize().unwrap_or(0))
            .collect(),
        dtype: match o.get("dtype").and_then(Json::as_str) {
            Some("i32") => Dtype::I32,
            _ => Dtype::F32,
        },
        role: o.get("role").and_then(Json::as_str).unwrap_or("act").to_string(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "tag": "t", "dims": {"tag":"t","vocab":512,"hidden":32,"ffn_hidden":64,
        "heads":2,"head_dim":16,"kv_latent":16,"ssm_state":8,"experts":2,
        "moe_hidden":48,"seq":16,"microbatch":2},
      "param_counts": {"ffn": 100},
      "kinds": {"ffn": {"params": [["ln_g",[32]],["w1",[32,64]]],
        "ops": {"fwd": {"file":"ffn_fwd.hlo.txt",
          "inputs":[{"name":"ln_g","shape":[32],"dtype":"f32","role":"param"},
                    {"name":"x","shape":[2,16,32],"dtype":"f32","role":"act"}],
          "outputs":[{"name":"y","shape":[2,16,32],"dtype":"f32","role":"act"}]}}}}
    }"#;

    #[test]
    fn parses_sample() {
        let m = ArtifactMeta::parse(SAMPLE).unwrap();
        assert_eq!(m.dims.vocab, 512);
        assert_eq!(m.dims.microbatch, 2);
        let op = m.op("ffn", "fwd").unwrap();
        assert_eq!(op.inputs.len(), 2);
        assert_eq!(op.inputs[1].shape, vec![2, 16, 32]);
        assert_eq!(op.inputs[1].dtype, Dtype::F32);
        assert_eq!(m.params_of("ffn").len(), 2);
        assert_eq!(m.param_counts["ffn"], 100);
    }

    #[test]
    fn missing_fields_error() {
        assert!(ArtifactMeta::parse("{}").is_err());
    }
}
