//! PJRT runtime: load the AOT HLO-text artifacts emitted by
//! `python/compile/aot.py` and execute them on the CPU PJRT client.
//!
//! Interchange is HLO **text** (see aot.py: jax ≥ 0.5 emits protos with
//! 64-bit ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids).  Every executable returns a root tuple — outputs are
//! decomposed to host tensors after each call (CPU PJRT device memory
//! *is* host memory, so this costs one memcpy per output).
//!
//! One executable exists per (layer kind, op); a pipeline stage is run
//! by chaining layer executables — which is exactly what lets one
//! artifact set serve every model partition the generator emits.

pub mod meta;
pub mod tensor;

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use anyhow::{anyhow, Context, Result};

pub use meta::{ArtifactMeta, OpSig, TensorSig};
pub use tensor::Tensor;

/// Loaded artifact family: PJRT client + lazily compiled executables.
pub struct ArtifactStore {
    dir: PathBuf,
    pub meta: ArtifactMeta,
    client: xla::PjRtClient,
    // op key "kind_op" -> compiled executable (lazy).
    exes: Mutex<HashMap<String, std::sync::Arc<xla::PjRtLoadedExecutable>>>,
}

// The PJRT CPU client is thread-safe (TFRT CPU client); the xla crate
// just doesn't mark its wrappers Send/Sync.  We only share the store
// behind &self across executor threads.
unsafe impl Send for ArtifactStore {}
unsafe impl Sync for ArtifactStore {}

impl ArtifactStore {
    /// Open `artifacts/<tag>` and parse its meta.json.
    pub fn open(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let meta_path = dir.join("meta.json");
        let text = std::fs::read_to_string(&meta_path)
            .with_context(|| format!("reading {}", meta_path.display()))?;
        let meta = ArtifactMeta::parse(&text)
            .map_err(|e| anyhow!("parsing {}: {e}", meta_path.display()))?;
        let client = xla::PjRtClient::cpu()?;
        Ok(ArtifactStore { dir, meta, client, exes: Mutex::new(HashMap::new()) })
    }

    pub fn client(&self) -> &xla::PjRtClient {
        &self.client
    }

    /// Compile (or fetch cached) the executable for `kind`/`op`.
    pub fn executable(
        &self,
        kind: &str,
        op: &str,
    ) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
        let key = format!("{kind}_{op}");
        if let Some(e) = self.exes.lock().unwrap().get(&key) {
            return Ok(e.clone());
        }
        let sig = self
            .meta
            .op(kind, op)
            .ok_or_else(|| anyhow!("no artifact for {kind}/{op}"))?;
        let path = self.dir.join(&sig.file);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow!("loading {}: {e}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = std::sync::Arc::new(self.client.compile(&comp)?);
        self.exes.lock().unwrap().insert(key, exe.clone());
        Ok(exe)
    }

    /// Pre-compile every op of the given kinds (avoids first-use lag on
    /// the training hot path).
    pub fn warmup(&self, kinds: &[&str]) -> Result<()> {
        for kind in kinds {
            let ops: Vec<String> = self
                .meta
                .ops_of(kind)
                .ok_or_else(|| anyhow!("unknown kind {kind}"))?
                .keys()
                .cloned()
                .collect();
            for op in ops {
                self.executable(kind, &op)?;
            }
        }
        Ok(())
    }

    /// Execute `kind/op` on host tensors (by reference — parameters are
    /// large and must not be cloned per call), returning the decomposed
    /// output tuple as host tensors.
    pub fn run_refs(&self, kind: &str, op: &str, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
        let exe = self.executable(kind, op)?;
        let sig = self.meta.op(kind, op).unwrap();
        if inputs.len() != sig.inputs.len() {
            return Err(anyhow!(
                "{kind}/{op}: expected {} inputs, got {}",
                sig.inputs.len(),
                inputs.len()
            ));
        }
        let lits: Vec<xla::Literal> = inputs
            .iter()
            .zip(&sig.inputs)
            .map(|(t, s)| t.to_literal(s))
            .collect::<Result<_>>()?;
        let out = exe.execute::<xla::Literal>(&lits)?;
        let root = out[0][0].to_literal_sync()?;
        let parts = root.to_tuple()?;
        parts
            .into_iter()
            .zip(&sig.outputs)
            .map(|(l, s)| Tensor::from_literal(&l, s))
            .collect()
    }

    /// Owned-slice convenience wrapper around [`Self::run_refs`].
    pub fn run(&self, kind: &str, op: &str, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let refs: Vec<&Tensor> = inputs.iter().collect();
        self.run_refs(kind, op, &refs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn micro_dir() -> Option<PathBuf> {
        let d = PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/micro"));
        d.join("meta.json").exists().then_some(d)
    }

    #[test]
    fn roundtrip_ffn_fwd() {
        let Some(dir) = micro_dir() else {
            eprintln!("skipping: run `make artifacts` first");
            return;
        };
        let store = ArtifactStore::open(dir).unwrap();
        let d = &store.meta.dims;
        let sig = store.meta.op("ffn", "fwd").unwrap().clone();
        // Zero params except ln gain=1 ⇒ output == input (residual).
        let mut inputs = Vec::new();
        for ts in &sig.inputs {
            let t = match ts.name.as_str() {
                "ln_g" => Tensor::ones(&ts.shape),
                "x" => Tensor::iota(&ts.shape, 0.01),
                _ => Tensor::zeros_like_sig(ts),
            };
            inputs.push(t);
        }
        let out = store.run("ffn", "fwd", &inputs).unwrap();
        assert_eq!(out.len(), 1);
        let x = &inputs[sig.inputs.len() - 1];
        let y = &out[0];
        assert_eq!(y.shape, vec![d.microbatch, d.seq, d.hidden]);
        // gelu(0@w1+0)@w2+0 = 0 ⇒ y == x.
        for (a, b) in x.f32s().iter().zip(y.f32s()) {
            assert!((a - b).abs() < 1e-5, "{a} vs {b}");
        }
    }

    #[test]
    fn head_fwdbwd_shapes() {
        let Some(dir) = micro_dir() else {
            eprintln!("skipping: run `make artifacts` first");
            return;
        };
        let store = ArtifactStore::open(dir).unwrap();
        let sig = store.meta.op("head", "fwdbwd").unwrap().clone();
        let inputs: Vec<Tensor> = sig
            .inputs
            .iter()
            .map(|ts| match ts.name.as_str() {
                "ln_g" => Tensor::ones(&ts.shape),
                "wout" => Tensor::iota(&ts.shape, 1e-4),
                "x" => Tensor::iota(&ts.shape, 0.01),
                _ => Tensor::zeros_like_sig(ts),
            })
            .collect();
        let out = store.run("head", "fwdbwd", &inputs).unwrap();
        assert_eq!(out.len(), sig.outputs.len());
        let loss = out[0].f32s()[0];
        assert!(loss.is_finite() && loss > 0.0, "loss={loss}");
    }
}
