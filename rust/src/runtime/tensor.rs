//! Host tensor: the executor/trainer-side value passed between layer
//! executables and across pipeline P2P channels.

use anyhow::{anyhow, Result};

use super::meta::{Dtype, TensorSig};

/// Host data buffer.
#[derive(Clone, Debug)]
pub enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

/// A host tensor (row-major).
#[derive(Clone, Debug)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Data,
}

impl Tensor {
    pub fn f32(shape: &[usize], data: Vec<f32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Tensor { shape: shape.to_vec(), data: Data::F32(data) }
    }

    pub fn i32(shape: &[usize], data: Vec<i32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Tensor { shape: shape.to_vec(), data: Data::I32(data) }
    }

    pub fn zeros(shape: &[usize]) -> Tensor {
        Tensor::f32(shape, vec![0.0; shape.iter().product()])
    }

    pub fn ones(shape: &[usize]) -> Tensor {
        Tensor::f32(shape, vec![1.0; shape.iter().product()])
    }

    /// 0, step, 2·step, … — handy deterministic test data.
    pub fn iota(shape: &[usize], step: f32) -> Tensor {
        let n: usize = shape.iter().product();
        Tensor::f32(shape, (0..n).map(|i| i as f32 * step).collect())
    }

    pub fn zeros_like_sig(sig: &TensorSig) -> Tensor {
        match sig.dtype {
            Dtype::F32 => Tensor::f32(&sig.shape, vec![0.0; sig.numel()]),
            Dtype::I32 => Tensor::i32(&sig.shape, vec![0; sig.numel()]),
        }
    }

    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn size_bytes(&self) -> usize {
        self.numel() * 4
    }

    pub fn f32s(&self) -> &[f32] {
        match &self.data {
            Data::F32(v) => v,
            Data::I32(_) => panic!("tensor is i32"),
        }
    }

    pub fn f32s_mut(&mut self) -> &mut [f32] {
        match &mut self.data {
            Data::F32(v) => v,
            Data::I32(_) => panic!("tensor is i32"),
        }
    }

    pub fn i32s(&self) -> &[i32] {
        match &self.data {
            Data::I32(v) => v,
            Data::F32(_) => panic!("tensor is f32"),
        }
    }

    pub fn scalar_f32(&self) -> f32 {
        assert_eq!(self.numel(), 1);
        self.f32s()[0]
    }

    /// `self += other` (f32, elementwise) — gradient accumulation.
    pub fn add_assign(&mut self, other: &Tensor) {
        assert_eq!(self.shape, other.shape);
        let o = other.f32s();
        for (a, b) in self.f32s_mut().iter_mut().zip(o) {
            *a += b;
        }
    }

    /// `self -= lr * g` — the host-side SGD fallback.
    pub fn sgd_step(&mut self, g: &Tensor, lr: f32) {
        assert_eq!(self.shape, g.shape);
        let gs = g.f32s();
        for (p, gi) in self.f32s_mut().iter_mut().zip(gs) {
            *p -= lr * gi;
        }
    }

    /// Upload to an XLA literal matching `sig` (shape/dtype checked).
    pub fn to_literal(&self, sig: &TensorSig) -> Result<xla::Literal> {
        if self.shape != sig.shape {
            return Err(anyhow!(
                "{}: shape {:?} != artifact {:?}",
                sig.name,
                self.shape,
                sig.shape
            ));
        }
        let dims: Vec<i64> = self.shape.iter().map(|&d| d as i64).collect();
        let lit = match (&self.data, sig.dtype) {
            (Data::F32(v), Dtype::F32) => xla::Literal::vec1(v),
            (Data::I32(v), Dtype::I32) => xla::Literal::vec1(v),
            _ => return Err(anyhow!("{}: dtype mismatch", sig.name)),
        };
        if dims.is_empty() {
            // reshape(&[]) yields the scalar literal.
            Ok(lit.reshape(&[])?)
        } else {
            Ok(lit.reshape(&dims)?)
        }
    }

    /// Download from an XLA literal.
    pub fn from_literal(lit: &xla::Literal, sig: &TensorSig) -> Result<Tensor> {
        let data = match sig.dtype {
            Dtype::F32 => Data::F32(lit.to_vec::<f32>()?),
            Dtype::I32 => Data::I32(lit.to_vec::<i32>()?),
        };
        let t = Tensor { shape: sig.shape.clone(), data };
        if t.numel()
            != match &t.data {
                Data::F32(v) => v.len(),
                Data::I32(v) => v.len(),
            }
        {
            return Err(anyhow!("{}: element count mismatch", sig.name));
        }
        Ok(t)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulate_and_sgd() {
        let mut g = Tensor::zeros(&[2, 2]);
        g.add_assign(&Tensor::ones(&[2, 2]));
        g.add_assign(&Tensor::ones(&[2, 2]));
        assert_eq!(g.f32s(), &[2.0; 4]);
        let mut p = Tensor::ones(&[2, 2]);
        p.sgd_step(&g, 0.25);
        assert_eq!(p.f32s(), &[0.5; 4]);
    }

    #[test]
    fn iota_steps() {
        let t = Tensor::iota(&[3], 0.5);
        assert_eq!(t.f32s(), &[0.0, 0.5, 1.0]);
    }

    #[test]
    #[should_panic]
    fn dtype_guard() {
        Tensor::i32(&[1], vec![1]).f32s();
    }
}
