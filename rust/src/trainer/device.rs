//! Device worker: one OS thread of the RealCluster.  Executes its
//! per-device instruction list against the PJRT artifacts, owning the
//! parameters/gradients of its layers and the activation stashes the
//! rematerialised backward needs.

use std::collections::HashMap;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::cluster::real::{Fabric, Mailbox, Tag};
use crate::executor::Instr;
use crate::runtime::{ArtifactStore, Tensor};
use crate::schedule::OpKind;
use crate::util::rng::Rng;

/// Static configuration handed to each worker thread.
#[derive(Clone)]
pub struct WorkerCfg {
    pub id: usize,
    /// Global layer kinds (flat model), by layer index.
    pub kinds: Vec<&'static str>,
    /// Partition bounds (stage s = layers bounds[s]..bounds[s+1]).
    pub bounds: Vec<usize>,
    /// Stage → device.
    pub device_of: Vec<usize>,
    /// This device's lowered instruction list.
    pub program: Vec<Instr>,
    pub steps: usize,
    pub nmb: usize,
    pub lr: f32,
    pub split_bw: bool,
    pub seed: u64,
    /// Collect wall-clock compute events (Fig 11 real traces).
    pub collect_timing: bool,
}

/// Timing record: (op code 0/1/2, mb, stage, start µs, dur µs).
pub type TimingRow = [f32; 5];

struct LayerState {
    #[allow(dead_code)]
    kind: &'static str,
    params: Vec<Tensor>,
    grads: Vec<Tensor>,
}

/// Deterministic parameter init (matches the python scheme in spirit:
/// gains 1, biases 0, S4D a_log, He-scaled matrices).
pub fn init_layer_params(
    store: &ArtifactStore,
    kind: &str,
    layer_idx: usize,
    seed: u64,
) -> Vec<Tensor> {
    let mut rng = Rng::new(seed ^ (layer_idx as u64).wrapping_mul(0x9E37_79B9));
    store
        .meta
        .params_of(kind)
        .iter()
        .map(|(name, shape)| {
            let n: usize = shape.iter().product();
            let data: Vec<f32> = match name.as_str() {
                "ln_g" | "dskip" => vec![1.0; n],
                "b1" | "b2" | "bdt" => vec![0.0; n],
                "wdt" => vec![0.5; n],
                "a_log" => {
                    let cols = *shape.last().unwrap();
                    (0..n).map(|i| (((i % cols) + 1) as f32).ln()).collect()
                }
                _ => {
                    let fan_in =
                        if shape.len() >= 2 { shape[shape.len() - 2] } else { shape[0] };
                    let scale = 1.0 / (fan_in as f32).sqrt();
                    (0..n).map(|_| rng.normal() as f32 * scale).collect()
                }
            };
            Tensor::f32(shape, data)
        })
        .collect()
}

pub struct Worker {
    cfg: WorkerCfg,
    store: Arc<ArtifactStore>,
    fabric: Fabric,
    mailbox: Mailbox,
    epoch: Instant,
    layers: HashMap<usize, LayerState>,
    /// (mb, layer) → stashed forward input.
    x_stash: HashMap<(u32, usize), Tensor>,
    /// (mb, layer) → stashed upstream gradient (split-B/W mode).
    gy_stash: HashMap<(u32, usize), Tensor>,
    /// (mb, stage) → activation from a colocated previous stage.
    local_act: HashMap<(u32, u32), Tensor>,
    /// (mb, stage) → gradient from a colocated next stage.
    local_gy: HashMap<(u32, u32), Tensor>,
    /// (mb, stage, kind) → tensor awaiting its Send instruction.
    outbox: HashMap<(u32, u32, OpKind), Tensor>,
    /// (mb) → targets (head device only).
    targets: HashMap<u32, Tensor>,
    timing: Vec<TimingRow>,
    driver: usize,
}

impl Worker {
    pub fn new(
        cfg: WorkerCfg,
        store: Arc<ArtifactStore>,
        fabric: Fabric,
        mailbox: Mailbox,
        epoch: Instant,
    ) -> Worker {
        let mut layers = HashMap::new();
        for s in 0..cfg.device_of.len() {
            if cfg.device_of[s] != cfg.id {
                continue;
            }
            for l in cfg.bounds[s]..cfg.bounds[s + 1] {
                let kind = cfg.kinds[l];
                let params = init_layer_params(&store, kind, l, cfg.seed);
                let grads = params
                    .iter()
                    .map(|p| Tensor::zeros(&p.shape))
                    .collect();
                layers.insert(l, LayerState { kind, params, grads });
            }
        }
        Worker {
            driver: fabric.senders.len() - 1,
            cfg,
            store,
            fabric,
            mailbox,
            epoch,
            layers,
            x_stash: HashMap::new(),
            gy_stash: HashMap::new(),
            local_act: HashMap::new(),
            local_gy: HashMap::new(),
            outbox: HashMap::new(),
            targets: HashMap::new(),
            timing: Vec::new(),
        }
    }

    fn stage_layers(&self, stage: u32) -> std::ops::Range<usize> {
        self.cfg.bounds[stage as usize]..self.cfg.bounds[stage as usize + 1]
    }

    fn is_first_stage(&self, stage: u32) -> bool {
        stage == 0
    }

    fn is_last_stage(&self, stage: u32) -> bool {
        stage as usize + 1 == self.cfg.device_of.len()
    }

    fn colocated(&self, a: u32, b: u32) -> bool {
        self.cfg.device_of[a as usize] == self.cfg.device_of[b as usize]
    }

    /// Run the full training loop; returns per-step mean losses are the
    /// driver's business — the worker just executes.
    pub fn run(mut self) -> Result<()> {
        for step in 0..self.cfg.steps as u64 {
            // Barrier: wait for the driver's release.
            self.mailbox.recv(Tag::Step(step));
            self.timing.clear();
            let program = std::mem::take(&mut self.cfg.program);
            for ins in &program {
                self.exec(ins)?;
            }
            self.cfg.program = program;
            self.apply_sgd();
            self.check_clean_state(step)?;
            // Report completion (+timing payload).
            let payload = self.timing_tensor();
            self.fabric.send(self.driver, Tag::Done(step), payload);
        }
        Ok(())
    }

    fn timing_tensor(&self) -> Tensor {
        let n = self.timing.len();
        let mut data = Vec::with_capacity(n * 5);
        for row in &self.timing {
            data.extend_from_slice(row);
        }
        Tensor::f32(&[n, 5], data)
    }

    fn exec(&mut self, ins: &Instr) -> Result<()> {
        match *ins {
            Instr::RecvF { .. } | Instr::RecvB { .. } => Ok(()), // transport is eager
            Instr::WaitF { mb, stage } => {
                let t = self.mailbox.recv(Tag::Chan((mb, stage - 1, stage, OpKind::F)));
                self.local_act.insert((mb, stage), t);
                Ok(())
            }
            Instr::WaitB { mb, stage } => {
                let t = self.mailbox.recv(Tag::Chan((mb, stage + 1, stage, OpKind::B)));
                self.local_gy.insert((mb, stage), t);
                Ok(())
            }
            Instr::SendF { mb, stage, to_stage } => {
                let t = self
                    .outbox
                    .remove(&(mb, stage, OpKind::F))
                    .ok_or_else(|| anyhow!("SendF before compute (mb={mb} s={stage})"))?;
                let to_dev = self.cfg.device_of[to_stage as usize];
                self.fabric.send(to_dev, Tag::Chan((mb, stage, to_stage, OpKind::F)), t);
                Ok(())
            }
            Instr::SendB { mb, stage, to_stage } => {
                let t = self
                    .outbox
                    .remove(&(mb, stage, OpKind::B))
                    .ok_or_else(|| anyhow!("SendB before compute (mb={mb} s={stage})"))?;
                let to_dev = self.cfg.device_of[to_stage as usize];
                self.fabric.send(to_dev, Tag::Chan((mb, stage, to_stage, OpKind::B)), t);
                Ok(())
            }
            Instr::Compute { op, mb, stage } => {
                let t0 = self.epoch.elapsed().as_secs_f64();
                match op {
                    OpKind::F => self.compute_f(mb, stage)?,
                    OpKind::B => self.compute_b(mb, stage)?,
                    OpKind::W => self.compute_w(mb, stage)?,
                }
                if self.cfg.collect_timing {
                    let t1 = self.epoch.elapsed().as_secs_f64();
                    let code = match op {
                        OpKind::F => 0.0,
                        OpKind::B => 1.0,
                        OpKind::W => 2.0,
                    };
                    self.timing.push([
                        code,
                        mb as f32,
                        stage as f32,
                        (t0 * 1e6) as f32,
                        ((t1 - t0) * 1e6) as f32,
                    ]);
                }
                Ok(())
            }
        }
    }

    fn compute_f(&mut self, mb: u32, stage: u32) -> Result<()> {
        // Fetch stage input.
        let mut x = if self.is_first_stage(stage) {
            self.mailbox.recv(Tag::Ids(mb))
        } else if self.colocated(stage - 1, stage) {
            self.local_act
                .remove(&(mb, stage))
                .ok_or_else(|| anyhow!("F: missing colocated act (mb={mb} s={stage})"))?
        } else {
            self.local_act
                .remove(&(mb, stage))
                .ok_or_else(|| anyhow!("F: missing received act (mb={mb} s={stage})"))?
        };
        for l in self.stage_layers(stage) {
            let kind = self.cfg.kinds[l];
            if kind == "head" {
                let targets = self.mailbox.recv(Tag::Targets(mb));
                let st = self.layers.get(&l).unwrap();
                let mut inputs: Vec<&Tensor> = st.params.iter().collect();
                inputs.push(&x);
                inputs.push(&targets);
                let mut out = self.store.run_refs("head", "fwd", &inputs)?;
                self.fabric.send(self.driver, Tag::Loss(mb), out.pop().unwrap());
                self.targets.insert(mb, targets);
                self.x_stash.insert((mb, l), x);
                return Ok(()); // head is terminal
            }
            let st = self.layers.get(&l).unwrap();
            let mut inputs: Vec<&Tensor> = st.params.iter().collect();
            inputs.push(&x);
            let mut out = self.store.run_refs(kind, "fwd", &inputs)?;
            let y = out.pop().unwrap();
            self.x_stash.insert((mb, l), x);
            x = y;
        }
        // Ship the stage output.
        if self.colocated(stage, stage + 1) {
            self.local_act.insert((mb, stage + 1), x);
        } else {
            self.outbox.insert((mb, stage, OpKind::F), x);
        }
        Ok(())
    }

    fn compute_b(&mut self, mb: u32, stage: u32) -> Result<()> {
        // Upstream gradient for the stage's last layer.
        let mut gy: Option<Tensor> = if self.is_last_stage(stage) {
            None // seeded by head fwdbwd below
        } else if self.colocated(stage, stage + 1) {
            Some(
                self.local_gy
                    .remove(&(mb, stage))
                    .ok_or_else(|| anyhow!("B: missing colocated gy (mb={mb} s={stage})"))?,
            )
        } else {
            Some(
                self.local_gy
                    .remove(&(mb, stage))
                    .ok_or_else(|| anyhow!("B: missing received gy (mb={mb} s={stage})"))?,
            )
        };
        let layers: Vec<usize> = self.stage_layers(stage).rev().collect();
        for l in layers {
            let kind = self.cfg.kinds[l];
            match kind {
                "head" => {
                    let x = self
                        .x_stash
                        .remove(&(mb, l))
                        .ok_or_else(|| anyhow!("B: head stash missing"))?;
                    let targets = self.targets.remove(&mb).unwrap();
                    let st = self.layers.get(&l).unwrap();
                    let mut inputs: Vec<&Tensor> = st.params.iter().collect();
                    inputs.push(&x);
                    inputs.push(&targets);
                    // (loss, gx, *gparams) — the head takes its param
                    // grads here even in split mode (it has no separate
                    // bwdx artifact), so W for the head layer is a no-op.
                    let mut out = self.store.run_refs("head", "fwdbwd", &inputs)?;
                    let gparams = out.split_off(2);
                    let gx = out.pop().unwrap();
                    self.accumulate(l, &gparams);
                    gy = Some(gx);
                }
                "embed" => {
                    // Terminal: embed has no gx.  In split mode the
                    // scatter-add (its whole backward) is the W op.
                    let g = gy.take().ok_or_else(|| anyhow!("B: embed without gy"))?;
                    if self.cfg.split_bw {
                        self.gy_stash.insert((mb, l), g);
                    } else {
                        let ids = self.x_stash.remove(&(mb, l)).unwrap();
                        let st = self.layers.get(&l).unwrap();
                        let mut inputs: Vec<&Tensor> = st.params.iter().collect();
                        inputs.push(&ids);
                        inputs.push(&g);
                        let out = self.store.run_refs("embed", "bwdw", &inputs)?;
                        self.accumulate(l, &out);
                    }
                    return Ok(());
                }
                _ => {
                    let g = gy.take().ok_or_else(|| anyhow!("B: missing gy at {l}"))?;
                    let st = self.layers.get(&l).unwrap();
                    if self.cfg.split_bw {
                        let x = self
                            .x_stash
                            .get(&(mb, l))
                            .ok_or_else(|| anyhow!("B: stash missing at {l}"))?;
                        let mut inputs: Vec<&Tensor> = st.params.iter().collect();
                        inputs.push(x);
                        inputs.push(&g);
                        let mut out = self.store.run_refs(kind, "bwdx", &inputs)?;
                        gy = Some(out.pop().unwrap());
                        self.gy_stash.insert((mb, l), g);
                    } else {
                        let x = self
                            .x_stash
                            .remove(&(mb, l))
                            .ok_or_else(|| anyhow!("B: stash missing at {l}"))?;
                        let mut inputs: Vec<&Tensor> = st.params.iter().collect();
                        inputs.push(&x);
                        inputs.push(&g);
                        let mut out = self.store.run_refs(kind, "bwd", &inputs)?;
                        let gparams = out.split_off(1);
                        gy = Some(out.pop().unwrap());
                        self.accumulate(l, &gparams);
                    }
                }
            }
        }
        // Ship gx to the previous stage.
        if !self.is_first_stage(stage) {
            let gx = gy.ok_or_else(|| anyhow!("B: no gx produced"))?;
            if self.colocated(stage - 1, stage) {
                self.local_gy.insert((mb, stage - 1), gx);
            } else {
                self.outbox.insert((mb, stage, OpKind::B), gx);
            }
        }
        Ok(())
    }

    fn compute_w(&mut self, mb: u32, stage: u32) -> Result<()> {
        if !self.cfg.split_bw {
            return Err(anyhow!("W op in fused-backward program"));
        }
        let layers: Vec<usize> = self.stage_layers(stage).rev().collect();
        for l in layers {
            let kind = self.cfg.kinds[l];
            if kind == "head" {
                continue; // gparams were taken at B (see compute_b)
            }
            let x = self
                .x_stash
                .remove(&(mb, l))
                .ok_or_else(|| anyhow!("W: x stash missing at layer {l}"))?;
            let g = self
                .gy_stash
                .remove(&(mb, l))
                .ok_or_else(|| anyhow!("W: gy stash missing at layer {l}"))?;
            let st = self.layers.get(&l).unwrap();
            let mut inputs: Vec<&Tensor> = st.params.iter().collect();
            inputs.push(&x);
            inputs.push(&g);
            let out = self.store.run_refs(kind, "bwdw", &inputs)?;
            self.accumulate(l, &out);
        }
        Ok(())
    }

    fn accumulate(&mut self, l: usize, gparams: &[Tensor]) {
        let st = self.layers.get_mut(&l).unwrap();
        assert_eq!(st.grads.len(), gparams.len(), "layer {l} grad arity");
        for (g, d) in st.grads.iter_mut().zip(gparams) {
            g.add_assign(d);
        }
    }

    fn apply_sgd(&mut self) {
        let scale = self.cfg.lr / self.cfg.nmb as f32;
        for st in self.layers.values_mut() {
            for (p, g) in st.params.iter_mut().zip(&mut st.grads) {
                p.sgd_step(g, scale);
                for v in g.f32s_mut() {
                    *v = 0.0;
                }
            }
        }
    }

    /// All stashes must drain every step — catches schedule/executor
    /// bookkeeping bugs immediately.
    fn check_clean_state(&self, step: u64) -> Result<()> {
        if !self.x_stash.is_empty()
            || !self.gy_stash.is_empty()
            || !self.outbox.is_empty()
            || !self.targets.is_empty()
        {
            return Err(anyhow!(
                "device {} step {step}: leaked state (x={} gy={} out={} tgt={})",
                self.cfg.id,
                self.x_stash.len(),
                self.gy_stash.len(),
                self.outbox.len(),
                self.targets.len()
            ));
        }
        Ok(())
    }
}
