//! Synthetic training corpus with learnable structure — the rust twin
//! of `python/compile/model.py::synthetic_batch`: Zipf-ish unigram
//! distribution plus first-order Markov structure (with p=0.5 the next
//! token is `(prev*7 + 3) % V`), so the loss visibly decreases once the
//! model picks up the transition rule.

use crate::runtime::Tensor;
use crate::util::rng::Rng;

/// Generates (ids, targets) micro-batches of shape `[mb, seq]` (i32).
pub struct CorpusGen {
    rng: Rng,
    vocab: usize,
    mb: usize,
    seq: usize,
}

impl CorpusGen {
    pub fn new(seed: u64, vocab: usize, mb: usize, seq: usize) -> Self {
        CorpusGen { rng: Rng::new(seed), vocab, mb, seq }
    }

    /// Zipf-ish token: floor of a bounded Pareto sample, biased to low
    /// ranks (exact tail shape is irrelevant — we need a skewed,
    /// learnable unigram distribution).
    fn base_token(&mut self) -> usize {
        let u = self.rng.f64().max(1e-12);
        let x = ((self.vocab as f64 + 1.0).powf(u) - 1.0).max(0.0);
        (x as usize).min(self.vocab - 1)
    }

    /// One micro-batch: (ids, targets), each `[mb, seq]`.
    pub fn next_batch(&mut self) -> (Tensor, Tensor) {
        let n = self.mb * self.seq;
        let mut ids = Vec::with_capacity(n);
        let mut tgt = Vec::with_capacity(n);
        for _ in 0..self.mb {
            let mut prev = self.base_token();
            for _ in 0..self.seq {
                ids.push(prev as i32);
                let next = if self.rng.f64() < 0.5 {
                    (prev * 7 + 3) % self.vocab
                } else {
                    self.base_token()
                };
                tgt.push(next as i32);
                prev = next;
            }
        }
        (
            Tensor::i32(&[self.mb, self.seq], ids),
            Tensor::i32(&[self.mb, self.seq], tgt),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_ranges() {
        let mut g = CorpusGen::new(1, 512, 2, 16);
        let (ids, tgt) = g.next_batch();
        assert_eq!(ids.shape, vec![2, 16]);
        assert_eq!(tgt.shape, vec![2, 16]);
        assert!(ids.i32s().iter().all(|&t| (0..512).contains(&t)));
        assert!(tgt.i32s().iter().all(|&t| (0..512).contains(&t)));
    }

    #[test]
    fn markov_structure_present() {
        // Roughly half the transitions must follow the rule.
        let mut g = CorpusGen::new(2, 512, 4, 64);
        let (ids, tgt) = g.next_batch();
        let (i, t) = (ids.i32s(), tgt.i32s());
        let hits = i
            .iter()
            .zip(t)
            .filter(|&(&a, &b)| (a as usize * 7 + 3) % 512 == b as usize)
            .count();
        let frac = hits as f64 / i.len() as f64;
        assert!(frac > 0.3 && frac < 0.7, "markov fraction {frac}");
    }

    #[test]
    fn deterministic_per_seed() {
        let (a, _) = CorpusGen::new(7, 128, 1, 8).next_batch();
        let (b, _) = CorpusGen::new(7, 128, 1, 8).next_batch();
        assert_eq!(a.i32s(), b.i32s());
    }
}
