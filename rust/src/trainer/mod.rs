//! End-to-end pipeline trainer (RealCluster): drives actual training of
//! a heterogeneous model over P worker threads, each executing lowered
//! pipeline instructions against the PJRT artifacts.  Python never runs
//! here — the artifacts were AOT-compiled once by `make artifacts`.

pub mod data;
pub mod device;

use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::adapt::{Decision, Monitor, MonitorCfg};
use crate::baselines::{self, Method, Pipeline};
use crate::cluster::real::{Fabric, Tag};
use crate::config::ModelCfg;
use crate::executor::lower::{lower, LowerOptions};
use crate::generator::{generate, GenOptions};
use crate::model::{LayerCost, LayerKind};
use crate::profile::ProfiledData;
use crate::runtime::{ArtifactStore, Tensor};
use crate::schedule::OpKind;
use crate::trainer::device::{Worker, WorkerCfg};
use crate::util::trace::TraceEvent;

/// Which pipeline to train with.
#[derive(Clone, Debug)]
pub enum TrainMethod {
    Baseline(Method),
    AdaPtis,
}

impl TrainMethod {
    pub fn name(&self) -> String {
        match self {
            TrainMethod::Baseline(m) => m.name().to_string(),
            TrainMethod::AdaPtis => "AdaPtis".to_string(),
        }
    }
}

/// Trainer options.
#[derive(Clone, Debug)]
pub struct TrainOptions {
    pub p: usize,
    pub nmb: usize,
    pub steps: usize,
    pub lr: f32,
    pub seed: u64,
    pub method: TrainMethod,
    pub collect_trace: bool,
    /// Log each step to stderr as it completes (long runs).
    pub live_log: bool,
    /// Attach an *advisory* drift monitor ([`crate::adapt`]): measured
    /// step times feed a [`Monitor`] whose predicted step time
    /// self-calibrates from the median of the first `window` steps
    /// (wall-clock and model-seconds live on different scales, so the
    /// plan's simulated makespan can't be used directly).  Re-plan
    /// advice is recorded in [`TrainResult::replan_advice`] — the
    /// RealCluster can't migrate weights, so nothing is acted on.
    pub monitor: Option<MonitorCfg>,
}

impl Default for TrainOptions {
    fn default() -> Self {
        TrainOptions {
            p: 2,
            nmb: 4,
            steps: 10,
            lr: 0.1,
            seed: 0,
            method: TrainMethod::AdaPtis,
            collect_trace: false,
            live_log: false,
            monitor: None,
        }
    }
}

/// Trainer output.
#[derive(Debug)]
pub struct TrainResult {
    pub pipeline_name: String,
    pub losses: Vec<f64>,
    pub step_times: Vec<f64>,
    pub tokens_per_step: usize,
    pub trace: Vec<TraceEvent>,
    /// The measured per-layer profile used for pipeline generation.
    pub profile: ProfiledData,
    pub pipeline: Pipeline,
    /// Steps at which the advisory monitor recommended re-planning
    /// (empty when [`TrainOptions::monitor`] is `None`).
    pub replan_advice: Vec<usize>,
}

impl TrainResult {
    pub fn tokens_per_s(&self) -> f64 {
        let t: f64 = self.step_times.iter().sum();
        self.tokens_per_step as f64 * self.step_times.len() as f64 / t.max(1e-12)
    }
}

/// The demo model per artifact tag: a heterogeneous flat layer list
/// compatible with the tag's dims.
pub fn demo_model(tag: &str) -> Vec<LayerKind> {
    use LayerKind::*;
    let mut v = vec![Embed];
    match tag {
        "micro" => v.extend([Sa, Mla, Mamba, Ffn, Moe]),
        "fidelity" => {
            for _ in 0..2 {
                v.extend([Mamba, Ffn, Sa, Ffn, Mla, Moe]);
            }
        }
        // ~100M params with e2e100m dims (embed+head ≈ 75M, layers ≈ 24M).
        "e2e100m" => {
            for _ in 0..4 {
                v.extend([Sa, Ffn, Mamba, Ffn, Mla, Moe]);
            }
        }
        _ => v.extend([Sa, Ffn]),
    }
    v.push(Head);
    v
}

/// Measure per-layer F/B/W wall-clock on the artifacts — the *measured*
/// profile backend (DESIGN.md: replaces the paper's GPU profiling; this
/// is what Fig 12 calls "profiled data" for the real testbed).
pub fn calibrate(
    store: &ArtifactStore,
    kinds: &[LayerKind],
    reps: usize,
) -> Result<ProfiledData> {
    let d = &store.meta.dims;
    let act_bytes = (d.microbatch * d.seq * d.hidden * 4) as f64;
    let mut per_kind: std::collections::HashMap<&str, LayerCost> =
        std::collections::HashMap::new();
    for &k in kinds {
        let kind = k.name();
        if per_kind.contains_key(kind) {
            continue;
        }
        let time_op = |op: &str| -> Result<f64> {
            let sig = store
                .meta
                .op(kind, op)
                .ok_or_else(|| anyhow!("no artifact {kind}/{op}"))?
                .clone();
            let inputs: Vec<Tensor> = sig
                .inputs
                .iter()
                .map(|ts| match ts.name.as_str() {
                    "ln_g" | "dskip" => Tensor::ones(&ts.shape),
                    _ => Tensor::zeros_like_sig(ts),
                })
                .collect();
            store.run(kind, op, &inputs)?; // warmup/compile
            let mut best = f64::INFINITY;
            for _ in 0..reps.max(1) {
                let t0 = Instant::now();
                store.run(kind, op, &inputs)?;
                best = best.min(t0.elapsed().as_secs_f64());
            }
            Ok(best)
        };
        let f = time_op("fwd")?;
        let (b, w) = match kind {
            "embed" => (0.0, time_op("bwdw")?),
            "head" => (time_op("fwdbwd")?, 0.0),
            _ => (time_op("bwdx")?, time_op("bwdw")?),
        };
        let params: usize = store
            .meta
            .params_of(kind)
            .iter()
            .map(|(_, s)| s.iter().product::<usize>())
            .sum();
        per_kind.insert(
            kind,
            LayerCost {
                f,
                b,
                w,
                mem_static: (params * 16) as f64,
                mem_act: act_bytes,
                mem_act_w: act_bytes,
                comm_bytes: act_bytes,
            },
        );
    }
    let layers = kinds.iter().map(|k| per_kind[k.name()]).collect();
    // Thread-channel transport: ~latency of a send/recv pair plus copy
    // bandwidth of Vec<f32> clones (measured once, conservative).
    Ok(ProfiledData::from_measured(layers, 30e-6, 4e9, 1e15))
}

/// A ModelCfg view of the artifact dims (for analytical comparisons).
pub fn model_cfg_of(store: &ArtifactStore, blocks: usize) -> ModelCfg {
    let d = &store.meta.dims;
    ModelCfg {
        family: crate::config::Family::Gemma,
        size: crate::config::Size::Small,
        blocks,
        vocab: d.vocab,
        hidden: d.hidden,
        ffn_hidden: d.ffn_hidden,
        heads: d.heads,
        head_dim: d.head_dim,
        kv_latent: d.kv_latent,
        ssm_state: d.ssm_state,
        experts: d.experts,
        moe_hidden: d.moe_hidden,
        topk: 1,
    }
}

/// Train `kinds` on synthetic data; see module docs.
pub fn train(
    store: Arc<ArtifactStore>,
    kinds: &[LayerKind],
    opts: &TrainOptions,
) -> Result<TrainResult> {
    assert_eq!(kinds[0], LayerKind::Embed);
    assert_eq!(*kinds.last().unwrap(), LayerKind::Head);
    let profile = calibrate(&store, kinds, 2)?;

    // Pick the pipeline.
    let pipeline = match &opts.method {
        TrainMethod::Baseline(m) => baselines::build(*m, &profile, opts.p, opts.nmb),
        TrainMethod::AdaPtis => {
            let g = generate(&profile, &GenOptions::new(opts.p, opts.nmb));
            g.pipeline
        }
    };
    pipeline
        .schedule
        .validate(&pipeline.placement)
        .map_err(|e| anyhow!("invalid schedule: {e}"))?;
    let prog = lower(&pipeline.schedule, &pipeline.placement, LowerOptions::default());
    prog.validate().map_err(|e| anyhow!("malformed program: {e}"))?;
    crate::executor::lower::check_rendezvous(&prog)
        .map_err(|(d, pc)| anyhow!("program deadlocks at dev {d} pc {pc}"))?;

    // Pre-compile every needed executable once (shared PJRT client).
    let kind_names: Vec<&str> = {
        let mut v: Vec<&str> = kinds.iter().map(|k| k.name()).collect();
        v.sort();
        v.dedup();
        v
    };
    store.warmup(&kind_names)?;

    // Spawn workers.
    let (fabric, mut boxes) = Fabric::new(opts.p);
    let mut driver_box = boxes.pop().unwrap();
    let epoch = Instant::now();
    let kind_strs: Vec<&'static str> = kinds.iter().map(|k| k.name()).collect();
    let mut handles = Vec::new();
    for id in (0..opts.p).rev() {
        let cfg = WorkerCfg {
            id,
            kinds: kind_strs.clone(),
            bounds: pipeline.partition.bounds.clone(),
            device_of: pipeline.placement.device_of.clone(),
            program: prog.per_device[id].clone(),
            steps: opts.steps,
            nmb: opts.nmb,
            lr: opts.lr,
            split_bw: pipeline.schedule.split_bw,
            seed: opts.seed,
            collect_timing: opts.collect_trace,
        };
        let w = Worker::new(cfg, store.clone(), fabric.clone_senders(), boxes.pop().unwrap(), epoch);
        handles.push(std::thread::spawn(move || w.run()));
    }

    // Drive steps.
    let d = &store.meta.dims;
    let mut gen = data::CorpusGen::new(opts.seed, d.vocab, d.microbatch, d.seq);
    let first_dev = pipeline.placement.device_of[0];
    let last_dev = *pipeline.placement.device_of.last().unwrap();
    let mut losses = Vec::with_capacity(opts.steps);
    let mut step_times = Vec::with_capacity(opts.steps);
    let mut trace = Vec::new();
    let mut advisor: Option<Monitor> = None;
    let mut warmup_times: Vec<f64> = Vec::new();
    let mut replan_advice: Vec<usize> = Vec::new();
    for step in 0..opts.steps as u64 {
        let t0 = Instant::now();
        for mb in 0..opts.nmb as u32 {
            let (ids, targets) = gen.next_batch();
            fabric.send(first_dev, Tag::Ids(mb), ids);
            fabric.send(last_dev, Tag::Targets(mb), targets);
        }
        for dev in 0..opts.p {
            fabric.send(dev, Tag::Step(step), Tensor::zeros(&[1]));
        }
        let mut loss = 0.0f64;
        for mb in 0..opts.nmb as u32 {
            loss += driver_box.recv(Tag::Loss(mb)).scalar_f32() as f64;
        }
        losses.push(loss / opts.nmb as f64);
        for dev in 0..opts.p {
            let payload = driver_box.recv(Tag::Done(step));
            if opts.collect_trace && step as usize == opts.steps - 1 {
                decode_timing(&payload, dev, &mut trace);
            }
        }
        step_times.push(t0.elapsed().as_secs_f64());
        if let Some(mcfg) = opts.monitor {
            let dt = *step_times.last().unwrap();
            match &mut advisor {
                None => {
                    // Self-calibration: the predicted step time is the
                    // median of the first `window` measured steps.
                    warmup_times.push(dt);
                    if warmup_times.len() >= mcfg.window {
                        let mut s = warmup_times.clone();
                        s.sort_by(|a, b| a.total_cmp(b));
                        let n = s.len();
                        let med =
                            if n % 2 == 1 { s[n / 2] } else { 0.5 * (s[n / 2 - 1] + s[n / 2]) };
                        let mut m = Monitor::new(opts.p, mcfg);
                        m.set_plan(med.max(1e-9), vec![0.0; opts.p], vec![1.0; opts.p]);
                        advisor = Some(m);
                    }
                }
                Some(m) => {
                    if let Decision::Replan { .. } = m.observe(dt, None) {
                        replan_advice.push(step as usize);
                        // Advisory only: dismiss so the monitor cools
                        // down instead of awaiting a switch forever.
                        m.dismissed();
                        if opts.live_log {
                            eprintln!("step {step:>4}  drift gap {:.0}% — re-plan advised", 100.0 * m.gap());
                        }
                    }
                }
            }
        }
        if opts.live_log {
            eprintln!(
                "step {step:>4}  loss {:.4}  ({:.2} s)",
                losses.last().unwrap(),
                step_times.last().unwrap()
            );
        }
    }
    for h in handles {
        h.join().map_err(|_| anyhow!("worker panicked"))??;
    }

    Ok(TrainResult {
        pipeline_name: format!("{} ({})", opts.method.name(), pipeline.name),
        losses,
        step_times,
        tokens_per_step: opts.nmb * d.microbatch * d.seq,
        trace,
        profile,
        pipeline,
        replan_advice,
    })
}

fn decode_timing(payload: &Tensor, dev: usize, out: &mut Vec<TraceEvent>) {
    let rows = payload.shape[0];
    let v = payload.f32s();
    let base = v.chunks(5).map(|r| r[3]).fold(f32::INFINITY, f32::min);
    let base = if base.is_finite() { base } else { 0.0 };
    for i in 0..rows {
        let r = &v[i * 5..i * 5 + 5];
        let op = match r[0] as usize {
            0 => OpKind::F,
            1 => OpKind::B,
            _ => OpKind::W,
        };
        out.push(TraceEvent {
            name: format!("{}{}@s{}", op.name(), r[1] as usize, r[2] as usize),
            cat: op.name().into(),
            ts_us: (r[3] - base) as f64,
            dur_us: r[4] as f64,
            pid: dev,
            tid: 0,
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn open_micro() -> Option<Arc<ArtifactStore>> {
        let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/micro");
        ArtifactStore::open(dir).ok().map(Arc::new)
    }

    #[test]
    fn micro_training_loss_decreases() {
        let Some(store) = open_micro() else {
            eprintln!("skipping: run `make artifacts` first");
            return;
        };
        let kinds = demo_model("micro");
        let opts = TrainOptions {
            p: 2,
            nmb: 2,
            steps: 8,
            lr: 0.2,
            method: TrainMethod::Baseline(Method::S1F1B),
            ..Default::default()
        };
        let r = train(store, &kinds, &opts).unwrap();
        assert_eq!(r.losses.len(), 8);
        let first = r.losses[0];
        let last = *r.losses.last().unwrap();
        assert!(
            last < first,
            "loss should decrease: {first:.4} -> {last:.4} ({:?})",
            r.losses
        );
        // Initial loss ≈ ln(V) for a fresh model over 512 tokens.
        assert!((first - (512f64).ln()).abs() < 1.5, "first loss {first}");
    }

    #[test]
    fn pipeline_depth_does_not_change_losses() {
        // P=1 and P=2 run the same artifacts on the same data: per-step
        // losses must agree to fp-accumulation tolerance.  This is the
        // core executor-correctness check.
        let Some(store) = open_micro() else {
            eprintln!("skipping: run `make artifacts` first");
            return;
        };
        let kinds = demo_model("micro");
        let mk = |p: usize, method: TrainMethod| TrainOptions {
            p,
            nmb: 2,
            steps: 4,
            lr: 0.2,
            method,
            ..Default::default()
        };
        let r1 = train(store.clone(), &kinds, &mk(1, TrainMethod::Baseline(Method::GPipe)))
            .unwrap();
        let r2 = train(store.clone(), &kinds, &mk(2, TrainMethod::Baseline(Method::S1F1B)))
            .unwrap();
        let r3 = train(store, &kinds, &mk(2, TrainMethod::Baseline(Method::ZB))).unwrap();
        for i in 0..4 {
            assert!(
                (r1.losses[i] - r2.losses[i]).abs() < 1e-3,
                "step {i}: P1 {} vs P2 {}",
                r1.losses[i],
                r2.losses[i]
            );
            assert!(
                (r1.losses[i] - r3.losses[i]).abs() < 1e-3,
                "step {i}: P1 {} vs ZB {}",
                r1.losses[i],
                r3.losses[i]
            );
        }
    }
}
