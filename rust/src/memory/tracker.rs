//! Reference peak-memory tracker.
//!
//! Per-device stash only changes when that device executes one of its
//! own slots, and a device executes its slots strictly in list order —
//! so the activation peak is a pure function of the per-device slot
//! sequence, independent of cross-device timing.  That makes this
//! tracker a timing-free oracle for the event-driven kernels: it
//! applies the exact same f64 charge/release sequence the kernels do,
//! so `static_d + peak` must equal `PerfReport::m_d` *bitwise*
//! (pinned by `tests/memory_differential.rs`).
//!
//! [`peak_stash_collapsed`] is the steady-state-collapse analogue of
//! the kernels' cycle replay (`perfmodel::collapse`) at the tracker
//! level: when a device's slot list repeats a per-micro-batch cycle
//! *and* the stash level at the cycle boundary is a bitwise fixpoint,
//! every further repetition replays the exact same f64 values — the
//! peak cannot move — so whole cycles are skipped structurally.  The
//! result is pinned bitwise-equal to [`peak_stash`] (and therefore to
//! the kernels' `m_d`/headroom accounting) by
//! `tests/memory_differential.rs`.

use super::model::MemoryModel;
use crate::schedule::{OpKind, Schedule, Slot};

/// Per-device peak activation stash (bytes) under the subsystem's
/// charge/release protocol: charge `act_per_mb` at F; fused backward
/// releases all of it at B; split backward releases the B-consumed
/// part at B and the W-retained slice at W.
pub fn peak_stash(schedule: &Schedule, model: &MemoryModel) -> Vec<f64> {
    replay(schedule, model, true)
}

/// The coarse accounting the seed code used for split backwards: B
/// releases nothing and the *whole* stash is retained until W — i.e.
/// the memory a fused-B implementation would hold if it only freed at
/// backward completion.  Kept as the comparison baseline: at identical
/// timing, split-aware release is strictly below this whenever a stage
/// has a non-empty B-released part (the ZB/Controllable-Memory
/// observation).
pub fn peak_stash_fused_release(schedule: &Schedule, model: &MemoryModel) -> Vec<f64> {
    replay(schedule, model, false)
}

/// [`peak_stash`] with steady-state cycle skipping (module docs):
/// bitwise-identical peaks, O(slots) structural compares but only
/// O(warmup + drain) f64 operations on periodic schedules.
pub fn peak_stash_collapsed(schedule: &Schedule, model: &MemoryModel) -> Vec<f64> {
    assert_eq!(schedule.p, model.p);
    const KMAX: usize = 4;
    let mut peaks = vec![0.0f64; schedule.p];
    for (d, slots) in schedule.per_device.iter().enumerate() {
        let mut stash = 0.0f64;
        let mut peak = 0.0f64;
        let anchor = slots.first().map(|sl| (sl.op, sl.stage));
        // Closed rounds: (round, end position exclusive, stash bits).
        let mut hist: Vec<(i64, usize, u64)> = Vec::new();
        let mut i = 0usize;
        while i < slots.len() {
            let sl = slots[i];
            apply_slot(&mut stash, &mut peak, schedule.split_bw, true, model, sl);
            i += 1;
            if Some((sl.op, sl.stage)) != anchor {
                continue;
            }
            let r = sl.mb as i64;
            if hist.last().is_some_and(|&(pr, _, _)| pr != r - 1) {
                hist.clear();
            }
            hist.push((r, i, stash.to_bits()));
            if hist.len() > 2 * KMAX + 1 {
                hist.remove(0);
            }
            let n = hist.len();
            for k in 1..=KMAX {
                if n < 2 * k + 1 {
                    break;
                }
                // Stash fixpoint over the candidate cycle, bitwise.
                if hist[n - 1].2 != hist[n - 1 - k].2 {
                    continue;
                }
                let (a0, a, b) = (hist[n - 1 - 2 * k].1, hist[n - 1 - k].1, hist[n - 1].1);
                if a - a0 != b - a || !cycles_match(&slots[a0..a], &slots[a..b], k as u32)
                {
                    continue;
                }
                // Locked: skip whole repetitions — the stash trajectory
                // is a pure function of (fixpoint value, cycle ops), so
                // every skipped block replays the same values and the
                // peak cannot move.
                let len = b - a;
                let mut j = b;
                while j + len <= slots.len()
                    && cycles_match(&slots[j - len..j], &slots[j..j + len], k as u32)
                {
                    j += len;
                }
                if j > b {
                    i = j;
                    hist.clear();
                }
                break;
            }
        }
        peaks[d] = peak;
    }
    peaks
}

/// `cur` continues `prev`'s per-micro-batch cycle: same ops on the
/// same stages, micro-batches advanced by exactly the period.
fn cycles_match(prev: &[Slot], cur: &[Slot], period: u32) -> bool {
    prev.len() == cur.len()
        && prev
            .iter()
            .zip(cur)
            .all(|(p, c)| p.op == c.op && p.stage == c.stage && c.mb == p.mb + period)
}

/// The one copy of the charge/release arithmetic (shared by the plain
/// replay, the fused-release baseline and the cycle-skipping tracker —
/// the protocol is bitwise-pinned against the kernels, so it must not
/// fork).  `early_release: false` models the coarse fused-B accounting
/// (B frees nothing, W frees the whole stash).
#[inline]
fn apply_slot(
    stash: &mut f64,
    peak: &mut f64,
    split_bw: bool,
    early_release: bool,
    model: &MemoryModel,
    sl: Slot,
) {
    let fp = &model.stages[sl.stage as usize];
    match sl.op {
        OpKind::F => {
            *stash += fp.act_per_mb;
            *peak = peak.max(*stash);
        }
        OpKind::B => {
            if !split_bw {
                *stash -= fp.act_per_mb;
            } else if early_release {
                *stash -= fp.act_per_mb - fp.act_w_per_mb;
            }
        }
        OpKind::W => {
            *stash -= if early_release { fp.act_w_per_mb } else { fp.act_per_mb };
        }
    }
}

fn replay(schedule: &Schedule, model: &MemoryModel, early_release: bool) -> Vec<f64> {
    assert_eq!(schedule.p, model.p);
    let mut peaks = vec![0.0f64; schedule.p];
    for (d, slots) in schedule.per_device.iter().enumerate() {
        let mut stash = 0.0f64;
        let mut peak = 0.0f64;
        for &sl in slots {
            apply_slot(&mut stash, &mut peak, schedule.split_bw, early_release, model, sl);
        }
        peaks[d] = peak;
    }
    peaks
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Family, HardwareCfg, ModelCfg, ParallelCfg, Size};
    use crate::model::build_model;
    use crate::partition::uniform;
    use crate::placement::sequential;
    use crate::profile::ProfiledData;
    use crate::schedule::builders::{gpipe, one_f_one_b, zb_h1};

    fn setup(p: usize, nmb: usize) -> (ProfiledData, MemoryModel) {
        let spec = build_model(&ModelCfg::table5(Family::Gemma, Size::Small));
        let prof = ProfiledData::analytical(
            &spec,
            &HardwareCfg::default(),
            &ParallelCfg::new(p, 2, nmb, 1, 4096),
        );
        let part = uniform(prof.n_layers(), p);
        let mm = MemoryModel::build(&prof, &part, &sequential(p));
        (prof, mm)
    }

    #[test]
    fn gpipe_stashes_everything() {
        let (_, mm) = setup(4, 8);
        let peaks = peak_stash(&gpipe(4, 8), &mm);
        for d in 0..4 {
            let expect = 8.0 * mm.stages[d].act_per_mb;
            assert!(
                (peaks[d] - expect).abs() <= 1e-9 * expect,
                "dev {d}: {} vs {expect}",
                peaks[d]
            );
        }
    }

    #[test]
    fn one_f_one_b_bounded_by_depth() {
        let (_, mm) = setup(4, 8);
        let peaks = peak_stash(&one_f_one_b(4, 8), &mm);
        for d in 0..4 {
            let expect = (4 - d) as f64 * mm.stages[d].act_per_mb;
            assert!(
                (peaks[d] - expect).abs() <= 1e-9 * expect,
                "dev {d}: {} vs {expect}",
                peaks[d]
            );
        }
    }

    #[test]
    fn collapsed_tracker_is_bitwise_equal_on_builders() {
        for (p, nmb) in [(2, 4), (4, 8), (4, 32), (8, 64)] {
            let (_, mm) = setup(p, nmb);
            for sch in [gpipe(p, nmb), one_f_one_b(p, nmb), zb_h1(p, nmb)] {
                let full = peak_stash(&sch, &mm);
                let fast = peak_stash_collapsed(&sch, &mm);
                assert_eq!(full, fast, "p={p} nmb={nmb} split={}", sch.split_bw);
            }
        }
    }

    #[test]
    fn collapsed_tracker_survives_aperiodic_tail() {
        // Swapping two mid-stream slots breaks the cycle on one device;
        // the skipper must stop at the break and still match bitwise.
        let (_, mm) = setup(4, 32);
        let mut sch = one_f_one_b(4, 32);
        let v = &mut sch.per_device[1];
        let mid = v.len() / 2;
        v.swap(mid, mid + 1);
        assert_eq!(peak_stash(&sch, &mm), peak_stash_collapsed(&sch, &mm));
    }

    #[test]
    fn split_release_strictly_below_fused_release() {
        let (_, mm) = setup(4, 8);
        let sch = zb_h1(4, 8);
        let split = peak_stash(&sch, &mm);
        let coarse = peak_stash_fused_release(&sch, &mm);
        for d in 0..4 {
            assert!(
                split[d] < coarse[d],
                "dev {d}: split {} !< coarse {}",
                split[d],
                coarse[d]
            );
        }
    }
}
