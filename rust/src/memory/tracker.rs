//! Reference peak-memory tracker.
//!
//! Per-device stash only changes when that device executes one of its
//! own slots, and a device executes its slots strictly in list order —
//! so the activation peak is a pure function of the per-device slot
//! sequence, independent of cross-device timing.  That makes this
//! tracker a timing-free oracle for the event-driven kernels: it
//! applies the exact same f64 charge/release sequence the kernels do,
//! so `static_d + peak` must equal `PerfReport::m_d` *bitwise*
//! (pinned by `tests/memory_differential.rs`).

use super::model::MemoryModel;
use crate::schedule::{OpKind, Schedule};

/// Per-device peak activation stash (bytes) under the subsystem's
/// charge/release protocol: charge `act_per_mb` at F; fused backward
/// releases all of it at B; split backward releases the B-consumed
/// part at B and the W-retained slice at W.
pub fn peak_stash(schedule: &Schedule, model: &MemoryModel) -> Vec<f64> {
    replay(schedule, model, true)
}

/// The coarse accounting the seed code used for split backwards: B
/// releases nothing and the *whole* stash is retained until W — i.e.
/// the memory a fused-B implementation would hold if it only freed at
/// backward completion.  Kept as the comparison baseline: at identical
/// timing, split-aware release is strictly below this whenever a stage
/// has a non-empty B-released part (the ZB/Controllable-Memory
/// observation).
pub fn peak_stash_fused_release(schedule: &Schedule, model: &MemoryModel) -> Vec<f64> {
    replay(schedule, model, false)
}

fn replay(schedule: &Schedule, model: &MemoryModel, early_release: bool) -> Vec<f64> {
    assert_eq!(schedule.p, model.p);
    let mut peaks = vec![0.0f64; schedule.p];
    for (d, slots) in schedule.per_device.iter().enumerate() {
        let mut stash = 0.0f64;
        let mut peak = 0.0f64;
        for sl in slots {
            let fp = &model.stages[sl.stage as usize];
            match sl.op {
                OpKind::F => {
                    stash += fp.act_per_mb;
                    peak = peak.max(stash);
                }
                OpKind::B => {
                    if !schedule.split_bw {
                        stash -= fp.act_per_mb;
                    } else if early_release {
                        stash -= fp.act_per_mb - fp.act_w_per_mb;
                    }
                }
                OpKind::W => {
                    stash -= if early_release { fp.act_w_per_mb } else { fp.act_per_mb };
                }
            }
        }
        peaks[d] = peak;
    }
    peaks
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Family, HardwareCfg, ModelCfg, ParallelCfg, Size};
    use crate::model::build_model;
    use crate::partition::uniform;
    use crate::placement::sequential;
    use crate::profile::ProfiledData;
    use crate::schedule::builders::{gpipe, one_f_one_b, zb_h1};

    fn setup(p: usize, nmb: usize) -> (ProfiledData, MemoryModel) {
        let spec = build_model(&ModelCfg::table5(Family::Gemma, Size::Small));
        let prof = ProfiledData::analytical(
            &spec,
            &HardwareCfg::default(),
            &ParallelCfg::new(p, 2, nmb, 1, 4096),
        );
        let part = uniform(prof.n_layers(), p);
        let mm = MemoryModel::build(&prof, &part, &sequential(p));
        (prof, mm)
    }

    #[test]
    fn gpipe_stashes_everything() {
        let (_, mm) = setup(4, 8);
        let peaks = peak_stash(&gpipe(4, 8), &mm);
        for d in 0..4 {
            let expect = 8.0 * mm.stages[d].act_per_mb;
            assert!(
                (peaks[d] - expect).abs() <= 1e-9 * expect,
                "dev {d}: {} vs {expect}",
                peaks[d]
            );
        }
    }

    #[test]
    fn one_f_one_b_bounded_by_depth() {
        let (_, mm) = setup(4, 8);
        let peaks = peak_stash(&one_f_one_b(4, 8), &mm);
        for d in 0..4 {
            let expect = (4 - d) as f64 * mm.stages[d].act_per_mb;
            assert!(
                (peaks[d] - expect).abs() <= 1e-9 * expect,
                "dev {d}: {} vs {expect}",
                peaks[d]
            );
        }
    }

    #[test]
    fn split_release_strictly_below_fused_release() {
        let (_, mm) = setup(4, 8);
        let sch = zb_h1(4, 8);
        let split = peak_stash(&sch, &mm);
        let coarse = peak_stash_fused_release(&sch, &mm);
        for d in 0..4 {
            assert!(
                split[d] < coarse[d],
                "dev {d}: split {} !< coarse {}",
                split[d],
                coarse[d]
            );
        }
    }
}
