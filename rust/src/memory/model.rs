//! Per-stage memory footprints derived from the profiled layer tables.
//!
//! [`MemoryModel::build`] aggregates [`crate::model::LayerCost`] memory
//! fields over a (partition, placement) into [`StageFootprint`]s — the
//! same numbers the evaluation kernels consume via
//! [`crate::perfmodel::StageTable`], exposed here in taxonomy form
//! (weights / grads / optimizer / activations / W-retained slice) for
//! the generator's feasibility gate, the reference tracker and the
//! reports.

use crate::partition::Partition;
use crate::placement::Placement;
use crate::profile::ProfiledData;

/// Fraction of a stage's static memory that is raw parameters.  The
/// cost model packs static memory as `params + grads + 2 Adam moments`,
/// all fp32 ⇒ 4× the parameter bytes (see `model/cost.rs`); the
/// fractions below are exact binary values so the decomposition
/// round-trips bitwise (`weights + grads + optimizer == mem_static`).
pub const WEIGHTS_FRAC: f64 = 0.25;
/// Fraction that is the gradient accumulation buffer.
pub const GRADS_FRAC: f64 = 0.25;
/// Fraction that is optimizer state (two Adam moments).
pub const OPTIMIZER_FRAC: f64 = 0.5;

/// Memory footprint of one pipeline stage.
#[derive(Clone, Copy, Debug, Default)]
pub struct StageFootprint {
    /// Parameter bytes (TP-sharded).
    pub weights: f64,
    /// Gradient accumulation buffer — allocated for the whole step
    /// whether or not the backward is split.
    pub grads: f64,
    /// Optimizer state (Adam moments).
    pub optimizer: f64,
    /// Saved activations per in-flight micro-batch, charged at F: the
    /// backward working set (layer inputs + stashed intermediates).
    pub act_per_mb: f64,
    /// The slice of `act_per_mb` a delayed W still needs (the layer
    /// inputs feeding the param-grad matmuls).  A split backward
    /// releases `act_per_mb − act_w_per_mb` at B and this part at W; a
    /// fused backward releases everything at B.
    pub act_w_per_mb: f64,
    /// Bytes of the stage's boundary output tensor (what an F message
    /// to the next stage carries; a B message carries the gradient of
    /// the *consumer* stage's output, i.e. that stage's `out_bytes`).
    /// Prices checkpointing pending boundary tensors in
    /// [`crate::executor::recover`].
    pub out_bytes: f64,
}

impl StageFootprint {
    /// Schedule-independent memory: weights + grads + optimizer.
    pub fn static_total(&self) -> f64 {
        self.weights + self.grads + self.optimizer
    }

    /// The B-released part of the activation stash under a split
    /// backward.
    pub fn act_b_per_mb(&self) -> f64 {
        self.act_per_mb - self.act_w_per_mb
    }
}

/// Footprint of one stage (a contiguous layer range) — the aggregation
/// the whole subsystem is built on.  O(1) via the profile prefix sums.
pub fn stage_footprint(profile: &ProfiledData, range: std::ops::Range<usize>) -> StageFootprint {
    let c = profile.stage_cost(range);
    StageFootprint {
        weights: c.mem_static * WEIGHTS_FRAC,
        grads: c.mem_static * GRADS_FRAC,
        optimizer: c.mem_static * OPTIMIZER_FRAC,
        act_per_mb: c.mem_act,
        act_w_per_mb: c.mem_act_w,
        out_bytes: c.comm_bytes,
    }
}

/// Bytes that must move when layer `l` changes owner during a live
/// re-plan: weights plus optimizer state.  The gradient accumulation
/// buffer is *not* shipped — it is zeroed and re-accumulated on the new
/// owner — so the fraction is `WEIGHTS_FRAC + OPTIMIZER_FRAC` (exact
/// binary values; see the decomposition note above).  This is the
/// per-layer unit of the generator's migration-cost term
/// (`GenOptions::migration`) and of the adapt harness's switch charge.
pub fn layer_migration_bytes(profile: &ProfiledData, l: usize) -> f64 {
    profile.layers[l].mem_static * (WEIGHTS_FRAC + OPTIMIZER_FRAC)
}

/// Per-stage footprints plus the stage → device mapping: everything the
/// memory side of Algorithm 1 needs.
#[derive(Clone, Debug)]
pub struct MemoryModel {
    /// Pipeline devices.
    pub p: usize,
    /// Owning device per stage.
    pub device: Vec<usize>,
    /// Footprint per stage.
    pub stages: Vec<StageFootprint>,
}

impl MemoryModel {
    pub fn build(
        profile: &ProfiledData,
        partition: &Partition,
        placement: &Placement,
    ) -> MemoryModel {
        let s_n = partition.n_stages();
        assert_eq!(placement.n_stages(), s_n);
        MemoryModel {
            p: placement.p,
            device: placement.device_of.clone(),
            stages: (0..s_n)
                .map(|s| stage_footprint(profile, partition.stage_range(s)))
                .collect(),
        }
    }

    pub fn n_stages(&self) -> usize {
        self.stages.len()
    }

    /// Static memory aggregated per device (ascending stage order —
    /// the same summation the evaluation kernels use, so the result is
    /// bit-identical to `PerfReport::static_d`).
    pub fn static_d(&self) -> Vec<f64> {
        let mut out = vec![0.0; self.p];
        for (s, fp) in self.stages.iter().enumerate() {
            out[self.device[s]] += fp.static_total();
        }
        out
    }

    /// Optimizer-state bytes resident on `device` — what a rolled-back
    /// or re-installed optimizer step must move/rewrite, pricing the
    /// rollback charge in [`crate::executor::recover`].
    pub fn optimizer_bytes(&self, device: usize) -> f64 {
        self.stages
            .iter()
            .enumerate()
            .filter(|(s, _)| self.device[*s] == device)
            .map(|(_, fp)| fp.optimizer)
            .sum()
    }

    /// Stage indices owned by `device`, ascending.
    pub fn stages_of(&self, device: usize) -> Vec<usize> {
        (0..self.stages.len()).filter(|&s| self.device[s] == device).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Family, HardwareCfg, ModelCfg, ParallelCfg, Size};
    use crate::model::build_model;
    use crate::partition::uniform;
    use crate::placement::interleaved;

    fn prof() -> ProfiledData {
        let spec = build_model(&ModelCfg::table5(Family::Gemma, Size::Small));
        ProfiledData::analytical(
            &spec,
            &HardwareCfg::default(),
            &ParallelCfg::new(4, 2, 8, 1, 4096),
        )
    }

    #[test]
    fn static_decomposition_is_lossless() {
        let p = prof();
        let part = uniform(p.n_layers(), 4);
        for s in 0..4 {
            let fp = stage_footprint(&p, part.stage_range(s));
            let c = p.stage_cost(part.stage_range(s));
            // 0.25/0.25/0.5 are exact binary fractions: bitwise equal.
            assert_eq!(fp.static_total(), c.mem_static);
            assert!(fp.act_w_per_mb <= fp.act_per_mb);
            assert!(fp.act_b_per_mb() >= 0.0);
        }
    }

    #[test]
    fn static_d_matches_kernel_aggregation() {
        let p = prof();
        let part = uniform(p.n_layers(), 8);
        let pl = interleaved(4, 2);
        let mm = MemoryModel::build(&p, &part, &pl);
        let table = crate::perfmodel::StageTable::build(&p, &part, &pl);
        assert_eq!(mm.static_d(), table.static_d);
    }

    #[test]
    fn recovery_pricing_helpers() {
        let p = prof();
        let part = uniform(p.n_layers(), 4);
        let pl = interleaved(4, 1);
        let mm = MemoryModel::build(&p, &part, &pl);
        for s in 0..4 {
            assert!(mm.stages[s].out_bytes > 0.0, "boundary tensors have bytes");
            assert_eq!(mm.stages[s].out_bytes, p.stage_cost(part.stage_range(s)).comm_bytes);
        }
        let total: f64 = (0..4).map(|d| mm.optimizer_bytes(d)).sum();
        let expect: f64 = mm.stages.iter().map(|fp| fp.optimizer).sum();
        assert_eq!(total, expect);
        assert_eq!(mm.stages_of(2), vec![2]);
    }
}
