//! Memory subsystem: the peak-memory model that sits next to the
//! Pipeline Performance Model.
//!
//! Every axis the Pipeline Generator tunes trades bubbles against
//! per-device memory — warmup depth sets the live-activation count,
//! ZB-style W-delay retains part of the stash longer, and interleaved /
//! wave placements stack several stages' static state on one device.
//! Zero Bubble Pipeline Parallelism and Pipeline Parallelism with
//! Controllable Memory (see PAPERS.md) make the point explicit:
//! schedule families are points on a throughput/memory frontier.  This
//! module supplies the memory half of that frontier:
//!
//! - [`model`]: [`MemoryModel`] / [`StageFootprint`] — per-stage
//!   footprints (weights, gradient accumulators, optimizer state,
//!   saved activations per in-flight micro-batch, and the W-retained
//!   slice) derived from the profiled layer tables;
//! - [`caps`]: [`MemCaps`] — per-device memory capacities
//!   (heterogeneous caps allowed), consumed by the simulation kernels
//!   (OOM + headroom reporting) and the generator (feasibility gate);
//! - [`tracker`]: the retained *reference* peak tracker.  Per-device
//!   stash only changes when that device executes one of its own
//!   slots, so the peak is a pure function of the device's slot order —
//!   the tracker replays it directly and must agree bit-for-bit with
//!   the event-driven kernels (`tests/memory_differential.rs`).
//!
//! Charge/release protocol (shared by the fast kernels and the
//! tracker): `act_per_mb` is charged when F executes; a fused backward
//! releases all of it at B; a split backward releases the B-consumed
//! part (`act_per_mb − act_w_per_mb`) at B and the W-retained slice
//! (`act_w_per_mb`) at W.  Static memory is schedule-independent and is
//! reported separately (`PerfReport::static_d`).

pub mod caps;
pub mod model;
pub mod tracker;

pub use caps::MemCaps;
pub use model::{MemoryModel, StageFootprint};
pub use tracker::{peak_stash, peak_stash_collapsed, peak_stash_fused_release};
