//! Per-device memory capacities.
//!
//! The seed code carried one scalar capacity on [`crate::profile::ProfiledData`];
//! real clusters mix device generations (80 GB H800s next to 40 GB
//! A100s), and the generator must reject plans that fit the average but
//! not the smallest device.  [`MemCaps`] is the per-device vector the
//! whole evaluation stack consumes; `f64::INFINITY` entries model
//! unbounded devices (throughput-only search).

/// Per-device memory capacity (bytes).  Entries may be
/// `f64::INFINITY` (unbounded); non-positive entries are permitted and
/// simply mark every plan on that device OOM (the seed code's scalar
/// capacity had the same degenerate behaviour — kept so profiles with
/// a zeroed `mem_capacity` degrade to OOM reports, not panics).
#[derive(Clone, Debug, PartialEq)]
pub struct MemCaps {
    caps: Vec<f64>,
}

impl MemCaps {
    /// Same capacity on every device (the homogeneous-cluster default).
    pub fn uniform(p: usize, bytes: f64) -> MemCaps {
        assert!(p > 0, "no devices");
        assert!(!bytes.is_nan(), "NaN capacity");
        MemCaps { caps: vec![bytes; p] }
    }

    /// No memory constraint (throughput-only search).
    pub fn unbounded(p: usize) -> MemCaps {
        MemCaps::uniform(p, f64::INFINITY)
    }

    /// Heterogeneous capacities, one entry per device.
    pub fn per_device(caps: Vec<f64>) -> MemCaps {
        assert!(!caps.is_empty(), "no devices");
        assert!(caps.iter().all(|c| !c.is_nan()), "NaN capacity");
        MemCaps { caps }
    }

    pub fn p(&self) -> usize {
        self.caps.len()
    }

    /// Capacity of device `d`.
    #[inline]
    pub fn cap(&self, d: usize) -> f64 {
        self.caps[d]
    }

    pub fn as_slice(&self) -> &[f64] {
        &self.caps
    }

    /// True when at least one device has a finite cap — i.e. memory can
    /// constrain the search at all.
    pub fn bounded(&self) -> bool {
        self.caps.iter().any(|c| c.is_finite())
    }

    /// Feasibility lower bound: a pipeline whose *static* per-device
    /// memory (weights + grads + optimizer) already exceeds a cap can
    /// never fit, whatever the schedule does with activations.  The
    /// generator uses this to reject candidates before scoring them.
    pub fn fits_static(&self, static_d: &[f64]) -> bool {
        debug_assert_eq!(static_d.len(), self.caps.len());
        static_d.iter().zip(&self.caps).all(|(&m, &c)| m <= c)
    }

}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_and_unbounded() {
        let u = MemCaps::uniform(4, 80e9);
        assert_eq!(u.p(), 4);
        assert_eq!(u.cap(3), 80e9);
        assert!(u.bounded());
        let inf = MemCaps::unbounded(2);
        assert!(!inf.bounded());
        assert_eq!(inf.cap(0), f64::INFINITY);
    }

    #[test]
    fn static_gate() {
        let caps = MemCaps::per_device(vec![10.0, 20.0]);
        assert!(caps.fits_static(&[10.0, 19.0]));
        assert!(!caps.fits_static(&[10.1, 19.0]));
        // Unbounded devices never bind.
        let hetero = MemCaps::per_device(vec![f64::INFINITY, 8.0]);
        assert!(hetero.bounded());
        assert!(hetero.fits_static(&[1e30, 8.0]));
    }
}
