//! Elastic re-planning (DESIGN.md § Elastic re-planning): the runtime
//! loop that keeps an AdaPtis pipeline near-optimal while the cluster
//! degrades underneath it.
//!
//! The paper's Pipeline Generator plans once, offline, for a cluster
//! profile assumed constant.  Real clusters drift — thermal
//! throttling, noisy neighbours, slow links, outright device loss —
//! and a static plan silently decays (or stalls) as its assumptions
//! rot.  This module closes the loop:
//!
//! ```text
//!   SimCluster + FaultPlan ──step timings──▶ Monitor ──Replan──▶ Replanner
//!        ▲                                   (drift est.,        (warm-started
//!        │                                    hysteresis,         generate_with_cache)
//!        └────────── switch (pay migration) ◀─rollback)◀──────────────┘
//! ```
//!
//! - [`monitor`]: consumes executed-step timings (total + per-device
//!   busy), maintains rolling per-device *rate* estimates with
//!   median-based outlier rejection, and decides — with hysteresis, a
//!   cooldown, and a probation window that rolls back switches that
//!   don't pay off — when the gap between observed and predicted step
//!   time justifies re-planning.
//! - [`replan`]: wraps [`crate::generator::generate_with_cache`] with
//!   the persistent [`crate::generator::cache::EvalCache`], quantized
//!   rate estimates (cache-fingerprint stability), the incumbent warm
//!   start and the migration-cost objective.
//! - [`harness`]: the closed-loop scenario runner — Static vs Elastic
//!   vs Oracle over the *same* deterministic
//!   [`crate::cluster::FaultPlan`] — producing the recovery metrics
//!   `benches/replan.rs` emits (re-plan latency, steps-to-recover,
//!   throughput retained vs oracle).
//!
//! Everything downstream of the fault seed is deterministic: the fault
//! views are pure functions of `(plan, step)`, the simulator and the
//! generator are bitwise-reproducible, and re-plan *latency* is kept
//! out of the virtual-time accounting (searches run async with
//! training; only the migration pause is charged).  Scenario runs
//! therefore replay bitwise (`tests/adapt_replan.rs`).

pub mod harness;
pub mod monitor;
pub mod replan;

pub use harness::{
    run_scenario, throughput_retained, ElasticCfg, Policy, RecoveryCfg, RecoveryEvent, RunStats,
    Scenario,
};
pub use monitor::{Decision, Monitor, MonitorCfg};
pub use replan::{ReplanCfg, Replanner};
