//! Closed-loop fault-scenario harness: Static vs Elastic vs Oracle
//! over the *same* deterministic [`FaultPlan`].
//!
//! One virtual training run = `steps` executions of the active plan's
//! lowered [`Program`] on the timed SimCluster, each under the fault
//! view of its step.  Three policies:
//!
//! - **Static**: plan once, never adapt.  A straggler degrades every
//!   remaining step; a device kill stalls the run permanently (the
//!   paper's implicit baseline).
//! - **Elastic**: the full loop — [`Monitor`] watches executed-step
//!   timings, [`Replanner`] re-generates warm-started plans under the
//!   monitor's rate estimates, switches pay the migration pause, bad
//!   switches roll back.
//! - **Oracle**: reads the fault plan directly and re-plans with zero
//!   latency and zero switch cost whenever the (quantized) true rates
//!   move — the upper bound "throughput retained" is measured against.
//!
//! **Accounting.**  Virtual time advances by each step's simulated
//! makespan plus, for Elastic, the migration pause of every switch
//! (`switch_seconds`: weights + optimizer state of every layer whose
//! *physical* owner changes, at [`MigrationCfg`]'s bandwidth).
//! Re-plan *search latency* is measured and reported
//! ([`ReplanEvent::latency_s`]) but not charged to virtual time — the
//! search runs host-side while the old plan keeps training; only the
//! weight movement pauses the pipeline.  That keeps every virtual
//! quantity a pure function of the fault seed, so scenario runs replay
//! bitwise (`tests/adapt_replan.rs`) while latency percentiles stay
//! honest wall-clock measurements (`benches/replan.rs`).
//!
//! **Device loss.**  Plans live in a *logical* device space;
//! [`ActivePlan`]'s `phys` map ties logical indices to the fault
//! plan's physical devices.  When a physical device dies, the harness
//! remaps to the survivors, drops the (structurally meaningless)
//! incumbent, re-plans on `p−1` logical devices, and keeps going —
//! the sim never has to execute a program on a dead device, so the
//! [`crate::cluster::sim::SimDeadlock`] stall path stays an
//! exceptional diagnostic rather than a control-flow mechanism.

use std::time::Instant;

use crate::cluster::fault::{FaultPlan, FaultView};
use crate::cluster::sim::{run_timed_faulted, SimOptions};
use crate::executor::lower::{lower, LowerOptions};
use crate::executor::Program;
use crate::generator::{GenResult, Incumbent, MigrationCfg};
use crate::memory::model::layer_migration_bytes;
use crate::memory::MemCaps;
use crate::partition::Partition;
use crate::placement::{sequential, Placement};
use crate::perfmodel::{simulate_in, SimArena, StageTable};
use crate::profile::ProfiledData;
use crate::schedule::greedy::{greedy_schedule_in, SchedKnobs};

use super::monitor::{Decision, Monitor, MonitorCfg};
use super::replan::{ReplanCfg, Replanner};

/// Adaptation policy for one scenario run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Policy {
    Static,
    Elastic,
    Oracle,
}

impl Policy {
    pub fn name(self) -> &'static str {
        match self {
            Policy::Static => "static",
            Policy::Elastic => "elastic",
            Policy::Oracle => "oracle",
        }
    }
}

/// A named fault schedule plus a step horizon.
#[derive(Clone, Debug)]
pub struct Scenario {
    pub name: &'static str,
    pub fault: FaultPlan,
    pub steps: usize,
}

impl Scenario {
    /// Canonical straggler: `device` slows `factor`× from `from` to
    /// the end of the run.
    pub fn straggler(p: usize, device: usize, factor: f64, from: usize, steps: usize) -> Scenario {
        Scenario {
            name: "straggler",
            fault: FaultPlan::healthy(p).with_event(
                crate::cluster::fault::FaultEvent::Straggler {
                    device,
                    factor,
                    from,
                    until: usize::MAX,
                },
            ),
            steps,
        }
    }

    /// Canonical device loss at `at`.
    pub fn kill(p: usize, device: usize, at: usize, steps: usize) -> Scenario {
        Scenario {
            name: "kill",
            fault: FaultPlan::healthy(p)
                .with_event(crate::cluster::fault::FaultEvent::Kill { device, step: at }),
            steps,
        }
    }

    /// Mild smooth drift (stays under the default gap threshold): the
    /// control scenario where the elastic loop must *not* fire.
    pub fn drift_mild(p: usize, device: usize, steps: usize) -> Scenario {
        Scenario {
            name: "drift_mild",
            fault: FaultPlan::healthy(p).with_drift(crate::cluster::fault::Drift {
                device,
                amplitude: 0.04,
                period: 2.0 * steps as f64,
                phase: 0.0,
            }),
            steps,
        }
    }
}

/// Elastic-policy configuration (also carries the migration pricing
/// Static/Oracle accounting shares).
#[derive(Clone, Debug, Default)]
pub struct ElasticCfg {
    pub monitor: MonitorCfg,
    pub replan: ReplanCfg,
    /// Chaos knob for the rollback path: replace the *first* re-plan's
    /// result with a deliberately terrible (but valid) plan, so
    /// probation must fail and the monitor must restore the incumbent.
    pub sabotage_first_replan: bool,
}

/// One switch (or attempted switch) of the active plan.
#[derive(Clone, Debug)]
pub struct ReplanEvent {
    pub step: usize,
    /// Wall-clock seconds the re-generation search took (0 for the
    /// oracle and for rollbacks, which need no search).
    pub latency_s: f64,
    /// Virtual seconds the pipeline paused to move weights.
    pub switch_s: f64,
    /// "drift" | "kill" | "rollback" | "oracle".
    pub kind: &'static str,
}

/// Outcome of one (scenario, policy) run.
#[derive(Clone, Debug)]
pub struct RunStats {
    pub policy: &'static str,
    pub scenario: &'static str,
    /// Steps actually completed (`< steps` only when stalled).
    pub steps_done: usize,
    /// Simulated seconds: step makespans + migration pauses.
    pub virtual_time_s: f64,
    pub step_times: Vec<f64>,
    pub replans: Vec<ReplanEvent>,
    pub rollbacks: usize,
    /// Steps from the first over-threshold gap to the first
    /// post-switch step back under the threshold (elastic only).
    pub steps_to_recover: Option<usize>,
    /// Step at which a static run hit a dead device and froze.
    pub stalled_at: Option<usize>,
}

/// Throughput of `run` relative to the oracle, both measured over the
/// longer of the two virtual horizons — so a stalled run is charged
/// for the steps it never delivered, and the oracle scores 1.0 by
/// construction.
pub fn throughput_retained(run: &RunStats, oracle: &RunStats) -> f64 {
    let horizon = run.virtual_time_s.max(oracle.virtual_time_s);
    let own = run.steps_done as f64 / horizon;
    let orc = oracle.steps_done as f64 / oracle.virtual_time_s;
    own / orc
}

/// The running plan: logical-space artifacts plus the logical →
/// physical device map.
struct ActivePlan {
    part: Partition,
    plac: Placement,
    knobs: SchedKnobs,
    prog: Program,
    pred_total: f64,
    pred_busy: Vec<f64>,
    /// Rates the predictions were priced under (logical space).
    plan_rates: Vec<f64>,
    /// Logical device `d` runs on physical device `phys[d]`.
    phys: Vec<usize>,
}

impl ActivePlan {
    fn from_gen(res: &GenResult, phys: Vec<usize>, plan_rates: Vec<f64>) -> ActivePlan {
        let prog =
            lower(&res.pipeline.schedule, &res.pipeline.placement, LowerOptions::default());
        ActivePlan {
            part: res.pipeline.partition.clone(),
            plac: res.pipeline.placement.clone(),
            knobs: res.knobs,
            prog,
            pred_total: res.report.total,
            pred_busy: res.report.busy_d.clone(),
            plan_rates,
            phys,
        }
    }

    fn incumbent(&self) -> Incumbent {
        Incumbent {
            partition: self.part.clone(),
            placement: self.plac.clone(),
            knobs: self.knobs,
        }
    }
}

/// Project the physical fault view into a plan's logical space.
fn remap_view(view: &FaultView, phys: &[usize]) -> FaultView {
    let p = phys.len();
    let pp = view.alive.len();
    let mut v = FaultView::healthy(p);
    v.step = view.step;
    for (i, &pi) in phys.iter().enumerate() {
        v.compute_scale[i] = view.compute_scale[pi];
        v.alive[i] = view.alive[pi];
        for (j, &pj) in phys.iter().enumerate() {
            v.link_scale[i * p + j] = view.link_scale[pi * pp + pj];
        }
    }
    v
}

/// Physical owner per layer.
fn phys_owner(plan: &ActivePlan, n_layers: usize) -> Vec<usize> {
    let mut out = vec![usize::MAX; n_layers];
    for s in 0..plan.part.n_stages() {
        let d = plan.phys[plan.plac.device_of[s]];
        for l in plan.part.stage_range(s) {
            out[l] = d;
        }
    }
    out
}

/// Virtual seconds the pipeline pauses to ship weights + optimizer
/// state for every layer whose physical owner changes between plans.
fn switch_seconds(
    profile: &ProfiledData,
    from: &ActivePlan,
    to: &ActivePlan,
    cfg: MigrationCfg,
) -> f64 {
    let n = profile.n_layers();
    let (a, b) = (phys_owner(from, n), phys_owner(to, n));
    let mut bytes = 0.0;
    for l in 0..n {
        if a[l] != b[l] {
            bytes += layer_migration_bytes(profile, l);
        }
    }
    bytes / cfg.bw
}

/// A valid but deliberately terrible plan (nearly all layers on one
/// device) with honest predictions — the sabotage target for rollback
/// tests.  `Placement::is_valid` requires every device to own a stage,
/// so "terrible" is a maximally imbalanced partition, not an
/// all-on-one placement.
fn sabotage_plan(
    profile: &ProfiledData,
    p: usize,
    nmb: usize,
    rates: &[f64],
    phys: Vec<usize>,
) -> ActivePlan {
    let n = profile.n_layers();
    assert!(n >= p && p >= 2);
    let mut sizes = vec![1usize; p];
    sizes[0] = n - (p - 1);
    let part = Partition::from_sizes(&sizes);
    let plac = sequential(p);
    let knobs = SchedKnobs::default();
    let table = StageTable::build_rated(profile, &part, &plac, rates);
    let caps = MemCaps::unbounded(p);
    let mut arena = SimArena::new();
    let schedule = greedy_schedule_in(&mut arena, &table, &caps, nmb, knobs);
    let report =
        simulate_in(&mut arena, &table, &caps, &schedule, false).expect("sabotage plan simulates");
    let prog = lower(&schedule, &plac, LowerOptions::default());
    ActivePlan {
        part,
        plac,
        knobs,
        prog,
        pred_total: report.total,
        pred_busy: report.busy_d,
        plan_rates: rates.to_vec(),
        phys,
    }
}

/// Run one (scenario, policy) pair.  See the module docs for the
/// accounting rules.
pub fn run_scenario(
    profile: &ProfiledData,
    scenario: &Scenario,
    nmb: usize,
    policy: Policy,
    cfg: &ElasticCfg,
) -> RunStats {
    let p0 = scenario.fault.p;
    let sim = SimOptions::matched();
    let mut replanner = Replanner::new(cfg.replan);
    let unit = vec![1.0; p0];
    let res0 = replanner.plan(profile, p0, nmb, &unit);
    let mut plan = ActivePlan::from_gen(&res0, (0..p0).collect(), unit);
    let mut monitor = Monitor::new(p0, cfg.monitor);
    monitor.set_plan(plan.pred_total, plan.pred_busy.clone(), plan.plan_rates.clone());

    let mut stats = RunStats {
        policy: policy.name(),
        scenario: scenario.name,
        steps_done: 0,
        virtual_time_s: 0.0,
        step_times: Vec::with_capacity(scenario.steps),
        replans: Vec::new(),
        rollbacks: 0,
        steps_to_recover: None,
        stalled_at: None,
    };
    let mut rollback_to: Option<ActivePlan> = None;
    let mut sabotaged = false;
    let mut gap_onset: Option<usize> = None;
    let mut switched_since_gap = false;

    for step in 0..scenario.steps {
        let pview = scenario.fault.view(step);

        // ---- Device loss ------------------------------------------------
        if plan.phys.iter().any(|&d| !pview.alive[d]) {
            if policy == Policy::Static {
                stats.stalled_at = Some(step);
                break;
            }
            let alive: Vec<usize> = (0..p0).filter(|&d| pview.alive[d]).collect();
            let p_new = alive.len();
            assert!(p_new >= 2, "scenario killed the cluster below a pipeline");
            // Carry estimates across the remap where the physical
            // device survives; the oracle reads the true scales.
            let mut est = vec![1.0; p_new];
            for (j, &pd) in alive.iter().enumerate() {
                est[j] = if policy == Policy::Oracle {
                    pview.compute_scale[pd]
                } else if let Some(l) = plan.phys.iter().position(|&q| q == pd) {
                    monitor.rates().get(l).copied().unwrap_or(1.0)
                } else {
                    1.0
                };
            }
            let t = Instant::now();
            let res = replanner.plan(profile, p_new, nmb, &est);
            let latency = t.elapsed().as_secs_f64();
            let rates_q = replanner.quantize(&est).unwrap_or_else(|| vec![1.0; p_new]);
            let new_plan = ActivePlan::from_gen(&res, alive, rates_q);
            let switch_s = switch_seconds(profile, &plan, &new_plan, cfg.replan.migration);
            if policy == Policy::Elastic {
                stats.virtual_time_s += switch_s;
            }
            stats.replans.push(ReplanEvent {
                step,
                latency_s: if policy == Policy::Oracle { 0.0 } else { latency },
                switch_s,
                kind: "kill",
            });
            plan = new_plan;
            rollback_to = None;
            monitor = Monitor::new(p_new, cfg.monitor);
            monitor.set_plan(plan.pred_total, plan.pred_busy.clone(), plan.plan_rates.clone());
            gap_onset.get_or_insert(step);
            switched_since_gap = true;
        }

        // ---- Oracle: re-plan the moment true rates move -----------------
        if policy == Policy::Oracle {
            let true_rates: Vec<f64> =
                plan.phys.iter().map(|&pd| pview.compute_scale[pd]).collect();
            let q = replanner
                .quantize(&true_rates)
                .unwrap_or_else(|| vec![1.0; plan.phys.len()]);
            if q.iter()
                .zip(&plan.plan_rates)
                .any(|(a, b)| (a - b).abs() > 0.03)
            {
                let res = replanner.plan(profile, plan.phys.len(), nmb, &true_rates);
                plan = ActivePlan::from_gen(&res, plan.phys.clone(), q);
                stats.replans.push(ReplanEvent {
                    step,
                    latency_s: 0.0,
                    switch_s: 0.0,
                    kind: "oracle",
                });
            }
        }

        // ---- Execute the step -------------------------------------------
        let lview = remap_view(&pview, &plan.phys);
        let run = run_timed_faulted(profile, &plan.part, &plan.prog, sim, Some(&lview))
            .expect("no live plan may stall (kills are handled above)");
        let dt = run.makespan;
        stats.virtual_time_s += dt;
        stats.step_times.push(dt);
        stats.steps_done += 1;

        if policy != Policy::Elastic {
            continue;
        }

        // ---- Elastic: monitor + decisions -------------------------------
        let gap = (dt - plan.pred_total) / plan.pred_total;
        if gap > cfg.monitor.gap_threshold {
            if gap_onset.is_none() {
                gap_onset = Some(step);
                switched_since_gap = false;
            }
        } else if let Some(onset) = gap_onset {
            if switched_since_gap && stats.steps_to_recover.is_none() {
                stats.steps_to_recover = Some(step - onset);
            }
        }
        match monitor.observe(dt, Some(&run.busy_d)) {
            Decision::Steady => {}
            Decision::Commit => {
                rollback_to = None;
            }
            Decision::Rollback => {
                if let Some(old) = rollback_to.take() {
                    let switch_s = switch_seconds(profile, &plan, &old, cfg.replan.migration);
                    stats.virtual_time_s += switch_s;
                    stats.replans.push(ReplanEvent {
                        step,
                        latency_s: 0.0,
                        switch_s,
                        kind: "rollback",
                    });
                    replanner.set_incumbent(old.incumbent());
                    plan = old;
                    monitor.set_plan(
                        plan.pred_total,
                        plan.pred_busy.clone(),
                        plan.plan_rates.clone(),
                    );
                    stats.rollbacks += 1;
                }
            }
            Decision::Replan { .. } => {
                let est = monitor.rates().to_vec();
                let t = Instant::now();
                let res = replanner.plan(profile, plan.phys.len(), nmb, &est);
                let latency = t.elapsed().as_secs_f64();
                let rates_q =
                    replanner.quantize(&est).unwrap_or_else(|| vec![1.0; plan.phys.len()]);
                let mut new_plan = ActivePlan::from_gen(&res, plan.phys.clone(), rates_q);
                if cfg.sabotage_first_replan && !sabotaged {
                    sabotaged = true;
                    new_plan = sabotage_plan(
                        profile,
                        plan.phys.len(),
                        nmb,
                        &new_plan.plan_rates.clone(),
                        plan.phys.clone(),
                    );
                    replanner.set_incumbent(new_plan.incumbent());
                }
                let unchanged = new_plan.part == plan.part
                    && new_plan.plac == plan.plac
                    && new_plan.knobs == plan.knobs;
                if unchanged {
                    // Nothing better exists under the current
                    // estimates; cool down instead of thrashing.
                    monitor.dismissed();
                } else {
                    let switch_s = switch_seconds(profile, &plan, &new_plan, cfg.replan.migration);
                    stats.virtual_time_s += switch_s;
                    stats.replans.push(ReplanEvent { step, latency_s: latency, switch_s, kind: "drift" });
                    rollback_to = Some(std::mem::replace(&mut plan, new_plan));
                    monitor.switched(
                        plan.pred_total,
                        plan.pred_busy.clone(),
                        plan.plan_rates.clone(),
                    );
                    switched_since_gap = true;
                }
            }
        }
    }
    stats
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Family, HardwareCfg, ModelCfg, ParallelCfg, Size};
    use crate::model::build_model;

    fn prof(p: usize, nmb: usize) -> ProfiledData {
        let spec = build_model(&ModelCfg::table5(Family::Gemma, Size::Small));
        ProfiledData::analytical(
            &spec,
            &HardwareCfg::default(),
            &ParallelCfg::new(p, 2, nmb, 1, 4096),
        )
    }

    #[test]
    fn healthy_scenario_is_identical_across_policies() {
        let pr = prof(4, 8);
        let sc = Scenario { name: "healthy", fault: FaultPlan::healthy(4), steps: 12 };
        let cfg = ElasticCfg::default();
        let st = run_scenario(&pr, &sc, 8, Policy::Static, &cfg);
        let el = run_scenario(&pr, &sc, 8, Policy::Elastic, &cfg);
        let or = run_scenario(&pr, &sc, 8, Policy::Oracle, &cfg);
        // No faults: nobody re-plans, all three run the same plan and
        // the virtual clocks agree bitwise.
        assert!(el.replans.is_empty() && or.replans.is_empty());
        assert_eq!(st.virtual_time_s, el.virtual_time_s);
        assert_eq!(st.virtual_time_s, or.virtual_time_s);
        assert_eq!(throughput_retained(&el, &or), 1.0);
        // Matched-mode predictions are exact: zero healthy-state gap.
        assert_eq!(el.step_times[0], el.step_times[11]);
    }

    #[test]
    fn remapped_views_index_physical_space() {
        let v = FaultPlan::healthy(4)
            .with_event(crate::cluster::fault::FaultEvent::Straggler {
                device: 2,
                factor: 2.0,
                from: 0,
                until: usize::MAX,
            })
            .view(0);
        let r = remap_view(&v, &[0, 2, 3]);
        assert_eq!(r.compute_scale, vec![1.0, 2.0, 1.0]);
        assert_eq!(r.alive, vec![true, true, true]);
    }
}
