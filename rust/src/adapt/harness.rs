//! Closed-loop fault-scenario harness: Static vs Elastic vs Oracle
//! over the *same* deterministic [`FaultPlan`].
//!
//! One virtual training run = `steps` executions of the active plan's
//! lowered [`Program`] on the timed SimCluster, each under the fault
//! view of its step.  Three policies:
//!
//! - **Static**: plan once, never adapt.  A straggler degrades every
//!   remaining step; a device kill stalls the run permanently (the
//!   paper's implicit baseline).
//! - **Elastic**: the full loop — [`Monitor`] watches executed-step
//!   timings, [`Replanner`] re-generates warm-started plans under the
//!   monitor's rate estimates, switches pay the migration pause, bad
//!   switches roll back.
//! - **Oracle**: reads the fault plan directly and re-plans with zero
//!   latency and zero switch cost whenever the (quantized) true rates
//!   move — the upper bound "throughput retained" is measured against.
//!
//! **Accounting.**  Virtual time advances by each step's simulated
//! makespan plus, for Elastic, the migration pause of every switch
//! (`switch_seconds`: weights + optimizer state of every layer whose
//! *physical* owner changes, at [`MigrationCfg`]'s bandwidth).
//! Re-plan *search latency* is measured and reported
//! ([`ReplanEvent::latency_s`]) but not charged to virtual time — the
//! search runs host-side while the old plan keeps training; only the
//! weight movement pauses the pipeline.  That keeps every virtual
//! quantity a pure function of the fault seed, so scenario runs replay
//! bitwise (`tests/adapt_replan.rs`) while latency percentiles stay
//! honest wall-clock measurements (`benches/replan.rs`).
//!
//! **Device loss.**  Plans live in a *logical* device space;
//! [`ActivePlan`]'s `phys` map ties logical indices to the fault
//! plan's physical devices.  When a physical device dies, the harness
//! remaps to the survivors, drops the (structurally meaningless)
//! incumbent, re-plans on `p−1` logical devices, and keeps going —
//! the sim never has to execute a program on a dead device, so the
//! [`crate::cluster::sim::SimDeadlock`] stall path stays an
//! exceptional diagnostic rather than a control-flow mechanism.

use std::collections::HashSet;
use std::time::Instant;

use crate::cluster::fault::{FaultPlan, FaultView, RetryPolicy, StepFaults};
use crate::cluster::sim::{run_timed_faulted, run_timed_midstep, MidstepOutcome, SimOptions};
use crate::executor::lower::{lower, LowerOptions};
use crate::executor::recover::{self, CheckpointCfg, OpKey};
use crate::executor::Program;
use crate::generator::{GenResult, Incumbent, MigrationCfg};
use crate::memory::model::layer_migration_bytes;
use crate::memory::{MemCaps, MemoryModel};
use crate::partition::Partition;
use crate::placement::{sequential, Placement};
use crate::perfmodel::{simulate_in, SimArena, StageTable};
use crate::profile::ProfiledData;
use crate::schedule::greedy::{greedy_schedule_in, SchedKnobs};
use crate::schedule::Schedule;

use super::monitor::{Decision, Monitor, MonitorCfg};
use super::replan::{ReplanCfg, Replanner};

/// Adaptation policy for one scenario run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Policy {
    Static,
    Elastic,
    Oracle,
}

impl Policy {
    pub fn name(self) -> &'static str {
        match self {
            Policy::Static => "static",
            Policy::Elastic => "elastic",
            Policy::Oracle => "oracle",
        }
    }
}

/// A named fault schedule plus a step horizon.
#[derive(Clone, Debug)]
pub struct Scenario {
    pub name: &'static str,
    pub fault: FaultPlan,
    pub steps: usize,
}

impl Scenario {
    /// Canonical straggler: `device` slows `factor`× from `from` to
    /// the end of the run.
    pub fn straggler(p: usize, device: usize, factor: f64, from: usize, steps: usize) -> Scenario {
        Scenario {
            name: "straggler",
            fault: FaultPlan::healthy(p).with_event(
                crate::cluster::fault::FaultEvent::Straggler {
                    device,
                    factor,
                    from,
                    until: usize::MAX,
                },
            ),
            steps,
        }
    }

    /// Canonical device loss at `at`.
    pub fn kill(p: usize, device: usize, at: usize, steps: usize) -> Scenario {
        Scenario {
            name: "kill",
            fault: FaultPlan::healthy(p)
                .with_event(crate::cluster::fault::FaultEvent::Kill { device, step: at }),
            steps,
        }
    }

    /// Mild smooth drift (stays under the default gap threshold): the
    /// control scenario where the elastic loop must *not* fire.
    pub fn drift_mild(p: usize, device: usize, steps: usize) -> Scenario {
        Scenario {
            name: "drift_mild",
            fault: FaultPlan::healthy(p).with_drift(crate::cluster::fault::Drift {
                device,
                amplitude: 0.04,
                period: 2.0 * steps as f64,
                phase: 0.0,
            }),
            steps,
        }
    }
}

/// Elastic-policy configuration (also carries the migration pricing
/// Static/Oracle accounting shares).
#[derive(Clone, Debug, Default)]
pub struct ElasticCfg {
    pub monitor: MonitorCfg,
    pub replan: ReplanCfg,
    /// Chaos knob for the rollback path: replace the *first* re-plan's
    /// result with a deliberately terrible (but valid) plan, so
    /// probation must fail and the monitor must restore the incumbent.
    pub sabotage_first_replan: bool,
    /// Execution-layer fault tolerance (DESIGN.md §10).  Default-off:
    /// with recovery disabled every scenario run is bit-identical to
    /// the pre-recovery harness.
    pub recovery: RecoveryCfg,
}

/// Checkpointed mid-step recovery knobs.
#[derive(Clone, Debug)]
pub struct RecoveryCfg {
    /// Splice-and-complete recovery onto a spare instead of the
    /// shrink-and-restart fallback (which stays available when no
    /// spare is free).
    pub enabled: bool,
    /// Physical devices held out of the initial plan as hot spares:
    /// plans are generated on `fault.p − spares` logical devices.
    pub spares: usize,
    /// Intra-step checkpoint cadence and capture/restore pricing.
    pub checkpoint: CheckpointCfg,
    /// Timeout/backoff transport policy — prices failure *detection*
    /// and rides out transient link windows.
    pub retry: RetryPolicy,
}

impl Default for RecoveryCfg {
    fn default() -> RecoveryCfg {
        RecoveryCfg {
            enabled: false,
            spares: 0,
            checkpoint: CheckpointCfg::default(),
            retry: RetryPolicy::default(),
        }
    }
}

/// One checkpointed mid-step recovery (or optimizer-only rollback when
/// the kill landed after the victim's last instruction of the step).
#[derive(Clone, Debug)]
pub struct RecoveryEvent {
    pub step: usize,
    /// Virtual time within the step the device froze.
    pub kill_at_s: f64,
    /// Timeout/retry-ladder detection charge (0 for the oracle).
    pub detect_s: f64,
    /// Virtual seconds discarded: work to the abort plus detection
    /// (for an after-the-fact kill, the optimizer rollback instead).
    pub lost_s: f64,
    /// Capture pauses charged on this step's pre-kill prefix.
    pub ckpt_overhead_s: f64,
    /// Pause to install the dead stages' weights + optimizer on the
    /// spare (0 for the oracle).
    pub switch_s: f64,
    /// Pause to restore checkpointed tensors onto the spare.
    pub restore_s: f64,
    /// Makespan of the spliced recovery program.
    pub replay_s: f64,
    /// Counterfactual: full-step restart makespan on the patched
    /// cluster — what the shrink-and-restart baseline would re-run.
    pub restart_s: f64,
    /// |replay set| (ops re-executed on the spare).
    pub replayed_ops: usize,
    /// Retention-buffer resends spliced into the recovery program.
    pub resends: usize,
    /// Bytes restored from the checkpoint.
    pub restored_bytes: f64,
    /// Optimizer re-install charge (after-update kills only).
    pub opt_rollback_s: f64,
}

/// One switch (or attempted switch) of the active plan.
#[derive(Clone, Debug)]
pub struct ReplanEvent {
    pub step: usize,
    /// Wall-clock seconds the re-generation search took (0 for the
    /// oracle and for rollbacks, which need no search).
    pub latency_s: f64,
    /// Virtual seconds the pipeline paused to move weights.
    pub switch_s: f64,
    /// "drift" | "kill" | "rollback" | "oracle".
    pub kind: &'static str,
}

/// Outcome of one (scenario, policy) run.
#[derive(Clone, Debug)]
pub struct RunStats {
    pub policy: &'static str,
    pub scenario: &'static str,
    /// Steps actually completed (`< steps` only when stalled).
    pub steps_done: usize,
    /// Simulated seconds: step makespans + migration pauses.
    pub virtual_time_s: f64,
    pub step_times: Vec<f64>,
    pub replans: Vec<ReplanEvent>,
    pub rollbacks: usize,
    /// Steps from the first over-threshold gap to the first
    /// post-switch step back under the threshold (elastic only).
    pub steps_to_recover: Option<usize>,
    /// Step at which a static run hit a dead device and froze.
    pub stalled_at: Option<usize>,
    /// Checkpointed mid-step recoveries (empty unless
    /// [`RecoveryCfg::enabled`]).
    pub recoveries: Vec<RecoveryEvent>,
    /// Virtual seconds discarded to faults: pre-abort work + detection
    /// + optimizer rollbacks.
    pub lost_work_s: f64,
    /// Virtual seconds spent capturing checkpoints.
    pub checkpoint_overhead_s: f64,
}

/// Throughput of `run` relative to the oracle, both measured over the
/// longer of the two virtual horizons — so a stalled run is charged
/// for the steps it never delivered, and the oracle scores 1.0 by
/// construction.
pub fn throughput_retained(run: &RunStats, oracle: &RunStats) -> f64 {
    let horizon = run.virtual_time_s.max(oracle.virtual_time_s);
    let own = run.steps_done as f64 / horizon;
    let orc = oracle.steps_done as f64 / oracle.virtual_time_s;
    own / orc
}

/// The running plan: logical-space artifacts plus the logical →
/// physical device map.
struct ActivePlan {
    part: Partition,
    plac: Placement,
    knobs: SchedKnobs,
    /// The logical schedule the program was lowered from — kept so
    /// [`recover::plan_recovery`] can splice a recovery program.
    sched: Schedule,
    prog: Program,
    pred_total: f64,
    pred_busy: Vec<f64>,
    /// Rates the predictions were priced under (logical space).
    plan_rates: Vec<f64>,
    /// Logical device `d` runs on physical device `phys[d]`.
    phys: Vec<usize>,
}

impl ActivePlan {
    fn from_gen(res: &GenResult, phys: Vec<usize>, plan_rates: Vec<f64>) -> ActivePlan {
        let prog =
            lower(&res.pipeline.schedule, &res.pipeline.placement, LowerOptions::default());
        ActivePlan {
            part: res.pipeline.partition.clone(),
            plac: res.pipeline.placement.clone(),
            knobs: res.knobs,
            sched: res.pipeline.schedule.clone(),
            prog,
            pred_total: res.report.total,
            pred_busy: res.report.busy_d.clone(),
            plan_rates,
            phys,
        }
    }

    fn incumbent(&self) -> Incumbent {
        Incumbent {
            partition: self.part.clone(),
            placement: self.plac.clone(),
            knobs: self.knobs,
        }
    }
}

/// Project the physical fault view into a plan's logical space.
fn remap_view(view: &FaultView, phys: &[usize]) -> FaultView {
    let p = phys.len();
    let pp = view.alive.len();
    let mut v = FaultView::healthy(p);
    v.step = view.step;
    for (i, &pi) in phys.iter().enumerate() {
        v.compute_scale[i] = view.compute_scale[pi];
        v.alive[i] = view.alive[pi];
        for (j, &pj) in phys.iter().enumerate() {
            v.link_scale[i * p + j] = view.link_scale[pi * pp + pj];
        }
    }
    v
}

/// Physical owner per layer.
fn phys_owner(plan: &ActivePlan, n_layers: usize) -> Vec<usize> {
    let mut out = vec![usize::MAX; n_layers];
    for s in 0..plan.part.n_stages() {
        let d = plan.phys[plan.plac.device_of[s]];
        for l in plan.part.stage_range(s) {
            out[l] = d;
        }
    }
    out
}

/// Switch pause for a spare swap: the logical plan is unchanged, only
/// the dead logical device's stages move — exactly the layers whose
/// physical owner changes, so this equals [`switch_seconds`] between
/// the old and patched phys maps.
fn spare_switch_seconds(
    profile: &ProfiledData,
    plan: &ActivePlan,
    dead_l: usize,
    cfg: &ElasticCfg,
) -> f64 {
    let mut bytes = 0.0;
    for s in 0..plan.part.n_stages() {
        if plan.plac.device_of[s] == dead_l {
            for l in plan.part.stage_range(s) {
                bytes += layer_migration_bytes(profile, l);
            }
        }
    }
    bytes / cfg.replan.migration.bw
}

/// Virtual seconds the pipeline pauses to ship weights + optimizer
/// state for every layer whose physical owner changes between plans.
fn switch_seconds(
    profile: &ProfiledData,
    from: &ActivePlan,
    to: &ActivePlan,
    cfg: MigrationCfg,
) -> f64 {
    let n = profile.n_layers();
    let (a, b) = (phys_owner(from, n), phys_owner(to, n));
    let mut bytes = 0.0;
    for l in 0..n {
        if a[l] != b[l] {
            bytes += layer_migration_bytes(profile, l);
        }
    }
    bytes / cfg.bw
}

/// A valid but deliberately terrible plan (nearly all layers on one
/// device) with honest predictions — the sabotage target for rollback
/// tests.  `Placement::is_valid` requires every device to own a stage,
/// so "terrible" is a maximally imbalanced partition, not an
/// all-on-one placement.
fn sabotage_plan(
    profile: &ProfiledData,
    p: usize,
    nmb: usize,
    rates: &[f64],
    phys: Vec<usize>,
) -> ActivePlan {
    let n = profile.n_layers();
    assert!(n >= p && p >= 2);
    let mut sizes = vec![1usize; p];
    sizes[0] = n - (p - 1);
    let part = Partition::from_sizes(&sizes);
    let plac = sequential(p);
    let knobs = SchedKnobs::default();
    let table = StageTable::build_rated(profile, &part, &plac, rates);
    let caps = MemCaps::unbounded(p);
    let mut arena = SimArena::new();
    let schedule = greedy_schedule_in(&mut arena, &table, &caps, nmb, knobs);
    let report =
        simulate_in(&mut arena, &table, &caps, &schedule, false).expect("sabotage plan simulates");
    let prog = lower(&schedule, &plac, LowerOptions::default());
    ActivePlan {
        part,
        plac,
        knobs,
        sched: schedule,
        prog,
        pred_total: report.total,
        pred_busy: report.busy_d,
        plan_rates: rates.to_vec(),
        phys,
    }
}

/// Run one (scenario, policy) pair.  See the module docs for the
/// accounting rules.
pub fn run_scenario(
    profile: &ProfiledData,
    scenario: &Scenario,
    nmb: usize,
    policy: Policy,
    cfg: &ElasticCfg,
) -> RunStats {
    let p0 = scenario.fault.p;
    scenario.fault.validate().expect("scenario fault plan must be well-formed");
    let sim = SimOptions::matched();
    let mut replanner = Replanner::new(cfg.replan);
    // Hot spares are held out of the logical plan; with `spares == 0`
    // (the default) this is exactly the historical behavior.
    let p_plan = p0 - cfg.recovery.spares.min(p0.saturating_sub(2));
    let unit = vec![1.0; p_plan];
    let res0 = replanner.plan(profile, p_plan, nmb, &unit);
    let mut plan = ActivePlan::from_gen(&res0, (0..p_plan).collect(), unit);
    let mut monitor = Monitor::new(p_plan, cfg.monitor);
    monitor.set_plan(plan.pred_total, plan.pred_busy.clone(), plan.plan_rates.clone());

    let mut stats = RunStats {
        policy: policy.name(),
        scenario: scenario.name,
        steps_done: 0,
        virtual_time_s: 0.0,
        step_times: Vec::with_capacity(scenario.steps),
        replans: Vec::new(),
        rollbacks: 0,
        steps_to_recover: None,
        stalled_at: None,
        recoveries: Vec::new(),
        lost_work_s: 0.0,
        checkpoint_overhead_s: 0.0,
    };
    let mut rollback_to: Option<ActivePlan> = None;
    let mut sabotaged = false;
    let mut gap_onset: Option<usize> = None;
    let mut switched_since_gap = false;

    for step in 0..scenario.steps {
        let pview = scenario.fault.view(step);

        // ---- Device loss ------------------------------------------------
        let dead_l: Vec<usize> =
            (0..plan.phys.len()).filter(|&l| !pview.alive[plan.phys[l]]).collect();
        let mut step_executed = false;
        if !dead_l.is_empty() {
            if policy == Policy::Static {
                stats.stalled_at = Some(step);
                break;
            }
            // The kill lands *inside* the step at a deterministic
            // fraction of its predicted makespan.  Replay the pre-kill
            // timeline with the mid-step runner so the lost work and
            // the timeout/retry detection latency are charged from the
            // actual virtual time of the abort — never rounded to a
            // step boundary.
            let dl = dead_l[0];
            let kill_at = scenario.fault.kill_frac(plan.phys[dl]) * plan.pred_total;
            let mut lview_pre = remap_view(&pview, &plan.phys);
            for &l in &dead_l {
                lview_pre.alive[l] = true; // pre-kill world: still up
            }
            let sf = StepFaults { kill: Some((dl, kill_at)), links: Vec::new() };
            let out = run_timed_midstep(
                profile,
                &plan.part,
                &plan.prog,
                sim,
                Some(&lview_pre),
                &sf,
                &cfg.recovery.retry,
            )
            .expect("pre-kill replay on an all-alive view cannot deadlock");
            let spare =
                (0..p0).find(|&d| pview.alive[d] && !plan.phys.contains(&d));

            match out {
                MidstepOutcome::Completed { run, .. } => {
                    // The victim died after its last instruction: the
                    // step lands, but the optimizer update it joined
                    // must be rolled back and re-applied by whoever
                    // inherits its stages.
                    let mm = MemoryModel::build(profile, &plan.part, &plan.plac);
                    let opt_s =
                        recover::optimizer_rollback_s(&mm, dl, &cfg.recovery.checkpoint);
                    stats.virtual_time_s += run.makespan;
                    stats.step_times.push(run.makespan);
                    stats.steps_done += 1;
                    if policy == Policy::Elastic {
                        stats.virtual_time_s += opt_s;
                        stats.lost_work_s += opt_s;
                    }
                    step_executed = true;
                    if cfg.recovery.enabled && dead_l.len() == 1 {
                        if let Some(sp) = spare {
                            let switch_s = spare_switch_seconds(profile, &plan, dl, cfg);
                            if policy == Policy::Elastic {
                                stats.virtual_time_s += switch_s;
                            }
                            stats.recoveries.push(RecoveryEvent {
                                step,
                                kill_at_s: kill_at,
                                detect_s: 0.0,
                                lost_s: opt_s,
                                ckpt_overhead_s: 0.0,
                                switch_s,
                                restore_s: 0.0,
                                replay_s: 0.0,
                                restart_s: 0.0,
                                replayed_ops: 0,
                                resends: 0,
                                restored_bytes: 0.0,
                                opt_rollback_s: opt_s,
                            });
                            plan.phys[dl] = sp;
                        }
                    }
                }
                MidstepOutcome::Interrupted(si) => {
                    // Lost work: everything to the abort.  The oracle
                    // knows instantly; real policies pay detection.
                    let lost =
                        if policy == Policy::Oracle { si.kill_at } else { si.abort_at };
                    stats.virtual_time_s += lost;
                    stats.lost_work_s += lost;
                    let mut step_total = lost;
                    if cfg.recovery.enabled && dead_l.len() == 1 {
                        if let Some(sp) = spare {
                            // Capture pauses the pre-kill prefix paid.
                            let mm =
                                MemoryModel::build(profile, &plan.part, &plan.plac);
                            let cks = recover::plan_checkpoints(
                                &si.records,
                                si.kill_at,
                                &mm,
                                nmb,
                                plan.prog.split_bw,
                                &cfg.recovery.checkpoint,
                            );
                            let pauses: f64 = cks.iter().map(|c| c.pause_s).sum();
                            stats.virtual_time_s += pauses;
                            stats.checkpoint_overhead_s += pauses;
                            step_total += pauses;
                            // Committed frontier per logical device.
                            let mut done: Vec<HashSet<OpKey>> =
                                vec![HashSet::new(); plan.phys.len()];
                            for r in &si.records {
                                done[r.device].insert((r.op, r.stage, r.mb));
                            }
                            let rec = recover::plan_recovery(
                                &plan.sched,
                                &plan.plac,
                                dl,
                                &done,
                                cks.last(),
                            )
                            .expect("spliced recovery program must be sound");
                            debug_assert_eq!(
                                rec.final_ops,
                                recover::schedule_ops(&plan.sched),
                                "recovery must complete exactly the step's op set"
                            );
                            let switch_s = spare_switch_seconds(profile, &plan, dl, cfg);
                            let restore_s = if rec.restore_bytes > 0.0 {
                                cfg.recovery.checkpoint.latency_s
                                    + rec.restore_bytes / cfg.recovery.checkpoint.restore_bw
                            } else {
                                0.0
                            };
                            if policy == Policy::Elastic {
                                stats.virtual_time_s += switch_s + restore_s;
                                step_total += switch_s + restore_s;
                            }
                            plan.phys[dl] = sp;
                            let lview_post = remap_view(&pview, &plan.phys);
                            let replay_s = run_timed_faulted(
                                profile,
                                &plan.part,
                                &rec.prog,
                                sim,
                                Some(&lview_post),
                            )
                            .expect("validated recovery program may not stall")
                            .makespan;
                            stats.virtual_time_s += replay_s;
                            step_total += replay_s;
                            // Counterfactual the baseline would pay.
                            let restart_s = run_timed_faulted(
                                profile,
                                &plan.part,
                                &plan.prog,
                                sim,
                                Some(&lview_post),
                            )
                            .expect("full restart on live devices may not stall")
                            .makespan;
                            stats.recoveries.push(RecoveryEvent {
                                step,
                                kill_at_s: si.kill_at,
                                detect_s: if policy == Policy::Oracle {
                                    0.0
                                } else {
                                    si.detect_s
                                },
                                lost_s: lost,
                                ckpt_overhead_s: pauses,
                                switch_s: if policy == Policy::Elastic {
                                    switch_s
                                } else {
                                    0.0
                                },
                                restore_s: if policy == Policy::Elastic {
                                    restore_s
                                } else {
                                    0.0
                                },
                                replay_s,
                                restart_s,
                                replayed_ops: rec.replay.len(),
                                resends: rec.resends,
                                restored_bytes: rec.restore_bytes,
                                opt_rollback_s: 0.0,
                            });
                            stats.step_times.push(step_total);
                            stats.steps_done += 1;
                            step_executed = true;
                        }
                    }
                }
            }

            // Shrink-and-restart fallback: no recovery (or no spare) —
            // re-plan on the survivors; the step (if not already
            // landed) re-runs from scratch on the new plan below.
            if plan.phys.iter().any(|&d| !pview.alive[d]) {
                let alive: Vec<usize> = (0..p0).filter(|&d| pview.alive[d]).collect();
                let p_new = alive.len();
                assert!(p_new >= 2, "scenario killed the cluster below a pipeline");
                // Carry estimates across the remap where the physical
                // device survives; the oracle reads the true scales.
                let mut est = vec![1.0; p_new];
                for (j, &pd) in alive.iter().enumerate() {
                    est[j] = if policy == Policy::Oracle {
                        pview.compute_scale[pd]
                    } else if let Some(l) = plan.phys.iter().position(|&q| q == pd) {
                        monitor.rates().get(l).copied().unwrap_or(1.0)
                    } else {
                        1.0
                    };
                }
                let t = Instant::now();
                let res = replanner.plan(profile, p_new, nmb, &est);
                let latency = t.elapsed().as_secs_f64();
                let rates_q = replanner.quantize(&est).unwrap_or_else(|| vec![1.0; p_new]);
                let new_plan = ActivePlan::from_gen(&res, alive, rates_q);
                let switch_s = switch_seconds(profile, &plan, &new_plan, cfg.replan.migration);
                if policy == Policy::Elastic {
                    stats.virtual_time_s += switch_s;
                }
                stats.replans.push(ReplanEvent {
                    step,
                    latency_s: if policy == Policy::Oracle { 0.0 } else { latency },
                    switch_s,
                    kind: "kill",
                });
                plan = new_plan;
                rollback_to = None;
                monitor = Monitor::new(p_new, cfg.monitor);
                monitor.set_plan(plan.pred_total, plan.pred_busy.clone(), plan.plan_rates.clone());
                gap_onset.get_or_insert(step);
                switched_since_gap = true;
            }
            if step_executed {
                // The step landed inside the recovery path; skip the
                // normal execution and the monitor for this step.
                continue;
            }
        }

        // ---- Oracle: re-plan the moment true rates move -----------------
        if policy == Policy::Oracle {
            let true_rates: Vec<f64> =
                plan.phys.iter().map(|&pd| pview.compute_scale[pd]).collect();
            let q = replanner
                .quantize(&true_rates)
                .unwrap_or_else(|| vec![1.0; plan.phys.len()]);
            if q.iter()
                .zip(&plan.plan_rates)
                .any(|(a, b)| (a - b).abs() > 0.03)
            {
                let res = replanner.plan(profile, plan.phys.len(), nmb, &true_rates);
                plan = ActivePlan::from_gen(&res, plan.phys.clone(), q);
                stats.replans.push(ReplanEvent {
                    step,
                    latency_s: 0.0,
                    switch_s: 0.0,
                    kind: "oracle",
                });
            }
        }

        // ---- Execute the step -------------------------------------------
        let lview = remap_view(&pview, &plan.phys);
        let run = if cfg.recovery.enabled {
            // Same arithmetic via the mid-step runner (bitwise-equal
            // makespans, pinned in `cluster::sim` tests) — it also
            // yields the op records that price checkpoint captures.
            let out = run_timed_midstep(
                profile,
                &plan.part,
                &plan.prog,
                sim,
                Some(&lview),
                &StepFaults::none(),
                &cfg.recovery.retry,
            )
            .expect("no live plan may stall (kills are handled above)");
            let MidstepOutcome::Completed { run, records } = out else {
                unreachable!("no step faults and an all-alive view cannot interrupt")
            };
            if cfg.recovery.checkpoint.interval_s.is_some() {
                let mm = MemoryModel::build(profile, &plan.part, &plan.plac);
                let cks = recover::plan_checkpoints(
                    &records,
                    run.makespan,
                    &mm,
                    nmb,
                    plan.prog.split_bw,
                    &cfg.recovery.checkpoint,
                );
                let pauses: f64 = cks.iter().map(|c| c.pause_s).sum();
                stats.virtual_time_s += pauses;
                stats.checkpoint_overhead_s += pauses;
            }
            run
        } else {
            run_timed_faulted(profile, &plan.part, &plan.prog, sim, Some(&lview))
                .expect("no live plan may stall (kills are handled above)")
        };
        let dt = run.makespan;
        stats.virtual_time_s += dt;
        stats.step_times.push(dt);
        stats.steps_done += 1;

        if policy != Policy::Elastic {
            continue;
        }

        // ---- Elastic: monitor + decisions -------------------------------
        let gap = (dt - plan.pred_total) / plan.pred_total;
        if gap > cfg.monitor.gap_threshold {
            if gap_onset.is_none() {
                gap_onset = Some(step);
                switched_since_gap = false;
            }
        } else if let Some(onset) = gap_onset {
            if switched_since_gap && stats.steps_to_recover.is_none() {
                stats.steps_to_recover = Some(step - onset);
            }
        }
        match monitor.observe(dt, Some(&run.busy_d)) {
            Decision::Steady => {}
            Decision::Commit => {
                rollback_to = None;
            }
            Decision::Rollback => {
                if let Some(old) = rollback_to.take() {
                    let switch_s = switch_seconds(profile, &plan, &old, cfg.replan.migration);
                    stats.virtual_time_s += switch_s;
                    stats.replans.push(ReplanEvent {
                        step,
                        latency_s: 0.0,
                        switch_s,
                        kind: "rollback",
                    });
                    replanner.set_incumbent(old.incumbent());
                    plan = old;
                    monitor.set_plan(
                        plan.pred_total,
                        plan.pred_busy.clone(),
                        plan.plan_rates.clone(),
                    );
                    stats.rollbacks += 1;
                }
            }
            Decision::Replan { .. } => {
                let est = monitor.rates().to_vec();
                let t = Instant::now();
                let res = replanner.plan(profile, plan.phys.len(), nmb, &est);
                let latency = t.elapsed().as_secs_f64();
                let rates_q =
                    replanner.quantize(&est).unwrap_or_else(|| vec![1.0; plan.phys.len()]);
                let mut new_plan = ActivePlan::from_gen(&res, plan.phys.clone(), rates_q);
                if cfg.sabotage_first_replan && !sabotaged {
                    sabotaged = true;
                    new_plan = sabotage_plan(
                        profile,
                        plan.phys.len(),
                        nmb,
                        &new_plan.plan_rates.clone(),
                        plan.phys.clone(),
                    );
                    replanner.set_incumbent(new_plan.incumbent());
                }
                let unchanged = new_plan.part == plan.part
                    && new_plan.plac == plan.plac
                    && new_plan.knobs == plan.knobs;
                if unchanged {
                    // Nothing better exists under the current
                    // estimates; cool down instead of thrashing.
                    monitor.dismissed();
                } else {
                    let switch_s = switch_seconds(profile, &plan, &new_plan, cfg.replan.migration);
                    stats.virtual_time_s += switch_s;
                    stats.replans.push(ReplanEvent { step, latency_s: latency, switch_s, kind: "drift" });
                    rollback_to = Some(std::mem::replace(&mut plan, new_plan));
                    monitor.switched(
                        plan.pred_total,
                        plan.pred_busy.clone(),
                        plan.plan_rates.clone(),
                    );
                    switched_since_gap = true;
                }
            }
        }
    }
    stats
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Family, HardwareCfg, ModelCfg, ParallelCfg, Size};
    use crate::model::build_model;

    fn prof(p: usize, nmb: usize) -> ProfiledData {
        let spec = build_model(&ModelCfg::table5(Family::Gemma, Size::Small));
        ProfiledData::analytical(
            &spec,
            &HardwareCfg::default(),
            &ParallelCfg::new(p, 2, nmb, 1, 4096),
        )
    }

    #[test]
    fn healthy_scenario_is_identical_across_policies() {
        let pr = prof(4, 8);
        let sc = Scenario { name: "healthy", fault: FaultPlan::healthy(4), steps: 12 };
        let cfg = ElasticCfg::default();
        let st = run_scenario(&pr, &sc, 8, Policy::Static, &cfg);
        let el = run_scenario(&pr, &sc, 8, Policy::Elastic, &cfg);
        let or = run_scenario(&pr, &sc, 8, Policy::Oracle, &cfg);
        // No faults: nobody re-plans, all three run the same plan and
        // the virtual clocks agree bitwise.
        assert!(el.replans.is_empty() && or.replans.is_empty());
        assert_eq!(st.virtual_time_s, el.virtual_time_s);
        assert_eq!(st.virtual_time_s, or.virtual_time_s);
        assert_eq!(throughput_retained(&el, &or), 1.0);
        // Matched-mode predictions are exact: zero healthy-state gap.
        assert_eq!(el.step_times[0], el.step_times[11]);
    }

    #[test]
    fn recovery_enabled_healthy_run_is_bitwise_identical() {
        // No faults + no cadence: routing execution through the
        // mid-step runner must not move a single bit.
        let pr = prof(4, 8);
        let sc = Scenario { name: "healthy", fault: FaultPlan::healthy(4), steps: 10 };
        let base = run_scenario(&pr, &sc, 8, Policy::Elastic, &ElasticCfg::default());
        let mut cfg = ElasticCfg::default();
        cfg.recovery.enabled = true; // spares: 0, cadence off
        let rec = run_scenario(&pr, &sc, 8, Policy::Elastic, &cfg);
        assert_eq!(base.virtual_time_s, rec.virtual_time_s);
        assert_eq!(base.step_times, rec.step_times);
        assert_eq!(rec.checkpoint_overhead_s, 0.0);
        assert!(rec.recoveries.is_empty() && rec.lost_work_s == 0.0);
    }

    #[test]
    fn checkpoint_cadence_charges_overhead_without_touching_makespans() {
        let pr = prof(4, 8);
        let sc = Scenario { name: "healthy", fault: FaultPlan::healthy(4), steps: 6 };
        let base = run_scenario(&pr, &sc, 8, Policy::Elastic, &ElasticCfg::default());
        let mut cfg = ElasticCfg::default();
        cfg.recovery.enabled = true;
        cfg.recovery.checkpoint.interval_s = Some(base.step_times[0] / 3.0);
        let rec = run_scenario(&pr, &sc, 8, Policy::Elastic, &cfg);
        // Captures pause the pipeline but never perturb step makespans.
        assert_eq!(base.step_times, rec.step_times);
        assert!(rec.checkpoint_overhead_s > 0.0);
        let expect = base.virtual_time_s + rec.checkpoint_overhead_s;
        assert!((rec.virtual_time_s - expect).abs() <= 1e-9 * expect);
    }

    #[test]
    fn midstep_kill_recovers_onto_spare_and_beats_full_restart() {
        // 5 physical devices, 1 held as a hot spare: a mid-step kill
        // splices a recovery program instead of shrinking the plan.
        let pr = prof(5, 8);
        let sc = Scenario::kill(5, 1, 4, 16);
        let mut cfg = ElasticCfg::default();
        cfg.recovery.enabled = true;
        cfg.recovery.spares = 1;
        let el = run_scenario(&pr, &sc, 8, Policy::Elastic, &cfg);
        assert_eq!(el.steps_done, 16, "recovery completes every step");
        assert_eq!(el.stalled_at, None);
        assert_eq!(el.recoveries.len(), 1);
        let ev = &el.recoveries[0];
        assert_eq!(ev.step, 4);
        assert!(ev.kill_at_s > 0.0 && ev.detect_s > 0.0 && ev.lost_s >= ev.kill_at_s);
        assert!(ev.replay_s > 0.0 && ev.replay_s <= ev.restart_s);
        assert!(el.lost_work_s > 0.0);
        // The spare absorbed the loss: no shrink re-plan happened.
        assert!(el.replans.iter().all(|r| r.kind != "kill"), "{:?}", el.replans);
        // Deterministic: the whole trajectory replays bitwise.
        let el2 = run_scenario(&pr, &sc, 8, Policy::Elastic, &cfg);
        assert_eq!(el.virtual_time_s, el2.virtual_time_s);
        assert_eq!(el.lost_work_s, el2.lost_work_s);
        assert_eq!(el.recoveries[0].replay_s, el2.recoveries[0].replay_s);
    }

    #[test]
    fn remapped_views_index_physical_space() {
        let v = FaultPlan::healthy(4)
            .with_event(crate::cluster::fault::FaultEvent::Straggler {
                device: 2,
                factor: 2.0,
                from: 0,
                until: usize::MAX,
            })
            .view(0);
        let r = remap_view(&v, &[0, 2, 3]);
        assert_eq!(r.compute_scale, vec![1.0, 2.0, 1.0]);
        assert_eq!(r.alive, vec![true, true, true]);
    }
}
