//! The re-planner: warm-started incremental re-generation.
//!
//! A thin stateful wrapper over
//! [`crate::generator::generate_with_cache`] that owns the two pieces
//! of cross-re-plan state the generator deliberately leaves to its
//! caller:
//!
//! - the persistent [`EvalCache`] — scores survive between re-plans
//!   with the same evaluation context, so a re-plan that re-visits the
//!   neighbourhood of the incumbent answers from the table;
//! - the incumbent plan — each successful `plan()` becomes the warm
//!   seed (and the migration-cost reference) of the next one;
//! - a persistent shared [`EvalPool`] — workers spawn once per
//!   `Replanner` and park between re-plans, so a re-plan no longer
//!   pays thread-spawn latency on its critical path (scores are
//!   bit-identical either way; see `generator/pool.rs`).
//!
//! **Rate quantization.**  Monitor estimates move a little every step
//! (medians of finite windows).  Feeding them to the generator raw
//! would change the cache fingerprint on every re-plan, clearing the
//! table exactly when it is most useful.  [`Replanner::quantize`]
//! snaps estimates to a `1/64` grid (exact binary fractions — `1.0`
//! stays bitwise `1.0`) with a floor at [`ReplanCfg::rate_floor`], and
//! collapses an all-healthy vector to `None` so the unit-rate search
//! stays on the generator's bit-pinned default path.
//!
//! When the device count changes (a kill dropped a device), the
//! incumbent is structurally meaningless — it is discarded and the
//! re-plan runs cold (the fingerprint change clears the cache anyway).

use std::sync::Arc;

use crate::generator::cache::{CacheStats, EvalCache};
use crate::generator::pool::EvalPool;
use crate::generator::{
    generate_with_cache, CancelToken, GenOptions, GenResult, Incumbent, MigrationCfg,
};
use crate::profile::ProfiledData;

/// Re-planner configuration.
#[derive(Clone, Copy, Debug)]
pub struct ReplanCfg {
    /// Migration pricing for the warm-started objective (and the
    /// harness's switch-pause accounting).
    pub migration: MigrationCfg,
    /// Optional wall-clock budget per re-plan (passed through to
    /// [`GenOptions::time_budget_s`]).
    pub time_budget_s: Option<f64>,
    /// Rate quantization grid (an exact binary fraction keeps
    /// quantized healthy rates bitwise `1.0`).
    pub quantum: f64,
    /// Lower clamp on quantized rates (an estimate below this is
    /// noise — no device credibly runs 4× faster than profiled).
    pub rate_floor: f64,
}

impl Default for ReplanCfg {
    fn default() -> ReplanCfg {
        ReplanCfg {
            migration: MigrationCfg::default(),
            time_budget_s: None,
            quantum: 1.0 / 64.0,
            rate_floor: 0.25,
        }
    }
}

/// See the module docs.
pub struct Replanner {
    cfg: ReplanCfg,
    cache: EvalCache,
    /// Long-lived evaluation workers shared by every re-plan.
    pool: Arc<EvalPool>,
    last: Option<Incumbent>,
    /// Total `plan()` calls served.
    pub replans: usize,
}

impl Replanner {
    pub fn new(cfg: ReplanCfg) -> Replanner {
        assert!(cfg.quantum > 0.0 && cfg.rate_floor > 0.0);
        let threads =
            std::thread::available_parallelism().map(|v| v.get()).unwrap_or(1);
        Replanner {
            cfg,
            cache: EvalCache::new(),
            pool: Arc::new(EvalPool::new(threads)),
            last: None,
            replans: 0,
        }
    }

    /// Snap rate estimates to the quantization grid; `None` when the
    /// result is all-healthy (the generator's unit-rate path).
    pub fn quantize(&self, rates: &[f64]) -> Option<Vec<f64>> {
        let q: Vec<f64> = rates
            .iter()
            .map(|&r| ((r / self.cfg.quantum).round() * self.cfg.quantum).max(self.cfg.rate_floor))
            .collect();
        if q.iter().all(|&r| r == 1.0) {
            None
        } else {
            Some(q)
        }
    }

    /// Re-generate for `p` devices under the given rate estimates,
    /// warm-started from the previous plan when the device space still
    /// matches.  The result becomes the next call's incumbent.
    pub fn plan(
        &mut self,
        profile: &ProfiledData,
        p: usize,
        nmb: usize,
        rates: &[f64],
    ) -> GenResult {
        self.plan_inner(profile, p, nmb, rates, None)
    }

    /// [`Replanner::plan`] under a cooperative deadline: the token is
    /// checked at the generator's budget boundaries, so a re-plan
    /// racing a recovery deadline returns its best-so-far plan (prefix
    /// bitwise-identical to the unbounded run) instead of overrunning
    /// the stall it is trying to fix.  A cut re-plan still updates the
    /// incumbent — it is the plan the harness will switch to.
    pub fn plan_with_cancel(
        &mut self,
        profile: &ProfiledData,
        p: usize,
        nmb: usize,
        rates: &[f64],
        cancel: &CancelToken,
    ) -> GenResult {
        self.plan_inner(profile, p, nmb, rates, Some(cancel.clone()))
    }

    fn plan_inner(
        &mut self,
        profile: &ProfiledData,
        p: usize,
        nmb: usize,
        rates: &[f64],
        cancel: Option<CancelToken>,
    ) -> GenResult {
        assert_eq!(rates.len(), p, "one rate estimate per (logical) device");
        if self.last.as_ref().is_some_and(|inc| inc.placement.p != p) {
            self.last = None;
        }
        let mut opts = GenOptions::new(p, nmb);
        opts.rates = self.quantize(rates);
        opts.time_budget_s = self.cfg.time_budget_s;
        opts.cancel = cancel;
        opts.shared_pool = Some(Arc::clone(&self.pool));
        if let Some(inc) = &self.last {
            opts.incumbent = Some(inc.clone());
            opts.migration = Some(self.cfg.migration);
        }
        let res = generate_with_cache(profile, &opts, &mut self.cache);
        self.last = Some(res.incumbent());
        self.replans += 1;
        res
    }

    /// Override the incumbent — the harness calls this after a
    /// rollback so the next re-plan warm-starts from the plan that is
    /// actually running, not the one that was abandoned.
    pub fn set_incumbent(&mut self, inc: Incumbent) {
        self.last = Some(inc);
    }

    pub fn incumbent(&self) -> Option<&Incumbent> {
        self.last.as_ref()
    }

    /// Lifetime traffic of the persistent cache.
    pub fn cache_stats(&self) -> CacheStats {
        self.cache.stats()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Family, HardwareCfg, ModelCfg, ParallelCfg, Size};
    use crate::model::build_model;

    fn prof() -> ProfiledData {
        let spec = build_model(&ModelCfg::table5(Family::Gemma, Size::Small));
        ProfiledData::analytical(
            &spec,
            &HardwareCfg::default(),
            &ParallelCfg::new(4, 2, 8, 1, 4096),
        )
    }

    #[test]
    fn quantization_snaps_floors_and_normalizes() {
        let r = Replanner::new(ReplanCfg::default());
        // Exactly representable grid points survive bitwise.
        assert_eq!(r.quantize(&[1.0, 2.5, 1.0]), Some(vec![1.0, 2.5, 1.0]));
        // Near-1 noise snaps back to the unit path.
        assert_eq!(r.quantize(&[1.0000001, 0.9999999]), None);
        // Off-grid estimates snap to the nearest 1/64.
        let q = r.quantize(&[1.51, 1.0]).unwrap();
        assert!((q[0] - 96.0 / 64.0).abs() < 1e-12 || (q[0] - 97.0 / 64.0).abs() < 1e-12);
        // Implausibly fast estimates clamp at the floor.
        assert_eq!(r.quantize(&[0.01, 1.0]), Some(vec![0.25, 1.0]));
    }

    #[test]
    fn replans_warm_start_and_survive_device_loss() {
        let p = prof();
        let mut r = Replanner::new(ReplanCfg::default());
        let cold = r.plan(&p, 4, 8, &[1.0; 4]);
        assert!(r.incumbent().is_some());
        // Same context: the second plan answers from the cache and the
        // warm seed — a small fraction of the cold search.
        let warm = r.plan(&p, 4, 8, &[1.0; 4]);
        assert!(warm.cache.hits > 0);
        assert!(warm.evals * 4 <= cold.evals, "warm {} vs cold {}", warm.evals, cold.evals);
        assert_eq!(warm.report.total, cold.report.total, "re-plan of an unchanged world");
        // Device count change: incumbent dropped, plan still produced.
        let shrunk = r.plan(&p, 3, 8, &[1.0; 3]);
        assert_eq!(shrunk.pipeline.placement.p, 3);
        assert_eq!(r.incumbent().unwrap().placement.p, 3);
        assert_eq!(r.replans, 3);
    }

    #[test]
    fn deadline_cut_replan_still_yields_a_plan() {
        let p = prof();
        let mut r = Replanner::new(ReplanCfg::default());
        // Pre-fired token: the tuning loop exits at its first check,
        // but the seed grid already produced a valid incumbent plan.
        let token = CancelToken::new();
        token.cancel();
        let res = r.plan_with_cancel(&p, 4, 8, &[1.0; 4], &token);
        assert!(res.cancelled);
        assert_eq!(res.iters, 0, "cut before the first tuning iteration");
        assert!(res.pipeline.partition.is_valid());
        assert!(r.incumbent().is_some(), "cut plan still seeds the next re-plan");
        // An inert token changes nothing bitwise.
        let mut fresh = Replanner::new(ReplanCfg::default());
        let plain = fresh.plan(&p, 4, 8, &[1.0; 4]);
        let mut fresh2 = Replanner::new(ReplanCfg::default());
        let inert =
            fresh2.plan_with_cancel(&p, 4, 8, &[1.0; 4], &CancelToken::new());
        assert!(!inert.cancelled);
        assert_eq!(inert.report.total.to_bits(), plain.report.total.to_bits());
        assert_eq!(inert.evals, plain.evals);
    }
}
