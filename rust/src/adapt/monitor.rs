//! Runtime monitor: executed-step timings in, re-plan decisions out.
//!
//! The monitor holds the active plan's *predictions* — step makespan
//! and per-device busy time, both priced under the rates the plan was
//! generated for — and compares them against what the cluster actually
//! delivers.  Three mechanisms keep it from thrashing:
//!
//! - **Hysteresis**: the relative gap `(obs − pred)/pred` must exceed
//!   [`MonitorCfg::gap_threshold`] for [`MonitorCfg::hysteresis`]
//!   *consecutive* steps before [`Decision::Replan`] fires — one slow
//!   step (GC pause, jitter spike) is not a regime change.
//! - **Cooldown**: after any switch, rollback or dismissed advice, no
//!   new re-plan fires for [`MonitorCfg::cooldown_steps`] steps.
//! - **Probation**: a switch is provisional.  For
//!   [`MonitorCfg::probation_steps`] steps the new plan's mean step
//!   time must beat the old plan's recent mean by
//!   [`MonitorCfg::min_improve`], else [`Decision::Rollback`] tells
//!   the driver to restore the incumbent.
//!
//! **Rate estimation.**  Per-device estimates are *absolute*: each
//! step contributes `obs_busy_d / pred_busy_d × plan_rate_d` — the
//! device's current slowdown relative to the healthy profile,
//! independent of which plan is running — so the sample windows
//! survive plan switches.  The estimate is the *median* of the last
//! `2·hysteresis − 1` samples: jitter outliers are rejected, while a
//! persistent shift flips the median after exactly `hysteresis`
//! consistent samples — the same step the gap hysteresis fires, so the
//! re-plan prices the shift it just confirmed.

use std::collections::VecDeque;

/// Monitor tuning knobs (defaults follow the module docs).
#[derive(Clone, Copy, Debug)]
pub struct MonitorCfg {
    /// Rolling window of observed step totals (drives `must_beat`).
    pub window: usize,
    /// Relative gap above which a step counts toward re-planning.
    pub gap_threshold: f64,
    /// Consecutive over-gap steps required before `Replan` fires.
    pub hysteresis: usize,
    /// Steps with no new re-plan advice after a switch/rollback/dismiss.
    pub cooldown_steps: usize,
    /// Steps a switched-to plan has to prove itself.
    pub probation_steps: usize,
    /// Relative improvement over the old plan's recent mean a switch
    /// must deliver to be kept.
    pub min_improve: f64,
}

impl Default for MonitorCfg {
    fn default() -> MonitorCfg {
        MonitorCfg {
            window: 8,
            gap_threshold: 0.10,
            hysteresis: 3,
            cooldown_steps: 24,
            probation_steps: 6,
            min_improve: 0.02,
        }
    }
}

/// What the driver should do after this step.
#[derive(Clone, Debug, PartialEq)]
pub enum Decision {
    /// Keep running the current plan.
    Steady,
    /// The gap persisted: re-generate now.  `must_beat` is the old
    /// plan's recent mean step time — the bar a switched-to plan must
    /// clear during probation.  The monitor waits in place until the
    /// driver answers with [`Monitor::switched`] or
    /// [`Monitor::dismissed`].
    Replan { must_beat: f64 },
    /// Probation passed: the switch is confirmed; the driver can drop
    /// its rollback copy of the old plan.
    Commit,
    /// Probation failed: restore the incumbent plan (then call
    /// [`Monitor::set_plan`] with its predictions).
    Rollback,
}

#[derive(Clone, Debug)]
enum State {
    Stable { over: usize },
    /// `Replan` fired; awaiting `switched`/`dismissed` from the driver.
    Await { must_beat: f64 },
    Probation { left: usize, must_beat: f64, acc: f64, n: usize },
    Cooldown { left: usize },
}

/// See the module docs.  One monitor per running pipeline; feed every
/// executed step to [`Monitor::observe`].
pub struct Monitor {
    cfg: MonitorCfg,
    state: State,
    /// Predicted step makespan of the active plan (under `plan_rates`).
    pred_total: f64,
    /// Predicted per-device busy time of the active plan.
    pred_busy: Vec<f64>,
    /// Rates the active plan's predictions were priced under.
    plan_rates: Vec<f64>,
    /// Current absolute per-device rate estimates (median-filtered).
    rate_est: Vec<f64>,
    /// Per-device absolute-rate sample windows (len `2·hysteresis−1`).
    samples: Vec<VecDeque<f64>>,
    /// Recent observed step totals (len `window`).
    recent: VecDeque<f64>,
    last_gap: f64,
    scratch: Vec<f64>,
}

/// Median under `total_cmp` (deterministic, NaN-tolerant); `buf` is a
/// reusable sort buffer.
fn median(w: &VecDeque<f64>, buf: &mut Vec<f64>) -> f64 {
    buf.clear();
    buf.extend(w.iter().copied());
    buf.sort_by(|a, b| a.total_cmp(b));
    let n = buf.len();
    if n % 2 == 1 {
        buf[n / 2]
    } else {
        0.5 * (buf[n / 2 - 1] + buf[n / 2])
    }
}

impl Monitor {
    pub fn new(p: usize, cfg: MonitorCfg) -> Monitor {
        assert!(cfg.window >= 1 && cfg.hysteresis >= 1 && cfg.probation_steps >= 1);
        Monitor {
            cfg,
            state: State::Stable { over: 0 },
            pred_total: 1.0,
            pred_busy: vec![0.0; p],
            plan_rates: vec![1.0; p],
            rate_est: vec![1.0; p],
            samples: vec![VecDeque::new(); p],
            recent: VecDeque::new(),
            last_gap: 0.0,
            scratch: Vec::new(),
        }
    }

    fn install(&mut self, pred_total: f64, pred_busy: Vec<f64>, plan_rates: Vec<f64>) {
        assert!(pred_total > 0.0, "predictions must be positive");
        assert_eq!(pred_busy.len(), plan_rates.len());
        if pred_busy.len() != self.pred_busy.len() {
            // Device count changed (kill + remap): the old windows are
            // in a different index space — start estimation over.
            self.samples = vec![VecDeque::new(); pred_busy.len()];
            self.rate_est = plan_rates.clone();
        }
        self.pred_total = pred_total;
        self.pred_busy = pred_busy;
        self.plan_rates = plan_rates;
    }

    /// Install a plan's predictions without touching the decision
    /// state: the initial plan, or the incumbent after a rollback (the
    /// `Rollback` decision already put the monitor in cooldown).
    pub fn set_plan(&mut self, pred_total: f64, pred_busy: Vec<f64>, plan_rates: Vec<f64>) {
        self.install(pred_total, pred_busy, plan_rates);
    }

    /// The driver took the `Replan` advice and switched: install the
    /// new plan's predictions and start probation against the
    /// `must_beat` captured when the advice fired.
    pub fn switched(&mut self, pred_total: f64, pred_busy: Vec<f64>, plan_rates: Vec<f64>) {
        let must_beat = match self.state {
            State::Await { must_beat } => must_beat,
            // Forced switch (e.g. device kill): nothing meaningful to
            // probe against — install and cool down instead.
            _ => {
                self.install(pred_total, pred_busy, plan_rates);
                self.state = State::Cooldown { left: self.cfg.cooldown_steps.max(1) };
                return;
            }
        };
        self.install(pred_total, pred_busy, plan_rates);
        self.state = State::Probation {
            left: self.cfg.probation_steps,
            must_beat,
            acc: 0.0,
            n: 0,
        };
    }

    /// The driver declined the `Replan` advice (the search returned
    /// the same plan): cool down so the advice doesn't re-fire every
    /// step while the condition persists.
    pub fn dismissed(&mut self) {
        self.state = State::Cooldown { left: self.cfg.cooldown_steps.max(1) };
    }

    /// Current absolute per-device rate estimates (what the re-planner
    /// should price the search under).
    pub fn rates(&self) -> &[f64] {
        &self.rate_est
    }

    /// Relative gap of the most recent observed step.
    pub fn gap(&self) -> f64 {
        self.last_gap
    }

    /// Feed one executed step: total step seconds and, when available,
    /// per-device busy seconds (from a `SimRun` trace or device-side
    /// timers).  Returns the decision for this step.
    pub fn observe(&mut self, obs_total: f64, obs_busy: Option<&[f64]>) -> Decision {
        if let Some(busy) = obs_busy {
            debug_assert_eq!(busy.len(), self.pred_busy.len());
            let win = 2 * self.cfg.hysteresis - 1;
            for d in 0..self.pred_busy.len().min(busy.len()) {
                if self.pred_busy[d] > 0.0 {
                    let sample = busy[d] / self.pred_busy[d] * self.plan_rates[d];
                    let w = &mut self.samples[d];
                    while w.len() >= win {
                        w.pop_front();
                    }
                    w.push_back(sample);
                    self.rate_est[d] = median(&self.samples[d], &mut self.scratch);
                }
            }
        }
        while self.recent.len() >= self.cfg.window {
            self.recent.pop_front();
        }
        self.recent.push_back(obs_total);
        self.last_gap = (obs_total - self.pred_total) / self.pred_total;

        match &mut self.state {
            State::Cooldown { left } => {
                *left -= 1;
                if *left == 0 {
                    self.state = State::Stable { over: 0 };
                }
                Decision::Steady
            }
            State::Await { .. } => Decision::Steady,
            State::Probation { left, must_beat, acc, n } => {
                *acc += obs_total;
                *n += 1;
                *left -= 1;
                if *left == 0 {
                    let mean = *acc / *n as f64;
                    let ok = mean <= *must_beat * (1.0 - self.cfg.min_improve);
                    self.state = State::Cooldown { left: self.cfg.cooldown_steps.max(1) };
                    if ok {
                        Decision::Commit
                    } else {
                        Decision::Rollback
                    }
                } else {
                    Decision::Steady
                }
            }
            State::Stable { over } => {
                if self.last_gap > self.cfg.gap_threshold {
                    *over += 1;
                } else {
                    *over = 0;
                }
                if *over >= self.cfg.hysteresis {
                    // The bar is the *degraded* regime — the mean of
                    // the over-gap streak, not of the whole window
                    // (which still holds pre-fault steps no plan on
                    // the degraded cluster could match).
                    let k = self.cfg.hysteresis.min(self.recent.len());
                    let must_beat =
                        self.recent.iter().rev().take(k).sum::<f64>() / k as f64;
                    self.state = State::Await { must_beat };
                    Decision::Replan { must_beat }
                } else {
                    Decision::Steady
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mon() -> Monitor {
        let mut m = Monitor::new(2, MonitorCfg::default());
        m.set_plan(1.0, vec![0.6, 0.4], vec![1.0, 1.0]);
        m
    }

    #[test]
    fn small_gaps_never_fire() {
        let mut m = mon();
        for _ in 0..100 {
            assert_eq!(m.observe(1.05, None), Decision::Steady);
        }
        assert!((m.gap() - 0.05).abs() < 1e-12);
    }

    #[test]
    fn persistent_gap_fires_after_hysteresis_then_waits() {
        let mut m = mon();
        assert_eq!(m.observe(1.5, None), Decision::Steady);
        // An in-threshold step resets the streak.
        assert_eq!(m.observe(1.0, None), Decision::Steady);
        assert_eq!(m.observe(1.5, None), Decision::Steady);
        assert_eq!(m.observe(1.5, None), Decision::Steady);
        let d = m.observe(1.5, None);
        assert!(matches!(d, Decision::Replan { .. }), "3rd consecutive over-gap step: {d:?}");
        // Awaiting the driver: no duplicate advice.
        assert_eq!(m.observe(1.5, None), Decision::Steady);
        // Dismissed advice cools down — the persisting gap stays quiet
        // for cooldown_steps, then advice can fire again.
        m.dismissed();
        for _ in 0..MonitorCfg::default().cooldown_steps {
            assert_eq!(m.observe(1.5, None), Decision::Steady);
        }
        let mut fired = false;
        for _ in 0..MonitorCfg::default().hysteresis {
            fired |= matches!(m.observe(1.5, None), Decision::Replan { .. });
        }
        assert!(fired, "advice re-fires after cooldown");
    }

    #[test]
    fn probation_commits_good_switches_and_rolls_back_bad_ones() {
        let cfg = MonitorCfg::default();
        // Good switch: new plan delivers well under must_beat.
        let mut m = mon();
        for _ in 0..cfg.hysteresis {
            m.observe(1.5, None);
        }
        m.switched(1.2, vec![0.7, 0.5], vec![1.25, 1.0]);
        let mut last = Decision::Steady;
        for _ in 0..cfg.probation_steps {
            last = m.observe(1.2, None);
        }
        assert_eq!(last, Decision::Commit);

        // Bad switch: the "better" plan is slower than the old mean.
        let mut m = mon();
        for _ in 0..cfg.hysteresis {
            m.observe(1.5, None);
        }
        m.switched(1.2, vec![0.7, 0.5], vec![1.25, 1.0]);
        let mut last = Decision::Steady;
        for _ in 0..cfg.probation_steps {
            last = m.observe(2.0, None);
        }
        assert_eq!(last, Decision::Rollback);
        // Rollback put us in cooldown: quiet for a while.
        assert_eq!(m.observe(2.0, None), Decision::Steady);
    }

    #[test]
    fn rate_estimates_track_a_persistent_shift_via_median() {
        let cfg = MonitorCfg::default();
        let mut m = mon();
        // Healthy samples first: estimates pinned at 1.0.
        for _ in 0..5 {
            m.observe(1.0, Some(&[0.6, 0.4]));
        }
        assert_eq!(m.rates(), &[1.0, 1.0]);
        // Device 1 slows 2×: after `hysteresis` consistent samples the
        // median flips — the same step the gap hysteresis confirms.
        for _ in 0..cfg.hysteresis {
            m.observe(1.4, Some(&[0.6, 0.8]));
        }
        assert!((m.rates()[1] - 2.0).abs() < 1e-12, "rates: {:?}", m.rates());
        assert_eq!(m.rates()[0], 1.0);
        // A single jitter spike is rejected outright.
        m.observe(1.0, Some(&[3.0, 0.8]));
        assert_eq!(m.rates()[0], 1.0, "median rejects one outlier");
    }

    #[test]
    fn kill_remap_resets_estimation_dimensions() {
        let mut m = mon();
        m.observe(1.0, Some(&[0.6, 0.4]));
        // Forced switch onto 3 devices (no Await state): cooldown, new
        // windows sized for the new device space.
        m.switched(2.0, vec![0.5, 0.5, 0.5], vec![1.0, 1.0, 1.5]);
        assert_eq!(m.rates(), &[1.0, 1.0, 1.5]);
        assert_eq!(m.observe(2.0, Some(&[0.5, 0.5, 0.5])), Decision::Steady);
    }
}
