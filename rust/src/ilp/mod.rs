//! Exact schedule search — the "ILP solver" comparison point of Fig 13.
//!
//! The paper frames workload scheduling as a Job-Shop Scheduling
//! Problem and reports that ILP-based methods (Tessel, ZB's solver,
//! controllable-memory ZB) blow up combinatorially.  We reproduce that
//! baseline with an exact branch-and-bound over the same decision
//! space (which ready op each device runs next), with a lower-bound
//! prune and a wall-clock budget:
//!
//! - `Simple`: schedule only (fixed S-1F1B partition + placement) —
//!   Fig 13's "ILP Solver (Simple)";
//! - `Full`: also branches over partitions (boundary enumeration) —
//!   Fig 13's "ILP Solver".
//!
//! For instances beyond the budget the harness extrapolates with the
//! exponential fit in [`crate::util::stats::fit_exponential`], exactly
//! as the paper does with scipy's curve_fit (§5.6).

use std::time::Instant;

use crate::partition::{uniform, Partition};
use crate::placement::{sequential, Placement};
use crate::profile::ProfiledData;
use crate::schedule::block::{BlockIr, Pattern, StashRule};
use crate::schedule::{OpKind, Schedule, Slot};

/// Search outcome.
#[derive(Debug, Clone)]
pub struct ExactResult {
    /// Best makespan found (s).
    pub best: f64,
    /// True if the search ran to completion (proof of optimality).
    pub complete: bool,
    /// Decision nodes explored.
    pub nodes: u64,
    /// Wall-clock seconds spent.
    pub elapsed_s: f64,
    /// The optimal schedule (when complete or best-so-far otherwise).
    pub schedule: Option<Schedule>,
}

struct Searcher<'a> {
    f: Vec<f64>,
    b: Vec<f64>,
    comm_f: Vec<f64>,
    comm_b: Vec<f64>,
    device_of: Vec<usize>,
    s_n: usize,
    p: usize,
    nmb: usize,
    deadline: Instant,
    nodes: u64,
    best: f64,
    best_order: Option<Vec<Vec<Slot>>>,
    profile: &'a ProfiledData,
}

#[derive(Clone)]
struct State {
    clock: Vec<f64>,
    end_f: Vec<f64>,
    end_b: Vec<f64>,
    next_f: Vec<usize>,
    next_b: Vec<usize>,
    emitted: Vec<Vec<Slot>>,
    done: usize,
}

impl<'a> Searcher<'a> {
    fn new(
        profile: &'a ProfiledData,
        part: &Partition,
        plac: &Placement,
        nmb: usize,
        deadline: Instant,
    ) -> Self {
        let s_n = part.n_stages();
        let costs: Vec<_> = (0..s_n).map(|s| profile.stage_cost(part.stage_range(s))).collect();
        let comm_f = (0..s_n)
            .map(|s| {
                if s > 0 && plac.device_of[s - 1] != plac.device_of[s] {
                    profile.p2p(costs[s - 1].comm_bytes)
                } else {
                    0.0
                }
            })
            .collect();
        let comm_b = (0..s_n)
            .map(|s| {
                if s + 1 < s_n && plac.device_of[s + 1] != plac.device_of[s] {
                    profile.p2p(costs[s].comm_bytes)
                } else {
                    0.0
                }
            })
            .collect();
        Searcher {
            f: costs.iter().map(|c| c.f).collect(),
            b: costs.iter().map(|c| c.b + c.w).collect(),
            comm_f,
            comm_b,
            device_of: plac.device_of.clone(),
            s_n,
            p: plac.p,
            nmb,
            deadline,
            nodes: 0,
            best: f64::INFINITY,
            best_order: None,
            profile,
        }
    }

    fn total_ops(&self) -> usize {
        2 * self.s_n * self.nmb
    }

    /// Remaining-work lower bound for pruning: any device's clock plus
    /// its outstanding compute.
    fn lower_bound(&self, st: &State) -> f64 {
        let mut lb: f64 = 0.0;
        for d in 0..self.p {
            let mut rem = 0.0;
            for s in 0..self.s_n {
                if self.device_of[s] != d {
                    continue;
                }
                rem += (self.nmb - st.next_f[s]) as f64 * self.f[s]
                    + (self.nmb - st.next_b[s]) as f64 * self.b[s];
            }
            lb = lb.max(st.clock[d] + rem);
        }
        lb
    }

    fn dfs(&mut self, st: &mut State) -> bool {
        self.nodes += 1;
        if self.nodes % 4096 == 0 && Instant::now() > self.deadline {
            return false; // budget exhausted
        }
        if st.done == self.total_ops() {
            let makespan = st.clock.iter().cloned().fold(0.0, f64::max);
            if makespan < self.best {
                self.best = makespan;
                self.best_order = Some(st.emitted.clone());
            }
            return true;
        }
        if self.lower_bound(st) >= self.best {
            return true; // pruned
        }
        // Branch on every ready op (the JSSP decision space).
        let mut progressed = true;
        let idx = |s: usize, mb: usize, nmb: usize| s * nmb + mb;
        for s in 0..self.s_n {
            let d = self.device_of[s];
            // F branch.
            let mb = st.next_f[s];
            if mb < self.nmb {
                let dep = if s == 0 { 0.0 } else { st.end_f[idx(s - 1, mb, self.nmb)] };
                if !dep.is_nan() {
                    let start = st.clock[d].max(dep + self.comm_f[s]);
                    let end = start + self.f[s];
                    let (pc, pe) = (st.clock[d], st.end_f[idx(s, mb, self.nmb)]);
                    st.clock[d] = end;
                    st.end_f[idx(s, mb, self.nmb)] = end;
                    st.next_f[s] += 1;
                    st.emitted[d].push(Slot::new(OpKind::F, mb, s));
                    st.done += 1;
                    progressed &= self.dfs(st);
                    st.done -= 1;
                    st.emitted[d].pop();
                    st.next_f[s] -= 1;
                    st.end_f[idx(s, mb, self.nmb)] = pe;
                    st.clock[d] = pc;
                    if !progressed {
                        return false;
                    }
                }
            }
            // B branch.
            let mb = st.next_b[s];
            if mb < self.nmb && !st.end_f[idx(s, mb, self.nmb)].is_nan() {
                let dep = if s == self.s_n - 1 {
                    st.end_f[idx(s, mb, self.nmb)]
                } else {
                    st.end_b[idx(s + 1, mb, self.nmb)]
                };
                if !dep.is_nan() {
                    let start = st.clock[d].max(dep + self.comm_b[s]);
                    let end = start + self.b[s];
                    let (pc, pe) = (st.clock[d], st.end_b[idx(s, mb, self.nmb)]);
                    st.clock[d] = end;
                    st.end_b[idx(s, mb, self.nmb)] = end;
                    st.next_b[s] += 1;
                    st.emitted[d].push(Slot::new(OpKind::B, mb, s));
                    st.done += 1;
                    progressed &= self.dfs(st);
                    st.done -= 1;
                    st.emitted[d].pop();
                    st.next_b[s] -= 1;
                    st.end_b[idx(s, mb, self.nmb)] = pe;
                    st.clock[d] = pc;
                    if !progressed {
                        return false;
                    }
                }
            }
        }
        let _ = self.profile;
        progressed
    }
}

/// Exact schedule search over a fixed (partition, placement).
pub fn exact_schedule(
    profile: &ProfiledData,
    part: &Partition,
    plac: &Placement,
    nmb: usize,
    budget_s: f64,
) -> ExactResult {
    let t0 = Instant::now();
    let deadline = t0 + std::time::Duration::from_secs_f64(budget_s);
    let mut se = Searcher::new(profile, part, plac, nmb, deadline);
    let s_n = part.n_stages();
    let mut st = State {
        clock: vec![0.0; plac.p],
        end_f: vec![f64::NAN; s_n * nmb],
        end_b: vec![f64::NAN; s_n * nmb],
        next_f: vec![0; s_n],
        next_b: vec![0; s_n],
        emitted: vec![Vec::new(); plac.p],
        done: 0,
    };
    let complete = se.dfs(&mut st);
    // The branch-and-bound timing uses background transfers
    // (`max(clock, dep+comm)`), so the returned schedule is
    // overlap-aware — keep the simulator semantics consistent.
    let schedule = se.best_order.map(|per_device| Schedule {
        p: plac.p,
        nmb,
        n_stages: s_n,
        split_bw: false,
        overlap_aware: true,
        per_device,
    });
    ExactResult {
        best: se.best,
        complete,
        nodes: se.nodes,
        elapsed_s: t0.elapsed().as_secs_f64(),
        schedule,
    }
}

/// Exact co-search: schedule × partition (the full "ILP Solver" bar of
/// Fig 13).  Enumerates every partition of `n_layers` into `p` stages
/// and runs the exact schedule search on each.
pub fn exact_full(
    profile: &ProfiledData,
    p: usize,
    nmb: usize,
    budget_s: f64,
) -> ExactResult {
    let t0 = Instant::now();
    let deadline = t0 + std::time::Duration::from_secs_f64(budget_s);
    let n = profile.n_layers();
    let plac = sequential(p);
    let mut best = ExactResult {
        best: f64::INFINITY,
        complete: true,
        nodes: 0,
        elapsed_s: 0.0,
        schedule: None,
    };
    // Enumerate compositions of n into p positive parts.
    let mut sizes = vec![1usize; p];
    sizes[p - 1] = n - (p - 1);
    loop {
        let part = Partition::from_sizes(&sizes);
        let remain = (deadline - Instant::now().min(deadline)).as_secs_f64();
        if remain <= 0.0 {
            best.complete = false;
            break;
        }
        let r = exact_schedule(profile, &part, &plac, nmb, remain);
        best.nodes += r.nodes;
        best.complete &= r.complete;
        if r.best < best.best {
            best.best = r.best;
            best.schedule = r.schedule;
        }
        // Next composition (colex order).
        let mut i = p - 1;
        loop {
            if i == 0 {
                best.elapsed_s = t0.elapsed().as_secs_f64();
                return best;
            }
            if sizes[i] > 1 {
                sizes[i - 1] += 1;
                let moved: usize = sizes[i..].iter().sum::<usize>() - 1;
                for s in &mut sizes[i..] {
                    *s = 1;
                }
                sizes[p - 1] = moved - (p - 1 - i);
                break;
            }
            i -= 1;
        }
    }
    best.elapsed_s = t0.elapsed().as_secs_f64();
    best
}

/// Fallback default when `uniform` is wanted by callers.
pub fn default_setup(profile: &ProfiledData, p: usize) -> (Partition, Placement) {
    (uniform(profile.n_layers(), p), sequential(p))
}

/// Distill a [`BlockIr`] from a provably optimal probe schedule — the
/// bridge from the exact solver to the Generator's block knob.
///
/// Runs the branch-and-bound on the S-1F1B setup with a *tiny* probe
/// (`nmb` clamped to 4) so completion takes milliseconds; an incomplete
/// probe returns `None` rather than distilling from an unproven
/// schedule (which would make the move set depend on machine speed).
/// The probe's per-device warmup depths (forwards before the first
/// backward) become the block's offsets; both interleaving patterns are
/// compiled and the one with the smaller simulated makespan on the
/// probe setup wins.
pub fn synthesize_block(
    profile: &ProfiledData,
    p: usize,
    nmb: usize,
    budget_s: f64,
) -> Option<BlockIr> {
    let probe_nmb = nmb.min(4).max(1);
    let (part, plac) = default_setup(profile, p);
    let res = exact_schedule(profile, &part, &plac, probe_nmb, budget_s);
    if !res.complete {
        return None;
    }
    let exact = res.schedule?;
    // Warmup depth per device: forwards emitted before the first B.
    let first_b: Vec<usize> = exact
        .per_device
        .iter()
        .map(|slots| {
            slots.iter().position(|s| s.op == OpKind::B).unwrap_or(slots.len())
        })
        .collect();
    let mut best: Option<(f64, BlockIr)> = None;
    for pattern in [Pattern::FThenB, Pattern::BThenF] {
        let offsets: Vec<usize> = first_b
            .iter()
            .map(|&fb| match pattern {
                // FThenB alternation opens with a steady F, so the
                // first B sits one past the warmup depth.
                Pattern::FThenB => fb.saturating_sub(1),
                Pattern::BThenF => fb,
            })
            .collect();
        let block = BlockIr {
            pattern,
            split_bw: false,
            group: 1,
            offsets,
            lag: vec![0; p],
            stash: StashRule::Warmup,
            overlap_aware: true,
        };
        let Ok(sch) = block.compile(&plac, probe_nmb) else { continue };
        let Ok(rep) = crate::perfmodel::simulate(profile, &part, &plac, &sch, false)
        else {
            continue;
        };
        if best.as_ref().is_none_or(|(t, _)| rep.total < *t) {
            best = Some((rep.total, block));
        }
    }
    best.map(|(_, b)| b)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Family, HardwareCfg, ModelCfg, ParallelCfg, Size};
    use crate::model::build_model;
    use crate::perfmodel::simulate;
    use crate::schedule::builders::one_f_one_b;

    fn profile(p: usize, nmb: usize) -> ProfiledData {
        let spec = build_model(&ModelCfg::table5(Family::Llama2, Size::Small));
        ProfiledData::analytical(
            &spec,
            &HardwareCfg::default(),
            &ParallelCfg::new(p, 2, nmb, 1, 2048),
        )
    }

    #[test]
    fn exact_matches_simulator_semantics() {
        // The optimum must be ≤ the 1F1B makespan under the same timing
        // model, and the returned schedule must re-simulate to ≈ best.
        let prof = profile(2, 2);
        let (part, plac) = default_setup(&prof, 2);
        let r = exact_schedule(&prof, &part, &plac, 2, 30.0);
        assert!(r.complete);
        let s1f1b = one_f_one_b(2, 2);
        let base = simulate(&prof, &part, &plac, &s1f1b, false).unwrap();
        assert!(r.best <= base.total + 1e-9, "{} !<= {}", r.best, base.total);
        let sch = r.schedule.unwrap();
        sch.validate(&plac).unwrap();
        let re = simulate(&prof, &part, &plac, &sch, false).unwrap();
        assert!((re.total - r.best).abs() < 1e-9);
    }

    #[test]
    fn node_count_grows_fast() {
        let prof = profile(2, 2);
        let (part, plac) = default_setup(&prof, 2);
        let n2 = exact_schedule(&prof, &part, &plac, 2, 30.0).nodes;
        let n3 = exact_schedule(&prof, &part, &plac, 3, 30.0).nodes;
        assert!(n3 > 2 * n2, "n2={n2} n3={n3}");
    }

    #[test]
    fn synthesized_block_is_valid_and_competitive() {
        // The distilled block must compile, validate, run deadlock-free
        // on the probe setup, and keep warmup depths within the probe's
        // horizon (they come straight from the proven-optimal order).
        let prof = profile(2, 4);
        let (part, plac) = default_setup(&prof, 2);
        let block = synthesize_block(&prof, 2, 4, 30.0).expect("tiny probe completes");
        assert!(block.offsets.iter().all(|&o| o <= 4), "{:?}", block.offsets);
        let sch = block.compile(&plac, 4).unwrap();
        sch.validate(&plac).unwrap();
        let rep = simulate(&prof, &part, &plac, &sch, false).unwrap();
        // Sanity, not optimality: the block is a structured projection
        // of the exact schedule, so it must at least beat GPipe's
        // all-warmup makespan on the same setup.
        let gpipe = crate::schedule::builders::gpipe(2, 4);
        let base = simulate(&prof, &part, &plac, &gpipe, false).unwrap();
        assert!(rep.total <= base.total + 1e-9, "{} !<= {}", rep.total, base.total);
    }

    #[test]
    fn synthesize_block_rejects_incomplete_probes() {
        // A probe that cannot prove optimality inside the budget must
        // be discarded — never distill from an unproven order.
        let prof = profile(4, 8);
        assert!(synthesize_block(&prof, 4, 8, 0.0).is_none());
    }

    #[test]
    fn budget_is_respected() {
        let prof = profile(4, 8);
        let (part, plac) = default_setup(&prof, 4);
        let t0 = std::time::Instant::now();
        let r = exact_schedule(&prof, &part, &plac, 8, 0.2);
        assert!(t0.elapsed().as_secs_f64() < 5.0);
        assert!(!r.complete);
    }
}
