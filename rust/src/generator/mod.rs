//! Pipeline Generator (paper §4.3): co-optimizes model partition,
//! model placement and workload scheduling, guided by the Pipeline
//! Performance Model.
//!
//! Search structure (Fig 6):
//! 1. **Seed selection** — evaluate a small grid of representative
//!    baselines (partition ∈ {uniform/S-1F1B, balanced/Mist} ×
//!    placement ∈ {sequential, interleaved, wave} × scheduling knobs ∈
//!    {1F1B-like, ZB-like}) and keep the best.
//! 2. **Bottleneck-phase tuning** — per iteration, try the tuning move
//!    of each enabled phase (most-blamed phase first), keep the best
//!    improving move, roll back the rest.  Moves:
//!    - *partition*: single-boundary layer shifts, steered toward
//!      moving work from the lowest-bubble device to the highest
//!      (§4.3 Model Partition Tuning);
//!    - *placement*: grouped permutation — refine every stage into
//!      finer sub-stages spread round-robin across devices (more
//!      effective stages, §4.3 Model Placement Tuning) plus pairwise
//!      stage-device swaps;
//!    - *scheduling*: knob search over B/W split, W-fill, overlap
//!      awareness and the memory-cap factor (§4.3 Workload Scheduling
//!      Tuning; the OOM-repair path lowers `mem_cap_factor`).
//! 3. Stop when no phase improves (or `max_iters`).
//!
//! The phase-by-phase loop with rollback avoids the combinatorial
//! explosion of joint search (Fig 4) while still escaping the
//! single-phase local optima the paper shows for partially adaptive
//! methods (Fig 10).

pub mod searchspace;

use std::time::Instant;

use crate::baselines::Pipeline;
use crate::partition::{balanced, uniform, Partition};
use crate::placement::{interleaved, sequential, wave, Placement};
use crate::perfmodel::{simulate, PerfReport};
use crate::profile::ProfiledData;
use crate::schedule::greedy::{greedy_schedule, SchedKnobs};

/// Which phases the generator may tune (Fig 10 ablation masks).
#[derive(Clone, Copy, Debug)]
pub struct PhaseMask {
    pub partition: bool,
    pub placement: bool,
    pub schedule: bool,
}

impl PhaseMask {
    pub fn all() -> Self {
        PhaseMask { partition: true, placement: true, schedule: true }
    }

    pub fn none() -> Self {
        PhaseMask { partition: false, placement: false, schedule: false }
    }
}

/// Generator options.
#[derive(Clone, Debug)]
pub struct GenOptions {
    pub nmb: usize,
    pub p: usize,
    pub max_iters: usize,
    pub phases: PhaseMask,
    /// Restrict seeds to the plain S-1F1B start (used by the Fig 10
    /// ablation so single-phase runs start from the static pipeline).
    pub seed_s1f1b_only: bool,
    /// Maximum virtual stages per device explored by placement moves.
    pub max_chunks: usize,
}

impl GenOptions {
    pub fn new(p: usize, nmb: usize) -> Self {
        GenOptions {
            nmb,
            p,
            max_iters: 64,
            phases: PhaseMask::all(),
            seed_s1f1b_only: false,
            max_chunks: 4,
        }
    }
}

/// One entry of the tuning log (drives the Fig 3 storyline).
#[derive(Clone, Debug)]
pub struct GenLogEntry {
    pub iter: usize,
    pub phase: &'static str,
    pub action: String,
    pub total: f64,
}

/// Generator output.
pub struct GenResult {
    pub pipeline: Pipeline,
    pub report: PerfReport,
    pub knobs: SchedKnobs,
    pub iters: usize,
    pub evals: usize,
    pub elapsed_s: f64,
    pub log: Vec<GenLogEntry>,
}

/// Candidate = (partition, placement, knobs); schedules are derived.
#[derive(Clone)]
struct Cand {
    part: Partition,
    plac: Placement,
    knobs: SchedKnobs,
}

struct Evaluator<'a> {
    profile: &'a ProfiledData,
    nmb: usize,
    evals: usize,
}

impl<'a> Evaluator<'a> {
    /// Build the schedule and simulate; returns (score, report).
    /// OOM candidates score +inf (constraint Eq. 2).
    fn eval(&mut self, c: &Cand) -> (f64, Option<PerfReport>) {
        self.evals += 1;
        let sch = greedy_schedule(self.profile, &c.part, &c.plac, self.nmb, c.knobs);
        match simulate(self.profile, &c.part, &c.plac, &sch, false) {
            Ok(r) if !r.oom => (r.total, Some(r)),
            Ok(r) => (f64::INFINITY, Some(r)),
            Err(_) => (f64::INFINITY, None),
        }
    }
}

/// Run the Pipeline Generator.
pub fn generate(profile: &ProfiledData, opts: &GenOptions) -> GenResult {
    let t0 = Instant::now();
    let n_layers = profile.n_layers();
    let p = opts.p;
    let mut ev = Evaluator { profile, nmb: opts.nmb, evals: 0 };
    let mut log = Vec::new();

    // ---- Seed selection --------------------------------------------------
    let knobs_1f1b = SchedKnobs {
        split_bw: false,
        w_fill: false,
        mem_cap_factor: 1.0,
        overlap_aware: false,
    };
    let knobs_zb = SchedKnobs {
        split_bw: true,
        w_fill: true,
        mem_cap_factor: 1.0,
        overlap_aware: false,
    };
    let mut seeds: Vec<Cand> = Vec::new();
    if opts.seed_s1f1b_only {
        seeds.push(Cand {
            part: uniform(n_layers, p),
            plac: sequential(p),
            knobs: knobs_1f1b,
        });
    } else {
        let parts: Vec<Partition> = vec![uniform(n_layers, p), balanced(profile, p)];
        for part_seed in &parts {
            for plac in [sequential(p), interleaved(p, 2), wave(p, 2)] {
                let s_n = plac.n_stages();
                let part = if s_n == part_seed.n_stages() {
                    part_seed.clone()
                } else {
                    let refined = refine_partition(profile, part_seed, s_n / p);
                    if refined.n_stages() == s_n {
                        refined
                    } else {
                        // A 1-layer stage could not split; re-balance
                        // globally for the finer stage count.
                        balanced(profile, s_n)
                    }
                };
                for knobs in [knobs_1f1b, knobs_zb] {
                    seeds.push(Cand { part: part.clone(), plac: plac.clone(), knobs });
                }
            }
        }
    }

    let mut best: Option<(f64, Cand)> = None;
    for c in seeds {
        let (score, _) = ev.eval(&c);
        if best.as_ref().map_or(true, |(b, _)| score < *b) {
            best = Some((score, c));
        }
    }
    let (mut best_score, mut cur) = best.unwrap();
    log.push(GenLogEntry {
        iter: 0,
        phase: "seed",
        action: format!(
            "S={} v={} split={} seed selected",
            cur.part.n_stages(),
            cur.plac.n_stages() / p,
            cur.knobs.split_bw
        ),
        total: best_score,
    });

    // ---- Bottleneck-phase tuning loop ------------------------------------
    let mut iter = 0;
    while iter < opts.max_iters {
        iter += 1;
        let mut improved = false;

        // Phase order: blame the phase with the strongest signal first.
        for phase in phase_order(&mut ev, &cur, opts) {
            let moves: Vec<(String, Cand)> = match phase {
                "partition" => partition_moves(&mut ev, profile, &cur),
                "placement" => placement_moves(profile, &cur, opts),
                "schedule" => schedule_moves(&cur),
                _ => unreachable!(),
            };
            let mut best_move: Option<(f64, String, Cand)> = None;
            for (desc, cand) in moves {
                let (score, _) = ev.eval(&cand);
                if score < best_score - 1e-12
                    && best_move.as_ref().map_or(true, |(b, _, _)| score < *b)
                {
                    best_move = Some((score, desc, cand));
                }
            }
            if let Some((score, desc, cand)) = best_move {
                best_score = score;
                cur = cand;
                log.push(GenLogEntry { iter, phase, action: desc, total: score });
                improved = true;
                break; // re-assess bottleneck from the new pipeline
            }
            // else: roll back (nothing kept) and try the next phase.
        }

        if !improved {
            break;
        }
    }

    // Final artifacts.
    let schedule = greedy_schedule(profile, &cur.part, &cur.plac, opts.nmb, cur.knobs);
    let report = simulate(profile, &cur.part, &cur.plac, &schedule, false)
        .expect("final pipeline must simulate");
    GenResult {
        pipeline: Pipeline {
            name: "AdaPtis".into(),
            partition: cur.part,
            placement: cur.plac,
            schedule,
        },
        report,
        knobs: cur.knobs,
        iters: iter,
        evals: ev.evals,
        elapsed_s: t0.elapsed().as_secs_f64(),
        log,
    }
}

/// Decide phase attempt order from bottleneck signals (paper: "identify
/// the bottleneck phase … and tune it accordingly").
fn phase_order(ev: &mut Evaluator, cur: &Cand, opts: &GenOptions) -> Vec<&'static str> {
    let (_, report) = ev.eval(cur);
    let mut order: Vec<(&'static str, f64)> = Vec::new();
    if let Some(r) = report {
        let max_busy = r.busy_d.iter().cloned().fold(0.0, f64::max);
        let min_busy = r.busy_d.iter().cloned().fold(f64::INFINITY, f64::min);
        let imbalance = (max_busy - min_busy) / r.total.max(1e-12);
        let bubble = r.bubble_ratio();
        if opts.phases.partition {
            order.push(("partition", imbalance));
        }
        if opts.phases.placement {
            // Placement helps when bubbles persist despite balance —
            // blame it by the residual bubble.
            order.push(("placement", (bubble - imbalance).max(0.0)));
        }
        if opts.phases.schedule {
            order.push(("schedule", bubble * 0.5));
        }
    }
    order.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
    order.into_iter().map(|(n, _)| n).collect()
}

/// Partition tuning moves: all single-boundary shifts, plus a steered
/// multi-shift that moves one layer from the lowest-bubble device
/// toward the highest-bubble device (§4.3).
fn partition_moves(
    ev: &mut Evaluator,
    profile: &ProfiledData,
    cur: &Cand,
) -> Vec<(String, Cand)> {
    let mut out = Vec::new();
    let s_n = cur.part.n_stages();
    for b in 0..s_n - 1 {
        for dir in [true, false] {
            let mut part = cur.part.clone();
            if part.shift_boundary(b, dir) {
                out.push((
                    format!("shift boundary {b} {}", if dir { "←" } else { "→" }),
                    Cand { part, plac: cur.plac.clone(), knobs: cur.knobs },
                ));
            }
        }
    }
    // Steered flow: overloaded (low-bubble) device donates a layer to
    // the starved (high-bubble) device through the chain of boundaries.
    if let (_, Some(r)) = ev.eval(cur) {
        let donor = argmin(&r.bubble_d);
        let recv = argmax(&r.bubble_d);
        if donor != recv {
            let sd = cur.plac.stages_of(donor);
            let sr = cur.plac.stages_of(recv);
            if let (Some(&a), Some(&b)) = (sd.first(), sr.first()) {
                let (lo, hi, dir) = if a < b { (a, b, false) } else { (b, a, true) };
                let mut part = cur.part.clone();
                let mut ok = true;
                for k in lo..hi {
                    ok &= part.shift_boundary(k, dir);
                }
                if ok && part.is_valid() {
                    out.push((
                        format!("flow layer dev{donor}→dev{recv}"),
                        Cand { part, plac: cur.plac.clone(), knobs: cur.knobs },
                    ));
                }
            }
        }
        let _ = profile;
    }
    out
}

/// Placement tuning moves: grouped permutations (finer interleaving /
/// wave layouts) and pairwise stage swaps.
fn placement_moves(
    profile: &ProfiledData,
    cur: &Cand,
    opts: &GenOptions,
) -> Vec<(String, Cand)> {
    let p = cur.plac.p;
    let n_layers = profile.n_layers();
    let mut out = Vec::new();
    for v in 1..=opts.max_chunks {
        if p * v > n_layers {
            break;
        }
        for (name, plac) in [("interleave", interleaved(p, v)), ("wave", wave(p, v))] {
            if plac.device_of == cur.plac.device_of {
                continue;
            }
            let part = repartition_for(profile, p * v);
            out.push((format!("{name} v={v}"), Cand { part, plac, knobs: cur.knobs }));
            if v == 1 {
                break; // wave(p,1) == interleaved(p,1) == sequential
            }
        }
    }
    // Pairwise device swaps between consecutive stages.
    let s_n = cur.plac.n_stages();
    for s in 0..s_n.saturating_sub(1) {
        if cur.plac.device_of[s] != cur.plac.device_of[s + 1] {
            let mut plac = cur.plac.clone();
            plac.swap_stages(s, s + 1);
            if plac.is_valid() {
                out.push((
                    format!("swap stages {s},{}", s + 1),
                    Cand { part: cur.part.clone(), plac, knobs: cur.knobs },
                ));
            }
        }
    }
    out
}

/// Scheduling tuning moves: knob grid around the current setting.
fn schedule_moves(cur: &Cand) -> Vec<(String, Cand)> {
    let k0 = cur.knobs;
    let variants = [
        ("split B/W", SchedKnobs { split_bw: !k0.split_bw, ..k0 }),
        ("toggle W-fill", SchedKnobs { w_fill: !k0.w_fill, ..k0 }),
        ("toggle overlap", SchedKnobs { overlap_aware: !k0.overlap_aware, ..k0 }),
        ("tighten memory", SchedKnobs { mem_cap_factor: k0.mem_cap_factor * 0.75, ..k0 }),
        (
            "relax memory",
            SchedKnobs { mem_cap_factor: (k0.mem_cap_factor * 1.25).min(1.0), ..k0 },
        ),
        (
            "zb-full",
            SchedKnobs {
                split_bw: true,
                w_fill: true,
                overlap_aware: true,
                mem_cap_factor: k0.mem_cap_factor,
            },
        ),
    ];
    variants
        .into_iter()
        .map(|(name, knobs)| {
            (
                name.to_string(),
                Cand { part: cur.part.clone(), plac: cur.plac.clone(), knobs },
            )
        })
        .collect()
}

/// Split each stage of `part` into `g` compute-balanced sub-stages.
fn refine_partition(profile: &ProfiledData, part: &Partition, g: usize) -> Partition {
    if g <= 1 {
        return part.clone();
    }
    let mut sizes = Vec::new();
    for s in 0..part.n_stages() {
        let range = part.stage_range(s);
        let sub = balanced_range(profile, range.clone(), g.min(range.len()));
        sizes.extend(sub);
    }
    Partition::from_sizes(&sizes)
}

/// Re-balance the whole model into `s_n` stages (used when a placement
/// move changes the stage count).
fn repartition_for(profile: &ProfiledData, s_n: usize) -> Partition {
    balanced(profile, s_n)
}

/// Balance `range` into `g` contiguous chunks by fused compute weight.
fn balanced_range(
    profile: &ProfiledData,
    range: std::ops::Range<usize>,
    g: usize,
) -> Vec<usize> {
    let n = range.len();
    assert!(g >= 1 && g <= n);
    let w: Vec<f64> = range
        .clone()
        .map(|l| {
            let c = &profile.layers[l];
            c.f + c.b + c.w
        })
        .collect();
    let mut prefix = vec![0.0; n + 1];
    for i in 0..n {
        prefix[i + 1] = prefix[i] + w[i];
    }
    let total = prefix[n];
    // Cut after the layer where the prefix first reaches i/g of the
    // total, keeping each chunk non-empty and leaving room for the rest.
    let mut cuts = vec![0usize];
    for i in 1..g {
        let target = total * i as f64 / g as f64;
        let lo = cuts[i - 1] + 1; // ≥1 layer per chunk
        let hi = n - (g - i); // leave ≥1 layer per remaining chunk
        let mut c = lo;
        while c < hi && prefix[c] < target {
            c += 1;
        }
        cuts.push(c.clamp(lo, hi));
    }
    cuts.push(n);
    cuts.windows(2).map(|wd| wd[1] - wd[0]).collect()
}

fn argmax(xs: &[f64]) -> usize {
    xs.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap_or(0)
}

fn argmin(xs: &[f64]) -> usize {
    xs.iter()
        .enumerate()
        .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::{build, Method};
    use crate::config::{Family, HardwareCfg, ModelCfg, ParallelCfg, Size};
    use crate::model::build_model;

    fn profile(fam: Family, p: usize, nmb: usize) -> ProfiledData {
        let spec = build_model(&ModelCfg::table5(fam, Size::Small));
        ProfiledData::analytical(
            &spec,
            &HardwareCfg::default(),
            &ParallelCfg::new(p, 2, nmb, 1, 4096),
        )
    }

    #[test]
    fn beats_all_baselines_on_heterogeneous_models() {
        for fam in [Family::Gemma, Family::DeepSeek, Family::NemotronH] {
            let prof = profile(fam, 4, 16);
            let res = generate(&prof, &GenOptions::new(4, 16));
            res.pipeline.schedule.validate(&res.pipeline.placement).unwrap();
            for m in Method::paper_baselines() {
                let b = build(m, &prof, 4, 16);
                let rb = simulate(&prof, &b.partition, &b.placement, &b.schedule, false)
                    .unwrap();
                assert!(
                    res.report.total <= rb.total * 1.001,
                    "{fam:?}: AdaPtis {:.4} !<= {} {:.4}",
                    res.report.total,
                    m.name(),
                    rb.total
                );
            }
        }
    }

    #[test]
    fn respects_phase_masks() {
        let prof = profile(Family::Gemma, 4, 8);
        let mut opts = GenOptions::new(4, 8);
        opts.phases = PhaseMask { partition: false, placement: false, schedule: true };
        opts.seed_s1f1b_only = true;
        let res = generate(&prof, &opts);
        // Partition must remain the uniform seed.
        assert_eq!(res.pipeline.partition, uniform(prof.n_layers(), 4));
        assert_eq!(res.pipeline.placement, sequential(4));
    }

    #[test]
    fn log_is_monotone_improving() {
        let prof = profile(Family::NemotronH, 4, 16);
        let res = generate(&prof, &GenOptions::new(4, 16));
        for w in res.log.windows(2) {
            assert!(w[1].total <= w[0].total + 1e-12);
        }
        assert!(res.evals > 0 && res.elapsed_s >= 0.0);
    }

    #[test]
    fn refine_partition_preserves_layers() {
        let prof = profile(Family::Gemma, 4, 8);
        let part = uniform(prof.n_layers(), 4);
        let fine = refine_partition(&prof, &part, 2);
        assert_eq!(fine.n_layers(), part.n_layers());
        assert_eq!(fine.n_stages(), 8);
        assert!(fine.is_valid());
    }
}
