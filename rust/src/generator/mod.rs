//! Pipeline Generator (paper §4.3): co-optimizes model partition,
//! model placement and workload scheduling, guided by the Pipeline
//! Performance Model.
//!
//! Search structure (Fig 6):
//! 1. **Seed selection** — evaluate a small grid of representative
//!    baselines (partition ∈ {uniform/S-1F1B, balanced/Mist} ×
//!    placement ∈ {sequential, interleaved, wave} × scheduling knobs ∈
//!    {1F1B-like, ZB-like}) and keep the best.
//! 2. **Bottleneck-phase tuning** — per iteration, try the tuning move
//!    of each enabled phase (most-blamed phase first), keep the best
//!    improving move, roll back the rest.  Moves:
//!    - *partition*: single-boundary layer shifts, steered toward
//!      moving work from the lowest-bubble device to the highest
//!      (§4.3 Model Partition Tuning);
//!    - *placement*: grouped permutation — refine every stage into
//!      finer sub-stages spread round-robin across devices (more
//!      effective stages, §4.3 Model Placement Tuning) plus pairwise
//!      stage-device swaps;
//!    - *scheduling*: knob search over B/W split, W-fill, overlap
//!      awareness and the memory-cap factor (§4.3 Workload Scheduling
//!      Tuning; the OOM-repair path lowers `mem_cap_factor`).
//! 3. Stop when no phase improves (or `max_iters`).
//!
//! The phase-by-phase loop with rollback avoids the combinatorial
//! explosion of joint search (Fig 4) while still escaping the
//! single-phase local optima the paper shows for partially adaptive
//! methods (Fig 10).
//!
//! **Evaluation hot path** (DESIGN.md §Hot path): every candidate is a
//! [`Prepared`] bundle of (partition, placement, knobs) plus its
//! [`StageTable`] — built incrementally for single-boundary partition
//! moves, cloned for knob-only moves.  Scoring goes through the fused
//! schedule+simulate pass ([`crate::perfmodel::fused_eval`]) on
//! per-thread [`SimArena`]s, and move batches are scored concurrently
//! with `std::thread::scope`; selection is by `(score, index)` so
//! results are bit-identical to the serial order.  Set
//! [`GenOptions::engine`] to [`EvalEngine::Reference`] to route every
//! eval through the unfused two-pass path (materialise the schedule,
//! re-simulate with the O(slots·P) reference kernel, single-threaded) —
//! the two engines produce identical pipelines at identical eval
//! counts, which is what `benches/generator.rs` compares.

pub mod searchspace;

use std::time::Instant;

use crate::baselines::Pipeline;
use crate::memory::MemCaps;
use crate::partition::{balanced, memory_balanced, uniform, Partition};
use crate::placement::{interleaved, sequential, wave, Placement};
use crate::perfmodel::{
    fused_eval, fused_score, simulate_in, simulate_reference_in, PerfReport, SimArena,
    StageTable,
};
use crate::profile::ProfiledData;
use crate::schedule::greedy::{greedy_schedule_caps, SchedKnobs};

/// Which phases the generator may tune (Fig 10 ablation masks).
#[derive(Clone, Copy, Debug)]
pub struct PhaseMask {
    pub partition: bool,
    pub placement: bool,
    pub schedule: bool,
}

impl PhaseMask {
    pub fn all() -> Self {
        PhaseMask { partition: true, placement: true, schedule: true }
    }

    pub fn none() -> Self {
        PhaseMask { partition: false, placement: false, schedule: false }
    }
}

/// How candidate evaluations are executed (see module docs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EvalEngine {
    /// Fused schedule+simulate, reusable arenas, parallel move batches.
    Fast,
    /// Materialise each schedule and re-simulate with the reference
    /// kernel, serially — the pre-optimization behaviour, retained for
    /// differential tests and bench baselines.
    Reference,
}

/// Generator options.
#[derive(Clone, Debug)]
pub struct GenOptions {
    pub nmb: usize,
    pub p: usize,
    pub max_iters: usize,
    pub phases: PhaseMask,
    /// Restrict seeds to the plain S-1F1B start (used by the Fig 10
    /// ablation so single-phase runs start from the static pipeline).
    pub seed_s1f1b_only: bool,
    /// Maximum virtual stages per device explored by placement moves.
    pub max_chunks: usize,
    /// Candidate-evaluation engine (identical results either way).
    pub engine: EvalEngine,
    /// Per-device memory capacities the search must respect.  `None`
    /// uses the profile's uniform capacity (the seed behaviour);
    /// heterogeneous caps come from [`crate::cluster::ClusterSpec::mem_caps`].
    pub mem_caps: Option<MemCaps>,
}

impl GenOptions {
    pub fn new(p: usize, nmb: usize) -> Self {
        GenOptions {
            nmb,
            p,
            max_iters: 64,
            phases: PhaseMask::all(),
            seed_s1f1b_only: false,
            max_chunks: 4,
            engine: EvalEngine::Fast,
            mem_caps: None,
        }
    }

    /// Search under the given per-device memory capacities.
    pub fn with_mem_caps(mut self, caps: MemCaps) -> Self {
        self.mem_caps = Some(caps);
        self
    }
}

/// One entry of the tuning log (drives the Fig 3 storyline).
#[derive(Clone, Debug)]
pub struct GenLogEntry {
    pub iter: usize,
    pub phase: &'static str,
    pub action: String,
    pub total: f64,
}

/// Generator output.
pub struct GenResult {
    pub pipeline: Pipeline,
    pub report: PerfReport,
    pub knobs: SchedKnobs,
    pub iters: usize,
    pub evals: usize,
    pub elapsed_s: f64,
    pub log: Vec<GenLogEntry>,
}

/// Candidate = (partition, placement, knobs); schedules are derived.
#[derive(Clone)]
struct Cand {
    part: Partition,
    plac: Placement,
    knobs: SchedKnobs,
}

/// A candidate bundled with its stage-cost table, ready to score.
struct Prepared {
    desc: String,
    cand: Cand,
    table: StageTable,
}

impl Prepared {
    fn fresh(profile: &ProfiledData, desc: String, cand: Cand) -> Prepared {
        let table = StageTable::build(profile, &cand.part, &cand.plac);
        Prepared { desc, cand, table }
    }
}

/// Schedule-independent feasibility lower bound: a device holds its
/// static memory plus, at each stage's first F, at least that stage's
/// one-micro-batch stash (per-(stage, mb) holdings never go negative),
/// so `static_d + act[s] > cap` for any stage proves OOM before any
/// simulation runs.  O(S), allocation-free.
fn fits_lower_bound(table: &StageTable, caps: &MemCaps) -> bool {
    if !caps.fits_static(&table.static_d) {
        return false;
    }
    (0..table.n_stages).all(|s| {
        let d = table.device[s];
        table.static_d[d] + table.act[s] <= caps.cap(d)
    })
}

/// Score one candidate: step makespan, +inf on OOM / deadlock (Eq. 2).
/// Candidates rejected by the feasibility lower bound never get a
/// schedule built — no simulation for plans no schedule could save.
fn eval_candidate(
    profile: &ProfiledData,
    caps: &MemCaps,
    nmb: usize,
    engine: EvalEngine,
    prep: &Prepared,
    arena: &mut SimArena,
) -> f64 {
    if !fits_lower_bound(&prep.table, caps) {
        return f64::INFINITY;
    }
    match engine {
        EvalEngine::Fast => fused_score(&prep.table, caps, nmb, prep.cand.knobs, arena),
        EvalEngine::Reference => {
            let sch = greedy_schedule_caps(
                profile,
                caps,
                &prep.cand.part,
                &prep.cand.plac,
                nmb,
                prep.cand.knobs,
            );
            match simulate_reference_in(
                profile,
                caps,
                &prep.cand.part,
                &prep.cand.plac,
                &sch,
                false,
            ) {
                Ok(r) if !r.oom => r.total,
                Ok(_) => f64::INFINITY,
                Err(_) => f64::INFINITY,
            }
        }
    }
}

struct Evaluator<'a> {
    profile: &'a ProfiledData,
    caps: &'a MemCaps,
    nmb: usize,
    engine: EvalEngine,
    evals: usize,
    arena: SimArena,
}

impl<'a> Evaluator<'a> {
    fn new(
        profile: &'a ProfiledData,
        caps: &'a MemCaps,
        nmb: usize,
        engine: EvalEngine,
    ) -> Self {
        Evaluator { profile, caps, nmb, engine, evals: 0, arena: SimArena::new() }
    }

    /// Score a whole move batch.  With the fast engine, candidates are
    /// split across scoped threads (each with its own arena); output
    /// order is the input order, so downstream `(score, index)`
    /// selection is deterministic and identical to a serial run.
    fn scores(&mut self, batch: &[Prepared]) -> Vec<f64> {
        self.evals += batch.len();
        let n = batch.len();
        // Thread spawn/join costs tens of µs; only fan out when the
        // batch carries enough simulated ops to amortise it, else the
        // serial loop (reused arena) wins.  Same results either way.
        let work_per_eval =
            batch.first().map_or(0, |prep| prep.table.n_stages * self.nmb);
        let threads = match self.engine {
            EvalEngine::Reference => 1,
            EvalEngine::Fast if n < 4 || work_per_eval < 256 => 1,
            EvalEngine::Fast => std::thread::available_parallelism()
                .map(|v| v.get())
                .unwrap_or(1)
                .min(n),
        };
        if threads <= 1 {
            let mut out = Vec::with_capacity(n);
            for prep in batch {
                out.push(eval_candidate(
                    self.profile,
                    self.caps,
                    self.nmb,
                    self.engine,
                    prep,
                    &mut self.arena,
                ));
            }
            return out;
        }
        let mut out = vec![f64::INFINITY; n];
        let chunk = n.div_ceil(threads);
        let (profile, caps, nmb, engine) = (self.profile, self.caps, self.nmb, self.engine);
        std::thread::scope(|sc| {
            for (bch, och) in batch.chunks(chunk).zip(out.chunks_mut(chunk)) {
                sc.spawn(move || {
                    let mut arena = SimArena::new();
                    for (prep, o) in bch.iter().zip(och.iter_mut()) {
                        *o = eval_candidate(profile, caps, nmb, engine, prep, &mut arena);
                    }
                });
            }
        });
        out
    }

    /// Full report for the current pipeline (bottleneck attribution).
    fn report(&mut self, cand: &Cand, table: &StageTable) -> Option<PerfReport> {
        self.evals += 1;
        match self.engine {
            EvalEngine::Fast => Some(fused_eval(
                table,
                self.caps,
                self.nmb,
                cand.knobs,
                &mut self.arena,
                None,
            )),
            EvalEngine::Reference => {
                let sch = greedy_schedule_caps(
                    self.profile,
                    self.caps,
                    &cand.part,
                    &cand.plac,
                    self.nmb,
                    cand.knobs,
                );
                simulate_reference_in(
                    self.profile,
                    self.caps,
                    &cand.part,
                    &cand.plac,
                    &sch,
                    false,
                )
                .ok()
            }
        }
    }
}

/// Run the Pipeline Generator.
pub fn generate(profile: &ProfiledData, opts: &GenOptions) -> GenResult {
    let t0 = Instant::now();
    let n_layers = profile.n_layers();
    let p = opts.p;
    let caps = opts
        .mem_caps
        .clone()
        .unwrap_or_else(|| MemCaps::uniform(p, profile.mem_capacity));
    assert_eq!(caps.p(), p, "mem_caps must cover every pipeline device");
    let mut ev = Evaluator::new(profile, &caps, opts.nmb, opts.engine);
    let mut log = Vec::new();

    // ---- Seed selection --------------------------------------------------
    let knobs_1f1b = SchedKnobs {
        split_bw: false,
        w_fill: false,
        mem_cap_factor: 1.0,
        overlap_aware: false,
    };
    let knobs_zb = SchedKnobs {
        split_bw: true,
        w_fill: true,
        mem_cap_factor: 1.0,
        overlap_aware: false,
    };
    let mut seeds: Vec<Prepared> = Vec::new();
    if opts.seed_s1f1b_only {
        seeds.push(Prepared::fresh(
            profile,
            "S-1F1B seed".into(),
            Cand { part: uniform(n_layers, p), plac: sequential(p), knobs: knobs_1f1b },
        ));
    } else {
        let parts: Vec<Partition> = vec![uniform(n_layers, p), balanced(profile, p)];
        for part_seed in &parts {
            for plac in [sequential(p), interleaved(p, 2), wave(p, 2)] {
                let s_n = plac.n_stages();
                let part = if s_n == part_seed.n_stages() {
                    part_seed.clone()
                } else {
                    let refined = refine_partition(profile, part_seed, s_n / p);
                    if refined.n_stages() == s_n {
                        refined
                    } else {
                        // A 1-layer stage could not split; re-balance
                        // globally for the finer stage count.
                        balanced(profile, s_n)
                    }
                };
                for knobs in [knobs_1f1b, knobs_zb] {
                    seeds.push(Prepared::fresh(
                        profile,
                        "seed".into(),
                        Cand { part: part.clone(), plac: plac.clone(), knobs },
                    ));
                }
            }
        }
    }

    // Memory pressure: when a standard seed already fails the
    // feasibility lower bound under the caps, add memory-balanced
    // seeds — the throughput-balanced splits concentrate the heavy
    // embedding/head memory exactly where a tight cap rejects it.
    // With slack caps the seed set (and the search) is unchanged.
    if caps.bounded() && seeds.iter().any(|s| !fits_lower_bound(&s.table, &caps)) {
        for knobs in [knobs_1f1b, knobs_zb] {
            seeds.push(Prepared::fresh(
                profile,
                "memory-balanced seed".into(),
                Cand { part: memory_balanced(profile, p), plac: sequential(p), knobs },
            ));
        }
    }

    let seed_scores = ev.scores(&seeds);
    let mut best_i = 0usize;
    for (i, &sc) in seed_scores.iter().enumerate() {
        if sc < seed_scores[best_i] {
            best_i = i;
        }
    }
    let mut best_score = seed_scores[best_i];
    let chosen = seeds.swap_remove(best_i);
    let mut cur = chosen.cand;
    let mut cur_table = chosen.table;
    log.push(GenLogEntry {
        iter: 0,
        phase: "seed",
        action: format!(
            "S={} v={} split={} seed selected",
            cur.part.n_stages(),
            cur.plac.n_stages() / p,
            cur.knobs.split_bw
        ),
        total: best_score,
    });

    // ---- Bottleneck-phase tuning loop ------------------------------------
    let mut cur_report = ev.report(&cur, &cur_table);
    let mut iter = 0;
    while iter < opts.max_iters {
        iter += 1;
        let mut improved = false;

        // Phase order: blame the phase with the strongest signal first.
        for phase in phase_order(cur_report.as_ref(), opts) {
            let mut moves: Vec<Prepared> = match phase {
                "partition" => {
                    partition_moves(profile, &cur, &cur_table, cur_report.as_ref())
                }
                "placement" => placement_moves(profile, &cur, opts),
                "schedule" => schedule_moves(&cur, &cur_table),
                _ => unreachable!(),
            };
            // Memory-violating moves are pruned inside `eval_candidate`
            // (the feasibility lower bound short-circuits to +inf
            // before any schedule is built), so one gate serves seeds
            // and move batches alike.
            let scores = ev.scores(&moves);
            let mut best_move: Option<(f64, usize)> = None;
            for (i, &score) in scores.iter().enumerate() {
                if score < best_score - 1e-12
                    && best_move.is_none_or(|(b, _)| score < b)
                {
                    best_move = Some((score, i));
                }
            }
            if let Some((score, i)) = best_move {
                let prep = moves.swap_remove(i);
                best_score = score;
                cur = prep.cand;
                cur_table = prep.table;
                log.push(GenLogEntry { iter, phase, action: prep.desc, total: score });
                cur_report = ev.report(&cur, &cur_table);
                improved = true;
                break; // re-assess bottleneck from the new pipeline
            }
            // else: roll back (nothing kept) and try the next phase.
        }

        if !improved {
            break;
        }
    }

    // Final artifacts (evaluated under the same caps as the search, so
    // the reported OOM/headroom matches what the generator optimized).
    let final_table = StageTable::build(profile, &cur.part, &cur.plac);
    let mut arena = SimArena::new();
    let mut schedule =
        greedy_schedule_caps(profile, &caps, &cur.part, &cur.plac, opts.nmb, cur.knobs);
    let mut report = simulate_in(&mut arena, &final_table, &caps, &schedule, false)
        .expect("final pipeline must simulate");
    // OOM repair (Eq. 2): under a binding cap the list scheduler's
    // overlimit fallback can overshoot its activation budget (it admits
    // an over-budget F when nothing else can make progress).  Tighten
    // the budget factor geometrically — F's are deferred earlier,
    // trading bubbles for memory — and keep the first feasible result.
    if report.oom && caps.bounded() {
        let mut knobs = cur.knobs;
        for _ in 0..8 {
            knobs.mem_cap_factor *= 0.5;
            let sch =
                greedy_schedule_caps(profile, &caps, &cur.part, &cur.plac, opts.nmb, knobs);
            let rep = simulate_in(&mut arena, &final_table, &caps, &sch, false)
                .expect("repaired pipeline must simulate");
            if !rep.oom {
                log.push(GenLogEntry {
                    iter,
                    phase: "repair",
                    action: format!("tighten memory ×{:.4}", knobs.mem_cap_factor),
                    total: rep.total,
                });
                schedule = sch;
                report = rep;
                cur.knobs = knobs;
                break;
            }
        }
    }
    GenResult {
        pipeline: Pipeline {
            name: "AdaPtis".into(),
            partition: cur.part,
            placement: cur.plac,
            schedule,
        },
        report,
        knobs: cur.knobs,
        iters: iter,
        evals: ev.evals,
        elapsed_s: t0.elapsed().as_secs_f64(),
        log,
    }
}

/// Decide phase attempt order from bottleneck signals (paper: "identify
/// the bottleneck phase … and tune it accordingly").
fn phase_order(report: Option<&PerfReport>, opts: &GenOptions) -> Vec<&'static str> {
    let mut order: Vec<(&'static str, f64)> = Vec::new();
    if let Some(r) = report {
        let max_busy = r.busy_d.iter().cloned().fold(0.0, f64::max);
        let min_busy = r.busy_d.iter().cloned().fold(f64::INFINITY, f64::min);
        let imbalance = (max_busy - min_busy) / r.total.max(1e-12);
        let bubble = r.bubble_ratio();
        if opts.phases.partition {
            order.push(("partition", imbalance));
        }
        if opts.phases.placement {
            // Placement helps when bubbles persist despite balance —
            // blame it by the residual bubble.
            order.push(("placement", (bubble - imbalance).max(0.0)));
        }
        if opts.phases.schedule {
            order.push(("schedule", bubble * 0.5));
        }
    }
    order.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
    order.into_iter().map(|(n, _)| n).collect()
}

/// Partition tuning moves: all single-boundary shifts (stage tables
/// re-derived incrementally — only the two affected stages), plus a
/// steered multi-shift that moves one layer from the lowest-bubble
/// device toward the highest-bubble device (§4.3).
fn partition_moves(
    profile: &ProfiledData,
    cur: &Cand,
    cur_table: &StageTable,
    report: Option<&PerfReport>,
) -> Vec<Prepared> {
    let mut out = Vec::new();
    let s_n = cur.part.n_stages();
    for b in 0..s_n - 1 {
        for dir in [true, false] {
            let mut part = cur.part.clone();
            if part.shift_boundary(b, dir) {
                let mut table = cur_table.clone();
                table.update_boundary(profile, &part, b);
                out.push(Prepared {
                    desc: format!("shift boundary {b} {}", if dir { "←" } else { "→" }),
                    cand: Cand { part, plac: cur.plac.clone(), knobs: cur.knobs },
                    table,
                });
            }
        }
    }
    // Steered flow: overloaded (low-bubble) device donates a layer to
    // the starved (high-bubble) device through the chain of boundaries.
    if let Some(r) = report {
        let donor = argmin(&r.bubble_d);
        let recv = argmax(&r.bubble_d);
        if donor != recv {
            let sd = cur.plac.stages_of(donor);
            let sr = cur.plac.stages_of(recv);
            if let (Some(&a), Some(&b)) = (sd.first(), sr.first()) {
                let (lo, hi, dir) = if a < b { (a, b, false) } else { (b, a, true) };
                let mut part = cur.part.clone();
                let mut ok = true;
                for k in lo..hi {
                    ok &= part.shift_boundary(k, dir);
                }
                if ok && part.is_valid() {
                    out.push(Prepared::fresh(
                        profile,
                        format!("flow layer dev{donor}→dev{recv}"),
                        Cand { part, plac: cur.plac.clone(), knobs: cur.knobs },
                    ));
                }
            }
        }
    }
    out
}

/// Placement tuning moves: grouped permutations (finer interleaving /
/// wave layouts) and pairwise stage swaps.
fn placement_moves(
    profile: &ProfiledData,
    cur: &Cand,
    opts: &GenOptions,
) -> Vec<Prepared> {
    let p = cur.plac.p;
    let n_layers = profile.n_layers();
    let mut out = Vec::new();
    for v in 1..=opts.max_chunks {
        if p * v > n_layers {
            break;
        }
        for (name, plac) in [("interleave", interleaved(p, v)), ("wave", wave(p, v))] {
            if plac.device_of == cur.plac.device_of {
                continue;
            }
            let part = repartition_for(profile, p * v);
            out.push(Prepared::fresh(
                profile,
                format!("{name} v={v}"),
                Cand { part, plac, knobs: cur.knobs },
            ));
            if v == 1 {
                break; // wave(p,1) == interleaved(p,1) == sequential
            }
        }
    }
    // Pairwise device swaps between consecutive stages.
    let s_n = cur.plac.n_stages();
    for s in 0..s_n.saturating_sub(1) {
        if cur.plac.device_of[s] != cur.plac.device_of[s + 1] {
            let mut plac = cur.plac.clone();
            plac.swap_stages(s, s + 1);
            if plac.is_valid() {
                out.push(Prepared::fresh(
                    profile,
                    format!("swap stages {s},{}", s + 1),
                    Cand { part: cur.part.clone(), plac, knobs: cur.knobs },
                ));
            }
        }
    }
    out
}

/// Scheduling tuning moves: knob grid around the current setting.  The
/// stage table is knob-independent, so the current one is reused.
fn schedule_moves(cur: &Cand, cur_table: &StageTable) -> Vec<Prepared> {
    let k0 = cur.knobs;
    let variants = [
        ("split B/W", SchedKnobs { split_bw: !k0.split_bw, ..k0 }),
        ("toggle W-fill", SchedKnobs { w_fill: !k0.w_fill, ..k0 }),
        ("toggle overlap", SchedKnobs { overlap_aware: !k0.overlap_aware, ..k0 }),
        ("tighten memory", SchedKnobs { mem_cap_factor: k0.mem_cap_factor * 0.75, ..k0 }),
        (
            "relax memory",
            SchedKnobs { mem_cap_factor: (k0.mem_cap_factor * 1.25).min(1.0), ..k0 },
        ),
        (
            "zb-full",
            SchedKnobs {
                split_bw: true,
                w_fill: true,
                overlap_aware: true,
                mem_cap_factor: k0.mem_cap_factor,
            },
        ),
    ];
    variants
        .into_iter()
        .map(|(name, knobs)| Prepared {
            desc: name.to_string(),
            cand: Cand { part: cur.part.clone(), plac: cur.plac.clone(), knobs },
            table: cur_table.clone(),
        })
        .collect()
}

/// Split each stage of `part` into `g` compute-balanced sub-stages.
fn refine_partition(profile: &ProfiledData, part: &Partition, g: usize) -> Partition {
    if g <= 1 {
        return part.clone();
    }
    let mut sizes = Vec::new();
    for s in 0..part.n_stages() {
        let range = part.stage_range(s);
        let sub = balanced_range(profile, range.clone(), g.min(range.len()));
        sizes.extend(sub);
    }
    Partition::from_sizes(&sizes)
}

/// Re-balance the whole model into `s_n` stages (used when a placement
/// move changes the stage count).
fn repartition_for(profile: &ProfiledData, s_n: usize) -> Partition {
    balanced(profile, s_n)
}

/// Balance `range` into `g` contiguous chunks by fused compute weight.
fn balanced_range(
    profile: &ProfiledData,
    range: std::ops::Range<usize>,
    g: usize,
) -> Vec<usize> {
    let n = range.len();
    assert!(g >= 1 && g <= n);
    let w: Vec<f64> = range
        .clone()
        .map(|l| {
            let c = &profile.layers[l];
            c.f + c.b + c.w
        })
        .collect();
    let mut prefix = vec![0.0; n + 1];
    for i in 0..n {
        prefix[i + 1] = prefix[i] + w[i];
    }
    let total = prefix[n];
    // Cut after the layer where the prefix first reaches i/g of the
    // total, keeping each chunk non-empty and leaving room for the rest.
    let mut cuts = vec![0usize];
    for i in 1..g {
        let target = total * i as f64 / g as f64;
        let lo = cuts[i - 1] + 1; // ≥1 layer per chunk
        let hi = n - (g - i); // leave ≥1 layer per remaining chunk
        let mut c = lo;
        while c < hi && prefix[c] < target {
            c += 1;
        }
        cuts.push(c.clamp(lo, hi));
    }
    cuts.push(n);
    cuts.windows(2).map(|wd| wd[1] - wd[0]).collect()
}

fn argmax(xs: &[f64]) -> usize {
    xs.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap_or(0)
}

fn argmin(xs: &[f64]) -> usize {
    xs.iter()
        .enumerate()
        .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::{build, Method};
    use crate::config::{Family, HardwareCfg, ModelCfg, ParallelCfg, Size};
    use crate::model::build_model;
    use crate::perfmodel::simulate;

    fn profile(fam: Family, p: usize, nmb: usize) -> ProfiledData {
        let spec = build_model(&ModelCfg::table5(fam, Size::Small));
        ProfiledData::analytical(
            &spec,
            &HardwareCfg::default(),
            &ParallelCfg::new(p, 2, nmb, 1, 4096),
        )
    }

    #[test]
    fn beats_all_baselines_on_heterogeneous_models() {
        for fam in [Family::Gemma, Family::DeepSeek, Family::NemotronH] {
            let prof = profile(fam, 4, 16);
            let res = generate(&prof, &GenOptions::new(4, 16));
            res.pipeline.schedule.validate(&res.pipeline.placement).unwrap();
            for m in Method::paper_baselines() {
                let b = build(m, &prof, 4, 16);
                let rb = simulate(&prof, &b.partition, &b.placement, &b.schedule, false)
                    .unwrap();
                assert!(
                    res.report.total <= rb.total * 1.001,
                    "{fam:?}: AdaPtis {:.4} !<= {} {:.4}",
                    res.report.total,
                    m.name(),
                    rb.total
                );
            }
        }
    }

    #[test]
    fn respects_phase_masks() {
        let prof = profile(Family::Gemma, 4, 8);
        let mut opts = GenOptions::new(4, 8);
        opts.phases = PhaseMask { partition: false, placement: false, schedule: true };
        opts.seed_s1f1b_only = true;
        let res = generate(&prof, &opts);
        // Partition must remain the uniform seed.
        assert_eq!(res.pipeline.partition, uniform(prof.n_layers(), 4));
        assert_eq!(res.pipeline.placement, sequential(4));
    }

    #[test]
    fn log_is_monotone_improving() {
        let prof = profile(Family::NemotronH, 4, 16);
        let res = generate(&prof, &GenOptions::new(4, 16));
        for w in res.log.windows(2) {
            assert!(w[1].total <= w[0].total + 1e-12);
        }
        assert!(res.evals > 0 && res.elapsed_s >= 0.0);
    }

    #[test]
    fn refine_partition_preserves_layers() {
        let prof = profile(Family::Gemma, 4, 8);
        let part = uniform(prof.n_layers(), 4);
        let fine = refine_partition(&prof, &part, 2);
        assert_eq!(fine.n_layers(), part.n_layers());
        assert_eq!(fine.n_stages(), 8);
        assert!(fine.is_valid());
    }

    #[test]
    fn fast_and_reference_engines_agree() {
        // The fast engine (fused evals, parallel batches, incremental
        // stage tables) must reproduce the reference engine's search
        // bit-for-bit: same pipeline, same score, same eval count.
        for fam in [Family::Gemma, Family::NemotronH] {
            let prof = profile(fam, 4, 8);
            let mut fast_opts = GenOptions::new(4, 8);
            fast_opts.max_iters = 16;
            let mut ref_opts = fast_opts.clone();
            ref_opts.engine = EvalEngine::Reference;
            let a = generate(&prof, &fast_opts);
            let b = generate(&prof, &ref_opts);
            assert_eq!(a.report.total, b.report.total, "{fam:?}");
            assert_eq!(a.pipeline.partition, b.pipeline.partition, "{fam:?}");
            assert_eq!(a.pipeline.placement, b.pipeline.placement, "{fam:?}");
            assert_eq!(a.evals, b.evals, "{fam:?}");
            assert_eq!(a.iters, b.iters, "{fam:?}");
            assert_eq!(a.log.len(), b.log.len(), "{fam:?}");
        }
    }
}
