//! Pipeline Generator (paper §4.3): co-optimizes model partition,
//! model placement and workload scheduling, guided by the Pipeline
//! Performance Model.
//!
//! Search structure (Fig 6):
//! 1. **Seed selection** — evaluate a small grid of representative
//!    baselines (partition ∈ {uniform/S-1F1B, balanced/Mist} ×
//!    placement ∈ {sequential, interleaved, wave} × scheduling knobs ∈
//!    {1F1B-like, ZB-like}) and keep the best.
//! 2. **Bottleneck-phase tuning** — per iteration, try the tuning move
//!    of each enabled phase (most-blamed phase first), keep the best
//!    improving move, roll back the rest.  Moves:
//!    - *partition*: single-boundary layer shifts, steered toward
//!      moving work from the lowest-bubble device to the highest
//!      (§4.3 Model Partition Tuning);
//!    - *placement*: grouped permutation — refine every stage into
//!      finer sub-stages spread round-robin across devices (more
//!      effective stages, §4.3 Model Placement Tuning) plus pairwise
//!      stage-device swaps;
//!    - *scheduling*: knob search over B/W split, W-fill, overlap
//!      awareness and the memory-cap factor (§4.3 Workload Scheduling
//!      Tuning; the OOM-repair path lowers `mem_cap_factor`).
//! 3. Stop when no phase improves (or `max_iters`).
//!
//! The phase-by-phase loop with rollback avoids the combinatorial
//! explosion of joint search (Fig 4) while still escaping the
//! single-phase local optima the paper shows for partially adaptive
//! methods (Fig 10).
//!
//! **Three-layer scoring path** (DESIGN.md § Search acceleration).
//! Every candidate is a [`Prepared`] bundle of (partition, placement,
//! knobs) — shared immutably via `Arc`, so building a move clones only
//! the component it changes — plus its [`StageTable`], recycled
//! through a [`cache::PrepPool`] and re-derived incrementally for
//! single-boundary partition moves.  Scoring then goes through, in
//! order:
//!
//! 1. **Bound pruning** ([`crate::perfmodel::bounds`]): an O(S)
//!    analytic makespan lower bound; candidates that provably cannot
//!    beat the incumbent (`bound ≥ best − ε`, the exact acceptance
//!    threshold) are skipped without simulation and counted in
//!    [`GenResult::evals_pruned`].
//! 2. **Memoization** ([`cache::EvalCache`]): a transposition table
//!    keyed by the candidate's exact structural identity; regenerated
//!    candidates (undo moves, repeated knob-grid points) reuse their
//!    score and are counted in [`GenResult::evals_cached`].
//! 3. **Evaluation** — the fused schedule+simulate pass
//!    ([`crate::perfmodel::fused_eval`]) on per-worker [`SimArena`]s,
//!    with steady-state collapse ([`GenOptions::collapse`], default
//!    on): once a candidate's schedule locks into its per-micro-batch
//!    cycle, the remaining rounds are replayed by a per-op loop with
//!    no candidate scan — same f64 ops in the same order, so scores
//!    are bitwise-unchanged while the per-eval cost becomes (nearly)
//!    independent of `nmb` ([`GenResult::evals_collapsed`] counts the
//!    evaluations it fired in).  Batches large enough to amortise
//!    dispatch run on a persistent [`pool::EvalPool`] — either a
//!    process-wide pool shared across searches
//!    ([`GenOptions::shared_pool`], used by the elastic re-planner and
//!    the planner service) or a private pool spawned lazily for this
//!    search; results merge by `(score, index)`, so the outcome is
//!    bit-identical to a serial run either way.
//!
//! Both elisions only skip evaluations that cannot change the argmin —
//! the bound is a true lower bound and cache hits replay exact scores —
//! so the chosen pipeline, score and tuning log are **bit-identical**
//! to an elision-free run (`GenOptions::{prune_bounds, memoize}`
//! false; pinned by `tests/generator_accel.rs`).  Set
//! [`GenOptions::engine`] to [`EvalEngine::Reference`] to route every
//! eval through the unfused two-pass path (materialise the schedule,
//! re-simulate with the O(slots·P) reference kernel, single-threaded) —
//! the two engines produce identical pipelines at identical eval
//! counts, which is what `benches/generator.rs` compares.
//!
//! **Elastic re-planning hooks** (DESIGN.md § Elastic re-planning;
//! consumed by [`crate::adapt`]).  [`generate_with_cache`] runs the
//! same search against a *caller-owned* [`cache::EvalCache`] that
//! persists across re-plans — retargeted to a fingerprint of the
//! evaluation context first, so a stale score can never replay.  On
//! top of that, [`GenOptions`] grows four orthogonal knobs:
//! [`GenOptions::incumbent`] replaces the seed grid with the
//! currently-running plan (warm start — near a good optimum the loop
//! converges in a handful of evaluations); [`GenOptions::rates`]
//! prices every candidate under per-device compute slowdown estimates
//! (rated stage tables, [`StageTable::build_rated`]);
//! [`GenOptions::migration`] charges candidates an amortized
//! weight+optimizer shipping cost for every layer whose owner changes
//! relative to the incumbent (so a marginally-better plan that moves
//! half the model loses to a slightly-worse plan that moves nothing);
//! and [`GenOptions::time_budget_s`] bounds the tuning loop by wall
//! clock, returning the best plan so far with
//! [`GenResult::budget_exhausted`] set.  All four default off, and the
//! default path is bit-identical to a plain [`generate`] call.

pub mod cache;
pub mod pool;
pub mod searchspace;

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Instant;

use crate::baselines::Pipeline;
use crate::memory::MemCaps;
use crate::partition::{balanced, memory_balanced, uniform, Partition};
use crate::placement::{interleaved, sequential, wave, Placement};
use crate::perfmodel::{
    fits_lower_bound, fused_eval, fused_score, fused_score_collapsed,
    makespan_lower_bound_in, simulate_in, simulate_in_opts, simulate_reference_in,
    BoundScratch, EngineOpts, PerfReport, SimArena, StageTable,
};
use crate::profile::ProfiledData;
use crate::schedule::block::{BlockIr, StashRule};
use crate::schedule::greedy::{greedy_schedule_in, SchedKnobs};

use crate::memory::model::layer_migration_bytes;
use cache::{CacheStats, CandKey, EvalCache, PrepPool};
use pool::{EvalCtx, EvalPool, Job, PoolClient};

/// Acceptance epsilon: a move must beat the incumbent by more than
/// this to be kept.  The bound pruner reuses the same threshold, which
/// is what makes pruning unable to change the argmin.
const ACCEPT_EPS: f64 = 1e-12;

/// Cooperative cancellation handle ([`GenOptions::cancel`]).
///
/// A token fires either explicitly ([`CancelToken::cancel`], e.g. the
/// planner service when every waiter for a request disconnects) or by
/// an absolute wall-clock deadline fixed at construction.  The search
/// polls it at the **exact** iteration/phase boundaries where
/// [`GenOptions::time_budget_s`] is checked — never mid-batch — so a
/// cancelled run's tuning-log prefix is bitwise-identical to the
/// uncancelled run's, and the returned plan is the best one seen so
/// far ([`GenResult::cancelled`] reports the cut).
#[derive(Clone, Debug, Default)]
pub struct CancelToken {
    flag: Arc<AtomicBool>,
    deadline: Option<Instant>,
}

impl CancelToken {
    /// A token that only fires on an explicit [`CancelToken::cancel`].
    pub fn new() -> CancelToken {
        CancelToken::default()
    }

    /// A token that also fires once `deadline` passes.
    pub fn with_deadline(deadline: Instant) -> CancelToken {
        CancelToken { flag: Arc::new(AtomicBool::new(false)), deadline: Some(deadline) }
    }

    /// Request cancellation; all clones observe it.
    pub fn cancel(&self) {
        self.flag.store(true, Ordering::SeqCst);
    }

    /// True once cancelled explicitly or past the deadline.
    pub fn is_cancelled(&self) -> bool {
        self.flag.load(Ordering::SeqCst)
            || self.deadline.is_some_and(|d| Instant::now() >= d)
    }

    /// True iff the token carries a deadline and it has passed
    /// (explicit cancellation does not count).
    pub fn deadline_expired(&self) -> bool {
        self.deadline.is_some_and(|d| Instant::now() >= d)
    }
}

/// Which phases the generator may tune (Fig 10 ablation masks).
#[derive(Clone, Copy, Debug)]
pub struct PhaseMask {
    pub partition: bool,
    pub placement: bool,
    pub schedule: bool,
}

impl PhaseMask {
    pub fn all() -> Self {
        PhaseMask { partition: true, placement: true, schedule: true }
    }

    pub fn none() -> Self {
        PhaseMask { partition: false, placement: false, schedule: false }
    }
}

/// How candidate evaluations are executed (see module docs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EvalEngine {
    /// Fused schedule+simulate, reusable arenas, persistent-pool
    /// parallel move batches.
    Fast,
    /// Materialise each schedule and re-simulate with the reference
    /// kernel, serially — the pre-optimization behaviour, retained for
    /// differential tests and bench baselines.
    Reference,
}

/// Generator options.
#[derive(Clone, Debug)]
pub struct GenOptions {
    pub nmb: usize,
    pub p: usize,
    pub max_iters: usize,
    pub phases: PhaseMask,
    /// Restrict seeds to the plain S-1F1B start (used by the Fig 10
    /// ablation so single-phase runs start from the static pipeline).
    pub seed_s1f1b_only: bool,
    /// Maximum virtual stages per device explored by placement moves.
    pub max_chunks: usize,
    /// Candidate-evaluation engine (identical results either way).
    pub engine: EvalEngine,
    /// Per-device memory capacities the search must respect.  `None`
    /// uses the profile's uniform capacity (the seed behaviour);
    /// heterogeneous caps come from [`crate::cluster::ClusterSpec::mem_caps`].
    pub mem_caps: Option<MemCaps>,
    /// Skip full evaluation of candidates whose analytic makespan
    /// lower bound already exceeds the incumbent (bit-identical
    /// search; default on).
    pub prune_bounds: bool,
    /// Memoize candidate scores across tuning iterations
    /// (bit-identical search; default on).
    pub memoize: bool,
    /// Steady-state collapse in the fused evaluation kernel: replay
    /// the detected per-micro-batch cycle instead of re-scanning it
    /// (bit-identical scores — same f64 ops in the same order — so the
    /// chosen pipeline is unchanged, pinned by
    /// `tests/perfmodel_collapse.rs`; default on).
    pub collapse: bool,
    /// Warm start: seed the search from this plan *instead of* the
    /// seed grid (the elastic re-planner passes the currently-running
    /// pipeline).  Must cover the same `p` devices and layer count.
    pub incumbent: Option<Incumbent>,
    /// Charge candidates for weights/optimizer-state migration away
    /// from [`GenOptions::incumbent`] (no effect without one).
    pub migration: Option<MigrationCfg>,
    /// Per-device compute-time multipliers (`> 1` = slower): every
    /// candidate is priced on a rated [`StageTable`], so the search
    /// optimizes the *degraded* cluster the monitor observes.  `None`
    /// or all-`1.0` is bit-identical to the plain search.  Rates other
    /// than 1.0 require [`EvalEngine::Fast`] (the reference engine
    /// prices from the profile directly).
    pub rates: Option<Vec<f64>>,
    /// Wall-clock budget for the tuning loop, in seconds.  Seeds are
    /// always evaluated (there must be *a* plan to return); once the
    /// budget is spent the loop stops at the next phase boundary and
    /// the best plan so far is returned with
    /// [`GenResult::budget_exhausted`] set.
    pub time_budget_s: Option<f64>,
    /// Evaluate move batches on this process-wide pool instead of
    /// spawning a private one — workers park between searches and
    /// multiplex concurrent searches fairly.  Scores are pure
    /// functions of their jobs and merge positionally, so results are
    /// bit-identical to a private-pool (or serial) run.
    pub shared_pool: Option<Arc<EvalPool>>,
    /// Cooperative cancellation: polled at the same iteration/phase
    /// boundaries as [`GenOptions::time_budget_s`], so a cancelled
    /// run's prefix is bitwise-identical to the uncancelled run and
    /// the best plan so far comes back with [`GenResult::cancelled`]
    /// set.  The planner service uses this for per-request deadlines
    /// and client disconnects.
    pub cancel: Option<CancelToken>,
    /// Fourth search knob (schedule-synthesis block IR): add a "block"
    /// tuning phase whose moves introduce [`BlockIr`] families (ZB-V,
    /// memory-controllable V, an exact-search-synthesized seed) and
    /// then step their parameters (per-device offsets, chunk lag,
    /// F/B pattern, unit grouping, stash budgets).  Default **off** —
    /// with it off no block candidate is ever constructed and the
    /// search is bit-identical to the pre-IR generator (pinned by
    /// `block_search_off_is_bit_identical`).
    pub block_search: bool,
    /// Stash budget hint for block moves: seeds the `Fixed(k)` stash
    /// steps of the block phase (`None` derives steps from `nmb`).
    /// No effect without [`GenOptions::block_search`].
    pub block_stash: Option<u32>,
}

impl GenOptions {
    pub fn new(p: usize, nmb: usize) -> Self {
        GenOptions {
            nmb,
            p,
            max_iters: 64,
            phases: PhaseMask::all(),
            seed_s1f1b_only: false,
            max_chunks: 4,
            engine: EvalEngine::Fast,
            mem_caps: None,
            prune_bounds: true,
            memoize: true,
            collapse: true,
            incumbent: None,
            migration: None,
            rates: None,
            time_budget_s: None,
            shared_pool: None,
            cancel: None,
            block_search: false,
            block_stash: None,
        }
    }

    /// Enable the schedule-synthesis block phase (fourth search knob).
    pub fn with_block_search(mut self) -> Self {
        self.block_search = true;
        self
    }

    /// Search under the given per-device memory capacities.
    pub fn with_mem_caps(mut self, caps: MemCaps) -> Self {
        self.mem_caps = Some(caps);
        self
    }

    /// Warm-start from `incumbent`, charging migration per `cfg`.
    pub fn with_incumbent(mut self, incumbent: Incumbent, cfg: MigrationCfg) -> Self {
        self.incumbent = Some(incumbent);
        self.migration = Some(cfg);
        self
    }

    /// Price the search under per-device compute-time multipliers.
    pub fn with_rates(mut self, rates: Vec<f64>) -> Self {
        self.rates = Some(rates);
        self
    }

    /// Bound the tuning loop by wall clock.
    pub fn with_time_budget(mut self, seconds: f64) -> Self {
        self.time_budget_s = Some(seconds);
        self
    }

    /// Evaluate on a process-wide shared pool (see
    /// [`GenOptions::shared_pool`]).
    pub fn with_shared_pool(mut self, pool: Arc<EvalPool>) -> Self {
        self.shared_pool = Some(pool);
        self
    }

    /// Poll `cancel` at iteration/phase boundaries (see
    /// [`GenOptions::cancel`]).
    pub fn with_cancel(mut self, cancel: CancelToken) -> Self {
        self.cancel = Some(cancel);
        self
    }

    /// Disable bound pruning and memoization — every candidate is
    /// fully evaluated.  The baseline the accelerated search must
    /// match bit-for-bit (tests, `benches/generator.rs`).  Collapse is
    /// orthogonal (it elides no evaluations, only re-derivations
    /// inside one) and is controlled separately.
    pub fn elision_free(mut self) -> Self {
        self.prune_bounds = false;
        self.memoize = false;
        self
    }

    /// Disable steady-state collapse — every evaluation simulates all
    /// `S·nmb` slots.  The per-eval baseline the collapsed search must
    /// match bit-for-bit (tests, `benches/generator.rs`).
    pub fn no_collapse(mut self) -> Self {
        self.collapse = false;
        self
    }
}

/// The currently-running plan, as a warm-start seed for the next
/// re-generation ([`GenResult::incumbent`] packages one).
#[derive(Clone, Debug)]
pub struct Incumbent {
    pub partition: Partition,
    pub placement: Placement,
    pub knobs: SchedKnobs,
}

/// How migration away from the incumbent is charged.  A switch ships
/// weights + optimizer state for every layer whose owner changes
/// ([`layer_migration_bytes`]); the one-off shipping time is amortized
/// over `horizon_steps` future steps and added to each candidate's
/// per-step objective — so a plan that is `ε` faster but moves half
/// the model loses to one that is `ε` slower and moves nothing.
#[derive(Clone, Copy, Debug)]
pub struct MigrationCfg {
    /// Effective migration bandwidth (bytes/s) while the pipeline is
    /// paused for the switch.
    pub bw: f64,
    /// Steps the new plan is expected to run before the next re-plan —
    /// the amortization window.
    pub horizon_steps: f64,
}

impl Default for MigrationCfg {
    fn default() -> MigrationCfg {
        MigrationCfg { bw: 25e9, horizon_steps: 200.0 }
    }
}

/// Precomputed migration pricer: incumbent owner and shipping bytes
/// per *layer*, so a candidate's penalty is one O(layers) scan
/// regardless of how its stage boundaries differ from the incumbent's.
struct MigScorer {
    /// Incumbent owning device per layer.
    inc_dev: Vec<u32>,
    /// Weights + optimizer bytes per layer.
    bytes: Vec<f64>,
    bw: f64,
    horizon: f64,
}

impl MigScorer {
    fn new(profile: &ProfiledData, inc: &Incumbent, cfg: MigrationCfg) -> MigScorer {
        assert!(cfg.bw > 0.0 && cfg.horizon_steps > 0.0, "migration cfg must be positive");
        let n = profile.n_layers();
        let mut inc_dev = vec![0u32; n];
        for s in 0..inc.partition.n_stages() {
            let d = inc.placement.device_of[s] as u32;
            for l in inc.partition.stage_range(s) {
                inc_dev[l] = d;
            }
        }
        let bytes = (0..n).map(|l| layer_migration_bytes(profile, l)).collect();
        MigScorer { inc_dev, bytes, bw: cfg.bw, horizon: cfg.horizon_steps }
    }

    /// One-off seconds to ship every layer that changes owner (0.0 —
    /// exactly — when nothing moves, so the incumbent itself is never
    /// penalized).
    fn switch_seconds(&self, part: &Partition, plac: &Placement) -> f64 {
        let mut total = 0.0;
        for s in 0..part.n_stages() {
            let d = plac.device_of[s] as u32;
            for l in part.stage_range(s) {
                if self.inc_dev[l] != d {
                    total += self.bytes[l];
                }
            }
        }
        total / self.bw
    }

    /// Amortized per-step objective penalty (≥ 0, so adding it to an
    /// analytic lower bound keeps the bound sound).
    fn penalty(&self, part: &Partition, plac: &Placement) -> f64 {
        self.switch_seconds(part, plac) / self.horizon
    }
}

/// One entry of the tuning log (drives the Fig 3 storyline).
#[derive(Clone, Debug)]
pub struct GenLogEntry {
    pub iter: usize,
    pub phase: &'static str,
    pub action: String,
    pub total: f64,
}

/// Generator output.
pub struct GenResult {
    pub pipeline: Pipeline,
    pub report: PerfReport,
    pub knobs: SchedKnobs,
    pub iters: usize,
    /// Candidates fully evaluated (schedule built + simulated).
    pub evals: usize,
    /// Candidates skipped because their analytic lower bound already
    /// ruled them out (no schedule, no simulation).
    pub evals_pruned: usize,
    /// Candidates answered from the transposition table.
    pub evals_cached: usize,
    /// Full evaluations in which the steady-state collapse layer
    /// replayed at least one micro-batch round (subset of `evals`).
    pub evals_collapsed: usize,
    /// True iff [`GenOptions::time_budget_s`] ran out before the
    /// tuning loop converged (the result is still the best plan seen).
    pub budget_exhausted: bool,
    /// True iff [`GenOptions::cancel`] fired (explicitly or via its
    /// deadline) before the tuning loop converged — the result is
    /// still the best plan seen so far.
    pub cancelled: bool,
    /// Transposition-table traffic *during this search* (per-call
    /// delta, even when the cache is shared across re-plans).
    pub cache: CacheStats,
    /// One-off switch time from the incumbent to the chosen plan
    /// (0.0 without [`GenOptions::migration`], or when nothing moved).
    pub migration_s: f64,
    pub elapsed_s: f64,
    pub log: Vec<GenLogEntry>,
    /// Block-IR candidates fully evaluated (compiled + simulated;
    /// subset of `evals`, 0 unless [`GenOptions::block_search`]).
    pub block_evals: usize,
    /// [`BlockIr::family`] label of the winning candidate when the
    /// search settled on a block-synthesized schedule (`None` when the
    /// greedy knob schedules won, or with block search off).
    pub block_family: Option<String>,
}

impl GenResult {
    /// Package the chosen plan as the warm-start seed for the next
    /// re-generation.
    pub fn incumbent(&self) -> Incumbent {
        Incumbent {
            partition: self.pipeline.partition.clone(),
            placement: self.pipeline.placement.clone(),
            knobs: self.knobs,
        }
    }
}

/// Candidate = (partition, placement, knobs, optional block IR);
/// schedules are derived.  Components are `Arc`-shared: a move clones
/// only what it changes.  With `block` set the schedule comes from
/// [`BlockIr::compile`] instead of the greedy knob scheduler (the
/// knobs ride along untouched so knob moves can leave the family).
#[derive(Clone)]
struct Cand {
    part: Arc<Partition>,
    plac: Arc<Placement>,
    knobs: SchedKnobs,
    block: Option<Arc<BlockIr>>,
}

/// Score a block-IR candidate: compile over the table's stage→device
/// map, then run the reusable-arena engine.  `+inf` on compile
/// rejection, OOM or deadlock (Eq. 2), mirroring the greedy paths.
/// Shared verbatim by the serial evaluator and the pool workers, which
/// is what keeps pooled block scores bit-identical to serial ones.
pub(crate) fn block_score_in(
    arena: &mut SimArena,
    table: &StageTable,
    caps: &MemCaps,
    nmb: usize,
    block: &BlockIr,
    collapse: bool,
) -> (f64, bool) {
    let Ok((sch, _)) = block.compile_on(&table.device, table.p, nmb) else {
        return (f64::INFINITY, false);
    };
    let opts = EngineOpts { collapse, ..EngineOpts::default() };
    let (res, stats) = simulate_in_opts(arena, table, caps, &sch, opts);
    match res {
        Ok(rep) if !rep.oom => (rep.total, stats.fired),
        _ => (f64::INFINITY, false),
    }
}

/// A candidate bundled with its stage-cost table, ready to score.
struct Prepared {
    desc: String,
    cand: Cand,
    table: StageTable,
}

impl Prepared {
    fn fresh(
        profile: &ProfiledData,
        pool: &mut PrepPool,
        desc: String,
        cand: Cand,
    ) -> Prepared {
        let table = pool.build(profile, &cand.part, &cand.plac);
        Prepared { desc, cand, table }
    }
}

/// Score one candidate serially: step makespan, +inf on OOM / deadlock
/// (Eq. 2), plus whether the collapse layer fired.  Candidates
/// rejected by the feasibility lower bound never get a schedule built
/// — no simulation for plans no schedule could save.  (Parallel
/// batches route through [`pool::EvalPool`], which applies the
/// identical gate.)
fn eval_candidate(
    profile: &ProfiledData,
    caps: &MemCaps,
    nmb: usize,
    engine: EvalEngine,
    collapse: bool,
    prep: &Prepared,
    arena: &mut SimArena,
) -> (f64, bool) {
    if !fits_lower_bound(&prep.table, caps) {
        return (f64::INFINITY, false);
    }
    if let Some(block) = &prep.cand.block {
        return match engine {
            EvalEngine::Fast => {
                block_score_in(arena, &prep.table, caps, nmb, block, collapse)
            }
            EvalEngine::Reference => {
                let score = match block.compile(&prep.cand.plac, nmb) {
                    Ok(sch) => match simulate_reference_in(
                        profile,
                        caps,
                        &prep.cand.part,
                        &prep.cand.plac,
                        &sch,
                        false,
                    ) {
                        Ok(r) if !r.oom => r.total,
                        _ => f64::INFINITY,
                    },
                    Err(_) => f64::INFINITY,
                };
                (score, false)
            }
        };
    }
    match engine {
        EvalEngine::Fast => {
            if collapse {
                let (score, stats) =
                    fused_score_collapsed(&prep.table, caps, nmb, prep.cand.knobs, arena);
                (score, stats.fired)
            } else {
                (fused_score(&prep.table, caps, nmb, prep.cand.knobs, arena), false)
            }
        }
        EvalEngine::Reference => {
            let sch = greedy_schedule_in(arena, &prep.table, caps, nmb, prep.cand.knobs);
            let score = match simulate_reference_in(
                profile,
                caps,
                &prep.cand.part,
                &prep.cand.plac,
                &sch,
                false,
            ) {
                Ok(r) if !r.oom => r.total,
                Ok(_) => f64::INFINITY,
                Err(_) => f64::INFINITY,
            };
            (score, false)
        }
    }
}

struct Evaluator<'a> {
    profile: &'a ProfiledData,
    caps: &'a MemCaps,
    nmb: usize,
    engine: EvalEngine,
    prune: bool,
    memoize: bool,
    collapse: bool,
    evals: usize,
    evals_pruned: usize,
    evals_cached: usize,
    evals_collapsed: usize,
    /// Block-IR candidates among `evals`.
    block_evals: usize,
    arena: SimArena,
    scratch: BoundScratch,
    /// Caller-owned transposition table (persists across re-plans; the
    /// plain [`generate`] hands in a fresh one).
    cache: &'a mut EvalCache,
    /// Migration pricer (only under warm-started re-generation).
    mig: Option<MigScorer>,
    /// This search's handle into an evaluation pool, opened lazily on
    /// the first batch large enough to amortise dispatch and reused
    /// for the whole search.
    client: Option<PoolClient>,
    /// Process-wide pool shared across searches
    /// ([`GenOptions::shared_pool`]); when absent a private pool is
    /// spawned lazily instead.
    shared: Option<Arc<EvalPool>>,
    own_pool: Option<EvalPool>,
    threads: usize,
    // Per-batch bookkeeping, reused across batches.
    need: Vec<usize>,
    keys: Vec<Option<CandKey>>,
    /// Per-batch migration penalties (empty when `mig` is off — the
    /// scoring loop then adds exact zeros nowhere).
    migs: Vec<f64>,
}

impl<'a> Evaluator<'a> {
    fn new(
        profile: &'a ProfiledData,
        caps: &'a MemCaps,
        opts: &GenOptions,
        cache: &'a mut EvalCache,
        mig: Option<MigScorer>,
    ) -> Self {
        Evaluator {
            profile,
            caps,
            nmb: opts.nmb,
            engine: opts.engine,
            prune: opts.prune_bounds,
            memoize: opts.memoize,
            collapse: opts.collapse,
            evals: 0,
            evals_pruned: 0,
            evals_cached: 0,
            evals_collapsed: 0,
            block_evals: 0,
            arena: SimArena::new(),
            scratch: BoundScratch::default(),
            cache,
            mig,
            client: None,
            shared: opts.shared_pool.clone(),
            own_pool: None,
            threads: std::thread::available_parallelism().map(|v| v.get()).unwrap_or(1),
            need: Vec::new(),
            keys: Vec::new(),
            migs: Vec::new(),
        }
    }

    /// Score a whole move batch against the incumbent `best`.  Output
    /// order is the input order whatever elides or parallelises, so
    /// downstream `(score, index)` selection is deterministic and
    /// identical to a serial, elision-free run.  Pruned candidates
    /// score `+inf` (their true score provably cannot be accepted).
    ///
    /// Under a migration pricer the objective is `makespan + penalty`;
    /// the cache stores the *raw* makespan (independent of which plan
    /// happens to be incumbent, so entries stay valid across re-plans)
    /// and the penalty is added on the way out.  The penalty is ≥ 0,
    /// so `bound + penalty` is a sound lower bound on the objective
    /// and pruning still cannot change the argmin.
    fn scores(&mut self, batch: &mut [Prepared], best: f64) -> Vec<f64> {
        let n = batch.len();
        let mut out = vec![f64::INFINITY; n];
        self.need.clear();
        self.keys.clear();
        self.keys.resize_with(n, || None);
        self.migs.clear();
        if let Some(m) = &self.mig {
            self.migs
                .extend(batch.iter().map(|prep| m.penalty(&prep.cand.part, &prep.cand.plac)));
        }
        for (i, prep) in batch.iter().enumerate() {
            let mig_i = self.migs.get(i).copied().unwrap_or(0.0);
            // Block-IR candidates skip bound pruning: the makespan
            // bound is documented only over greedy list-scheduler
            // outputs, and a compiled block schedule is not one.  The
            // schedule-independent `fits_lower_bound` gate still runs
            // inside the eval itself.
            if self.prune && prep.cand.block.is_none() {
                let bound = makespan_lower_bound_in(
                    &mut self.scratch,
                    &prep.table,
                    self.caps,
                    self.nmb,
                    prep.cand.knobs.split_bw,
                    prep.cand.knobs.overlap_aware,
                );
                // Acceptance needs score < best − ε and score ≥ bound,
                // so bound ≥ best − ε proves the eval cannot matter.
                if bound + mig_i >= best - ACCEPT_EPS {
                    self.evals_pruned += 1;
                    continue;
                }
            }
            if self.memoize {
                let key = CandKey::of_cand(
                    &prep.cand.part,
                    &prep.cand.plac,
                    prep.cand.knobs,
                    prep.cand.block.as_deref(),
                );
                if let Some(score) = self.cache.get(&key) {
                    self.evals_cached += 1;
                    out[i] = score + mig_i;
                    continue;
                }
                self.keys[i] = Some(key);
            }
            self.need.push(i);
        }
        self.evals += self.need.len();
        self.block_evals +=
            self.need.iter().filter(|&&i| batch[i].cand.block.is_some()).count();

        // Dispatch heuristic: fan out only when the batch carries
        // enough simulated ops to amortise channel round-trips; the
        // serial loop (reused arena) wins otherwise.  Same results
        // either way.
        let work_per_eval =
            batch.first().map_or(0, |prep| prep.table.n_stages * self.nmb);
        let pool_threads = self.shared.as_ref().map_or(self.threads, |p| p.threads());
        let use_pool = self.engine == EvalEngine::Fast
            && pool_threads > 1
            && self.need.len() >= 4
            && work_per_eval >= 256;
        if use_pool {
            if self.client.is_none() {
                let ctx = EvalCtx {
                    caps: self.caps.clone(),
                    nmb: self.nmb,
                    collapse: self.collapse,
                };
                let pool = match &self.shared {
                    Some(p) => p.as_ref(),
                    None => {
                        if self.own_pool.is_none() {
                            self.own_pool = Some(EvalPool::new(self.threads));
                        }
                        self.own_pool.as_ref().expect("just created")
                    }
                };
                self.client = Some(pool.client(ctx));
            }
            let client = self.client.as_ref().expect("just created");
            for &i in &self.need {
                let table = std::mem::take(&mut batch[i].table);
                client.submit(Job {
                    idx: i,
                    table,
                    knobs: batch[i].cand.knobs,
                    block: batch[i].cand.block.clone(),
                });
            }
            for _ in 0..self.need.len() {
                // A lost evaluation (worker thread died → NaN sentinel
                // from its guard, or the pool itself vanished) aborts
                // this search with a *typed* panic payload: the
                // planner service catches it and fails exactly one
                // request (`ServiceError::WorkerLost`); direct callers
                // observe a panic, as the old assert gave them.
                let done = match client.collect() {
                    Ok(done) if !done.score.is_nan() => done,
                    Ok(_) | Err(_) => std::panic::panic_any(pool::EvalAborted),
                };
                out[done.idx] = done.score;
                self.evals_collapsed += usize::from(done.collapsed);
                batch[done.idx].table = done.table;
            }
        } else {
            for &i in &self.need {
                let (score, collapsed) = eval_candidate(
                    self.profile,
                    self.caps,
                    self.nmb,
                    self.engine,
                    self.collapse,
                    &batch[i],
                    &mut self.arena,
                );
                out[i] = score;
                self.evals_collapsed += usize::from(collapsed);
            }
        }
        if self.memoize {
            // Raw makespans — see the method docs.
            for &i in &self.need {
                if let Some(key) = self.keys[i].take() {
                    self.cache.insert(key, out[i]);
                }
            }
        }
        if !self.migs.is_empty() {
            for &i in &self.need {
                out[i] += self.migs[i];
            }
        }
        out
    }

    /// Full report for the current pipeline (bottleneck attribution).
    fn report(&mut self, cand: &Cand, table: &StageTable) -> Option<PerfReport> {
        self.evals += 1;
        if let Some(block) = &cand.block {
            self.block_evals += 1;
            let Ok((sch, _)) = block.compile_on(&table.device, table.p, self.nmb) else {
                return None;
            };
            return match self.engine {
                EvalEngine::Fast => {
                    simulate_in(&mut self.arena, table, self.caps, &sch, false).ok()
                }
                EvalEngine::Reference => simulate_reference_in(
                    self.profile,
                    self.caps,
                    &cand.part,
                    &cand.plac,
                    &sch,
                    false,
                )
                .ok(),
            };
        }
        match self.engine {
            EvalEngine::Fast => Some(fused_eval(
                table,
                self.caps,
                self.nmb,
                cand.knobs,
                &mut self.arena,
                None,
            )),
            EvalEngine::Reference => {
                let sch =
                    greedy_schedule_in(&mut self.arena, table, self.caps, self.nmb, cand.knobs);
                simulate_reference_in(
                    self.profile,
                    self.caps,
                    &cand.part,
                    &cand.plac,
                    &sch,
                    false,
                )
                .ok()
            }
        }
    }
}

/// Evaluation-context fingerprint for [`EvalCache::retarget`]: FNV-1a
/// over everything a cached score depends on besides the candidate's
/// own structure (profile bits, caps, `nmb`, `p`, engine, rates).
/// Search-shape knobs (`max_iters`, phases, budget, incumbent,
/// migration) deliberately excluded — they change which candidates get
/// scored, never what a candidate scores.
fn search_fingerprint(profile: &ProfiledData, caps: &MemCaps, opts: &GenOptions) -> u64 {
    fn mix(h: &mut u64, x: u64) {
        *h ^= x;
        *h = h.wrapping_mul(0x100_0000_01b3);
    }
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    mix(&mut h, profile.n_layers() as u64);
    for c in &profile.layers {
        for v in [c.f, c.b, c.w, c.mem_static, c.mem_act, c.mem_act_w, c.comm_bytes] {
            mix(&mut h, v.to_bits());
        }
    }
    for v in [profile.link_latency, profile.link_bw, profile.mem_capacity] {
        mix(&mut h, v.to_bits());
    }
    for &c in caps.as_slice() {
        mix(&mut h, c.to_bits());
    }
    mix(&mut h, opts.nmb as u64);
    mix(&mut h, opts.p as u64);
    mix(&mut h, match opts.engine {
        EvalEngine::Fast => 1,
        EvalEngine::Reference => 2,
    });
    match &opts.rates {
        Some(r) => {
            mix(&mut h, r.len() as u64 + 1);
            for &x in r {
                mix(&mut h, x.to_bits());
            }
        }
        None => mix(&mut h, 0),
    }
    h
}

/// Run the Pipeline Generator (one-shot: a fresh transposition table
/// per call — the elastic loop uses [`generate_with_cache`]).
pub fn generate(profile: &ProfiledData, opts: &GenOptions) -> GenResult {
    generate_with_cache(profile, opts, &mut EvalCache::new())
}

/// [`generate`] against a caller-owned [`EvalCache`] that persists
/// across calls.  The cache is retargeted to this call's evaluation
/// context first (entries survive iff the context is unchanged), which
/// is what makes a warm re-plan — same profile, same rates, incumbent
/// seed — answer most of its candidates from the table instead of the
/// simulator.  [`GenResult::cache`] reports this call's traffic.
pub fn generate_with_cache(
    profile: &ProfiledData,
    opts: &GenOptions,
    cache: &mut EvalCache,
) -> GenResult {
    let t0 = Instant::now();
    let n_layers = profile.n_layers();
    let p = opts.p;
    let caps = opts
        .mem_caps
        .clone()
        .unwrap_or_else(|| MemCaps::uniform(p, profile.mem_capacity));
    assert_eq!(caps.p(), p, "mem_caps must cover every pipeline device");
    let rates: &[f64] = opts.rates.as_deref().unwrap_or(&[]);
    if !rates.is_empty() {
        assert_eq!(rates.len(), p, "one compute rate per device");
        if rates.iter().any(|&r| r != 1.0) {
            assert_eq!(
                opts.engine,
                EvalEngine::Fast,
                "per-device rates need the Fast engine (Reference prices from the profile)"
            );
        }
    }
    cache.retarget(search_fingerprint(profile, &caps, opts));
    let stats0 = cache.stats();
    let mig = match (&opts.incumbent, opts.migration) {
        (Some(inc), Some(cfg)) => {
            assert_eq!(inc.placement.p, p, "incumbent must cover the same devices");
            assert_eq!(inc.partition.n_layers(), n_layers);
            Some(MigScorer::new(profile, inc, cfg))
        }
        _ => None,
    };
    let mut ev = Evaluator::new(profile, &caps, opts, cache, mig);
    let mut prep_pool = PrepPool::with_rates(rates.to_vec());
    let mut log = Vec::new();

    // ---- Seed selection --------------------------------------------------
    let knobs_1f1b = SchedKnobs {
        split_bw: false,
        w_fill: false,
        mem_cap_factor: 1.0,
        overlap_aware: false,
    };
    let knobs_zb = SchedKnobs {
        split_bw: true,
        w_fill: true,
        mem_cap_factor: 1.0,
        overlap_aware: false,
    };
    let mut seeds: Vec<Prepared> = Vec::new();
    if let Some(inc) = &opts.incumbent {
        // Warm start: the running plan replaces the whole seed grid.
        // Near a good optimum the tuning loop re-proposes mostly
        // already-cached moves and converges in a few evaluations; the
        // grid's diversity is recovered by placement moves (which
        // regenerate the interleave/wave layouts) if the incumbent has
        // drifted far from optimal.
        assert_eq!(inc.placement.p, p, "incumbent must cover the same devices");
        assert_eq!(inc.partition.n_layers(), n_layers, "incumbent must cover every layer");
        seeds.push(Prepared::fresh(
            profile,
            &mut prep_pool,
            "incumbent seed".into(),
            Cand {
                part: Arc::new(inc.partition.clone()),
                plac: Arc::new(inc.placement.clone()),
                knobs: inc.knobs,
                block: None,
            },
        ));
    } else if opts.seed_s1f1b_only {
        seeds.push(Prepared::fresh(
            profile,
            &mut prep_pool,
            "S-1F1B seed".into(),
            Cand {
                part: Arc::new(uniform(n_layers, p)),
                plac: Arc::new(sequential(p)),
                knobs: knobs_1f1b,
                block: None,
            },
        ));
    } else {
        let parts: Vec<Partition> = vec![uniform(n_layers, p), balanced(profile, p)];
        for part_seed in &parts {
            for plac in [sequential(p), interleaved(p, 2), wave(p, 2)] {
                let s_n = plac.n_stages();
                let part = if s_n == part_seed.n_stages() {
                    part_seed.clone()
                } else {
                    let refined = refine_partition(profile, part_seed, s_n / p);
                    if refined.n_stages() == s_n {
                        refined
                    } else {
                        // A 1-layer stage could not split; re-balance
                        // globally for the finer stage count.
                        balanced(profile, s_n)
                    }
                };
                let (part, plac) = (Arc::new(part), Arc::new(plac));
                for knobs in [knobs_1f1b, knobs_zb] {
                    seeds.push(Prepared::fresh(
                        profile,
                        &mut prep_pool,
                        "seed".into(),
                        Cand {
                            part: Arc::clone(&part),
                            plac: Arc::clone(&plac),
                            knobs,
                            block: None,
                        },
                    ));
                }
            }
        }
    }

    // Memory pressure: when a standard seed already fails the
    // feasibility lower bound under the caps, add memory-balanced
    // seeds — the throughput-balanced splits concentrate the heavy
    // embedding/head memory exactly where a tight cap rejects it.
    // With slack caps the seed set (and the search) is unchanged.
    if caps.bounded() && seeds.iter().any(|s| !fits_lower_bound(&s.table, &caps)) {
        let part = Arc::new(memory_balanced(profile, p));
        let plac = Arc::new(sequential(p));
        for knobs in [knobs_1f1b, knobs_zb] {
            seeds.push(Prepared::fresh(
                profile,
                &mut prep_pool,
                "memory-balanced seed".into(),
                Cand {
                    part: Arc::clone(&part),
                    plac: Arc::clone(&plac),
                    knobs,
                    block: None,
                },
            ));
        }
    }

    let seed_scores = ev.scores(&mut seeds, f64::INFINITY);
    let mut best_i = 0usize;
    for (i, &sc) in seed_scores.iter().enumerate() {
        if sc < seed_scores[best_i] {
            best_i = i;
        }
    }
    let mut best_score = seed_scores[best_i];
    let chosen = seeds.swap_remove(best_i);
    for s in seeds {
        prep_pool.recycle(s.table);
    }
    let mut cur = chosen.cand;
    let mut cur_table = chosen.table;
    log.push(GenLogEntry {
        iter: 0,
        phase: "seed",
        action: format!(
            "S={} v={} split={} seed selected",
            cur.part.n_stages(),
            cur.plac.n_stages() / p,
            cur.knobs.split_bw
        ),
        total: best_score,
    });

    // ---- Bottleneck-phase tuning loop ------------------------------------
    // Wall-clock budget and cooperative cancellation: both checked at
    // iteration and phase boundaries (the granularity of one move
    // batch), never mid-batch — so a budgeted/cancelled run's prefix
    // is identical to the unbounded run's.
    let over_budget = || opts.time_budget_s.is_some_and(|b| t0.elapsed().as_secs_f64() >= b);
    let cancel_fired = || opts.cancel.as_ref().is_some_and(CancelToken::is_cancelled);
    let mut budget_exhausted = false;
    let mut cancelled = false;
    let mut cur_report = ev.report(&cur, &cur_table);
    let mut iter = 0;
    'tuning: while iter < opts.max_iters {
        let (ob, cc) = (over_budget(), cancel_fired());
        if ob || cc {
            budget_exhausted = ob;
            cancelled = cc;
            break 'tuning;
        }
        iter += 1;
        let mut improved = false;

        // Phase order: blame the phase with the strongest signal first.
        for phase in phase_order(cur_report.as_ref(), opts) {
            let (ob, cc) = (over_budget(), cancel_fired());
            if ob || cc {
                budget_exhausted = ob;
                cancelled = cc;
                break 'tuning;
            }
            let mut moves: Vec<Prepared> = match phase {
                "partition" => partition_moves(
                    profile,
                    &mut prep_pool,
                    &cur,
                    &cur_table,
                    cur_report.as_ref(),
                ),
                "placement" => placement_moves(profile, &mut prep_pool, &cur, opts),
                "schedule" => schedule_moves(&mut prep_pool, &cur, &cur_table),
                "block" => searchspace::block_moves(
                    profile,
                    &mut prep_pool,
                    &cur,
                    &cur_table,
                    opts,
                ),
                _ => unreachable!(),
            };
            // Memory-violating moves are pruned by the same feasibility
            // lower bound (folded into the analytic bound, and applied
            // again before any simulation), so one gate serves seeds
            // and move batches alike.
            let scores = ev.scores(&mut moves, best_score);
            let mut best_move: Option<(f64, usize)> = None;
            for (i, &score) in scores.iter().enumerate() {
                if score < best_score - ACCEPT_EPS
                    && best_move.is_none_or(|(b, _)| score < b)
                {
                    best_move = Some((score, i));
                }
            }
            match best_move {
                Some((score, i)) => {
                    let Prepared { desc, cand, table } = moves.swap_remove(i);
                    for m in moves {
                        prep_pool.recycle(m.table);
                    }
                    best_score = score;
                    cur = cand;
                    prep_pool.recycle(std::mem::replace(&mut cur_table, table));
                    log.push(GenLogEntry { iter, phase, action: desc, total: score });
                    cur_report = ev.report(&cur, &cur_table);
                    improved = true;
                    break; // re-assess bottleneck from the new pipeline
                }
                None => {
                    // Roll back (nothing kept) and try the next phase.
                    for m in moves {
                        prep_pool.recycle(m.table);
                    }
                }
            }
        }

        if !improved {
            break;
        }
    }

    // Final artifacts (evaluated under the same caps and rates as the
    // search, so the reported OOM/headroom/makespan matches what the
    // generator optimized; with no rates this is the plain table).
    let final_table = StageTable::build_rated(profile, &cur.part, &cur.plac, rates);
    let mut arena = SimArena::new();
    let block_family = cur.block.as_ref().map(|b| b.family());
    let mut schedule = match &cur.block {
        Some(block) => {
            block
                .compile_on(&final_table.device, final_table.p, opts.nmb)
                .expect("accepted block must compile on its own placement")
                .0
        }
        None => greedy_schedule_in(&mut arena, &final_table, &caps, opts.nmb, cur.knobs),
    };
    let mut report = simulate_in(&mut arena, &final_table, &caps, &schedule, false)
        .expect("final pipeline must simulate");
    // OOM repair (Eq. 2): under a binding cap the list scheduler's
    // overlimit fallback can overshoot its activation budget (it admits
    // an over-budget F when nothing else can make progress).  Tighten
    // the budget factor geometrically — F's are deferred earlier,
    // trading bubbles for memory — and keep the first feasible result.
    // A block schedule has no budget factor; shrink its warmup depth
    // (offsets, lag, fixed stash) instead — same trade, same knob
    // direction, expressed in the block's own parameters.
    if report.oom && caps.bounded() {
        if let Some(block) = cur.block.as_deref() {
            let mut block = block.clone();
            for _ in 0..8 {
                let saturated = block.offsets.iter().all(|&o| o == 0)
                    && block.lag.iter().all(|&l| l == 0);
                for o in &mut block.offsets {
                    *o /= 2;
                }
                for l in &mut block.lag {
                    *l /= 2;
                }
                if let StashRule::Fixed(k) = &mut block.stash {
                    *k /= 2;
                }
                let Ok((sch, _)) =
                    block.compile_on(&final_table.device, final_table.p, opts.nmb)
                else {
                    break;
                };
                let Ok(rep) = simulate_in(&mut arena, &final_table, &caps, &sch, false)
                else {
                    break;
                };
                if !rep.oom {
                    log.push(GenLogEntry {
                        iter,
                        phase: "repair",
                        action: "shrink block warmup".into(),
                        total: rep.total,
                    });
                    schedule = sch;
                    report = rep;
                    break;
                }
                if saturated {
                    break; // fully drained the block's memory knobs
                }
            }
        } else {
            let mut knobs = cur.knobs;
            for _ in 0..8 {
                knobs.mem_cap_factor *= 0.5;
                let sch =
                    greedy_schedule_in(&mut arena, &final_table, &caps, opts.nmb, knobs);
                let rep = simulate_in(&mut arena, &final_table, &caps, &sch, false)
                    .expect("repaired pipeline must simulate");
                if !rep.oom {
                    log.push(GenLogEntry {
                        iter,
                        phase: "repair",
                        action: format!("tighten memory ×{:.4}", knobs.mem_cap_factor),
                        total: rep.total,
                    });
                    schedule = sch;
                    report = rep;
                    cur.knobs = knobs;
                    break;
                }
            }
        }
    }
    let migration_s = ev.mig.as_ref().map_or(0.0, |m| m.switch_seconds(&cur.part, &cur.plac));
    GenResult {
        pipeline: Pipeline {
            name: "AdaPtis".into(),
            partition: Arc::unwrap_or_clone(cur.part),
            placement: Arc::unwrap_or_clone(cur.plac),
            schedule,
        },
        report,
        knobs: cur.knobs,
        iters: iter,
        evals: ev.evals,
        evals_pruned: ev.evals_pruned,
        evals_cached: ev.evals_cached,
        evals_collapsed: ev.evals_collapsed,
        block_evals: ev.block_evals,
        block_family,
        budget_exhausted,
        cancelled,
        cache: ev.cache.stats().since(&stats0),
        migration_s,
        elapsed_s: t0.elapsed().as_secs_f64(),
        log,
    }
}

/// Decide phase attempt order from bottleneck signals (paper: "identify
/// the bottleneck phase … and tune it accordingly").  `total_cmp` keeps
/// the ordering total even when a degenerate profile (zero-cost layers)
/// turns a blame ratio into NaN.
fn phase_order(report: Option<&PerfReport>, opts: &GenOptions) -> Vec<&'static str> {
    let mut order: Vec<(&'static str, f64)> = Vec::new();
    if let Some(r) = report {
        let max_busy = r.busy_d.iter().cloned().fold(0.0, f64::max);
        let min_busy = r.busy_d.iter().cloned().fold(f64::INFINITY, f64::min);
        let imbalance = (max_busy - min_busy) / r.total.max(1e-12);
        let bubble = r.bubble_ratio();
        if opts.phases.partition {
            order.push(("partition", imbalance));
        }
        if opts.phases.placement {
            // Placement helps when bubbles persist despite balance —
            // blame it by the residual bubble.
            order.push(("placement", (bubble - imbalance).max(0.0)));
        }
        if opts.phases.schedule {
            order.push(("schedule", bubble * 0.5));
        }
        if opts.block_search {
            // The fourth knob (§4.3 extension): swap the list scheduler
            // for a synthesized building block.  Blamed slightly below
            // the schedule phase so knob tuning gets first shot at a
            // bubble, but block synthesis still runs every iteration.
            order.push(("block", bubble * 0.45));
        }
    }
    order.sort_by(|a, b| b.1.total_cmp(&a.1));
    order.into_iter().map(|(n, _)| n).collect()
}

/// Partition tuning moves: all single-boundary shifts (stage tables
/// re-derived incrementally — only the two affected stages), plus a
/// steered multi-shift that moves one layer from the lowest-bubble
/// device toward the highest-bubble device (§4.3).
fn partition_moves(
    profile: &ProfiledData,
    pool: &mut PrepPool,
    cur: &Cand,
    cur_table: &StageTable,
    report: Option<&PerfReport>,
) -> Vec<Prepared> {
    let mut out = Vec::new();
    let s_n = cur.part.n_stages();
    for b in 0..s_n - 1 {
        for dir in [true, false] {
            let mut part = (*cur.part).clone();
            if part.shift_boundary(b, dir) {
                let mut table = pool.take_like(cur_table);
                table.update_boundary(profile, &part, b);
                out.push(Prepared {
                    desc: format!("shift boundary {b} {}", if dir { "←" } else { "→" }),
                    cand: Cand {
                        part: Arc::new(part),
                        plac: Arc::clone(&cur.plac),
                        knobs: cur.knobs,
                        block: cur.block.clone(),
                    },
                    table,
                });
            }
        }
    }
    // Steered flow: overloaded (low-bubble) device donates a layer to
    // the starved (high-bubble) device through the chain of boundaries.
    if let Some(r) = report {
        let donor = argmin(&r.bubble_d);
        let recv = argmax(&r.bubble_d);
        if donor != recv {
            let sd = cur.plac.stages_of(donor);
            let sr = cur.plac.stages_of(recv);
            if let (Some(&a), Some(&b)) = (sd.first(), sr.first()) {
                let (lo, hi, dir) = if a < b { (a, b, false) } else { (b, a, true) };
                let mut part = (*cur.part).clone();
                let mut ok = true;
                for k in lo..hi {
                    ok &= part.shift_boundary(k, dir);
                }
                if ok && part.is_valid() {
                    out.push(Prepared::fresh(
                        profile,
                        pool,
                        format!("flow layer dev{donor}→dev{recv}"),
                        Cand {
                            part: Arc::new(part),
                            plac: Arc::clone(&cur.plac),
                            knobs: cur.knobs,
                            block: cur.block.clone(),
                        },
                    ));
                }
            }
        }
    }
    out
}

/// Placement tuning moves: grouped permutations (finer interleaving /
/// wave layouts) and pairwise stage swaps.
fn placement_moves(
    profile: &ProfiledData,
    pool: &mut PrepPool,
    cur: &Cand,
    opts: &GenOptions,
) -> Vec<Prepared> {
    let p = cur.plac.p;
    let n_layers = profile.n_layers();
    let mut out = Vec::new();
    for v in 1..=opts.max_chunks {
        if p * v > n_layers {
            break;
        }
        for (name, plac) in [("interleave", interleaved(p, v)), ("wave", wave(p, v))] {
            if plac.device_of == cur.plac.device_of {
                continue;
            }
            let part = repartition_for(profile, p * v);
            out.push(Prepared::fresh(
                profile,
                pool,
                format!("{name} v={v}"),
                // A layout change invalidates a block tuned to the old
                // stage→device map — restart that knob from scratch.
                Cand {
                    part: Arc::new(part),
                    plac: Arc::new(plac),
                    knobs: cur.knobs,
                    block: None,
                },
            ));
            if v == 1 {
                break; // wave(p,1) == interleaved(p,1) == sequential
            }
        }
    }
    // Pairwise device swaps between consecutive stages.
    let s_n = cur.plac.n_stages();
    for s in 0..s_n.saturating_sub(1) {
        if cur.plac.device_of[s] != cur.plac.device_of[s + 1] {
            let mut plac = (*cur.plac).clone();
            plac.swap_stages(s, s + 1);
            if plac.is_valid() {
                out.push(Prepared::fresh(
                    profile,
                    pool,
                    format!("swap stages {s},{}", s + 1),
                    Cand {
                        part: Arc::clone(&cur.part),
                        plac: Arc::new(plac),
                        knobs: cur.knobs,
                        block: cur.block.clone(),
                    },
                ));
            }
        }
    }
    out
}

/// Scheduling tuning moves: knob grid around the current setting.  The
/// stage table is knob-independent, so the current one is reused
/// (recycled buffers, no partition/placement clones at all).
fn schedule_moves(pool: &mut PrepPool, cur: &Cand, cur_table: &StageTable) -> Vec<Prepared> {
    let k0 = cur.knobs;
    let variants = [
        ("split B/W", SchedKnobs { split_bw: !k0.split_bw, ..k0 }),
        ("toggle W-fill", SchedKnobs { w_fill: !k0.w_fill, ..k0 }),
        ("toggle overlap", SchedKnobs { overlap_aware: !k0.overlap_aware, ..k0 }),
        ("tighten memory", SchedKnobs { mem_cap_factor: k0.mem_cap_factor * 0.75, ..k0 }),
        (
            "relax memory",
            SchedKnobs { mem_cap_factor: (k0.mem_cap_factor * 1.25).min(1.0), ..k0 },
        ),
        (
            "zb-full",
            SchedKnobs {
                split_bw: true,
                w_fill: true,
                overlap_aware: true,
                mem_cap_factor: k0.mem_cap_factor,
            },
        ),
    ];
    variants
        .into_iter()
        .map(|(name, knobs)| Prepared {
            desc: name.to_string(),
            // Knob moves propose *leaving* the block family for the
            // greedy scheduler — the block phase proposes entering it.
            cand: Cand {
                part: Arc::clone(&cur.part),
                plac: Arc::clone(&cur.plac),
                knobs,
                block: None,
            },
            table: pool.take_like(cur_table),
        })
        .collect()
}

/// Split each stage of `part` into `g` compute-balanced sub-stages.
fn refine_partition(profile: &ProfiledData, part: &Partition, g: usize) -> Partition {
    if g <= 1 {
        return part.clone();
    }
    let mut sizes = Vec::new();
    for s in 0..part.n_stages() {
        let range = part.stage_range(s);
        let sub = balanced_range(profile, range.clone(), g.min(range.len()));
        sizes.extend(sub);
    }
    Partition::from_sizes(&sizes)
}

/// Re-balance the whole model into `s_n` stages (used when a placement
/// move changes the stage count).
fn repartition_for(profile: &ProfiledData, s_n: usize) -> Partition {
    balanced(profile, s_n)
}

/// Balance `range` into `g` contiguous chunks by fused compute weight.
fn balanced_range(
    profile: &ProfiledData,
    range: std::ops::Range<usize>,
    g: usize,
) -> Vec<usize> {
    let n = range.len();
    assert!(g >= 1 && g <= n);
    let w: Vec<f64> = range
        .clone()
        .map(|l| {
            let c = &profile.layers[l];
            c.f + c.b + c.w
        })
        .collect();
    let mut prefix = vec![0.0; n + 1];
    for i in 0..n {
        prefix[i + 1] = prefix[i] + w[i];
    }
    let total = prefix[n];
    // Cut after the layer where the prefix first reaches i/g of the
    // total, keeping each chunk non-empty and leaving room for the rest.
    let mut cuts = vec![0usize];
    for i in 1..g {
        let target = total * i as f64 / g as f64;
        let lo = cuts[i - 1] + 1; // ≥1 layer per chunk
        let hi = n - (g - i); // leave ≥1 layer per remaining chunk
        let mut c = lo;
        while c < hi && prefix[c] < target {
            c += 1;
        }
        cuts.push(c.clamp(lo, hi));
    }
    cuts.push(n);
    cuts.windows(2).map(|wd| wd[1] - wd[0]).collect()
}

/// NaN-total argmax: `total_cmp` orders +NaN above +inf, so degenerate
/// blame vectors (0/0 bubbles on zero-cost profiles) select an index
/// instead of panicking.
fn argmax(xs: &[f64]) -> usize {
    xs.iter()
        .enumerate()
        .max_by(|a, b| a.1.total_cmp(b.1))
        .map(|(i, _)| i)
        .unwrap_or(0)
}

fn argmin(xs: &[f64]) -> usize {
    xs.iter()
        .enumerate()
        .min_by(|a, b| a.1.total_cmp(b.1))
        .map(|(i, _)| i)
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::{build, Method};
    use crate::config::{Family, HardwareCfg, ModelCfg, ParallelCfg, Size};
    use crate::model::build_model;
    use crate::perfmodel::simulate;

    fn profile(fam: Family, p: usize, nmb: usize) -> ProfiledData {
        let spec = build_model(&ModelCfg::table5(fam, Size::Small));
        ProfiledData::analytical(
            &spec,
            &HardwareCfg::default(),
            &ParallelCfg::new(p, 2, nmb, 1, 4096),
        )
    }

    #[test]
    fn beats_all_baselines_on_heterogeneous_models() {
        for fam in [Family::Gemma, Family::DeepSeek, Family::NemotronH] {
            let prof = profile(fam, 4, 16);
            let res = generate(&prof, &GenOptions::new(4, 16));
            res.pipeline.schedule.validate(&res.pipeline.placement).unwrap();
            for m in Method::paper_baselines() {
                let b = build(m, &prof, 4, 16);
                let rb = simulate(&prof, &b.partition, &b.placement, &b.schedule, false)
                    .unwrap();
                assert!(
                    res.report.total <= rb.total * 1.001,
                    "{fam:?}: AdaPtis {:.4} !<= {} {:.4}",
                    res.report.total,
                    m.name(),
                    rb.total
                );
            }
        }
    }

    #[test]
    fn respects_phase_masks() {
        let prof = profile(Family::Gemma, 4, 8);
        let mut opts = GenOptions::new(4, 8);
        opts.phases = PhaseMask { partition: false, placement: false, schedule: true };
        opts.seed_s1f1b_only = true;
        let res = generate(&prof, &opts);
        // Partition must remain the uniform seed.
        assert_eq!(res.pipeline.partition, uniform(prof.n_layers(), 4));
        assert_eq!(res.pipeline.placement, sequential(4));
    }

    #[test]
    fn log_is_monotone_improving() {
        let prof = profile(Family::NemotronH, 4, 16);
        let res = generate(&prof, &GenOptions::new(4, 16));
        for w in res.log.windows(2) {
            assert!(w[1].total <= w[0].total + ACCEPT_EPS);
        }
        assert!(res.evals > 0 && res.elapsed_s >= 0.0);
    }

    #[test]
    fn refine_partition_preserves_layers() {
        let prof = profile(Family::Gemma, 4, 8);
        let part = uniform(prof.n_layers(), 4);
        let fine = refine_partition(&prof, &part, 2);
        assert_eq!(fine.n_layers(), part.n_layers());
        assert_eq!(fine.n_stages(), 8);
        assert!(fine.is_valid());
    }

    #[test]
    fn fast_and_reference_engines_agree() {
        // The fast engine (fused evals, pooled batches, incremental
        // stage tables) must reproduce the reference engine's search
        // bit-for-bit: same pipeline, same score, same eval counts —
        // including the pruned/cached elision counters, which depend
        // only on the (identical) search trajectory.
        for fam in [Family::Gemma, Family::NemotronH] {
            let prof = profile(fam, 4, 8);
            let mut fast_opts = GenOptions::new(4, 8);
            fast_opts.max_iters = 16;
            let mut ref_opts = fast_opts.clone();
            ref_opts.engine = EvalEngine::Reference;
            let a = generate(&prof, &fast_opts);
            let b = generate(&prof, &ref_opts);
            assert_eq!(a.report.total, b.report.total, "{fam:?}");
            assert_eq!(a.pipeline.partition, b.pipeline.partition, "{fam:?}");
            assert_eq!(a.pipeline.placement, b.pipeline.placement, "{fam:?}");
            assert_eq!(a.evals, b.evals, "{fam:?}");
            assert_eq!(a.evals_pruned, b.evals_pruned, "{fam:?}");
            assert_eq!(a.evals_cached, b.evals_cached, "{fam:?}");
            assert_eq!(a.iters, b.iters, "{fam:?}");
            assert_eq!(a.log.len(), b.log.len(), "{fam:?}");
        }
    }

    /// Tentpole pin (ISSUE 9): the fourth knob is strictly additive.
    /// With `block_search` off (the default) no block candidate is
    /// ever constructed — `Cand::block` stays `None` everywhere, so
    /// `CandKey::of_cand(.., None)` degenerates to the pre-refactor
    /// key and the deterministic engines walk the pre-refactor
    /// trajectory bit-for-bit.  Pinned across {Fast, Reference} ×
    /// {collapse on, off} via run-to-run bit-identity plus a zero
    /// block counter (one constructed block candidate would perturb
    /// `evals` and the phase log).
    #[test]
    fn block_search_off_is_bit_identical() {
        for engine in [EvalEngine::Fast, EvalEngine::Reference] {
            for collapse in [false, true] {
                let prof = profile(Family::Gemma, 4, 8);
                let mut opts = GenOptions::new(4, 8);
                opts.engine = engine;
                opts.collapse = collapse;
                opts.max_iters = 12;
                let a = generate(&prof, &opts);
                let b = generate(&prof, &opts);
                let tag = format!("{engine:?}/collapse={collapse}");
                assert_eq!(a.report.total, b.report.total, "{tag}");
                assert_eq!(a.pipeline.partition, b.pipeline.partition, "{tag}");
                assert_eq!(a.pipeline.placement, b.pipeline.placement, "{tag}");
                assert_eq!(a.evals, b.evals, "{tag}");
                assert_eq!(a.evals_pruned, b.evals_pruned, "{tag}");
                assert_eq!(a.evals_cached, b.evals_cached, "{tag}");
                assert_eq!(a.block_evals, 0, "{tag}: no block candidate with knob off");
                assert_eq!(a.block_family, None, "{tag}");
                assert!(
                    a.log.iter().all(|e| e.phase != "block"),
                    "{tag}: block phase must not be scheduled"
                );
            }
        }
    }

    #[test]
    fn block_search_evaluates_and_stays_deterministic() {
        // Knob on: block candidates are actually evaluated (counter
        // moves), the result is a valid pipeline, and the run is
        // deterministic.  When the V family wins, the family label is
        // surfaced.
        let prof = profile(Family::Gemma, 4, 8);
        let mut opts = GenOptions::new(4, 8).with_block_search();
        opts.max_iters = 12;
        let a = generate(&prof, &opts);
        let b = generate(&prof, &opts);
        assert!(a.block_evals > 0, "block candidates must be scored");
        assert_eq!(a.report.total, b.report.total);
        assert_eq!(a.block_evals, b.block_evals);
        assert_eq!(a.block_family, b.block_family);
        a.pipeline.schedule.validate(&a.pipeline.placement).unwrap();
        simulate(
            &prof,
            &a.pipeline.partition,
            &a.pipeline.placement,
            &a.pipeline.schedule,
            false,
        )
        .expect("chosen pipeline must run deadlock-free");
        if let Some(fam) = &a.block_family {
            assert!(!fam.is_empty());
        }
    }

    #[test]
    fn selection_helpers_are_nan_safe() {
        // +NaN orders above +inf under total_cmp: argmax lands on it,
        // argmin skips it — and neither panics (the old
        // `partial_cmp().unwrap()` did).
        assert_eq!(argmax(&[f64::NAN, 1.0, 2.0]), 0);
        assert_eq!(argmin(&[f64::NAN, 1.0, 2.0]), 1);
        assert_eq!(argmax(&[0.5, f64::INFINITY]), 1);
        assert_eq!(argmin(&[]), 0);
    }

    #[test]
    fn phase_order_survives_nan_blame() {
        let nan = f64::NAN;
        let report = PerfReport {
            total: nan,
            t_d: vec![nan; 2],
            busy_d: vec![nan; 2],
            bubble_d: vec![nan; 2],
            overlap_d: vec![0.0; 2],
            comm_block_d: vec![0.0; 2],
            m_d: vec![0.0; 2],
            static_d: vec![0.0; 2],
            headroom_d: vec![f64::INFINITY; 2],
            oom: false,
            events: Vec::new(),
        };
        let order = phase_order(Some(&report), &GenOptions::new(2, 2));
        assert_eq!(order.len(), 3, "all phases still ranked: {order:?}");
    }

    #[test]
    fn zero_cost_profile_does_not_panic() {
        // A degenerate profile (all-zero layer costs) produces 0/0
        // blame ratios; the search must still terminate with a valid
        // pipeline instead of panicking in a comparator.
        use crate::model::LayerCost;
        let zero = LayerCost {
            f: 0.0,
            b: 0.0,
            w: 0.0,
            mem_static: 0.0,
            mem_act: 0.0,
            mem_act_w: 0.0,
            comm_bytes: 0.0,
        };
        let prof = ProfiledData::from_measured(vec![zero; 8], 0.0, 1.0, 1e12);
        let mut opts = GenOptions::new(2, 2);
        opts.max_iters = 4;
        let res = generate(&prof, &opts);
        res.pipeline.schedule.validate(&res.pipeline.placement).unwrap();
        assert!(res.report.total >= 0.0);
    }

    #[test]
    fn time_budget_zero_returns_best_seed() {
        let prof = profile(Family::Gemma, 4, 8);
        let full = generate(&prof, &GenOptions::new(4, 8));
        assert!(!full.budget_exhausted);
        // A zero budget is spent before the first tuning iteration:
        // the best grid seed comes back, flagged, still valid.
        let budgeted = generate(&prof, &GenOptions::new(4, 8).with_time_budget(0.0));
        assert!(budgeted.budget_exhausted);
        assert_eq!(budgeted.iters, 0);
        budgeted.pipeline.schedule.validate(&budgeted.pipeline.placement).unwrap();
        assert!(budgeted.report.total >= full.report.total - ACCEPT_EPS);
    }

    #[test]
    fn cancel_token_cuts_like_a_budget_and_is_inert_otherwise() {
        let prof = profile(Family::Gemma, 4, 8);
        // Pre-fired token: spent before the first tuning iteration —
        // the best grid seed comes back, flagged as cancelled (not as
        // budget-exhausted), still valid.
        let token = CancelToken::new();
        token.cancel();
        let cut = generate(&prof, &GenOptions::new(4, 8).with_cancel(token));
        assert!(cut.cancelled && !cut.budget_exhausted);
        assert_eq!(cut.iters, 0);
        cut.pipeline.schedule.validate(&cut.pipeline.placement).unwrap();
        // A far-future deadline token never fires: the search is
        // bitwise-identical to one with no token at all.
        let far = CancelToken::with_deadline(
            Instant::now() + std::time::Duration::from_secs(3600),
        );
        let free = generate(&prof, &GenOptions::new(4, 8).with_cancel(far));
        let plain = generate(&prof, &GenOptions::new(4, 8));
        assert!(!free.cancelled && !free.budget_exhausted);
        assert_eq!(free.report.total, plain.report.total);
        assert_eq!(free.pipeline.partition, plain.pipeline.partition);
        assert_eq!(free.pipeline.placement, plain.pipeline.placement);
        assert_eq!(free.evals, plain.evals);
        assert_eq!(free.log.len(), plain.log.len());
    }

    #[test]
    fn warm_incumbent_replan_is_a_fraction_of_cold() {
        let prof = profile(Family::NemotronH, 4, 16);
        let mut cache = EvalCache::new();
        let cold = generate_with_cache(&prof, &GenOptions::new(4, 16), &mut cache);
        assert!(cold.cache.misses > 0, "a cold search must miss");
        assert_eq!(cold.cache.hits, cold.evals_cached as u64, "hits = within-search reuse");
        let warm_opts =
            GenOptions::new(4, 16).with_incumbent(cold.incumbent(), MigrationCfg::default());
        let warm = generate_with_cache(&prof, &warm_opts, &mut cache);
        // Same evaluation context: the cold search's scores survived
        // retargeting, so the warm re-plan answers its seed and most
        // re-proposed moves from the table instead of the simulator.
        assert!(warm.cache.hits > 0, "warm re-plan must hit the shared cache");
        assert!(
            warm.evals * 4 <= cold.evals,
            "warm start should eval a small fraction: warm {} vs cold {}",
            warm.evals,
            cold.evals
        );
        // And it can never end up worse than the plan it started from.
        assert!(warm.report.total <= cold.report.total + 1e-9);
    }

    #[test]
    fn harsh_migration_pins_the_incumbent() {
        let prof = profile(Family::Gemma, 4, 16);
        // Deliberately bad incumbent: the static S-1F1B pipeline.
        let inc = Incumbent {
            partition: uniform(prof.n_layers(), 4),
            placement: sequential(4),
            knobs: SchedKnobs::default(),
        };
        // Near-zero amortization horizon: any layer move is charged
        // (nearly) its full switch time every step, so no relocation
        // can pay for itself.  Knob tuning moves nothing and stays
        // free, so partition/placement — not knobs — must be pinned.
        let harsh = MigrationCfg { bw: 25e9, horizon_steps: 1e-9 };
        let pinned =
            generate(&prof, &GenOptions::new(4, 16).with_incumbent(inc.clone(), harsh));
        assert_eq!(pinned.pipeline.partition, inc.partition);
        assert_eq!(pinned.pipeline.placement, inc.placement);
        assert_eq!(pinned.migration_s, 0.0);
        // A generous horizon frees the search to move layers again —
        // monotone improvement from the incumbent seed, and the switch
        // time is priced into the result.
        let free = generate(
            &prof,
            &GenOptions::new(4, 16)
                .with_incumbent(inc, MigrationCfg { bw: 25e9, horizon_steps: 1e12 }),
        );
        assert!(free.report.total <= free.log[0].total + 1e-9);
        if free.pipeline.partition != uniform(prof.n_layers(), 4) {
            assert!(free.migration_s > 0.0);
        }
    }

    #[test]
    fn unit_rates_reproduce_the_plain_search_bitwise() {
        let prof = profile(Family::Gemma, 4, 8);
        let plain = generate(&prof, &GenOptions::new(4, 8));
        let rated = generate(&prof, &GenOptions::new(4, 8).with_rates(vec![1.0; 4]));
        assert_eq!(plain.report.total, rated.report.total);
        assert_eq!(plain.pipeline.partition, rated.pipeline.partition);
        assert_eq!(plain.pipeline.placement, rated.pipeline.placement);
        assert_eq!(plain.evals, rated.evals);
        assert_eq!(plain.evals_pruned, rated.evals_pruned);
        assert_eq!(plain.evals_cached, rated.evals_cached);
        assert_eq!(plain.cache.misses, rated.cache.misses);
        assert_eq!(plain.migration_s, 0.0);
    }

    #[test]
    fn rates_price_a_degraded_cluster() {
        let prof = profile(Family::Gemma, 4, 16);
        let healthy = generate(&prof, &GenOptions::new(4, 16));
        let degraded =
            generate(&prof, &GenOptions::new(4, 16).with_rates(vec![1.0, 1.0, 1.0, 3.0]));
        // A 3× slower device makes the best achievable step slower
        // (its remaining work is inflated; the others absorb the rest).
        assert!(degraded.report.total > healthy.report.total);
        // And the search never loads the throttled device *more* than
        // the healthy search did.
        let layers_on = |res: &GenResult, d: usize| {
            let part = &res.pipeline.partition;
            let plac = &res.pipeline.placement;
            (0..part.n_stages())
                .filter(|&s| plac.device_of[s] == d)
                .map(|s| part.stage_range(s).len())
                .sum::<usize>()
        };
        assert!(layers_on(&degraded, 3) <= layers_on(&healthy, 3));
    }
}
