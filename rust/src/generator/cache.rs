//! Candidate memoization — the Pipeline Generator's transposition
//! table (DESIGN.md § Search acceleration).
//!
//! The tuning loop regenerates large parts of its move batch every
//! iteration: partition phases always propose the *undo* of the last
//! accepted shift, the knob grid re-proposes settings the search has
//! already visited, and rejected placement swaps come back verbatim
//! until the current pipeline changes.  Every candidate's score is a
//! pure function of `(partition boundaries, placement map, knobs)`
//! given a fixed `(profile, caps, nmb)` — both engines are
//! deterministic and bit-identical (pinned by the differential suites)
//! — so re-simulating a structurally identical candidate can only
//! reproduce the number already computed.  [`EvalCache`] stores that
//! number keyed by the full structural identity ([`CandKey`], exact
//! equality — hash collisions fall back to `Eq`, never to a wrong
//! score), which is what makes cache hits *provably* unable to change
//! the search trajectory.
//!
//! The cache is **bounded** (default [`EvalCache::DEFAULT_CAPACITY`])
//! with FIFO eviction: a long-lived elastic re-planning loop keeps one
//! cache across hundreds of `generate()` calls, so unbounded growth
//! would be a leak.  Eviction order is insertion order (a `VecDeque`
//! of keys), *never* hash-map iteration order — the engine-agreement
//! tests compare hit counters across runs, so eviction must be
//! deterministic.  Hit/miss/evict counters ([`CacheStats`]) are
//! surfaced per search in `GenResult`.
//!
//! Scores are only valid for the exact evaluation context — profile
//! bits, caps, `nmb`, engine, per-device rates.  A caller-owned cache
//! carried across re-plans declares its context via
//! [`EvalCache::retarget`] (a fingerprint computed by
//! `generator::generate_with_cache`): same fingerprint ⇒ entries
//! survive (the warm re-plan fast path), any change ⇒ the cache clears
//! itself rather than replay stale scores.
//!
//! [`PrepPool`] is the allocation side of the same story: move batches
//! used to clone a fresh `StageTable` (a dozen `Vec`s) per candidate
//! and drop them all at the end of the phase.  The pool recycles the
//! tables instead — `clone_from`/`rebuild` overwrite every entry in
//! place, so a recycled table is bit-identical to a fresh one while
//! steady-state candidate construction allocates nothing.  A pool
//! seeded with per-device rates ([`PrepPool::with_rates`]) builds every
//! candidate table rated, which is how the re-planner prices the whole
//! search under the monitor's drift estimates.

use std::collections::{HashMap, VecDeque};

use crate::partition::Partition;
use crate::placement::Placement;
use crate::perfmodel::StageTable;
use crate::profile::ProfiledData;
use crate::schedule::block::BlockIr;
use crate::schedule::greedy::SchedKnobs;

/// Structural identity of a candidate: everything the (deterministic)
/// evaluation reads besides the per-search constants.  Exact — two
/// keys compare equal iff the candidates are evaluation-equivalent.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct CandKey {
    /// Partition stage bounds (layer offsets).
    bounds: Vec<u32>,
    /// Placement stage → device map.
    device_of: Vec<u16>,
    /// Boolean knobs packed: bit 0 `split_bw`, bit 1 `w_fill`,
    /// bit 2 `overlap_aware`.
    knob_bits: u8,
    /// `mem_cap_factor`, compared bitwise (the knob grid only ever
    /// produces it by deterministic arithmetic, so bitwise identity is
    /// the right equivalence).
    mem_cap_bits: u64,
    /// Block-IR parameter words ([`BlockIr::key_bits`]); empty for
    /// greedy-scheduled candidates.  Folding the block into the
    /// structural identity is what keeps candidates that differ *only*
    /// in block parameters from ever sharing a cached score — `key_bits`
    /// is injective over the IR, and the empty vector is unreachable
    /// from it, so greedy and block candidates can never alias either.
    block_bits: Vec<u32>,
}

impl CandKey {
    pub fn of(part: &Partition, plac: &Placement, knobs: SchedKnobs) -> CandKey {
        CandKey::of_cand(part, plac, knobs, None)
    }

    /// Full structural identity including the optional block IR (the
    /// fourth search knob).
    pub fn of_cand(
        part: &Partition,
        plac: &Placement,
        knobs: SchedKnobs,
        block: Option<&BlockIr>,
    ) -> CandKey {
        debug_assert!(part.n_layers() < u32::MAX as usize);
        debug_assert!(plac.p <= u16::MAX as usize);
        CandKey {
            bounds: part.bounds.iter().map(|&b| b as u32).collect(),
            device_of: plac.device_of.iter().map(|&d| d as u16).collect(),
            knob_bits: u8::from(knobs.split_bw)
                | u8::from(knobs.w_fill) << 1
                | u8::from(knobs.overlap_aware) << 2,
            mem_cap_bits: knobs.mem_cap_factor.to_bits(),
            block_bits: block.map_or_else(Vec::new, BlockIr::key_bits),
        }
    }
}

/// Cumulative cache traffic counters (monotone over the cache's life;
/// `GenResult` reports per-search deltas).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
}

impl CacheStats {
    /// Component-wise `self - earlier` (for per-search deltas).
    pub fn since(&self, earlier: &CacheStats) -> CacheStats {
        CacheStats {
            hits: self.hits - earlier.hits,
            misses: self.misses - earlier.misses,
            evictions: self.evictions - earlier.evictions,
        }
    }
}

/// Transposition table: structural candidate identity → raw step
/// makespan.  Per-search constants (profile, caps, nmb, engine, rates)
/// are not part of the key; a cache reused across searches must be
/// [`EvalCache::retarget`]ed to the new context's fingerprint first
/// (done by `generate_with_cache`).  Bounded — see module docs.
pub struct EvalCache {
    map: HashMap<CandKey, f64>,
    /// Insertion-order queue driving FIFO eviction (deterministic,
    /// unlike hash-map iteration order).
    queue: VecDeque<CandKey>,
    capacity: usize,
    /// Evaluation-context fingerprint the entries are valid for.
    epoch: Option<u64>,
    stats: CacheStats,
}

impl Default for EvalCache {
    fn default() -> EvalCache {
        EvalCache::new()
    }
}

impl EvalCache {
    /// Generous default: a full cold search on paper-scale models
    /// inserts a few thousand entries, so this never evicts within one
    /// search while still bounding a long-lived re-planning loop.
    pub const DEFAULT_CAPACITY: usize = 1 << 18;

    pub fn new() -> EvalCache {
        EvalCache::with_capacity(Self::DEFAULT_CAPACITY)
    }

    pub fn with_capacity(capacity: usize) -> EvalCache {
        assert!(capacity >= 1);
        EvalCache {
            map: HashMap::new(),
            queue: VecDeque::new(),
            capacity,
            epoch: None,
            stats: CacheStats::default(),
        }
    }

    /// Declare the evaluation context: entries survive iff the
    /// fingerprint matches the one the cache was last retargeted to
    /// (traffic counters always survive — they describe the cache, not
    /// the entries).
    pub fn retarget(&mut self, fingerprint: u64) {
        if self.epoch != Some(fingerprint) {
            self.map.clear();
            self.queue.clear();
            self.epoch = Some(fingerprint);
        }
    }

    pub fn get(&mut self, key: &CandKey) -> Option<f64> {
        let hit = self.map.get(key).copied();
        match hit {
            Some(_) => self.stats.hits += 1,
            None => self.stats.misses += 1,
        }
        hit
    }

    pub fn insert(&mut self, key: CandKey, score: f64) {
        if self.map.contains_key(&key) {
            // Deterministic engines re-derive the same score; keep the
            // original queue position (no duplicate queue entries).
            return;
        }
        while self.map.len() >= self.capacity {
            let old = self.queue.pop_front().expect("queue tracks every entry");
            self.map.remove(&old);
            self.stats.evictions += 1;
        }
        self.queue.push_back(key.clone());
        self.map.insert(key, score);
    }

    pub fn stats(&self) -> CacheStats {
        self.stats
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }
}

/// Recycler for candidate stage tables (see module docs).  `take_like`
/// and `build` hand out tables that are bit-identical to freshly
/// cloned/built ones; `recycle` returns a batch's tables once the
/// phase is over.
#[derive(Default)]
pub struct PrepPool {
    free: Vec<StageTable>,
    /// Per-device compute-time multipliers stamped into every built
    /// table (empty = unit rates, the plain search).
    rates: Vec<f64>,
}

impl PrepPool {
    pub fn new() -> PrepPool {
        PrepPool::default()
    }

    /// A pool whose [`PrepPool::build`] produces *rated* tables — the
    /// re-planner's degraded-cluster pricing.  `take_like` is
    /// unaffected (a clone inherits the source's rates).
    pub fn with_rates(rates: Vec<f64>) -> PrepPool {
        PrepPool { free: Vec::new(), rates }
    }

    /// A table equal to `src` (recycled buffers when available).
    pub fn take_like(&mut self, src: &StageTable) -> StageTable {
        match self.free.pop() {
            Some(mut t) => {
                t.clone_from(src);
                t
            }
            None => src.clone(),
        }
    }

    /// A table built from scratch for `(part, plac)` under the pool's
    /// rates (recycled buffers when available).
    pub fn build(
        &mut self,
        profile: &ProfiledData,
        part: &Partition,
        plac: &Placement,
    ) -> StageTable {
        match self.free.pop() {
            Some(mut t) => {
                t.rebuild_rated(profile, part, plac, &self.rates);
                t
            }
            None => StageTable::build_rated(profile, part, plac, &self.rates),
        }
    }

    /// Return a table's buffers to the pool.
    pub fn recycle(&mut self, table: StageTable) {
        self.free.push(table);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Family, HardwareCfg, ModelCfg, ParallelCfg, Size};
    use crate::model::build_model;
    use crate::partition::{balanced, uniform};
    use crate::placement::{interleaved, sequential};

    fn prof() -> ProfiledData {
        let spec = build_model(&ModelCfg::table5(Family::Gemma, Size::Small));
        ProfiledData::analytical(
            &spec,
            &HardwareCfg::default(),
            &ParallelCfg::new(4, 2, 8, 1, 4096),
        )
    }

    #[test]
    fn key_distinguishes_every_component() {
        let pr = prof();
        let n = pr.n_layers();
        let knobs = SchedKnobs::default();
        let base = CandKey::of(&uniform(n, 4), &sequential(4), knobs);
        assert_eq!(base, CandKey::of(&uniform(n, 4), &sequential(4), knobs));
        assert_ne!(base, CandKey::of(&balanced(&pr, 4), &sequential(4), knobs));
        let mut swapped = sequential(4);
        swapped.swap_stages(1, 2);
        assert_ne!(base, CandKey::of(&uniform(n, 4), &swapped, knobs));
        assert_ne!(
            base,
            CandKey::of(
                &uniform(n, 4),
                &sequential(4),
                SchedKnobs { split_bw: !knobs.split_bw, ..knobs }
            )
        );
        assert_ne!(
            base,
            CandKey::of(
                &uniform(n, 4),
                &sequential(4),
                SchedKnobs { mem_cap_factor: 0.75, ..knobs }
            )
        );
    }

    /// Satellite regression (ISSUE 9): candidates that differ *only*
    /// in block parameters must never share a `CandKey` — a collision
    /// would replay one family's makespan for the other.
    #[test]
    fn key_distinguishes_block_parameters() {
        use crate::schedule::block::{zb_v, Pattern, StashRule};
        let pr = prof();
        let n = pr.n_layers();
        let (part, plac) = (uniform(n, 8), interleaved(4, 2));
        let knobs = SchedKnobs::default();
        let base_ir = zb_v(4, 8);
        let base = CandKey::of_cand(&part, &plac, knobs, Some(&base_ir));
        // Same everything ⇒ equal key (the memoization contract).
        assert_eq!(base, CandKey::of_cand(&part, &plac, knobs, Some(&zb_v(4, 8))));
        // Greedy (no block) and block candidates can never alias.
        assert_ne!(base, CandKey::of_cand(&part, &plac, knobs, None));
        assert_ne!(base, CandKey::of(&part, &plac, knobs));
        // Every individual block parameter is distinguishing.
        let mut ir = base_ir.clone();
        ir.pattern = Pattern::BThenF;
        assert_ne!(base, CandKey::of_cand(&part, &plac, knobs, Some(&ir)));
        let mut ir = base_ir.clone();
        ir.split_bw = !ir.split_bw;
        assert_ne!(base, CandKey::of_cand(&part, &plac, knobs, Some(&ir)));
        let mut ir = base_ir.clone();
        ir.group += 1;
        assert_ne!(base, CandKey::of_cand(&part, &plac, knobs, Some(&ir)));
        let mut ir = base_ir.clone();
        ir.offsets[2] += 1;
        assert_ne!(base, CandKey::of_cand(&part, &plac, knobs, Some(&ir)));
        let mut ir = base_ir.clone();
        ir.lag[1] += 1;
        assert_ne!(base, CandKey::of_cand(&part, &plac, knobs, Some(&ir)));
        let mut ir = base_ir.clone();
        ir.stash = StashRule::Fixed(3);
        assert_ne!(base, CandKey::of_cand(&part, &plac, knobs, Some(&ir)));
        // The stash rule carries its own discriminant word, so even the
        // extreme Fixed budget cannot alias Warmup (they compile to
        // different W retirement orders).
        let mut ir = base_ir.clone();
        ir.stash = StashRule::Fixed(u32::MAX);
        assert_ne!(base, CandKey::of_cand(&part, &plac, knobs, Some(&ir)));
    }

    #[test]
    fn cache_round_trips() {
        let pr = prof();
        let key = CandKey::of(&uniform(pr.n_layers(), 4), &sequential(4), SchedKnobs::default());
        let mut cache = EvalCache::new();
        assert!(cache.is_empty());
        assert_eq!(cache.get(&key), None);
        cache.insert(key.clone(), 42.0);
        assert_eq!(cache.get(&key), Some(42.0));
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn bounded_cache_evicts_fifo_with_counters() {
        let pr = prof();
        let part = uniform(pr.n_layers(), 4);
        let plac = sequential(4);
        let key_i = |i: usize| {
            CandKey::of(
                &part,
                &plac,
                SchedKnobs { mem_cap_factor: 1.0 / (i as f64 + 1.0), ..SchedKnobs::default() },
            )
        };
        let mut cache = EvalCache::with_capacity(4);
        for i in 0..10 {
            cache.insert(key_i(i), i as f64);
        }
        assert_eq!(cache.len(), 4);
        assert_eq!(cache.stats().evictions, 6);
        // FIFO: the oldest entries went first, the newest survive.
        assert_eq!(cache.get(&key_i(0)), None);
        assert_eq!(cache.get(&key_i(9)), Some(9.0));
        let st = cache.stats();
        assert_eq!((st.hits, st.misses), (1, 1));
        // Re-inserting an existing key neither grows nor evicts (and
        // keeps the original score — deterministic engines can only
        // re-derive the same number anyway).
        cache.insert(key_i(9), 9.0);
        assert_eq!(cache.len(), 4);
        assert_eq!(cache.stats().evictions, 6);
        let delta = cache.stats().since(&st);
        assert_eq!(delta, CacheStats { hits: 0, misses: 0, evictions: 0 });
    }

    #[test]
    fn capacity_one_cache_evicts_deterministically() {
        let pr = prof();
        let part = uniform(pr.n_layers(), 4);
        let plac = sequential(4);
        let key_i = |i: usize| {
            CandKey::of(
                &part,
                &plac,
                SchedKnobs { mem_cap_factor: 1.0 / (i as f64 + 1.0), ..SchedKnobs::default() },
            )
        };
        // Degenerate bound: every insert of a new key evicts the sole
        // occupant, in exactly insertion order, never leaving the
        // cache empty or above capacity.
        let mut cache = EvalCache::with_capacity(1);
        for i in 0..5 {
            cache.insert(key_i(i), i as f64);
            assert_eq!(cache.len(), 1, "capacity-1 cache holds exactly one entry");
            assert_eq!(cache.get(&key_i(i)), Some(i as f64), "newest survives");
            if i > 0 {
                assert_eq!(cache.get(&key_i(i - 1)), None, "previous evicted");
            }
        }
        assert_eq!(cache.stats().evictions, 4);
        // Re-inserting the occupant is idempotent — no self-eviction.
        cache.insert(key_i(4), 4.0);
        assert_eq!(cache.len(), 1);
        assert_eq!(cache.stats().evictions, 4);
        assert_eq!(cache.get(&key_i(4)), Some(4.0));
    }

    #[test]
    fn retarget_fingerprint_change_drops_stale_scores() {
        let pr = prof();
        let key =
            CandKey::of(&uniform(pr.n_layers(), 4), &sequential(4), SchedKnobs::default());
        let mut cache = EvalCache::new();
        // Search 1 under context A: one miss, one insert, one hit.
        cache.retarget(0xa);
        let s0 = cache.stats();
        assert_eq!(cache.get(&key), None);
        cache.insert(key.clone(), 1.0);
        assert_eq!(cache.get(&key), Some(1.0));
        assert_eq!(cache.stats().since(&s0), CacheStats { hits: 1, misses: 1, evictions: 0 });
        // Context changes (e.g. new rates ⇒ new fingerprint): the same
        // structural key must MISS, not replay the stale 1.0 — a
        // replay would silently price candidates under the old
        // context.
        cache.retarget(0xb);
        let s1 = cache.stats();
        assert!(cache.is_empty(), "fingerprint change clears every entry");
        assert_eq!(cache.get(&key), None, "stale score is dropped, not replayed");
        cache.insert(key.clone(), 2.0);
        assert_eq!(cache.get(&key), Some(2.0), "fresh score for the new context");
        // Per-search accounting resets cleanly through the snapshot:
        // the new search's delta counts only its own traffic (this is
        // how `generate_with_cache` reports `GenResult::cache`), while
        // lifetime counters keep accumulating monotonically.
        assert_eq!(cache.stats().since(&s1), CacheStats { hits: 1, misses: 1, evictions: 0 });
        assert_eq!(cache.stats(), CacheStats { hits: 2, misses: 2, evictions: 0 });
        // Flipping back to A does NOT resurrect A's entries — clearing
        // is irreversible, so A⇒B⇒A can never replay generation-A
        // scores.
        cache.retarget(0xa);
        assert!(cache.is_empty());
        assert_eq!(cache.get(&key), None);
    }

    #[test]
    fn retarget_clears_only_on_context_change() {
        let pr = prof();
        let key =
            CandKey::of(&uniform(pr.n_layers(), 4), &sequential(4), SchedKnobs::default());
        let mut cache = EvalCache::new();
        cache.retarget(0xabc);
        cache.insert(key.clone(), 1.5);
        cache.retarget(0xabc); // same context: entries survive
        assert_eq!(cache.get(&key), Some(1.5));
        cache.retarget(0xdef); // context changed: entries cleared
        assert!(cache.is_empty());
        assert_eq!(cache.get(&key), None);
        // Traffic counters describe the cache, not the entries.
        assert_eq!(cache.stats().hits, 1);
        assert_eq!(cache.stats().misses, 1);
    }

    #[test]
    fn rated_pool_builds_rated_tables() {
        let pr = prof();
        let part = balanced(&pr, 4);
        let plac = sequential(4);
        let rates = vec![1.0, 2.0, 1.0, 1.0];
        let mut pool = PrepPool::with_rates(rates.clone());
        let built = pool.build(&pr, &part, &plac);
        let fresh = StageTable::build_rated(&pr, &part, &plac, &rates);
        assert_eq!(built.f, fresh.f);
        assert_eq!(built.bw, fresh.bw);
        assert_eq!(built.rate_d, fresh.rate_d);
        // Recycling keeps producing rated tables.
        pool.recycle(built);
        let again = pool.build(&pr, &part, &plac);
        assert_eq!(again.f, fresh.f);
        assert_eq!(again.rate_d, fresh.rate_d);
    }

    #[test]
    fn recycled_tables_are_bit_identical() {
        let pr = prof();
        let mut pool = PrepPool::new();
        let a = StageTable::build(&pr, &uniform(pr.n_layers(), 8), &interleaved(4, 2));
        pool.recycle(a);
        // Recycle into a differently-shaped target: must equal a fresh
        // build/clone bitwise.
        let part = balanced(&pr, 4);
        let plac = sequential(4);
        let built = pool.build(&pr, &part, &plac);
        let fresh = StageTable::build(&pr, &part, &plac);
        assert_eq!(built.f, fresh.f);
        assert_eq!(built.static_d, fresh.static_d);
        assert_eq!(built.comm_b_in, fresh.comm_b_in);
        pool.recycle(built);
        let like = pool.take_like(&fresh);
        assert_eq!(like.f, fresh.f);
        assert_eq!(like.device, fresh.device);
        assert_eq!(like.act_w, fresh.act_w);
    }
}
