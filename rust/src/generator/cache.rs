//! Candidate memoization — the Pipeline Generator's transposition
//! table (DESIGN.md § Search acceleration).
//!
//! The tuning loop regenerates large parts of its move batch every
//! iteration: partition phases always propose the *undo* of the last
//! accepted shift, the knob grid re-proposes settings the search has
//! already visited, and rejected placement swaps come back verbatim
//! until the current pipeline changes.  Every candidate's score is a
//! pure function of `(partition boundaries, placement map, knobs)`
//! given a fixed `(profile, caps, nmb)` — both engines are
//! deterministic and bit-identical (pinned by the differential suites)
//! — so re-simulating a structurally identical candidate can only
//! reproduce the number already computed.  [`EvalCache`] stores that
//! number keyed by the full structural identity ([`CandKey`], exact
//! equality — hash collisions fall back to `Eq`, never to a wrong
//! score), which is what makes cache hits *provably* unable to change
//! the search trajectory.
//!
//! [`PrepPool`] is the allocation side of the same story: move batches
//! used to clone a fresh `StageTable` (a dozen `Vec`s) per candidate
//! and drop them all at the end of the phase.  The pool recycles the
//! tables instead — `clone_from`/`rebuild` overwrite every entry in
//! place, so a recycled table is bit-identical to a fresh one while
//! steady-state candidate construction allocates nothing.

use std::collections::HashMap;

use crate::partition::Partition;
use crate::placement::Placement;
use crate::perfmodel::StageTable;
use crate::profile::ProfiledData;
use crate::schedule::greedy::SchedKnobs;

/// Structural identity of a candidate: everything the (deterministic)
/// evaluation reads besides the per-search constants.  Exact — two
/// keys compare equal iff the candidates are evaluation-equivalent.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct CandKey {
    /// Partition stage bounds (layer offsets).
    bounds: Vec<u32>,
    /// Placement stage → device map.
    device_of: Vec<u16>,
    /// Boolean knobs packed: bit 0 `split_bw`, bit 1 `w_fill`,
    /// bit 2 `overlap_aware`.
    knob_bits: u8,
    /// `mem_cap_factor`, compared bitwise (the knob grid only ever
    /// produces it by deterministic arithmetic, so bitwise identity is
    /// the right equivalence).
    mem_cap_bits: u64,
}

impl CandKey {
    pub fn of(part: &Partition, plac: &Placement, knobs: SchedKnobs) -> CandKey {
        debug_assert!(part.n_layers() < u32::MAX as usize);
        debug_assert!(plac.p <= u16::MAX as usize);
        CandKey {
            bounds: part.bounds.iter().map(|&b| b as u32).collect(),
            device_of: plac.device_of.iter().map(|&d| d as u16).collect(),
            knob_bits: u8::from(knobs.split_bw)
                | u8::from(knobs.w_fill) << 1
                | u8::from(knobs.overlap_aware) << 2,
            mem_cap_bits: knobs.mem_cap_factor.to_bits(),
        }
    }
}

/// Transposition table: structural candidate identity → score.  Lives
/// for one `generate()` call (profile, caps, nmb and engine are fixed
/// per search, so they are not part of the key).
#[derive(Default)]
pub struct EvalCache {
    map: HashMap<CandKey, f64>,
}

impl EvalCache {
    pub fn new() -> EvalCache {
        EvalCache::default()
    }

    pub fn get(&self, key: &CandKey) -> Option<f64> {
        self.map.get(key).copied()
    }

    pub fn insert(&mut self, key: CandKey, score: f64) {
        self.map.insert(key, score);
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }
}

/// Recycler for candidate stage tables (see module docs).  `take_like`
/// and `build` hand out tables that are bit-identical to freshly
/// cloned/built ones; `recycle` returns a batch's tables once the
/// phase is over.
#[derive(Default)]
pub struct PrepPool {
    free: Vec<StageTable>,
}

impl PrepPool {
    pub fn new() -> PrepPool {
        PrepPool::default()
    }

    /// A table equal to `src` (recycled buffers when available).
    pub fn take_like(&mut self, src: &StageTable) -> StageTable {
        match self.free.pop() {
            Some(mut t) => {
                t.clone_from(src);
                t
            }
            None => src.clone(),
        }
    }

    /// A table built from scratch for `(part, plac)` (recycled buffers
    /// when available).
    pub fn build(
        &mut self,
        profile: &ProfiledData,
        part: &Partition,
        plac: &Placement,
    ) -> StageTable {
        match self.free.pop() {
            Some(mut t) => {
                t.rebuild(profile, part, plac);
                t
            }
            None => StageTable::build(profile, part, plac),
        }
    }

    /// Return a table's buffers to the pool.
    pub fn recycle(&mut self, table: StageTable) {
        self.free.push(table);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Family, HardwareCfg, ModelCfg, ParallelCfg, Size};
    use crate::model::build_model;
    use crate::partition::{balanced, uniform};
    use crate::placement::{interleaved, sequential};

    fn prof() -> ProfiledData {
        let spec = build_model(&ModelCfg::table5(Family::Gemma, Size::Small));
        ProfiledData::analytical(
            &spec,
            &HardwareCfg::default(),
            &ParallelCfg::new(4, 2, 8, 1, 4096),
        )
    }

    #[test]
    fn key_distinguishes_every_component() {
        let pr = prof();
        let n = pr.n_layers();
        let knobs = SchedKnobs::default();
        let base = CandKey::of(&uniform(n, 4), &sequential(4), knobs);
        assert_eq!(base, CandKey::of(&uniform(n, 4), &sequential(4), knobs));
        assert_ne!(base, CandKey::of(&balanced(&pr, 4), &sequential(4), knobs));
        let mut swapped = sequential(4);
        swapped.swap_stages(1, 2);
        assert_ne!(base, CandKey::of(&uniform(n, 4), &swapped, knobs));
        assert_ne!(
            base,
            CandKey::of(
                &uniform(n, 4),
                &sequential(4),
                SchedKnobs { split_bw: !knobs.split_bw, ..knobs }
            )
        );
        assert_ne!(
            base,
            CandKey::of(
                &uniform(n, 4),
                &sequential(4),
                SchedKnobs { mem_cap_factor: 0.75, ..knobs }
            )
        );
    }

    #[test]
    fn cache_round_trips() {
        let pr = prof();
        let key = CandKey::of(&uniform(pr.n_layers(), 4), &sequential(4), SchedKnobs::default());
        let mut cache = EvalCache::new();
        assert!(cache.is_empty());
        assert_eq!(cache.get(&key), None);
        cache.insert(key.clone(), 42.0);
        assert_eq!(cache.get(&key), Some(42.0));
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn recycled_tables_are_bit_identical() {
        let pr = prof();
        let mut pool = PrepPool::new();
        let a = StageTable::build(&pr, &uniform(pr.n_layers(), 8), &interleaved(4, 2));
        pool.recycle(a);
        // Recycle into a differently-shaped target: must equal a fresh
        // build/clone bitwise.
        let part = balanced(&pr, 4);
        let plac = sequential(4);
        let built = pool.build(&pr, &part, &plac);
        let fresh = StageTable::build(&pr, &part, &plac);
        assert_eq!(built.f, fresh.f);
        assert_eq!(built.static_d, fresh.static_d);
        assert_eq!(built.comm_b_in, fresh.comm_b_in);
        pool.recycle(built);
        let like = pool.take_like(&fresh);
        assert_eq!(like.f, fresh.f);
        assert_eq!(like.device, fresh.device);
        assert_eq!(like.act_w, fresh.act_w);
    }
}
