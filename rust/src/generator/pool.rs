//! Persistent candidate-evaluation pool (DESIGN.md § Search
//! acceleration).
//!
//! PR 1 parallelised move batches with `std::thread::scope`, which
//! spawns and joins a fresh set of OS threads for *every* batch — tens
//! of microseconds of overhead per batch, paid hundreds of times per
//! `generate()` call.  This pool spawns its workers once per search
//! and feeds them over channels instead:
//!
//! - jobs carry an owned [`StageTable`] + [`SchedKnobs`] (everything a
//!   fused evaluation reads besides the per-search constants), so no
//!   borrows cross the thread boundary and the workers outlive any
//!   batch;
//! - each worker owns one [`SimArena`] for its whole lifetime —
//!   steady-state evaluation allocates nothing;
//! - results return `(index, score, table)`; the caller writes scores
//!   by index and puts tables back, so the merged score vector is
//!   positionally identical to a serial evaluation.  Workers race only
//!   for *which job they pull* — every score is a pure function of its
//!   job — which is the pool's determinism argument: the `(score,
//!   index)` selection downstream sees bit-identical inputs regardless
//!   of scheduling.
//!
//! The pool evaluates the **Fast** engine only (fused scoring needs no
//! `ProfiledData`); the Reference engine stays serial by design — it
//! is the elision-free baseline the benches compare against.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use crate::memory::MemCaps;
use crate::perfmodel::{
    fits_lower_bound, fused_score, fused_score_collapsed, SimArena, StageTable,
};
use crate::schedule::greedy::SchedKnobs;

/// One candidate evaluation: score `table` under `knobs`.
pub struct Job {
    /// Caller's batch index — results are merged back by this.
    pub idx: usize,
    pub table: StageTable,
    pub knobs: SchedKnobs,
}

/// A finished evaluation; `table` is returned for recycling.
pub struct Done {
    pub idx: usize,
    pub score: f64,
    /// The steady-state collapse layer replayed rounds for this score.
    pub collapsed: bool,
    pub table: StageTable,
}

/// Long-lived worker pool; see module docs.  Dropping the pool closes
/// the job queue and joins every worker.
pub struct EvalPool {
    jobs: Option<Sender<Job>>,
    done: Receiver<Done>,
    workers: Vec<JoinHandle<()>>,
}

impl EvalPool {
    /// Spawn `threads` workers scoring against `caps` with `nmb`
    /// micro-batches (both fixed for one `generate()` call), with
    /// steady-state collapse on or off (`GenOptions::collapse`).
    pub fn new(threads: usize, caps: MemCaps, nmb: usize, collapse: bool) -> EvalPool {
        assert!(threads >= 1);
        let (jobs, job_rx) = channel::<Job>();
        let job_rx = Arc::new(Mutex::new(job_rx));
        let (done_tx, done) = channel::<Done>();
        let workers = (0..threads)
            .map(|_| {
                let rx = Arc::clone(&job_rx);
                let tx = done_tx.clone();
                let caps = caps.clone();
                std::thread::spawn(move || {
                    let mut arena = SimArena::new();
                    loop {
                        // The guard is a statement temporary: the lock
                        // is released as soon as `recv` returns, so
                        // workers only serialise on dequeue, not work.
                        let job = rx.lock().unwrap().recv();
                        let Ok(job) = job else { break };
                        // Same gate as the serial path: plans no
                        // schedule could fit are never simulated.  A
                        // panicking evaluation (unreachable for valid
                        // candidates) is reported as a NaN sentinel so
                        // the caller fails loudly instead of waiting
                        // forever for a result that never comes.
                        let (score, collapsed) = std::panic::catch_unwind(
                            std::panic::AssertUnwindSafe(|| {
                                if !fits_lower_bound(&job.table, &caps) {
                                    (f64::INFINITY, false)
                                } else if collapse {
                                    let (score, stats) = fused_score_collapsed(
                                        &job.table, &caps, nmb, job.knobs, &mut arena,
                                    );
                                    (score, stats.fired)
                                } else {
                                    (
                                        fused_score(
                                            &job.table, &caps, nmb, job.knobs, &mut arena,
                                        ),
                                        false,
                                    )
                                }
                            }),
                        )
                        .unwrap_or((f64::NAN, false));
                        let out = Done { idx: job.idx, score, collapsed, table: job.table };
                        if tx.send(out).is_err() {
                            break;
                        }
                    }
                })
            })
            .collect();
        EvalPool { jobs: Some(jobs), done, workers }
    }

    /// Enqueue one evaluation.
    pub fn submit(&self, job: Job) {
        self.jobs
            .as_ref()
            .expect("pool not shut down")
            .send(job)
            .expect("evaluation workers alive");
    }

    /// Block for one finished evaluation (any order; merge by `idx`).
    pub fn collect(&self) -> Done {
        self.done.recv().expect("evaluation workers alive")
    }
}

impl Drop for EvalPool {
    fn drop(&mut self) {
        // Closing the job channel ends every worker's recv loop.
        self.jobs.take();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Family, HardwareCfg, ModelCfg, ParallelCfg, Size};
    use crate::model::build_model;
    use crate::partition::uniform;
    use crate::placement::sequential;
    use crate::profile::ProfiledData;

    #[test]
    fn pool_scores_match_serial_fused_eval() {
        let spec = build_model(&ModelCfg::table5(Family::NemotronH, Size::Small));
        let prof = ProfiledData::analytical(
            &spec,
            &HardwareCfg::default(),
            &ParallelCfg::new(4, 2, 8, 1, 4096),
        );
        let caps = MemCaps::uniform(4, prof.mem_capacity);
        let plac = sequential(4);
        let knob_grid = [
            SchedKnobs::default(),
            SchedKnobs { split_bw: false, ..SchedKnobs::default() },
            SchedKnobs { w_fill: false, ..SchedKnobs::default() },
            SchedKnobs { overlap_aware: false, ..SchedKnobs::default() },
        ];
        let mut arena = SimArena::new();
        let mut tables = Vec::new();
        let mut serial = Vec::new();
        for (i, knobs) in knob_grid.iter().enumerate() {
            let mut part = uniform(prof.n_layers(), 4);
            if i % 2 == 1 {
                part.shift_boundary(i / 2, true);
            }
            let table = StageTable::build(&prof, &part, &plac);
            serial.push(fused_score(&table, &caps, 8, *knobs, &mut arena));
            tables.push(table);
        }

        let pool = EvalPool::new(3, caps.clone(), 8, false);
        for (idx, (table, knobs)) in
            tables.into_iter().zip(knob_grid.iter()).enumerate()
        {
            pool.submit(Job { idx, table, knobs: *knobs });
        }
        let mut pooled = vec![f64::NAN; knob_grid.len()];
        let mut returned = Vec::new();
        for _ in 0..knob_grid.len() {
            let done = pool.collect();
            pooled[done.idx] = done.score;
            // Returned tables are intact (recyclable).
            assert_eq!(done.table.n_stages, 4);
            assert!(!done.collapsed, "collapse off must report no collapse");
            returned.push((done.idx, done.table));
        }
        assert_eq!(pooled, serial, "pool must be positionally bit-identical");
        drop(pool); // joins workers without hanging

        // Collapse-enabled workers must return the exact same scores
        // (bitwise) whether or not the cycle replay fires.
        let pool = EvalPool::new(3, caps, 8, true);
        for (idx, table) in returned {
            pool.submit(Job { idx, table, knobs: knob_grid[idx] });
        }
        let mut collapsed = vec![f64::NAN; knob_grid.len()];
        for _ in 0..knob_grid.len() {
            let done = pool.collect();
            collapsed[done.idx] = done.score;
        }
        assert_eq!(collapsed, serial, "collapsed pool must be bit-identical");
    }
}
