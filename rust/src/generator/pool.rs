//! Process-wide candidate-evaluation pool (DESIGN.md §4, §9).
//!
//! PR 1 parallelised move batches with `std::thread::scope`, which
//! spawns and joins a fresh set of OS threads for *every* batch.  PR 3
//! replaced that with a pool spawned once per `generate()` call.  This
//! revision lifts the pool to process scope so one set of workers can
//! serve *many* searches — sequential re-plans (the elastic loop) and
//! concurrent planner-service requests alike:
//!
//! - the pool itself is context-free (`EvalPool::new(threads)`); each
//!   search registers a [`PoolClient`] carrying its own [`EvalCtx`]
//!   (mem caps, micro-batch count, collapse flag), so searches with
//!   different contexts can share workers;
//! - dispatch is **fair round-robin across clients**: workers pull one
//!   job from the next non-empty client queue in registration order,
//!   so a search submitting a huge batch cannot starve a concurrent
//!   search's small batch;
//! - workers are **idle-safe**: between searches they park on a
//!   condvar, consuming no CPU, and wake when any client submits;
//! - jobs carry an owned [`StageTable`] + [`SchedKnobs`], so no
//!   borrows cross the thread boundary; each worker owns one
//!   [`SimArena`] for its whole lifetime — steady-state evaluation
//!   allocates nothing;
//! - results return `(index, score, table)` on a per-client channel;
//!   the caller merges scores by index, so the merged vector is
//!   positionally identical to a serial evaluation.  Workers race only
//!   for *which job they pull* — every score is a pure function of its
//!   job — which is the pool's determinism argument: the `(score,
//!   index)` selection downstream sees bit-identical inputs regardless
//!   of scheduling, sharing, or reuse.
//!
//! The pool evaluates the **Fast** engine only (fused scoring needs no
//! `ProfiledData`); the Reference engine stays serial by design — it
//! is the elision-free baseline the benches compare against.
//!
//! Lifetime rules: a `PoolClient` must not outlive its `EvalPool` with
//! jobs still in flight (collect would block forever once the workers
//! are gone).  `Evaluator` and the planner service both hold the pool
//! in an `Arc` that outlives every client.
//!
//! Fault containment (DESIGN.md §9, fault tolerance): a panic *inside*
//! an evaluation is caught per-job and reported as a NaN sentinel.  A
//! panic *outside* that catch kills the worker thread itself — for
//! that case every worker carries a [`WorkerGuard`] whose unwind path
//! (a) delivers a NaN sentinel for the job the dying worker held, so
//! no collector waits forever, and (b) respawns a replacement worker,
//! so the pool never shrinks.  All dispatch locking is poison-tolerant
//! (`lock_dispatch`): a worker that dies while holding the lock leaves
//! `Dispatch` consistent (every critical section is a single-step
//! queue operation), so survivors simply keep going.

use std::collections::{HashMap, VecDeque};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, PoisonError};
use std::thread::JoinHandle;

use crate::memory::MemCaps;
use crate::perfmodel::{
    fits_lower_bound, fused_score, fused_score_collapsed, SimArena, StageTable,
};
use crate::schedule::block::BlockIr;
use crate::schedule::greedy::SchedKnobs;

/// Every sender for a client's completion channel is gone: the pool
/// (and its respawn machinery) was torn down with this client still
/// waiting.  Surfaced by [`PoolClient::collect`] instead of a panic so
/// the planner service can fail one request, not the process.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PoolLost;

impl std::fmt::Display for PoolLost {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "evaluation pool gone (all workers and dispatch state dropped)")
    }
}

/// Typed panic payload raised by `Evaluator::scores` when a pooled
/// evaluation is lost (the worker thread died, or the evaluation
/// panicked and came back as the NaN sentinel).  The planner service
/// catches it with `catch_unwind` and surfaces
/// `ServiceError::WorkerLost`; direct `generate()` callers observe a
/// panic, exactly as before this type existed.
#[derive(Clone, Copy, Debug)]
pub struct EvalAborted;

/// Poison-tolerant dispatch lock: a worker that panics while holding
/// the mutex leaves `Dispatch` consistent (single-step queue edits
/// only), so poisoning downgrades to "take the data as is" instead of
/// cascading the panic into every other search sharing the pool.
fn lock_dispatch(shared: &Shared) -> MutexGuard<'_, Dispatch> {
    shared.m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// One candidate evaluation: score `table` under `knobs`, or — when
/// `block` is set — under the compiled block schedule (the fourth
/// search knob; `knobs` ride along but are not consulted).
pub struct Job {
    /// Caller's batch index — results are merged back by this.
    pub idx: usize,
    pub table: StageTable,
    pub knobs: SchedKnobs,
    pub block: Option<Arc<BlockIr>>,
}

/// A finished evaluation; `table` is returned for recycling.
pub struct Done {
    pub idx: usize,
    pub score: f64,
    /// The steady-state collapse layer replayed rounds for this score.
    pub collapsed: bool,
    pub table: StageTable,
}

/// Per-search evaluation context: everything a fused evaluation reads
/// besides the job itself.  Fixed for the lifetime of one client.
#[derive(Clone, Debug)]
pub struct EvalCtx {
    pub caps: MemCaps,
    pub nmb: usize,
    /// Steady-state collapse on/off (`GenOptions::collapse`).
    pub collapse: bool,
}

struct ClientState {
    ctx: Arc<EvalCtx>,
    jobs: VecDeque<Job>,
    done: Sender<Done>,
}

struct Dispatch {
    clients: HashMap<u64, ClientState>,
    /// Round-robin ring of client ids; the fairness cursor is the
    /// ring's front.  Stale ids (dropped clients) are purged lazily.
    ring: VecDeque<u64>,
    next_id: u64,
    shutdown: bool,
    /// Test hook: how many upcoming dequeues should hard-abort their
    /// worker thread (outside the per-job panic catch).
    abort_next: usize,
    /// Workers that died and were replaced by their [`WorkerGuard`].
    workers_lost: u64,
    /// Join handles of replacement workers, joined at pool drop.
    respawned: Vec<JoinHandle<()>>,
}

impl Dispatch {
    /// Pull one job fairly: rotate the ring, taking the first job
    /// found; the serving client moves to the back either way.
    fn next_job(&mut self) -> Option<(Job, Arc<EvalCtx>, Sender<Done>)> {
        for _ in 0..self.ring.len() {
            let id = self.ring.pop_front().expect("ring non-empty in loop");
            let Some(client) = self.clients.get_mut(&id) else {
                continue; // client dropped: purge its ring slot
            };
            let job = client.jobs.pop_front();
            let ctx = Arc::clone(&client.ctx);
            let done = client.done.clone();
            self.ring.push_back(id);
            if let Some(job) = job {
                return Some((job, ctx, done));
            }
        }
        None
    }
}

struct Shared {
    m: Mutex<Dispatch>,
    cv: Condvar,
}

/// Long-lived, context-free worker pool; see module docs.  Dropping
/// the pool wakes and joins every worker (any still-queued jobs are
/// discarded).
pub struct EvalPool {
    shared: Arc<Shared>,
    threads: usize,
    workers: Vec<JoinHandle<()>>,
}

impl std::fmt::Debug for EvalPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "EvalPool({} threads)", self.threads)
    }
}

impl EvalPool {
    /// Spawn `threads` idle workers.  Context comes per-client.
    pub fn new(threads: usize) -> EvalPool {
        assert!(threads >= 1);
        let shared = Arc::new(Shared {
            m: Mutex::new(Dispatch {
                clients: HashMap::new(),
                ring: VecDeque::new(),
                next_id: 0,
                shutdown: false,
                abort_next: 0,
                workers_lost: 0,
                respawned: Vec::new(),
            }),
            cv: Condvar::new(),
        });
        let workers = (0..threads)
            .map(|_| {
                let shared = Arc::clone(&shared);
                std::thread::spawn(move || worker(shared))
            })
            .collect();
        EvalPool { shared, threads, workers }
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Test hook: make the next `n` dequeued jobs hard-abort their
    /// worker thread *outside* the per-job panic catch, exercising
    /// death detection, sentinel delivery, and respawn.
    #[doc(hidden)]
    pub fn inject_worker_abort(&self, n: usize) {
        lock_dispatch(&self.shared).abort_next += n;
    }

    /// Workers lost to hard aborts over the pool's lifetime (each one
    /// was replaced, so capacity never shrank).
    pub fn workers_lost(&self) -> u64 {
        lock_dispatch(&self.shared).workers_lost
    }

    /// Register a search with its evaluation context.  The client gets
    /// a private job queue and completion channel; dropping it
    /// unregisters (outstanding jobs are discarded, finished ones
    /// simply never read).
    pub fn client(&self, ctx: EvalCtx) -> PoolClient {
        let (done_tx, done_rx) = channel::<Done>();
        let mut d = self.shared.m.lock().unwrap();
        let id = d.next_id;
        d.next_id += 1;
        d.clients.insert(
            id,
            ClientState { ctx: Arc::new(ctx), jobs: VecDeque::new(), done: done_tx },
        );
        d.ring.push_back(id);
        drop(d);
        PoolClient { shared: Arc::clone(&self.shared), id, done: done_rx }
    }
}

impl Drop for EvalPool {
    fn drop(&mut self) {
        lock_dispatch(&self.shared).shutdown = true;
        self.shared.cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        // Guards only respawn while `shutdown` is false, and both the
        // spawn and this drain hold the dispatch lock, so every
        // replacement handle is visible here; loop in case a
        // respawned worker itself died and respawned while draining.
        loop {
            let respawned = std::mem::take(&mut lock_dispatch(&self.shared).respawned);
            if respawned.is_empty() {
                break;
            }
            for w in respawned {
                let _ = w.join();
            }
        }
    }
}

/// Unwind watchdog carried by every worker thread; see module docs.
struct WorkerGuard {
    shared: Arc<Shared>,
    /// The job the worker is currently evaluating, if any: its batch
    /// index and completion channel.
    inflight: Option<(usize, Sender<Done>)>,
}

impl Drop for WorkerGuard {
    fn drop(&mut self) {
        if !std::thread::panicking() {
            return; // orderly shutdown
        }
        // Deliver the NaN sentinel for the job that died with us so no
        // collector blocks forever on a result that will never come.
        if let Some((idx, done)) = self.inflight.take() {
            let _ = done.send(Done {
                idx,
                score: f64::NAN,
                collapsed: false,
                table: StageTable::default(),
            });
        }
        let mut d = lock_dispatch(&self.shared);
        d.workers_lost += 1;
        if !d.shutdown {
            let shared = Arc::clone(&self.shared);
            d.respawned.push(std::thread::spawn(move || worker(shared)));
        }
    }
}

fn worker(shared: Arc<Shared>) {
    let mut guard = WorkerGuard { shared: Arc::clone(&shared), inflight: None };
    let mut arena = SimArena::new();
    loop {
        // Park until a job exists or the pool shuts down; the lock is
        // held only across dequeue, never across evaluation.
        let (job, ctx, done, abort) = {
            let mut d = lock_dispatch(&shared);
            loop {
                if d.shutdown {
                    return;
                }
                if let Some((job, ctx, done)) = d.next_job() {
                    let abort = d.abort_next > 0;
                    if abort {
                        d.abort_next -= 1;
                    }
                    break (job, ctx, done, abort);
                }
                d = shared.cv.wait(d).unwrap_or_else(PoisonError::into_inner);
            }
        };
        // Register the in-flight job *before* anything can panic so
        // the guard covers the whole evaluation window.
        guard.inflight = Some((job.idx, done.clone()));
        if abort {
            // Test hook: die outside the per-job catch, as a real bug
            // in the dequeue/return path would.
            panic!("injected evaluation-worker abort (test hook)");
        }
        // Same gate as the serial path: plans no schedule could fit
        // are never simulated.  A panicking evaluation (unreachable
        // for valid candidates) is reported as a NaN sentinel so the
        // caller fails loudly instead of waiting forever for a result
        // that never comes.
        let (score, collapsed) =
            std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                if !fits_lower_bound(&job.table, &ctx.caps) {
                    (f64::INFINITY, false)
                } else if let Some(block) = &job.block {
                    // Exactly the serial path's block scorer, so pooled
                    // and serial block evaluations are bit-identical.
                    super::block_score_in(
                        &mut arena,
                        &job.table,
                        &ctx.caps,
                        ctx.nmb,
                        block,
                        ctx.collapse,
                    )
                } else if ctx.collapse {
                    let (score, stats) = fused_score_collapsed(
                        &job.table,
                        &ctx.caps,
                        ctx.nmb,
                        job.knobs,
                        &mut arena,
                    );
                    (score, stats.fired)
                } else {
                    (
                        fused_score(&job.table, &ctx.caps, ctx.nmb, job.knobs, &mut arena),
                        false,
                    )
                }
            }))
            .unwrap_or((f64::NAN, false));
        guard.inflight = None;
        // A dropped client means nobody wants the result — fine.
        let _ = done.send(Done { idx: job.idx, score, collapsed, table: job.table });
    }
}

/// One search's handle into a shared [`EvalPool`].
pub struct PoolClient {
    shared: Arc<Shared>,
    id: u64,
    done: Receiver<Done>,
}

impl PoolClient {
    /// Enqueue one evaluation.
    pub fn submit(&self, job: Job) {
        let mut d = lock_dispatch(&self.shared);
        assert!(!d.shutdown, "pool not shut down");
        d.clients
            .get_mut(&self.id)
            .expect("client registered until dropped")
            .jobs
            .push_back(job);
        drop(d);
        self.shared.cv.notify_one();
    }

    /// Block for one finished evaluation (any order; merge by `idx`).
    /// `Err(PoolLost)` means every completion sender is gone — the
    /// pool was torn down with this client still waiting, which the
    /// respawn guard makes unreachable in normal operation.
    pub fn collect(&self) -> Result<Done, PoolLost> {
        self.done.recv().map_err(|_| PoolLost)
    }
}

impl Drop for PoolClient {
    fn drop(&mut self) {
        lock_dispatch(&self.shared).clients.remove(&self.id);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Family, HardwareCfg, ModelCfg, ParallelCfg, Size};
    use crate::model::build_model;
    use crate::partition::uniform;
    use crate::placement::sequential;
    use crate::profile::ProfiledData;

    fn fixture() -> (ProfiledData, MemCaps, Vec<StageTable>, Vec<SchedKnobs>, Vec<f64>) {
        let spec = build_model(&ModelCfg::table5(Family::NemotronH, Size::Small));
        let prof = ProfiledData::analytical(
            &spec,
            &HardwareCfg::default(),
            &ParallelCfg::new(4, 2, 8, 1, 4096),
        );
        let caps = MemCaps::uniform(4, prof.mem_capacity);
        let plac = sequential(4);
        let knob_grid = vec![
            SchedKnobs::default(),
            SchedKnobs { split_bw: false, ..SchedKnobs::default() },
            SchedKnobs { w_fill: false, ..SchedKnobs::default() },
            SchedKnobs { overlap_aware: false, ..SchedKnobs::default() },
        ];
        let mut arena = SimArena::new();
        let mut tables = Vec::new();
        let mut serial = Vec::new();
        for (i, knobs) in knob_grid.iter().enumerate() {
            let mut part = uniform(prof.n_layers(), 4);
            if i % 2 == 1 {
                part.shift_boundary(i / 2, true);
            }
            let table = StageTable::build(&prof, &part, &plac);
            serial.push(fused_score(&table, &caps, 8, *knobs, &mut arena));
            tables.push(table);
        }
        (prof, caps, tables, knob_grid, serial)
    }

    #[test]
    fn pool_scores_match_serial_fused_eval() {
        let (_prof, caps, tables, knob_grid, serial) = fixture();

        let pool = EvalPool::new(3);
        let client =
            pool.client(EvalCtx { caps: caps.clone(), nmb: 8, collapse: false });
        for (idx, (table, knobs)) in
            tables.into_iter().zip(knob_grid.iter()).enumerate()
        {
            client.submit(Job { idx, table, knobs: *knobs, block: None });
        }
        let mut pooled = vec![f64::NAN; knob_grid.len()];
        let mut returned = Vec::new();
        for _ in 0..knob_grid.len() {
            let done = client.collect().expect("pool alive");
            pooled[done.idx] = done.score;
            // Returned tables are intact (recyclable).
            assert_eq!(done.table.n_stages, 4);
            assert!(!done.collapsed, "collapse off must report no collapse");
            returned.push((done.idx, done.table));
        }
        assert_eq!(pooled, serial, "pool must be positionally bit-identical");
        drop(client);

        // Collapse-enabled evaluation on the SAME (reused) pool must
        // return the exact same scores (bitwise) whether or not the
        // cycle replay fires — the second client exercises worker
        // survival between searches.
        let client = pool.client(EvalCtx { caps, nmb: 8, collapse: true });
        for (idx, table) in returned {
            client.submit(Job { idx, table, knobs: knob_grid[idx], block: None });
        }
        let mut collapsed = vec![f64::NAN; knob_grid.len()];
        for _ in 0..knob_grid.len() {
            let done = client.collect().expect("pool alive");
            collapsed[done.idx] = done.score;
        }
        assert_eq!(collapsed, serial, "collapsed pool must be bit-identical");
        drop(client);
        drop(pool); // joins workers without hanging
    }

    #[test]
    fn concurrent_clients_multiplex_one_pool() {
        let (_prof, caps, tables, knob_grid, serial) = fixture();
        let pool = EvalPool::new(2);
        // Two clients with different contexts interleave on the same
        // workers; each still sees its own positionally-exact scores.
        let a = pool.client(EvalCtx { caps: caps.clone(), nmb: 8, collapse: false });
        let b = pool.client(EvalCtx { caps, nmb: 8, collapse: true });
        let n = tables.len();
        for (idx, table) in tables.into_iter().enumerate() {
            a.submit(Job { idx, table: table.clone(), knobs: knob_grid[idx], block: None });
            b.submit(Job { idx, table, knobs: knob_grid[idx], block: None });
        }
        let (mut sa, mut sb) = (vec![f64::NAN; n], vec![f64::NAN; n]);
        for _ in 0..n {
            let da = a.collect().expect("pool alive");
            sa[da.idx] = da.score;
            let db = b.collect().expect("pool alive");
            sb[db.idx] = db.score;
        }
        assert_eq!(sa, serial, "client A bit-identical under multiplexing");
        assert_eq!(sb, serial, "client B (collapse) bit-identical");
    }

    /// Satellite regression (ISSUE 8): a worker thread hard-aborted
    /// outside the per-job catch loses exactly its in-flight job (NaN
    /// sentinel, no hang), is respawned, and the next batch on the
    /// same pool is served completely and bit-identically.
    #[test]
    fn aborted_worker_is_respawned_and_loses_only_its_job() {
        let (_prof, caps, tables, knob_grid, serial) = fixture();
        let n = tables.len();
        let pool = EvalPool::new(2);
        pool.inject_worker_abort(1);

        let client =
            pool.client(EvalCtx { caps: caps.clone(), nmb: 8, collapse: false });
        for (idx, table) in tables.iter().cloned().enumerate() {
            client.submit(Job { idx, table, knobs: knob_grid[idx], block: None });
        }
        let mut scores = vec![f64::NAN; n];
        let mut lost = 0usize;
        for _ in 0..n {
            let done = client.collect().expect("sentinel covers the dead worker");
            if done.score.is_nan() {
                lost += 1;
            } else {
                scores[done.idx] = done.score;
            }
        }
        assert_eq!(lost, 1, "exactly the aborted job is lost");
        assert_eq!(
            scores.iter().filter(|s| !s.is_nan()).count(),
            n - 1,
            "every other job completes"
        );
        for (s, want) in scores.iter().zip(&serial) {
            assert!(s.is_nan() || s == want, "survivors stay bit-identical");
        }
        assert_eq!(pool.workers_lost(), 1);
        drop(client);

        // The respawned worker restores full capacity: a fresh batch
        // on the same pool completes with serial-identical scores.
        let client = pool.client(EvalCtx { caps, nmb: 8, collapse: false });
        for (idx, table) in tables.into_iter().enumerate() {
            client.submit(Job { idx, table, knobs: knob_grid[idx], block: None });
        }
        let mut again = vec![f64::NAN; n];
        for _ in 0..n {
            let done = client.collect().expect("pool alive after respawn");
            again[done.idx] = done.score;
        }
        assert_eq!(again, serial, "post-respawn batch is bit-identical");
    }
}
