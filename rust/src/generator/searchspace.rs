//! Search-space size accounting (paper Fig 4): how many distinct model
//! partitions, model placements and workload schedules exist — the
//! combinatorial explosion motivating phase-by-phase tuning.
//!
//! All counts are returned as log10 (the raw numbers overflow u128
//! quickly, and the paper plots them on a log axis anyway).

/// log10 of C(n, k).
pub fn log10_choose(n: u64, k: u64) -> f64 {
    if k > n {
        return f64::NEG_INFINITY;
    }
    let k = k.min(n - k);
    let mut acc = 0.0f64;
    for i in 0..k {
        acc += ((n - i) as f64).log10() - ((i + 1) as f64).log10();
    }
    acc
}

/// log10 of n!.
pub fn log10_factorial(n: u64) -> f64 {
    (1..=n).map(|i| (i as f64).log10()).sum()
}

/// Number of model partitions: choose S-1 cut points among L-1 gaps.
pub fn log10_partitions(layers: u64, stages: u64) -> f64 {
    log10_choose(layers - 1, stages - 1)
}

/// Number of model placements: surjections of S stages onto P devices
/// ≈ P^S (upper bound the paper plots); exact would subtract
/// non-covering maps — negligible on a log axis for S ≫ P.
pub fn log10_placements(stages: u64, devices: u64) -> f64 {
    stages as f64 * (devices as f64).log10()
}

/// Number of workload schedules: per device, interleavings of its
/// F/B/W slots.  Lower bound: multinomial orderings of nmb·3 ops per
/// device across P devices ≈ ((3·nmb)!)^P — we report per-device
/// log10((3 nmb)!) · P.
pub fn log10_schedules(nmb: u64, devices: u64) -> f64 {
    log10_factorial(3 * nmb) * devices as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn choose_matches_small_cases() {
        assert!((log10_choose(5, 2) - (10f64).log10()).abs() < 1e-12);
        assert!((log10_choose(10, 0) - 0.0).abs() < 1e-12);
        assert_eq!(log10_choose(3, 5), f64::NEG_INFINITY);
    }

    #[test]
    fn factorial_matches() {
        assert!((log10_factorial(5) - 120f64.log10()).abs() < 1e-12);
    }

    #[test]
    fn growth_is_explosive() {
        // Fig 4's qualitative claim: schedules ≫ placements ≫ partitions.
        let parts = log10_partitions(66, 8);
        let places = log10_placements(16, 8);
        let scheds = log10_schedules(64, 8);
        assert!(parts < places && places < scheds);
        assert!(scheds > 100.0); // astronomically large
    }
}
