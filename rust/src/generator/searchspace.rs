//! Search-space size accounting (paper Fig 4): how many distinct model
//! partitions, model placements and workload schedules exist — the
//! combinatorial explosion motivating phase-by-phase tuning.
//!
//! All counts are returned as log10 (the raw numbers overflow u128
//! quickly, and the paper plots them on a log axis anyway).
//!
//! Since the schedule-synthesis refactor this module also hosts the
//! Generator's **fourth search knob**: [`block_moves`], the move
//! generator over [`BlockIr`] parameters.  Where the raw schedule
//! space is doubly exponential ([`log10_schedules`]), the block IR
//! parameterizes its structured slice with ~`4·P` small integers
//! ([`log10_blocks`]) — small enough for the same hill climb that
//! tunes partitions and placements.

use std::sync::Arc;

use crate::partition::{balanced, uniform};
use crate::perfmodel::StageTable;
use crate::placement::sequential;
use crate::profile::ProfiledData;
use crate::schedule::block::{v_mem, v_placement, BlockIr, Pattern, StashRule};

use super::cache::PrepPool;
use super::{Cand, GenOptions, Prepared};

/// Block-phase moves.  With no incumbent block the batch *introduces*
/// candidates: the memory-controllable V family over `wave(p, 2)`
/// (shapes the greedy list scheduler cannot express — `v_mem(p, ·,
/// 2p)` is exactly ZB-V) plus, on small pipelines, an ILP-synthesized
/// block distilled from a provably optimal probe schedule.  With an
/// incumbent block the batch proposes local parameter steps: warmup
/// offsets (±1 jointly and per device), chunk lag, pattern, group,
/// backward split, and stash budget.  Leaving the family back to the
/// greedy scheduler is proposed by the schedule phase, never here.
///
/// Deterministic: move order is fixed, and every candidate is deduped
/// against the incumbent by [`BlockIr::key_bits`].
pub(super) fn block_moves(
    profile: &ProfiledData,
    pool: &mut PrepPool,
    cur: &Cand,
    cur_table: &StageTable,
    opts: &GenOptions,
) -> Vec<Prepared> {
    let p = opts.p;
    let nmb = opts.nmb;
    let mut out = Vec::new();
    match cur.block.as_deref() {
        None => {
            if 2 * p <= profile.n_layers() {
                let part = Arc::new(balanced(profile, 2 * p));
                let plac = Arc::new(v_placement(p));
                let mut seen: Vec<Vec<u32>> = Vec::new();
                let mut lifespans = vec![2 * p, 1, 2, p];
                if let Some(k) = opts.block_stash {
                    lifespans.push(k as usize);
                }
                for ls in lifespans {
                    let block = v_mem(p, nmb, ls.max(1));
                    let bits = block.key_bits();
                    if seen.contains(&bits) {
                        continue;
                    }
                    seen.push(bits);
                    out.push(Prepared::fresh(
                        profile,
                        pool,
                        format!("enter block {} (lifespan {ls})", block.family()),
                        Cand {
                            part: Arc::clone(&part),
                            plac: Arc::clone(&plac),
                            knobs: cur.knobs,
                            block: Some(Arc::new(block)),
                        },
                    ));
                }
            }
            // ILP-distilled block: only on pipelines small enough for
            // the probe to *prove* optimality in (micro)seconds on any
            // machine — an incomplete probe is discarded, and a probe
            // whose completion straddled the wall-clock budget would
            // make the move set machine- and run-dependent.  At p ≤ 2
            // the probe tree is a few thousand nodes, so completion is
            // unconditional in practice.
            if p <= 2 && p <= profile.n_layers() {
                if let Some(block) = crate::ilp::synthesize_block(profile, p, nmb, 0.25) {
                    out.push(Prepared::fresh(
                        profile,
                        pool,
                        format!("enter block {} (ilp)", block.family()),
                        Cand {
                            part: Arc::new(uniform(profile.n_layers(), p)),
                            plac: Arc::new(sequential(p)),
                            knobs: cur.knobs,
                            block: Some(Arc::new(block)),
                        },
                    ));
                }
            }
        }
        Some(b) => {
            let cur_bits = b.key_bits();
            let mut push = |desc: String, block: BlockIr, pool: &mut PrepPool| {
                if block.key_bits() == cur_bits {
                    return;
                }
                out.push(Prepared {
                    desc,
                    cand: Cand {
                        part: Arc::clone(&cur.part),
                        plac: Arc::clone(&cur.plac),
                        knobs: cur.knobs,
                        block: Some(Arc::new(block)),
                    },
                    table: pool.take_like(cur_table),
                });
            };
            // Warmup depth: joint ±1, then per-device ±1.
            let mut deeper = b.clone();
            for o in &mut deeper.offsets {
                *o += 1;
            }
            push("block warmup +1".into(), deeper, pool);
            let mut shallower = b.clone();
            for o in &mut shallower.offsets {
                *o = o.saturating_sub(1);
            }
            push("block warmup -1".into(), shallower, pool);
            for d in 0..p {
                let mut up = b.clone();
                up.offsets[d] += 1;
                push(format!("block dev{d} offset +1"), up, pool);
                let mut down = b.clone();
                down.offsets[d] = down.offsets[d].saturating_sub(1);
                push(format!("block dev{d} offset -1"), down, pool);
            }
            // Chunk lag (the V-schedule shape knob): joint ±1.
            let mut lagged = b.clone();
            for l in &mut lagged.lag {
                *l += 1;
            }
            push("block lag +1".into(), lagged, pool);
            let mut unlagged = b.clone();
            for l in &mut unlagged.lag {
                *l = l.saturating_sub(1);
            }
            push("block lag -1".into(), unlagged, pool);
            // Interleaving pattern and grouping.
            let mut flipped = b.clone();
            flipped.pattern = match b.pattern {
                Pattern::FThenB => Pattern::BThenF,
                Pattern::BThenF => Pattern::FThenB,
            };
            push("block pattern flip".into(), flipped, pool);
            let mut regrouped = b.clone();
            regrouped.group = if b.group == 1 { p.max(1) } else { 1 };
            push(format!("block group {}", regrouped.group), regrouped, pool);
            // Backward split + stash budget (memory-controllability).
            let mut resplit = b.clone();
            resplit.split_bw = !b.split_bw;
            resplit.stash = StashRule::Warmup;
            push("block split flip".into(), resplit, pool);
            if b.split_bw {
                let budget0 = opts.block_stash.unwrap_or((nmb as u32) / 2).max(1);
                let steps: Vec<StashRule> = match b.stash {
                    StashRule::Warmup => {
                        vec![StashRule::Fixed(1), StashRule::Fixed(budget0)]
                    }
                    StashRule::Fixed(k) => vec![
                        StashRule::Fixed(k + 1),
                        StashRule::Fixed(k.saturating_sub(1).max(1)),
                        StashRule::Warmup,
                    ],
                };
                for stash in steps {
                    let mut stashed = b.clone();
                    stashed.stash = stash;
                    push(format!("block stash {stash:?}"), stashed, pool);
                }
            }
        }
    }
    out
}

/// log10 of C(n, k).
pub fn log10_choose(n: u64, k: u64) -> f64 {
    if k > n {
        return f64::NEG_INFINITY;
    }
    let k = k.min(n - k);
    let mut acc = 0.0f64;
    for i in 0..k {
        acc += ((n - i) as f64).log10() - ((i + 1) as f64).log10();
    }
    acc
}

/// log10 of n!.
pub fn log10_factorial(n: u64) -> f64 {
    (1..=n).map(|i| (i as f64).log10()).sum()
}

/// Number of model partitions: choose S-1 cut points among L-1 gaps.
pub fn log10_partitions(layers: u64, stages: u64) -> f64 {
    log10_choose(layers - 1, stages - 1)
}

/// Number of model placements: surjections of S stages onto P devices
/// ≈ P^S (upper bound the paper plots); exact would subtract
/// non-covering maps — negligible on a log axis for S ≫ P.
pub fn log10_placements(stages: u64, devices: u64) -> f64 {
    stages as f64 * (devices as f64).log10()
}

/// Number of workload schedules: per device, interleavings of its
/// F/B/W slots.  Lower bound: multinomial orderings of nmb·3 ops per
/// device across P devices ≈ ((3·nmb)!)^P — we report per-device
/// log10((3 nmb)!) · P.
pub fn log10_schedules(nmb: u64, devices: u64) -> f64 {
    log10_factorial(3 * nmb) * devices as f64
}

/// Number of block-IR instances over `P` devices: 2 patterns × 2 split
/// settings × `P` groups × warmup offsets in `[0, 2·nmb)` per device ×
/// chunk lags in `[0, P)` per device × (`Warmup` + `nmb` fixed stash
/// budgets).  Polynomially many parameters — the point of the IR: the
/// structured slice of the doubly-exponential schedule space that the
/// same hill climb that tunes partitions can walk.
pub fn log10_blocks(nmb: u64, devices: u64) -> f64 {
    let (p, n) = (devices as f64, nmb as f64);
    (2.0f64).log10()
        + (2.0f64).log10()
        + p.log10()
        + p * (2.0 * n).log10()
        + p * p.log10()
        + (n + 1.0).log10()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn choose_matches_small_cases() {
        assert!((log10_choose(5, 2) - (10f64).log10()).abs() < 1e-12);
        assert!((log10_choose(10, 0) - 0.0).abs() < 1e-12);
        assert_eq!(log10_choose(3, 5), f64::NEG_INFINITY);
    }

    #[test]
    fn factorial_matches() {
        assert!((log10_factorial(5) - 120f64.log10()).abs() < 1e-12);
    }

    #[test]
    fn growth_is_explosive() {
        // Fig 4's qualitative claim: schedules ≫ placements ≫ partitions.
        let parts = log10_partitions(66, 8);
        let places = log10_placements(16, 8);
        let scheds = log10_schedules(64, 8);
        assert!(parts < places && places < scheds);
        assert!(scheds > 100.0); // astronomically large
    }

    #[test]
    fn block_space_is_a_tractable_slice() {
        // The IR's reason to exist: its parameter space is tiny next
        // to the raw schedule space it carves structure out of, yet
        // big enough that enumeration stays off the table and local
        // search is the right tool.
        let blocks = log10_blocks(64, 8);
        let scheds = log10_schedules(64, 8);
        assert!(blocks < scheds / 10.0, "blocks {blocks} vs schedules {scheds}");
        assert!(blocks > 6.0, "still far beyond exhaustive enumeration: {blocks}");
    }
}
