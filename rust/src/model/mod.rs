//! Model structure: the fine-grained layer taxonomy, per-family model
//! builders (Table 5), and the analytical per-layer cost model.

pub mod cost;
pub mod layers;

pub use cost::{CostModel, LayerCost};
pub use layers::{build_model, LayerKind, ModelSpec};
