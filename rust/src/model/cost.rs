//! Analytical per-layer cost model, calibrated to the paper's H800
//! testbed (DESIGN.md §Substitutions: this replaces the profiled GPU
//! timings the paper feeds its Pipeline Performance Model; the
//! performance model itself only consumes the resulting per-layer
//! numbers, so the source is orthogonal).
//!
//! Per layer we derive forward FLOPs + bytes, then roofline time
//! `max(flops / (peak·eff), bytes / mem_bw) + op_overhead`, with the
//! backward split into input-grad (B) and param-grad (W) following the
//! ZB decomposition.  Tensor parallel divides matmul work by `T` and
//! adds an all-reduce term; expert parallel adds all-to-all for MoE.

use crate::config::{HardwareCfg, ModelCfg, ParallelCfg};
use crate::model::layers::LayerKind;

/// Per-layer cost record — everything the performance model needs.
#[derive(Clone, Copy, Debug, Default)]
pub struct LayerCost {
    /// Forward time (s) per micro-batch.
    pub f: f64,
    /// Input-grad backward time (s) per micro-batch.
    pub b: f64,
    /// Param-grad backward time (s) per micro-batch.
    pub w: f64,
    /// Parameter + gradient + optimizer memory (bytes, TP-sharded).
    pub mem_static: f64,
    /// Activation stash bytes per in-flight micro-batch: the backward
    /// working set saved at F (layer input + stashed intermediates, the
    /// ZB-paper taxonomy — see `memory/`).
    pub mem_act: f64,
    /// The slice of `mem_act` a *delayed* param-grad (W) still needs —
    /// the layer input feeding the dW matmuls.  The rest is consumed by
    /// the input-grad B and released when B completes.
    pub mem_act_w: f64,
    /// Output activation message size (bytes) if the next layer is on
    /// another device.
    pub comm_bytes: f64,
}

impl LayerCost {
    /// Fused backward (no B/W split) time.
    pub fn bw_fused(&self) -> f64 {
        self.b + self.w
    }
}

/// The cost model: hardware + parallelism context.
#[derive(Clone, Debug)]
pub struct CostModel {
    pub hw: HardwareCfg,
    pub par: ParallelCfg,
}

impl CostModel {
    pub fn new(hw: HardwareCfg, par: ParallelCfg) -> Self {
        CostModel { hw, par }
    }

    /// Cost of one layer of `kind` within `cfg`.
    pub fn layer(&self, kind: LayerKind, cfg: &ModelCfg) -> LayerCost {
        let n = self.par.tokens() as f64; // tokens per micro-batch
        let t = self.par.t as f64;
        let h = cfg.hidden as f64;
        let f = cfg.ffn_hidden as f64;
        let v = cfg.vocab as f64;
        let r = cfg.kv_latent as f64;
        let nn = cfg.ssm_state as f64;
        let e = cfg.experts as f64;
        let fm = cfg.moe_hidden as f64;
        let k = cfg.topk as f64;
        let s = self.par.seq as f64;
        let bytes_f32 = 4.0;

        // (fwd matmul flops, fwd attention-like flops, param count)
        let (mm_flops, irr_flops, params) = match kind {
            LayerKind::Embed => (0.0, 0.0, v * h),
            LayerKind::Sa => {
                // qkvo projections + QK^T + PV (causal halves the score
                // matmul work).
                (8.0 * n * h * h, 2.0 * n * s * h, 4.0 * h * h)
            }
            LayerKind::Mla => {
                // q proj + down-proj + two up-projs + o proj + attention.
                (
                    2.0 * n * h * h + 2.0 * n * h * r + 4.0 * n * r * h + 2.0 * n * h * h,
                    2.0 * n * s * h,
                    2.0 * h * h + h * r + 2.0 * r * h,
                )
            }
            LayerKind::Mamba => {
                // B/C projections + out proj; the scan itself is
                // elementwise (memory-bound, counted via bytes below).
                (
                    4.0 * n * h * nn + 2.0 * n * h * h,
                    10.0 * n * h * nn,
                    2.0 * h * nn + h * nn + 3.0 * h + h * h,
                )
            }
            LayerKind::Ffn => (4.0 * n * h * f, 0.0, 2.0 * h * f + f + h),
            LayerKind::Moe => {
                // gate + top-k expert FFNs (per token only k experts do
                // work — the real sparse cost, not our dense AOT fallback).
                (
                    2.0 * n * h * e + k * 4.0 * n * h * fm,
                    0.0,
                    h * e + e * (2.0 * h * fm + fm + h),
                )
            }
            LayerKind::Head => (2.0 * n * h * v, 5.0 * n * v, h * v),
        };

        // Bytes moved (fwd): read input + weights + write output.
        let act_bytes = n * h * bytes_f32;
        let weight_bytes = params * bytes_f32 / t;
        let scan_bytes = if kind == LayerKind::Mamba {
            // state (h·N) per token — the scan's HBM traffic if not fused;
            // the fused kernel keeps state in VMEM, ~3x act traffic.
            3.0 * n * h * bytes_f32
        } else {
            0.0
        };
        let fwd_bytes = 2.0 * act_bytes + weight_bytes + scan_bytes;

        let mm_time = mm_flops / t / (self.hw.flops_peak * self.hw.eff_matmul);
        let irr_time = irr_flops / t / (self.hw.flops_peak * self.hw.eff_attn);
        let mem_time = fwd_bytes / self.hw.mem_bw;
        // TP all-reduce per layer boundary (ring): 2(T-1)/T · act bytes.
        let tp_comm = if self.par.t > 1 && kind.is_hidden() {
            2.0 * (t - 1.0) / t * act_bytes / self.hw.tp_link_bw
        } else {
            0.0
        };
        // EP all-to-all for MoE.
        let ep_comm = if kind == LayerKind::Moe && self.par.e > 1 {
            2.0 * act_bytes * (self.par.e as f64 - 1.0) / self.par.e as f64 / self.hw.link_bw
        } else {
            0.0
        };

        let f_time =
            (mm_time + irr_time).max(mem_time) + tp_comm + ep_comm + self.hw.op_overhead;

        // Backward decomposition (ZB): B (input grad) re-runs roughly the
        // forward matmuls transposed; W (param grad) is the dW matmuls.
        // Embed has no B (input is ids); Head's B is the softmax+matmul
        // pullback (~fwd); elementwise-heavy layers put most of B in the
        // irregular term.
        let (b_time, w_time) = match kind {
            LayerKind::Embed => (0.0, mem_time + self.hw.op_overhead),
            _ => {
                let b = f_time - self.hw.op_overhead + irr_time; // dx: fwd-like + attn pullback
                let w = (mm_time).max(weight_bytes / self.hw.mem_bw);
                (
                    b + self.hw.op_overhead,
                    w + self.hw.op_overhead,
                )
            }
        };

        // Static memory: params + grads (fp32) + Adam moments (2×fp32).
        // memory/model.rs decomposes this 4× packing — keep in sync.
        let mem_static = 4.0 * weight_bytes;
        // Saved activations per in-flight micro-batch (ZB taxonomy,
        // consumed by `memory/`): the layer input plus the stashed
        // intermediates the input-grad B consumes.  Only the input
        // (`mem_act_w`) must survive until a delayed W; intermediates
        // are TP-sharded, inputs are TP-replicated.
        let input_bytes = match kind {
            LayerKind::Embed => n * bytes_f32, // ids (i32)
            _ => act_bytes,
        };
        let saved_intermediates = match kind {
            LayerKind::Embed => 0.0,
            // Logits are recomputed in the head backward (too big to stash).
            LayerKind::Head => 0.0,
            LayerKind::Sa => 4.0 * act_bytes / t, // q, k, v, attn out
            LayerKind::Mla => (2.0 * r / h + 2.0) * act_bytes / t, // latents, q, out
            LayerKind::Mamba => 3.0 * act_bytes / t, // gate + scan checkpoints
            LayerKind::Ffn => 2.0 * (f / h) * act_bytes / t, // up & gate projections
            LayerKind::Moe => 2.0 * k * (fm / h) * act_bytes / t, // top-k expert FFNs
        };
        let mem_act = input_bytes + saved_intermediates;
        let mem_act_w = input_bytes;
        // P2P message: hidden activations (head/embed boundaries also
        // move act-sized tensors: embed output, head input).
        let comm_bytes = act_bytes / t;

        LayerCost { f: f_time, b: b_time, w: w_time, mem_static, mem_act, mem_act_w, comm_bytes }
    }

    /// Costs for every layer of a model spec.
    pub fn model_costs(&self, spec: &crate::model::ModelSpec) -> Vec<LayerCost> {
        spec.layers.iter().map(|&k| self.layer(k, &spec.cfg)).collect()
    }

    /// P2P transfer time for `bytes` over the pipeline link.
    pub fn p2p_time(&self, bytes: f64) -> f64 {
        self.hw.link_latency + bytes / self.hw.link_bw
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Family, ModelCfg, Size};
    use crate::model::build_model;

    fn cm() -> CostModel {
        CostModel::new(HardwareCfg::default(), ParallelCfg::new(4, 2, 16, 1, 4096))
    }

    #[test]
    fn head_dominates_on_gemma() {
        // The paper's core observation: the vocab head is worth many
        // transformer blocks on Gemma.
        let cfg = ModelCfg::table5(Family::Gemma, Size::Small);
        let m = cm();
        let head = m.layer(LayerKind::Head, &cfg);
        let sa = m.layer(LayerKind::Sa, &cfg);
        let ffn = m.layer(LayerKind::Ffn, &cfg);
        let block = sa.f + ffn.f;
        assert!(
            head.f > 4.0 * block,
            "head {:.3e} should dwarf block {:.3e}",
            head.f,
            block
        );
    }

    #[test]
    fn llama2_is_balanced() {
        // Small vocab: head comparable to a couple of blocks, not 10+.
        let cfg = ModelCfg::table5(Family::Llama2, Size::Small);
        let m = cm();
        let head = m.layer(LayerKind::Head, &cfg);
        let sa = m.layer(LayerKind::Sa, &cfg);
        let ffn = m.layer(LayerKind::Ffn, &cfg);
        assert!(head.f < 2.0 * (sa.f + ffn.f));
    }

    #[test]
    fn backward_costs_exceed_forward() {
        let cfg = ModelCfg::table5(Family::NemotronH, Size::Small);
        let m = cm();
        for &k in &[LayerKind::Sa, LayerKind::Mamba, LayerKind::Ffn] {
            let c = m.layer(k, &cfg);
            assert!(c.bw_fused() > c.f, "{k:?}: bw {} !> f {}", c.bw_fused(), c.f);
            assert!(c.b > 0.0 && c.w > 0.0);
        }
    }

    #[test]
    fn tp_divides_compute() {
        // On the compute-dominated head layer TP must pay off; weights
        // shard for every layer.
        let cfg = ModelCfg::table5(Family::Gemma, Size::Small);
        let hw = HardwareCfg::default();
        let t1 = CostModel::new(hw, ParallelCfg::new(4, 1, 16, 1, 4096));
        let t4 = CostModel::new(hw, ParallelCfg::new(4, 4, 16, 1, 4096));
        let c1 = t1.layer(LayerKind::Head, &cfg);
        let c4 = t4.layer(LayerKind::Head, &cfg);
        assert!(c4.f < c1.f);
        let f1 = t1.layer(LayerKind::Ffn, &cfg);
        let f4 = t4.layer(LayerKind::Ffn, &cfg);
        assert!(f4.mem_static < f1.mem_static);
    }

    #[test]
    fn model_costs_cover_all_layers() {
        let spec = build_model(&ModelCfg::table5(Family::DeepSeek, Size::Small));
        let costs = cm().model_costs(&spec);
        assert_eq!(costs.len(), spec.n_layers());
        assert!(costs.iter().all(|c| c.f > 0.0));
    }

    #[test]
    fn activation_taxonomy_is_consistent() {
        // The W-retained slice is a non-empty subset of the stash, and
        // layers with backward intermediates stash more than the input.
        let cfg = ModelCfg::table5(Family::DeepSeek, Size::Small);
        let m = cm();
        for &k in &[
            LayerKind::Embed,
            LayerKind::Sa,
            LayerKind::Mla,
            LayerKind::Mamba,
            LayerKind::Ffn,
            LayerKind::Moe,
            LayerKind::Head,
        ] {
            let c = m.layer(k, &cfg);
            assert!(c.mem_act_w > 0.0 && c.mem_act_w <= c.mem_act, "{k:?}");
        }
        let ffn = m.layer(LayerKind::Ffn, &cfg);
        assert!(ffn.mem_act > ffn.mem_act_w, "FFN must stash intermediates");
    }

    #[test]
    fn moe_counts_topk_only() {
        let mut cfg = ModelCfg::table5(Family::DeepSeek, Size::Small);
        let m = cm();
        let c2 = m.layer(LayerKind::Moe, &cfg);
        cfg.topk = 4;
        let c4 = m.layer(LayerKind::Moe, &cfg);
        assert!(c4.f > c2.f);
    }
}
