//! Layer taxonomy and per-family model builders.
//!
//! A model is a *flat* list of fine-grained layers — the unit of model
//! partition, and exactly the granularity of the AOT artifacts (one
//! HLO executable per `LayerKind` × op), so every partition the
//! Pipeline Generator emits is executable from one artifact set.

use crate::config::{Family, ModelCfg};

/// Fine-grained layer kinds (mirrors python/compile/layers.py).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum LayerKind {
    Embed,
    Sa,
    Mla,
    Mamba,
    Ffn,
    Moe,
    Head,
}

impl LayerKind {
    pub fn name(&self) -> &'static str {
        match self {
            LayerKind::Embed => "embed",
            LayerKind::Sa => "sa",
            LayerKind::Mla => "mla",
            LayerKind::Mamba => "mamba",
            LayerKind::Ffn => "ffn",
            LayerKind::Moe => "moe",
            LayerKind::Head => "head",
        }
    }

    pub fn from_name(s: &str) -> Option<LayerKind> {
        Some(match s {
            "embed" => LayerKind::Embed,
            "sa" => LayerKind::Sa,
            "mla" => LayerKind::Mla,
            "mamba" => LayerKind::Mamba,
            "ffn" => LayerKind::Ffn,
            "moe" => LayerKind::Moe,
            "head" => LayerKind::Head,
            _ => return None,
        })
    }

    /// Whether this layer takes/produces hidden activations on both
    /// sides (false only for Embed input and Head output).
    pub fn is_hidden(&self) -> bool {
        !matches!(self, LayerKind::Embed | LayerKind::Head)
    }
}

/// A concrete model: hyper-parameters + flat layer list.
#[derive(Clone, Debug)]
pub struct ModelSpec {
    pub cfg: ModelCfg,
    pub layers: Vec<LayerKind>,
}

impl ModelSpec {
    pub fn n_layers(&self) -> usize {
        self.layers.len()
    }

    pub fn label(&self) -> String {
        self.cfg.label()
    }
}

/// Expand a `ModelCfg` into the flat layer list.
///
/// Family block patterns (one "block" = the paper's layer unit):
/// - LLaMA-2 / Gemma: `[sa, ffn]` — Gemma differs only by vocab scale.
/// - DeepSeek: first quarter `[mla, ffn]` (dense), rest `[mla, moe]`
///   (the paper: "dense FFNs in the first k layers … sparse MoE later").
/// - Nemotron-H: Mamba-dominant hybrid — every 4th block is
///   `[sa, ffn]`, the others `[mamba, ffn]` (the published model is
///   ~92% Mamba with periodic attention).
pub fn build_model(cfg: &ModelCfg) -> ModelSpec {
    let mut layers = vec![LayerKind::Embed];
    for b in 0..cfg.blocks {
        match cfg.family {
            Family::Llama2 | Family::Gemma => {
                layers.push(LayerKind::Sa);
                layers.push(LayerKind::Ffn);
            }
            Family::DeepSeek => {
                layers.push(LayerKind::Mla);
                if b < cfg.blocks / 4 {
                    layers.push(LayerKind::Ffn);
                } else {
                    layers.push(LayerKind::Moe);
                }
            }
            Family::NemotronH => {
                if b % 4 == 3 {
                    layers.push(LayerKind::Sa);
                } else {
                    layers.push(LayerKind::Mamba);
                }
                layers.push(LayerKind::Ffn);
            }
        }
    }
    layers.push(LayerKind::Head);
    ModelSpec { cfg: cfg.clone(), layers }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Family, ModelCfg, Size};

    #[test]
    fn gemma_structure() {
        let m = build_model(&ModelCfg::table5(Family::Gemma, Size::Small));
        assert_eq!(m.layers[0], LayerKind::Embed);
        assert_eq!(*m.layers.last().unwrap(), LayerKind::Head);
        assert_eq!(m.n_layers(), 2 + 2 * 32);
        assert!(m.layers[1..m.n_layers() - 1]
            .iter()
            .all(|l| matches!(l, LayerKind::Sa | LayerKind::Ffn)));
    }

    #[test]
    fn deepseek_dense_then_moe() {
        let m = build_model(&ModelCfg::table5(Family::DeepSeek, Size::Small));
        let n_moe = m.layers.iter().filter(|&&l| l == LayerKind::Moe).count();
        let n_ffn = m.layers.iter().filter(|&&l| l == LayerKind::Ffn).count();
        assert_eq!(n_ffn, 4); // 16 blocks / 4
        assert_eq!(n_moe, 12);
        // Dense blocks strictly before MoE blocks.
        let first_moe = m.layers.iter().position(|&l| l == LayerKind::Moe).unwrap();
        let last_ffn = m.layers.iter().rposition(|&l| l == LayerKind::Ffn).unwrap();
        assert!(last_ffn < first_moe);
    }

    #[test]
    fn nemotron_hybrid() {
        let m = build_model(&ModelCfg::table5(Family::NemotronH, Size::Small));
        let n_sa = m.layers.iter().filter(|&&l| l == LayerKind::Sa).count();
        let n_mamba = m.layers.iter().filter(|&&l| l == LayerKind::Mamba).count();
        assert_eq!(n_sa, 7); // every 4th of 28
        assert_eq!(n_mamba, 21);
    }

    #[test]
    fn kind_names_roundtrip() {
        for k in [
            LayerKind::Embed,
            LayerKind::Sa,
            LayerKind::Mla,
            LayerKind::Mamba,
            LayerKind::Ffn,
            LayerKind::Moe,
            LayerKind::Head,
        ] {
            assert_eq!(LayerKind::from_name(k.name()), Some(k));
        }
    }
}
