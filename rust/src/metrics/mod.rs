//! Experiment metrics: throughput accounting (with data-parallel
//! gradient synchronisation), bubble ratios, scaling efficiency, and a
//! tiny fixed-width table formatter shared by the figure harnesses.

use crate::config::{HardwareCfg, ParallelCfg};
use crate::perfmodel::PerfReport;

/// Tokens/second of the whole cluster for one simulated pipeline step:
/// `d` data-parallel replicas each process `nmb·tokens` per step, and
/// every step pays a gradient all-reduce over the largest per-device
/// parameter shard (ring over the DP group, IB bandwidth).
pub fn cluster_throughput(report: &PerfReport, par: &ParallelCfg, hw: &HardwareCfg) -> f64 {
    let tokens = (par.nmb * par.tokens() * par.d) as f64;
    tokens / step_time(report, par, hw)
}

/// Step wall time: pipeline makespan + DP all-reduce of gradients.
pub fn step_time(report: &PerfReport, par: &ParallelCfg, hw: &HardwareCfg) -> f64 {
    report.total + dp_sync_time(report, par, hw)
}

/// Ring all-reduce of the largest per-device gradient shard across the
/// DP group: `2(d−1)/d · bytes / bw`.
pub fn dp_sync_time(report: &PerfReport, par: &ParallelCfg, hw: &HardwareCfg) -> f64 {
    if par.d <= 1 {
        return 0.0;
    }
    // static_d = params+grads+opt = 4× params; grads = 1× params.
    let max_grad_bytes =
        report.static_d.iter().cloned().fold(0.0, f64::max) / 4.0;
    2.0 * (par.d as f64 - 1.0) / par.d as f64 * max_grad_bytes / hw.link_bw
}

/// Scaling efficiency vs a reference point (paper §5.7):
/// `(tput / tput_ref)` expressed in percent.
pub fn scaling_pct(tput: f64, tput_ref: f64) -> f64 {
    100.0 * tput / tput_ref.max(1e-12)
}

/// Fixed-width markdown-ish table builder for figure harness output.
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Table {
        Table { header: header.iter().map(|s| s.to_string()).collect(), rows: vec![] }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len());
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut w = vec![0usize; ncol];
        for c in 0..ncol {
            w[c] = self.header[c].chars().count();
            for r in &self.rows {
                w[c] = w[c].max(r[c].chars().count());
            }
        }
        let line = |cells: &[String]| -> String {
            let mut s = String::from("|");
            for (c, cell) in cells.iter().enumerate() {
                s.push(' ');
                s.push_str(cell);
                for _ in cell.chars().count()..w[c] {
                    s.push(' ');
                }
                s.push_str(" |");
            }
            s
        };
        let mut out = line(&self.header);
        out.push('\n');
        let sep: Vec<String> = w.iter().map(|&n| "-".repeat(n)).collect();
        out.push_str(&line(&sep));
        out.push('\n');
        for r in &self.rows {
            out.push_str(&line(r));
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_report(total: f64, static_d: Vec<f64>) -> PerfReport {
        let p = static_d.len();
        PerfReport {
            total,
            t_d: vec![total; p],
            busy_d: vec![total; p],
            bubble_d: vec![0.0; p],
            overlap_d: vec![0.0; p],
            comm_block_d: vec![0.0; p],
            m_d: static_d.clone(),
            headroom_d: vec![f64::INFINITY; p],
            static_d,
            oom: false,
            events: vec![],
        }
    }

    #[test]
    fn dp_sync_zero_for_single_replica() {
        let r = fake_report(1.0, vec![4e9, 4e9]);
        let par = ParallelCfg::new(2, 1, 4, 1, 1024);
        assert_eq!(dp_sync_time(&r, &par, &HardwareCfg::default()), 0.0);
    }

    #[test]
    fn throughput_scales_sublinearly_with_dp() {
        let hw = HardwareCfg::default();
        let r = fake_report(1.0, vec![40e9, 40e9]);
        let mut par = ParallelCfg::new(2, 1, 4, 1, 1024);
        let t1 = cluster_throughput(&r, &par, &hw);
        par.d = 8;
        let t8 = cluster_throughput(&r, &par, &hw);
        assert!(t8 > 4.0 * t1, "dp should still help: {t1} -> {t8}");
        assert!(t8 < 8.0 * t1, "but sub-linearly (allreduce cost)");
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["a", "long-header"]);
        t.row(vec!["x".into(), "1".into()]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 3);
        assert_eq!(lines[0].len(), lines[2].len());
    }
}
