//! `key=value` config-file / CLI-override parsing.
//!
//! Grammar: one `key = value` per line; `#` comments; sections are just
//! dotted keys (`hw.link_bw = 25e9`).  This is all the launcher needs —
//! a deliberate TOML subset.

use std::collections::BTreeMap;

/// Parse a kv config document into a flat map.
pub fn parse_kv(src: &str) -> Result<BTreeMap<String, String>, String> {
    let mut out = BTreeMap::new();
    for (lineno, raw) in src.lines().enumerate() {
        let line = match raw.find('#') {
            Some(i) => &raw[..i],
            None => raw,
        }
        .trim();
        if line.is_empty() {
            continue;
        }
        let (k, v) = line
            .split_once('=')
            .ok_or_else(|| format!("line {}: expected key=value, got {raw:?}", lineno + 1))?;
        let key = k.trim();
        if key.is_empty() {
            return Err(format!("line {}: empty key", lineno + 1));
        }
        out.insert(key.to_string(), v.trim().trim_matches('"').to_string());
    }
    Ok(out)
}

/// Typed accessors over the parsed map.
pub struct KvCfg(pub BTreeMap<String, String>);

impl KvCfg {
    pub fn from_str(src: &str) -> Result<Self, String> {
        parse_kv(src).map(KvCfg)
    }

    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.0.get(key).map(|s| s.as_str()).unwrap_or(default)
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.0
            .get(key)
            .and_then(|s| s.parse().ok())
            .unwrap_or(default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.0.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        self.0
            .get(key)
            .map(|s| matches!(s.as_str(), "1" | "true" | "yes" | "on"))
            .unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_basic() {
        let cfg = KvCfg::from_str(
            "model = gemma   # family\nhw.link_bw = 25e9\np=8\nsplit = true\n",
        )
        .unwrap();
        assert_eq!(cfg.str_or("model", "?"), "gemma");
        assert_eq!(cfg.f64_or("hw.link_bw", 0.0), 25e9);
        assert_eq!(cfg.usize_or("p", 0), 8);
        assert!(cfg.bool_or("split", false));
    }

    #[test]
    fn rejects_bad_lines() {
        assert!(parse_kv("just words\n").is_err());
        assert!(parse_kv("= novalue\n").is_err());
    }

    #[test]
    fn comments_and_blanks() {
        let m = parse_kv("# full comment\n\n a = 1 \n").unwrap();
        assert_eq!(m.get("a").unwrap(), "1");
        assert_eq!(m.len(), 1);
    }
}
