//! Configuration system: model families (paper Table 5), parallelism,
//! hardware, and training settings.
//!
//! Everything is constructible in code (library use) or from the tiny
//! key=value config format via [`parse_kv`] (launcher use) — the
//! vendored crate set has no serde, and a full TOML parser buys nothing
//! here.

pub mod kv;

pub use kv::parse_kv;

/// Model family — the heterogeneity axes of the paper's evaluation.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Family {
    /// Homogeneous baseline (Fig 1): SA+FFN blocks, small vocab.
    Llama2,
    /// Huge-vocabulary SA+FFN (Fig 1, §5): head-heavy.
    Gemma,
    /// MLA attention, dense FFN first quarter then MoE (Fig 1, §5).
    DeepSeek,
    /// Mamba+SA hybrid with FFN (Fig 1, §5).
    NemotronH,
}

impl Family {
    pub fn name(&self) -> &'static str {
        match self {
            Family::Llama2 => "LLaMA-2",
            Family::Gemma => "Gemma",
            Family::DeepSeek => "DeepSeek",
            Family::NemotronH => "Nemotron-H",
        }
    }
}

/// Paper Table 5 size tiers.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Size {
    Small,
    Medium,
    Large,
}

impl Size {
    pub fn name(&self) -> &'static str {
        match self {
            Size::Small => "Small",
            Size::Medium => "Medium",
            Size::Large => "Large",
        }
    }
}

/// Full model hyper-parameters (one row of Table 5 + derived dims).
#[derive(Clone, Debug)]
pub struct ModelCfg {
    pub family: Family,
    pub size: Size,
    /// Number of *blocks* (the paper's "L"); the flat layer list the
    /// partitioner sees has ~2L+2 fine-grained layers.
    pub blocks: usize,
    pub vocab: usize,
    pub hidden: usize,
    pub ffn_hidden: usize,
    pub heads: usize,
    pub head_dim: usize,
    /// MLA compressed-KV dim (DeepSeek only).
    pub kv_latent: usize,
    /// Mamba per-channel state size (Nemotron-H only).
    pub ssm_state: usize,
    /// MoE expert count (DeepSeek only; 1 = dense).
    pub experts: usize,
    pub moe_hidden: usize,
    /// Experts activated per token.
    pub topk: usize,
}

impl ModelCfg {
    /// Paper Table 5 rows (+ the LLaMA-2 config from Fig 1).
    pub fn table5(family: Family, size: Size) -> ModelCfg {
        use Family::*;
        use Size::*;
        let (blocks, vocab) = match (family, size) {
            (Gemma, Small) => (32, 256 << 10),
            (Gemma, Medium) => (64, 512 << 10),
            (Gemma, Large) => (128, 1024 << 10),
            (DeepSeek, Small) => (16, 128 << 10),
            (DeepSeek, Medium) => (32, 256 << 10),
            (DeepSeek, Large) => (64, 512 << 10),
            (NemotronH, Small) => (28, 128 << 10),
            (NemotronH, Medium) => (56, 256 << 10),
            (NemotronH, Large) => (112, 512 << 10),
            (Llama2, Small) => (32, 32 << 10),
            (Llama2, Medium) => (64, 32 << 10),
            (Llama2, Large) => (80, 32 << 10),
        };
        let hidden = match family {
            Gemma => 1536,
            DeepSeek => 2048,
            NemotronH => 1024,
            Llama2 => 4096,
        };
        let heads = hidden / 128;
        ModelCfg {
            family,
            size,
            blocks,
            vocab,
            hidden,
            ffn_hidden: 4 * hidden,
            heads,
            head_dim: 128,
            kv_latent: hidden / 4,
            ssm_state: 16,
            experts: if family == DeepSeek { 8 } else { 1 },
            moe_hidden: hidden, // fine-grained experts (DeepSeek-style)
            topk: 2,
        }
    }

    /// All nine Table 5 configs in paper order.
    pub fn all_table5() -> Vec<ModelCfg> {
        use Family::*;
        use Size::*;
        [Gemma, DeepSeek, NemotronH]
            .iter()
            .flat_map(|&f| {
                [Small, Medium, Large].iter().map(move |&s| ModelCfg::table5(f, s))
            })
            .collect()
    }

    pub fn label(&self) -> String {
        format!("{} ({})", self.family.name(), self.size.name())
    }
}

/// Parallelism + batching settings (paper Table 1 symbols).
#[derive(Clone, Copy, Debug)]
pub struct ParallelCfg {
    /// Pipeline parallel size — number of pipeline devices.
    pub p: usize,
    /// Tensor parallel size (divides per-layer compute & weights).
    pub t: usize,
    /// Data parallel size.
    pub d: usize,
    /// Expert parallel size.
    pub e: usize,
    /// Number of micro-batches per step (per pipeline).
    pub nmb: usize,
    /// Micro-batch size (sequences).
    pub mbs: usize,
    /// Sequence length.
    pub seq: usize,
}

impl ParallelCfg {
    pub fn new(p: usize, t: usize, nmb: usize, mbs: usize, seq: usize) -> Self {
        ParallelCfg { p, t, d: 1, e: 1, nmb, mbs, seq }
    }

    /// Tokens per micro-batch.
    pub fn tokens(&self) -> usize {
        self.mbs * self.seq
    }

    /// Global batch size in sequences (across DP replicas).
    pub fn gbs(&self) -> usize {
        self.nmb * self.mbs * self.d
    }

    pub fn gpus(&self) -> usize {
        self.p * self.t * self.d
    }
}

/// Hardware model — defaults calibrated to the paper's H800 testbed.
#[derive(Clone, Copy, Debug)]
pub struct HardwareCfg {
    /// Peak dense matmul throughput (flop/s), bf16 tensor core.
    pub flops_peak: f64,
    /// Achievable fraction of peak for large matmuls.
    pub eff_matmul: f64,
    /// Achievable fraction of peak for attention/scan (memory-irregular).
    pub eff_attn: f64,
    /// HBM bandwidth (B/s).
    pub mem_bw: f64,
    /// Pipeline P2P link bandwidth (B/s) — inter-node InfiniBand.
    pub link_bw: f64,
    /// Intra-node NVLink bandwidth for TP collectives (B/s).
    pub tp_link_bw: f64,
    /// P2P latency per message (s).
    pub link_latency: f64,
    /// Fixed per-op launch/dispatch overhead (s).
    pub op_overhead: f64,
    /// Device memory capacity (bytes).
    pub mem_capacity: f64,
}

impl Default for HardwareCfg {
    fn default() -> Self {
        HardwareCfg {
            flops_peak: 989e12, // H800 bf16 tensor
            eff_matmul: 0.42,
            eff_attn: 0.18,
            mem_bw: 3.35e12,
            link_bw: 25e9,    // IB per-GPU effective
            tp_link_bw: 200e9, // NVLink effective
            link_latency: 8e-6,
            op_overhead: 18e-6,
            mem_capacity: 80e9,
        }
    }
}

/// Training-run settings for the real trainer.
#[derive(Clone, Debug)]
pub struct TrainCfg {
    /// Artifact dims tag (see python/compile/dims.py).
    pub tag: String,
    pub steps: usize,
    pub lr: f32,
    pub seed: u64,
    pub log_every: usize,
}

impl Default for TrainCfg {
    fn default() -> Self {
        TrainCfg { tag: "micro".into(), steps: 20, lr: 0.1, seed: 0, log_every: 1 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table5_matches_paper() {
        let g = ModelCfg::table5(Family::Gemma, Size::Small);
        assert_eq!((g.blocks, g.vocab, g.hidden), (32, 262144, 1536));
        let d = ModelCfg::table5(Family::DeepSeek, Size::Large);
        assert_eq!((d.blocks, d.vocab, d.hidden), (64, 524288, 2048));
        let n = ModelCfg::table5(Family::NemotronH, Size::Medium);
        assert_eq!((n.blocks, n.vocab, n.hidden), (56, 262144, 1024));
    }

    #[test]
    fn parallel_derived() {
        let pc = ParallelCfg { p: 4, t: 2, d: 2, e: 1, nmb: 16, mbs: 1, seq: 4096 };
        assert_eq!(pc.tokens(), 4096);
        assert_eq!(pc.gbs(), 32);
        assert_eq!(pc.gpus(), 16);
    }
}
