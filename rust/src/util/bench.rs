//! Minimal benchmark harness (no criterion in the vendored crate set):
//! warms up, runs timed iterations, reports mean ± σ plus median and
//! p95 (robust to warmup-adjacent outliers — bench deltas across PRs
//! compare medians, not means), and derived throughput.
//! Used by the `cargo bench` targets (`harness = false`).

use std::time::Instant;

use crate::util::json::{num, obj, Json};
use crate::util::stats::{mean, percentile, std_dev};

/// Distribution summary of one benchmark run.
#[derive(Clone, Copy, Debug)]
pub struct BenchStats {
    pub mean: f64,
    pub sd: f64,
    pub median: f64,
    pub p95: f64,
    pub min: f64,
    pub max: f64,
    pub n: usize,
}

impl BenchStats {
    fn from_times(times: &[f64]) -> BenchStats {
        BenchStats {
            mean: mean(times),
            sd: std_dev(times),
            median: percentile(times, 50.0),
            p95: percentile(times, 95.0),
            min: times.iter().cloned().fold(f64::INFINITY, f64::min),
            max: times.iter().cloned().fold(0.0, f64::max),
            n: times.len(),
        }
    }

    /// Full distribution block for the `BENCH_*.json` artifacts —
    /// `iters` plus min/max alongside the medians let
    /// `scripts/bench_diff.py` judge cross-PR deltas against
    /// run-to-run noise.
    pub fn json(&self) -> Json {
        obj(vec![
            ("mean_s", num(self.mean)),
            ("sd_s", num(self.sd)),
            ("median_s", num(self.median)),
            ("p95_s", num(self.p95)),
            ("min_s", num(self.min)),
            ("max_s", num(self.max)),
            ("iters", num(self.n as f64)),
        ])
    }
}

/// Run `f` repeatedly for at least `min_iters` iterations and ~`budget`
/// seconds, print a criterion-style line, and return the distribution.
/// Warmup runs (two, or until ~20 ms elapses) are excluded from the
/// sample so first-call effects (allocation, page faults, lazy init)
/// don't skew the mean.
pub fn bench<F: FnMut()>(name: &str, min_iters: usize, budget_s: f64, mut f: F) -> BenchStats {
    // One guaranteed warmup; extras only while the warmup budget lasts
    // (so second-scale one-shot benches don't pay multiple spare runs).
    let warm_start = Instant::now();
    f();
    let mut warmups = 1usize;
    while warm_start.elapsed().as_secs_f64() < 0.02 && warmups < 16 {
        f();
        warmups += 1;
    }
    let mut times = Vec::new();
    let start = Instant::now();
    while times.len() < min_iters || start.elapsed().as_secs_f64() < budget_s {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64());
        if times.len() > 10_000 {
            break;
        }
    }
    let s = BenchStats::from_times(&times);
    println!(
        "bench {name:<44} {:>12}/iter  (±{:>10}, median {:>10}, p95 {:>10}, n={})",
        crate::util::fmt_time(s.mean),
        crate::util::fmt_time(s.sd),
        crate::util::fmt_time(s.median),
        crate::util::fmt_time(s.p95),
        s.n
    );
    s
}

/// Report a derived throughput metric alongside a bench.
pub fn report_rate(name: &str, per_iter_s: f64, units_per_iter: f64, unit: &str) {
    println!(
        "      {name:<44} {:>12} {unit}/s",
        crate::util::fmt_si(units_per_iter / per_iter_s)
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_are_consistent() {
        let s = bench("noop", 5, 0.0, || {
            std::hint::black_box(1 + 1);
        });
        assert!(s.n >= 5);
        assert!(s.min <= s.median && s.median <= s.p95 && s.p95 <= s.max);
        assert!(s.mean >= 0.0 && s.sd >= 0.0);
        let j = s.json();
        assert_eq!(j.get("iters").and_then(|x| x.as_usize()), Some(s.n));
        assert!(j.get("max_s").and_then(|x| x.as_f64()).unwrap() >= 0.0);
    }
}
