//! Minimal benchmark harness (no criterion in the vendored crate set):
//! warms up, runs timed iterations, reports mean ± σ and throughput.
//! Used by the `cargo bench` targets (`harness = false`).

use std::time::Instant;

use crate::util::stats::{mean, std_dev};

/// Run `f` repeatedly for at least `min_iters` iterations and ~`budget`
/// seconds, print a criterion-style line, and return mean seconds/iter.
pub fn bench<F: FnMut()>(name: &str, min_iters: usize, budget_s: f64, mut f: F) -> f64 {
    // Warmup.
    f();
    let mut times = Vec::new();
    let start = Instant::now();
    while times.len() < min_iters || start.elapsed().as_secs_f64() < budget_s {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64());
        if times.len() > 10_000 {
            break;
        }
    }
    let m = mean(&times);
    let sd = std_dev(&times);
    println!(
        "bench {name:<44} {:>12}/iter  (±{:>10}, n={})",
        crate::util::fmt_time(m),
        crate::util::fmt_time(sd),
        times.len()
    );
    m
}

/// Report a derived throughput metric alongside a bench.
pub fn report_rate(name: &str, per_iter_s: f64, units_per_iter: f64, unit: &str) {
    println!(
        "      {name:<44} {:>12} {unit}/s",
        crate::util::fmt_si(units_per_iter / per_iter_s)
    );
}
