//! Small self-contained utilities (the vendored crate set has no serde /
//! rand / clap, so these are hand-rolled).

pub mod bench;
pub mod json;
pub mod rng;
pub mod stats;
pub mod trace;

/// Format a duration in seconds with adaptive units.
pub fn fmt_time(secs: f64) -> String {
    if secs < 1e-6 {
        format!("{:.1} ns", secs * 1e9)
    } else if secs < 1e-3 {
        format!("{:.1} µs", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.2} ms", secs * 1e3)
    } else {
        format!("{:.2} s", secs)
    }
}

/// Format a large count with SI suffixes (1.2M, 3.4G …).
pub fn fmt_si(x: f64) -> String {
    let (v, suf) = if x >= 1e12 {
        (x / 1e12, "T")
    } else if x >= 1e9 {
        (x / 1e9, "G")
    } else if x >= 1e6 {
        (x / 1e6, "M")
    } else if x >= 1e3 {
        (x / 1e3, "K")
    } else {
        (x, "")
    };
    format!("{v:.2}{suf}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fmt_time_units() {
        assert_eq!(fmt_time(2.0), "2.00 s");
        assert_eq!(fmt_time(0.002), "2.00 ms");
        assert_eq!(fmt_time(2e-6), "2.0 µs");
        assert_eq!(fmt_time(2e-9), "2.0 ns");
    }

    #[test]
    fn fmt_si_units() {
        assert_eq!(fmt_si(1234.0), "1.23K");
        assert_eq!(fmt_si(1.5e9), "1.50G");
        assert_eq!(fmt_si(3.0), "3.00");
    }
}
