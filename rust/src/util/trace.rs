//! Chrome-trace (chrome://tracing / Perfetto) export for pipeline
//! timelines — the Fig 11 "real vs simulated trace" artifact.

use std::fmt::Write as _;

/// One complete-event ("X") trace entry.
#[derive(Clone, Debug)]
pub struct TraceEvent {
    /// Event name, e.g. "F3@s2" (op + micro-batch @ stage).
    pub name: String,
    /// Category: "F" | "B" | "W" | "comm" | "bubble".
    pub cat: String,
    /// Start time in microseconds.
    pub ts_us: f64,
    /// Duration in microseconds.
    pub dur_us: f64,
    /// Process id — we use the device id.
    pub pid: usize,
    /// Thread id — 0 compute, 1 comm lane.
    pub tid: usize,
}

/// Serialize to the Chrome trace JSON-array format.
pub fn to_chrome_trace(events: &[TraceEvent]) -> String {
    let mut out = String::from("[\n");
    for (i, e) in events.iter().enumerate() {
        if i > 0 {
            out.push_str(",\n");
        }
        let _ = write!(
            out,
            r#" {{"name":"{}","cat":"{}","ph":"X","ts":{:.3},"dur":{:.3},"pid":{},"tid":{}}}"#,
            e.name, e.cat, e.ts_us, e.dur_us, e.pid, e.tid
        );
    }
    out.push_str("\n]\n");
    out
}

/// Render an ASCII timeline (one row per device) — the quick-look
/// version of Fig 11 for terminals and EXPERIMENTS.md.
pub fn ascii_timeline(events: &[TraceEvent], devices: usize, width: usize) -> String {
    let t_end = events
        .iter()
        .map(|e| e.ts_us + e.dur_us)
        .fold(0.0f64, f64::max)
        .max(1e-9);
    let mut rows = vec![vec![' '; width]; devices];
    for e in events {
        if e.tid != 0 || e.pid >= devices {
            continue;
        }
        let c = match e.cat.as_str() {
            "F" => 'F',
            "B" => 'B',
            "W" => 'w',
            _ => continue,
        };
        let a = ((e.ts_us / t_end) * width as f64) as usize;
        let b = (((e.ts_us + e.dur_us) / t_end) * width as f64).ceil() as usize;
        for x in a..b.min(width) {
            rows[e.pid][x] = c;
        }
    }
    let mut out = String::new();
    for (d, row) in rows.iter().enumerate() {
        let _ = writeln!(out, "dev{d:>2} |{}|", row.iter().collect::<String>());
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(name: &str, cat: &str, ts: f64, dur: f64, pid: usize) -> TraceEvent {
        TraceEvent {
            name: name.into(),
            cat: cat.into(),
            ts_us: ts,
            dur_us: dur,
            pid,
            tid: 0,
        }
    }

    #[test]
    fn chrome_trace_is_valid_json() {
        let evs = vec![ev("F0", "F", 0.0, 5.0, 0), ev("B0", "B", 5.0, 9.0, 1)];
        let s = to_chrome_trace(&evs);
        let v = crate::util::json::Json::parse(&s).unwrap();
        assert_eq!(v.as_arr().unwrap().len(), 2);
    }

    #[test]
    fn ascii_rows_per_device() {
        let evs = vec![ev("F0", "F", 0.0, 10.0, 0), ev("B0", "B", 10.0, 10.0, 1)];
        let s = ascii_timeline(&evs, 2, 20);
        assert_eq!(s.lines().count(), 2);
        assert!(s.contains('F'));
        assert!(s.contains('B'));
    }
}
