//! Deterministic xorshift128+ PRNG — used for synthetic data, property
//! tests and tie-breaking in the generator (no `rand` in the vendored
//! crate set; determinism is a feature for reproducibility anyway).

#[derive(Clone, Debug)]
pub struct Rng {
    s0: u64,
    s1: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // SplitMix64 to spread the seed.
        let mut z = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = || {
            z = z.wrapping_add(0x9E3779B97F4A7C15);
            let mut x = z;
            x = (x ^ (x >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            x = (x ^ (x >> 27)).wrapping_mul(0x94D049BB133111EB);
            x ^ (x >> 31)
        };
        let s0 = next().max(1);
        let s1 = next().max(1);
        Rng { s0, s1 }
    }

    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.s0;
        let y = self.s1;
        self.s0 = y;
        x ^= x << 23;
        self.s1 = x ^ y ^ (x >> 17) ^ (y >> 26);
        self.s1.wrapping_add(y)
    }

    /// Uniform in `[0, n)`.
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform float in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample from a Zipf(1.0) distribution over `[0, n)` (matches the
    /// python synthetic corpus generator).
    pub fn zipf(&mut self, n: usize) -> usize {
        // Inverse-CDF on the harmonic weights would be O(n); use
        // rejection from the continuous bounded Zipf instead.
        loop {
            let u = self.f64();
            let x = ((n as f64 + 1.0).powf(u)).floor();
            if x >= 1.0 && x <= n as f64 {
                let k = x as usize;
                let accept = 1.0 / x / ((n as f64 + 1.0).ln() / (1.0 / x));
                // Cheap approximation; bias is irrelevant for workloads.
                if self.f64() < accept.min(1.0) || k == 1 {
                    return k - 1;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            assert!(r.below(10) < 10);
        }
    }

    #[test]
    fn f64_in_unit() {
        let mut r = Rng::new(9);
        for _ in 0..1000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(3);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort();
        assert_eq!(s, (0..50).collect::<Vec<_>>());
    }
}
