//! Minimal JSON parser + emitter (no serde in the vendored crate set).
//!
//! The parser covers the full JSON grammar we produce/consume:
//! `artifacts/<tag>/meta.json` from aot.py and our own trace/report
//! files.  Numbers parse as f64; integer access helpers round-trip
//! exactly for |x| < 2^53 which is far beyond any shape/count here.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, String> {
        let mut p = Parser { b: s.as_bytes(), i: 0, depth: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `obj["a"]["b"][2]`-style path access: keys for objects, indices
    /// for arrays.
    pub fn at(&self, path: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for p in path {
            cur = match cur {
                Json::Obj(m) => m.get(*p)?,
                Json::Arr(v) => v.get(p.parse::<usize>().ok()?)?,
                _ => return None,
            };
        }
        Some(cur)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.emit(&mut out, 0, true);
        out
    }

    /// Single-line emission (no newlines or indentation) — the
    /// newline-delimited-JSON framing `adaptis serve` speaks, where
    /// one value must be exactly one line.
    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.emit(&mut out, 0, false);
        out
    }

    fn emit(&self, out: &mut String, indent: usize, pretty: bool) {
        let pad = |out: &mut String, n: usize| {
            if pretty {
                out.push('\n');
                for _ in 0..n {
                    out.push(' ');
                }
            }
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 9e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => emit_str(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    x.emit(out, indent + 1, pretty);
                }
                if !v.is_empty() {
                    pad(out, indent);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, x)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    emit_str(out, k);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    x.emit(out, indent + 1, pretty);
                }
                if !m.is_empty() {
                    pad(out, indent);
                }
                out.push('}');
            }
        }
    }
}

fn emit_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience builders.
pub fn obj(entries: Vec<(&str, Json)>) -> Json {
    Json::Obj(entries.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn arr(v: Vec<Json>) -> Json {
    Json::Arr(v)
}

pub fn num(x: f64) -> Json {
    Json::Num(x)
}

pub fn s(x: &str) -> Json {
    Json::Str(x.to_string())
}

/// Nesting cap: the parser is recursive, so pathological inputs (a
/// line of 100k `[`s, say, from a hostile or broken NDJSON client)
/// would otherwise overflow the stack — an abort, not a catchable
/// error.  Nothing we produce or consume nests beyond single digits.
const MAX_DEPTH: usize = 128;

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
    depth: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.b.get(self.i) {
            Some(b'{') => self.nest(Parser::object),
            Some(b'[') => self.nest(Parser::array),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(_) => self.number(),
            None => Err("unexpected eof".into()),
        }
    }

    fn nest(
        &mut self,
        inner: fn(&mut Parser<'a>) -> Result<Json, String>,
    ) -> Result<Json, String> {
        self.depth += 1;
        if self.depth > MAX_DEPTH {
            return Err(format!("nesting deeper than {MAX_DEPTH} at byte {}", self.i));
        }
        let v = inner(self);
        self.depth -= 1;
        v
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.i += 1; // opening quote
        let mut out = String::new();
        loop {
            match self.b.get(self.i) {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.b.get(self.i) {
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'u') => {
                            let hex = std::str::from_utf8(
                                self.b.get(self.i + 1..self.i + 5).ok_or("bad \\u")?,
                            )
                            .map_err(|_| "bad \\u")?;
                            let cp = u32::from_str_radix(hex, 16).map_err(|_| "bad \\u")?;
                            out.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                            self.i += 4;
                        }
                        _ => return Err("bad escape".into()),
                    }
                    self.i += 1;
                }
                Some(&c) => {
                    // Multi-byte UTF-8: copy the full sequence.
                    let len = utf8_len(c);
                    let chunk = self.b.get(self.i..self.i + len).ok_or("bad utf8")?;
                    out.push_str(std::str::from_utf8(chunk).map_err(|_| "bad utf8")?);
                    self.i += len;
                }
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.i += 1;
        let mut m = BTreeMap::new();
        self.ws();
        if self.b.get(self.i) == Some(&b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            if self.b.get(self.i) != Some(&b':') {
                return Err(format!("expected ':' at byte {}", self.i));
            }
            self.i += 1;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.b.get(self.i) {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.i += 1;
        let mut v = Vec::new();
        self.ws();
        if self.b.get(self.i) == Some(&b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.b.get(self.i) {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }
}

fn utf8_len(b: u8) -> usize {
    if b < 0x80 {
        1
    } else if b >> 5 == 0b110 {
        2
    } else if b >> 4 == 0b1110 {
        3
    } else {
        4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let src = r#"{"a": [1, 2.5, -3], "b": {"c": "hi\n", "d": true, "e": null}}"#;
        let v = Json::parse(src).unwrap();
        let emitted = v.to_string_pretty();
        let v2 = Json::parse(&emitted).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn path_access() {
        let v = Json::parse(r#"{"xs": [{"y": 7}]}"#).unwrap();
        assert_eq!(v.at(&["xs", "0", "y"]).unwrap().as_usize(), Some(7));
    }

    #[test]
    fn parses_aot_meta_shape() {
        let v = Json::parse(
            r#"{"tag":"micro","dims":{"vocab":512},"kinds":{"ffn":{"params":[["w1",[32,64]]]}}}"#,
        )
        .unwrap();
        assert_eq!(v.at(&["dims", "vocab"]).unwrap().as_usize(), Some(512));
        assert_eq!(
            v.at(&["kinds", "ffn", "params", "0", "0"]).unwrap().as_str(),
            Some("w1")
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{oops}").is_err());
        assert!(Json::parse("[1,").is_err());
    }

    #[test]
    fn deep_nesting_is_an_error_not_a_stack_overflow() {
        let deep = "[".repeat(100_000);
        assert!(Json::parse(&deep).is_err());
        // …but reasonable nesting is untouched.
        let ok = format!("{}1{}", "[".repeat(100), "]".repeat(100));
        assert!(Json::parse(&ok).is_ok());
    }
}
