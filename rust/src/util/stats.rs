//! Tiny statistics helpers for benches and experiment reports.

/// Mean of a slice (0.0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Population standard deviation.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// p-th percentile (nearest-rank), p in [0, 100].
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = ((p / 100.0) * (v.len() as f64 - 1.0)).round() as usize;
    v[rank.min(v.len() - 1)]
}

/// Least-squares fit of `y = a * exp(b x)` via log-linear regression —
/// the same extrapolation the paper uses for infeasible ILP solve times
/// (scipy.optimize.curve_fit in §5.6).
pub fn fit_exponential(xs: &[f64], ys: &[f64]) -> (f64, f64) {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len() as f64;
    let logy: Vec<f64> = ys.iter().map(|y| y.max(1e-12).ln()).collect();
    let sx: f64 = xs.iter().sum();
    let sy: f64 = logy.iter().sum();
    let sxx: f64 = xs.iter().map(|x| x * x).sum();
    let sxy: f64 = xs.iter().zip(&logy).map(|(x, y)| x * y).sum();
    let b = (n * sxy - sx * sy) / (n * sxx - sx * sx);
    let a = ((sy - b * sx) / n).exp();
    (a, b)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-12);
        assert!((std_dev(&xs) - (1.25f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn percentile_ranks() {
        let xs = [5.0, 1.0, 3.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
    }

    #[test]
    fn exp_fit_recovers() {
        let xs: Vec<f64> = (0..10).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 2.0 * (0.5 * x).exp()).collect();
        let (a, b) = fit_exponential(&xs, &ys);
        assert!((a - 2.0).abs() < 1e-6, "a={a}");
        assert!((b - 0.5).abs() < 1e-6, "b={b}");
    }
}
