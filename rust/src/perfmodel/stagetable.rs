//! Per-stage aggregated costs for one (partition, placement) —
//! Algorithm 1 Steps 1–2 factored out of the simulation kernel.
//!
//! The Pipeline Generator builds one table per candidate (O(S) thanks
//! to the profile's prefix sums) and, for single-boundary partition
//! moves, re-derives only the two affected stages via
//! [`StageTable::update_boundary`] — bit-identical to a full rebuild,
//! so incremental evaluation cannot drift from the reference path.

use crate::partition::Partition;
use crate::placement::Placement;
use crate::profile::ProfiledData;

/// Stage-level cost vectors consumed by the evaluation engines
/// ([`crate::perfmodel::engine`] and [`crate::perfmodel::fused`]).
///
/// `Default` is the empty table (0 stages, 0 devices) — a placeholder
/// for `std::mem::take` when tables travel through the generator's
/// evaluation pool; never evaluate one.
#[derive(Clone, Debug, Default)]
pub struct StageTable {
    /// Pipeline devices.
    pub p: usize,
    pub n_stages: usize,
    /// Owning device per stage (from the placement).
    pub device: Vec<usize>,
    /// Forward seconds per stage per micro-batch.
    pub f: Vec<f64>,
    /// Input-grad backward seconds (B).  Fused backward is `b + w`.
    pub b: Vec<f64>,
    /// Param-grad backward seconds (W).
    pub w: Vec<f64>,
    /// Fused backward seconds, precomputed as `b + w` (the exact
    /// expression the kernels previously folded per executed op, so
    /// using it is bit-identical and saves an add in the hot loops).
    pub bw: Vec<f64>,
    /// Activation stash bytes per in-flight micro-batch (charged at F).
    pub act: Vec<f64>,
    /// W-retained slice of `act`: released at W under a split backward,
    /// together with the rest at B otherwise (see `memory/`).
    pub act_w: Vec<f64>,
    /// Static memory (params+grads+optimizer) per stage.
    pub mem_static: Vec<f64>,
    /// Boundary message bytes leaving each stage.
    pub comm_bytes: Vec<f64>,
    /// P2P seconds for the F input from stage `s-1` (0 when colocated
    /// or `s == 0`).
    pub comm_f_in: Vec<f64>,
    /// P2P seconds for the B input from stage `s+1` (0 when colocated
    /// or `s` is last).
    pub comm_b_in: Vec<f64>,
    /// Static memory aggregated per device.
    pub static_d: Vec<f64>,
    /// Per-device compute-time multipliers the table was built under
    /// (empty ⇒ 1.0 everywhere, the usual case).  `f`/`b`/`w`/`bw` are
    /// scaled at build, so the whole scoring stack — analytic bounds,
    /// fused kernel, evaluation pool — prices a degraded cluster with
    /// no changes.  Comm terms are deliberately *not* scaled: rates
    /// model compute throttling (thermal, stragglers); link faults are
    /// priced by the executor's fault view ([`crate::cluster::fault`]).
    /// Unit rates are normalized away (`rebuild_rated` with all-1.0
    /// leaves this empty), so a rated build at rate 1.0 is bitwise
    /// identical to [`StageTable::build`].
    pub rate_d: Vec<f64>,
}

impl StageTable {
    /// Aggregate the profile over a (partition, placement) — O(S).
    pub fn build(
        profile: &ProfiledData,
        partition: &Partition,
        placement: &Placement,
    ) -> StageTable {
        let mut t = StageTable::default();
        t.rebuild(profile, partition, placement);
        t
    }

    /// [`StageTable::build`] under per-device compute-time multipliers
    /// (the elastic re-planner's view of a drifted/straggling cluster).
    pub fn build_rated(
        profile: &ProfiledData,
        partition: &Partition,
        placement: &Placement,
        rates: &[f64],
    ) -> StageTable {
        let mut t = StageTable::default();
        t.rebuild_rated(profile, partition, placement, rates);
        t
    }

    /// [`StageTable::build`] into `self`, reusing every buffer — the
    /// generator's `PrepPool` recycles tables across move batches so
    /// steady-state candidate construction allocates nothing.
    /// Bit-identical to a fresh `build` (every entry is overwritten).
    pub fn rebuild(
        &mut self,
        profile: &ProfiledData,
        partition: &Partition,
        placement: &Placement,
    ) {
        self.rate_d.clear();
        self.rebuild_core(profile, partition, placement);
    }

    /// [`StageTable::rebuild`] under per-device compute-time
    /// multipliers.  An empty or all-1.0 `rates` slice normalizes to
    /// the unrated table (bitwise identical to [`StageTable::rebuild`]).
    pub fn rebuild_rated(
        &mut self,
        profile: &ProfiledData,
        partition: &Partition,
        placement: &Placement,
        rates: &[f64],
    ) {
        self.rate_d.clear();
        if !rates.is_empty() {
            assert_eq!(rates.len(), placement.p, "one compute rate per device");
            assert!(rates.iter().all(|r| r.is_finite() && *r > 0.0), "rates must be finite > 0");
            if rates.iter().any(|&r| r != 1.0) {
                self.rate_d.extend_from_slice(rates);
            }
        }
        self.rebuild_core(profile, partition, placement);
    }

    fn rebuild_core(
        &mut self,
        profile: &ProfiledData,
        partition: &Partition,
        placement: &Placement,
    ) {
        let s_n = partition.n_stages();
        assert_eq!(
            placement.n_stages(),
            s_n,
            "partition has {s_n} stages, placement {}",
            placement.n_stages()
        );
        self.p = placement.p;
        self.n_stages = s_n;
        self.device.clone_from(&placement.device_of);
        for v in [
            &mut self.f,
            &mut self.b,
            &mut self.w,
            &mut self.bw,
            &mut self.act,
            &mut self.act_w,
            &mut self.mem_static,
            &mut self.comm_bytes,
            &mut self.comm_f_in,
            &mut self.comm_b_in,
        ] {
            v.clear();
            v.resize(s_n, 0.0);
        }
        for s in 0..s_n {
            self.set_stage(profile, partition, s);
        }
        for s in 0..s_n {
            self.set_comm(profile, s);
        }
        self.recompute_static_d();
    }

    /// Re-derive the table after `partition.shift_boundary(b, _)`:
    /// only stages `b` and `b+1` changed, so only they — and the comm
    /// entries reading their boundary bytes — are recomputed.
    pub fn update_boundary(
        &mut self,
        profile: &ProfiledData,
        partition: &Partition,
        b: usize,
    ) {
        debug_assert!(b + 1 < self.n_stages);
        self.set_stage(profile, partition, b);
        self.set_stage(profile, partition, b + 1);
        // comm_f_in[s] reads comm_bytes[s-1]; comm_b_in[s] reads
        // comm_bytes[s] — stages b-1..=b+2 cover every affected entry.
        let lo = b.saturating_sub(1);
        let hi = (b + 2).min(self.n_stages - 1);
        for s in lo..=hi {
            self.set_comm(profile, s);
        }
        // Recomputed from scratch (ascending stage order) so the result
        // is bit-identical to `build` rather than patched ± ulps.
        self.recompute_static_d();
    }

    fn set_stage(&mut self, profile: &ProfiledData, partition: &Partition, s: usize) {
        let c = profile.stage_cost(partition.stage_range(s));
        if self.rate_d.is_empty() {
            self.f[s] = c.f;
            self.b[s] = c.b;
            self.w[s] = c.w;
        } else {
            // Scale each component *before* summing `bw` below, so a
            // rated table matches a faulted matched-mode SimCluster run
            // (which scales per component) bit-for-bit.
            let r = self.rate_d[self.device[s]];
            self.f[s] = c.f * r;
            self.b[s] = c.b * r;
            self.w[s] = c.w * r;
        }
        self.bw[s] = self.b[s] + self.w[s];
        self.act[s] = c.mem_act;
        self.act_w[s] = c.mem_act_w;
        self.mem_static[s] = c.mem_static;
        self.comm_bytes[s] = c.comm_bytes;
    }

    fn set_comm(&mut self, profile: &ProfiledData, s: usize) {
        self.comm_f_in[s] = if s > 0 && self.device[s - 1] != self.device[s] {
            profile.p2p(self.comm_bytes[s - 1])
        } else {
            0.0
        };
        self.comm_b_in[s] = if s + 1 < self.n_stages && self.device[s + 1] != self.device[s]
        {
            profile.p2p(self.comm_bytes[s])
        } else {
            0.0
        };
    }

    fn recompute_static_d(&mut self) {
        self.static_d.clear();
        self.static_d.resize(self.p, 0.0);
        for s in 0..self.n_stages {
            self.static_d[self.device[s]] += self.mem_static[s];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Family, HardwareCfg, ModelCfg, ParallelCfg, Size};
    use crate::model::build_model;
    use crate::partition::uniform;
    use crate::placement::{interleaved, sequential};

    fn prof() -> ProfiledData {
        let spec = build_model(&ModelCfg::table5(Family::Gemma, Size::Small));
        ProfiledData::analytical(
            &spec,
            &HardwareCfg::default(),
            &ParallelCfg::new(4, 2, 8, 1, 4096),
        )
    }

    #[test]
    fn build_matches_manual_aggregation() {
        let p = prof();
        let part = uniform(p.n_layers(), 4);
        let pl = sequential(4);
        let t = StageTable::build(&p, &part, &pl);
        for s in 0..4 {
            let c = p.stage_cost(part.stage_range(s));
            assert_eq!(t.f[s], c.f);
            assert_eq!(t.comm_bytes[s], c.comm_bytes);
        }
        // Sequential placement: every interior boundary crosses devices.
        assert_eq!(t.comm_f_in[0], 0.0);
        assert!(t.comm_f_in[1] > 0.0);
        assert!(t.comm_b_in[2] > 0.0);
        assert_eq!(t.comm_b_in[3], 0.0);
    }

    #[test]
    fn rebuild_into_recycled_table_matches_fresh_build() {
        let p = prof();
        // Dirty donor shaped differently from the target.
        let mut t = StageTable::build(&p, &uniform(p.n_layers(), 8), &interleaved(4, 2));
        let part = uniform(p.n_layers(), 4);
        let pl = sequential(4);
        t.rebuild(&p, &part, &pl);
        let fresh = StageTable::build(&p, &part, &pl);
        assert_eq!(t.n_stages, fresh.n_stages);
        assert_eq!(t.device, fresh.device);
        assert_eq!(t.f, fresh.f);
        assert_eq!(t.b, fresh.b);
        assert_eq!(t.w, fresh.w);
        assert_eq!(t.bw, fresh.bw);
        assert_eq!(t.act, fresh.act);
        assert_eq!(t.act_w, fresh.act_w);
        assert_eq!(t.mem_static, fresh.mem_static);
        assert_eq!(t.comm_bytes, fresh.comm_bytes);
        assert_eq!(t.comm_f_in, fresh.comm_f_in);
        assert_eq!(t.comm_b_in, fresh.comm_b_in);
        assert_eq!(t.static_d, fresh.static_d);
    }

    #[test]
    fn fused_backward_column_matches_fold() {
        let p = prof();
        let part = uniform(p.n_layers(), 4);
        let t = StageTable::build(&p, &part, &sequential(4));
        for s in 0..4 {
            assert_eq!(t.bw[s], t.b[s] + t.w[s]);
        }
    }

    #[test]
    fn rated_build_scales_compute_only() {
        let p = prof();
        let part = uniform(p.n_layers(), 4);
        let pl = sequential(4);
        let base = StageTable::build(&p, &part, &pl);
        let rates = [1.0, 2.0, 1.5, 1.0];
        let rated = StageTable::build_rated(&p, &part, &pl, &rates);
        for s in 0..4 {
            let r = rates[base.device[s]];
            assert_eq!(rated.f[s], base.f[s] * r);
            assert_eq!(rated.b[s], base.b[s] * r);
            assert_eq!(rated.w[s], base.w[s] * r);
            assert_eq!(rated.bw[s], rated.b[s] + rated.w[s]);
            // Memory and comm are rate-independent.
            assert_eq!(rated.act[s], base.act[s]);
            assert_eq!(rated.mem_static[s], base.mem_static[s]);
            assert_eq!(rated.comm_f_in[s], base.comm_f_in[s]);
            assert_eq!(rated.comm_b_in[s], base.comm_b_in[s]);
        }
        assert_eq!(rated.static_d, base.static_d);
    }

    #[test]
    fn unit_rates_normalize_to_unrated_table() {
        let p = prof();
        let part = uniform(p.n_layers(), 4);
        let pl = sequential(4);
        let base = StageTable::build(&p, &part, &pl);
        let rated = StageTable::build_rated(&p, &part, &pl, &[1.0; 4]);
        assert!(rated.rate_d.is_empty(), "all-1.0 rates must normalize away");
        assert_eq!(rated.f, base.f);
        assert_eq!(rated.bw, base.bw);
        // And a recycled rated table loses its rates on plain rebuild.
        let mut t = StageTable::build_rated(&p, &part, &pl, &[2.0; 4]);
        assert!(!t.rate_d.is_empty());
        t.rebuild(&p, &part, &pl);
        assert!(t.rate_d.is_empty());
        assert_eq!(t.f, base.f);
    }

    #[test]
    fn rated_incremental_update_is_bit_identical_to_rebuild() {
        let p = prof();
        let pl = interleaved(4, 2);
        let rates = [1.25, 1.0, 3.0, 0.5];
        let mut part = uniform(p.n_layers(), 8);
        let mut t = StageTable::build_rated(&p, &part, &pl, &rates);
        for (b, dir) in [(0usize, true), (3, false), (6, true)] {
            if !part.shift_boundary(b, dir) {
                continue;
            }
            t.update_boundary(&p, &part, b);
            let fresh = StageTable::build_rated(&p, &part, &pl, &rates);
            assert_eq!(t.f, fresh.f, "after shift {b}");
            assert_eq!(t.b, fresh.b);
            assert_eq!(t.w, fresh.w);
            assert_eq!(t.bw, fresh.bw);
            assert_eq!(t.rate_d, fresh.rate_d);
        }
    }

    #[test]
    fn incremental_update_is_bit_identical_to_rebuild() {
        let p = prof();
        let pl = interleaved(4, 2);
        let mut part = uniform(p.n_layers(), 8);
        let mut t = StageTable::build(&p, &part, &pl);
        for (b, dir) in [(0usize, true), (3, false), (6, true), (3, true)] {
            if !part.shift_boundary(b, dir) {
                continue;
            }
            t.update_boundary(&p, &part, b);
            let fresh = StageTable::build(&p, &part, &pl);
            assert_eq!(t.f, fresh.f, "after shift {b}");
            assert_eq!(t.b, fresh.b);
            assert_eq!(t.w, fresh.w);
            assert_eq!(t.bw, fresh.bw);
            assert_eq!(t.act, fresh.act);
            assert_eq!(t.act_w, fresh.act_w);
            assert_eq!(t.mem_static, fresh.mem_static);
            assert_eq!(t.comm_bytes, fresh.comm_bytes);
            assert_eq!(t.comm_f_in, fresh.comm_f_in);
            assert_eq!(t.comm_b_in, fresh.comm_b_in);
            assert_eq!(t.static_d, fresh.static_d);
        }
    }
}
