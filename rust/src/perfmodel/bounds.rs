//! Analytic makespan lower bounds — the Pipeline Generator's pruning
//! oracle (DESIGN.md § Search acceleration).
//!
//! A candidate whose *lower bound* already exceeds the incumbent best
//! score can never win the argmin, so the generator skips its full
//! fused evaluation.  The bound is computed from a [`StageTable`]
//! alone in one O(S) pass (allocation-free via [`BoundScratch`]) and
//! combines four certificates, each valid for *any* dependency-
//! respecting schedule the list scheduler could emit:
//!
//! 1. **Memory feasibility** (the PR-2 gate, [`fits_lower_bound`]):
//!    a device holds its static memory plus, at each hosted stage's
//!    first F, at least that stage's one-micro-batch stash — if that
//!    already exceeds the cap, every schedule is OOM and the objective
//!    is `+inf` (Eq. 2), so the bound is `+inf`.
//! 2. **Micro-batch critical path**: micro-batch 0 must flow F through
//!    stages `0..S` and B back through `S..0`; every hop waits at least
//!    `dep + comm` in both overlap modes, so the chain
//!    `Σ (comm_f_in + f) + Σ (comm_b_in + b')` bounds the makespan
//!    (`b' = b` under a split backward, `b + w` fused).
//! 3. **Per-device compute + fill/drain**: device `d` cannot start
//!    before the earliest F-chain arrival among its stages
//!    (`head_d`), must execute `nmb · Σ (f + b + w)` seconds of
//!    compute serially (`C_d`), and — without a B/W split, where its
//!    last op is necessarily some stage's B — the B-chain below that
//!    stage still runs afterwards (`tail_d`).  So
//!    `T ≥ head_d + C_d + tail_d` for every device.
//! 4. **Steady-state cycle with serial comm** (non-overlap mode only):
//!    without overlap-awareness every receive serialises on the
//!    consumer (`start = max(clk, dep) + comm`), so each op advances
//!    its device's clock by at least `comm + dur` — the device's
//!    steady-state cycle is `cycle_d = C_d/nmb + Σ_s (comm_f_in +
//!    comm_b_in)`, not just its compute.  With `warmup_d` the earliest
//!    *pre-receive* F-chain arrival (the first hop's comm is already
//!    inside the cycle sum — counting it in the head too would double
//!    count) this gives `T ≥ warmup_d + nmb·cycle_d + drain_d`, which
//!    is much tighter at large `nmb` on comm-heavy pipelines — the
//!    bound-side mirror of the kernels' steady-state collapse
//!    ([`crate::perfmodel::collapse`]).
//!
//! **Floating-point safety.** The chain folds reuse the kernels'
//! expression shapes (rounding is monotone, so the folded bound cannot
//! exceed the folded simulation), but `C_d` sums compute in stage
//! order while the simulation accumulates in execution order.  The
//! returned value is therefore deflated by `1 − 1e-9` — orders of
//! magnitude more than the worst-case accumulated rounding of any
//! realistic slot count (≈ `ops · ε ≈ 1e-11` relative at 100k slots)
//! — so `makespan_lower_bound ≤ simulate(..).total` holds *bitwise*,
//! not just in exact arithmetic (pinned on randomized pipelines by
//! `tests/generator_accel.rs`).

use super::stagetable::StageTable;
use crate::memory::MemCaps;

/// Relative deflation applied to the analytic bound so accumulated
/// floating-point rounding can never push it above a simulated
/// makespan (see module docs).
const FP_DEFLATION: f64 = 1e-9;

/// Schedule-independent memory feasibility: a device holds its static
/// memory plus, at each stage's first F, at least that stage's
/// one-micro-batch stash (per-(stage, mb) holdings never go negative),
/// so `static_d + act[s] > cap` for any stage proves OOM before any
/// simulation runs.  O(S), allocation-free.
pub fn fits_lower_bound(table: &StageTable, caps: &MemCaps) -> bool {
    if !caps.fits_static(&table.static_d) {
        return false;
    }
    (0..table.n_stages).all(|s| {
        let d = table.device[s];
        table.static_d[d] + table.act[s] <= caps.cap(d)
    })
}

/// Reusable per-device accumulators for [`makespan_lower_bound_in`] —
/// the generator keeps one so the hot pruning path allocates nothing.
#[derive(Default)]
pub struct BoundScratch {
    head: Vec<f64>,
    head_pre: Vec<f64>,
    tail: Vec<f64>,
    busy: Vec<f64>,
    comm: Vec<f64>,
}

fn refill(v: &mut Vec<f64>, n: usize, x: f64) {
    v.clear();
    v.resize(n, x);
}

/// Allocation-free analytic makespan lower bound (see module docs).
///
/// Returns `+inf` when no schedule can fit the memory caps (the
/// objective is `+inf` there too, Eq. 2); otherwise a value `≤` the
/// simulated makespan of *every* schedule the greedy list scheduler
/// can produce for this table under the given backward/overlap modes,
/// whatever the remaining knobs (`w_fill`, `mem_cap_factor`) choose.
pub fn makespan_lower_bound_in(
    scratch: &mut BoundScratch,
    table: &StageTable,
    caps: &MemCaps,
    nmb: usize,
    split_bw: bool,
    overlap_aware: bool,
) -> f64 {
    if !fits_lower_bound(table, caps) {
        return f64::INFINITY;
    }
    let s_n = table.n_stages;
    let p = table.p;
    let nmb_f = nmb as f64;
    refill(&mut scratch.head, p, f64::INFINITY);
    refill(&mut scratch.head_pre, p, f64::INFINITY);
    refill(&mut scratch.tail, p, if split_bw { 0.0 } else { f64::INFINITY });
    refill(&mut scratch.busy, p, 0.0);
    refill(&mut scratch.comm, p, 0.0);

    // Single forward pass: F-chain arrival per stage (head, and its
    // pre-receive variant), B-chain mass below each stage (tail),
    // per-device compute (C_d) and per-round serial comm.
    let mut chain_f = 0.0f64; // end of the mb-0 F chain through stage s-1
    let mut below = 0.0f64; // Σ_{u<s} (b'[u] + comm_b_in[u])
    for s in 0..s_n {
        let d = table.device[s];
        let arrive = if s == 0 { 0.0 } else { chain_f + table.comm_f_in[s] };
        if arrive < scratch.head[d] {
            scratch.head[d] = arrive;
        }
        if chain_f < scratch.head_pre[d] {
            scratch.head_pre[d] = chain_f;
        }
        if !split_bw && below < scratch.tail[d] {
            scratch.tail[d] = below;
        }
        scratch.busy[d] += (table.f[s] + table.b[s] + table.w[s]) * nmb_f;
        scratch.comm[d] += (table.comm_f_in[s] + table.comm_b_in[s]) * nmb_f;
        chain_f = arrive + table.f[s];
        let bp = if split_bw { table.b[s] } else { table.bw[s] };
        below += bp + table.comm_b_in[s];
    }

    // Certificate 2: full F chain + full B chain for one micro-batch
    // (comm_b_in of the last stage is 0 by construction).
    let mut bound = chain_f + below;

    // Certificates 3 and 4: per-device fill + cycle·nmb + drain.
    for d in 0..p {
        if scratch.head[d].is_infinite() {
            continue; // hosts no stage (invalid placement): no claim
        }
        let dev = scratch.head[d] + scratch.busy[d] + scratch.tail[d];
        if dev > bound {
            bound = dev;
        }
        if !overlap_aware {
            // Serial receives: every op advances the consumer's clock
            // by comm + dur, so the steady cycle includes the comm mass
            // (the head drops its last receive — it is in the sum).
            let dev =
                scratch.head_pre[d] + scratch.busy[d] + scratch.comm[d] + scratch.tail[d];
            if dev > bound {
                bound = dev;
            }
        }
    }
    bound * (1.0 - FP_DEFLATION)
}

/// [`makespan_lower_bound_in`] with throwaway scratch — tests and
/// one-shot callers.
pub fn makespan_lower_bound(
    table: &StageTable,
    caps: &MemCaps,
    nmb: usize,
    split_bw: bool,
    overlap_aware: bool,
) -> f64 {
    makespan_lower_bound_in(
        &mut BoundScratch::default(),
        table,
        caps,
        nmb,
        split_bw,
        overlap_aware,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Family, HardwareCfg, ModelCfg, ParallelCfg, Size};
    use crate::model::build_model;
    use crate::partition::uniform;
    use crate::placement::{interleaved, sequential};
    use crate::perfmodel::simulate;
    use crate::profile::ProfiledData;
    use crate::schedule::builders::{gpipe, one_f_one_b, zb_h1};

    fn prof(p: usize, nmb: usize) -> ProfiledData {
        let spec = build_model(&ModelCfg::table5(Family::Gemma, Size::Small));
        ProfiledData::analytical(
            &spec,
            &HardwareCfg::default(),
            &ParallelCfg::new(p, 2, nmb, 1, 4096),
        )
    }

    #[test]
    fn bound_below_builder_schedules() {
        let (p, nmb) = (4, 8);
        let pr = prof(p, nmb);
        let part = uniform(pr.n_layers(), p);
        let pl = sequential(p);
        let table = StageTable::build(&pr, &part, &pl);
        let caps = MemCaps::uniform(p, pr.mem_capacity);
        for (sch, split) in
            [(one_f_one_b(p, nmb), false), (gpipe(p, nmb), false), (zb_h1(p, nmb), true)]
        {
            // Builder schedules run with overlap_aware = false.
            let r = simulate(&pr, &part, &pl, &sch, false).unwrap();
            let lb = makespan_lower_bound(&table, &caps, nmb, split, false);
            assert!(
                lb <= r.total,
                "bound {lb:.6} > simulated {:.6} (split={split})",
                r.total
            );
            // The bound must be non-trivial: at least the busiest
            // device's compute, deflated.
            let max_busy = r.busy_d.iter().cloned().fold(0.0, f64::max);
            assert!(lb >= max_busy * 0.999, "bound {lb} too loose vs busy {max_busy}");
        }
    }

    #[test]
    fn serial_comm_cycle_tightens_non_overlap_bound() {
        // Certificate 4 only applies without overlap-awareness, where
        // every receive serialises on the consumer — the non-overlap
        // bound must be at least the overlap bound plus the busiest
        // device's serial comm mass growth, i.e. strictly above it on
        // any pipeline with cross-device boundaries.
        let (p, nmb) = (4, 32);
        let pr = prof(p, nmb);
        let part = uniform(pr.n_layers(), p);
        let table = StageTable::build(&pr, &part, &sequential(p));
        let caps = MemCaps::unbounded(p);
        let with_overlap = makespan_lower_bound(&table, &caps, nmb, false, true);
        let without = makespan_lower_bound(&table, &caps, nmb, false, false);
        assert!(
            without > with_overlap,
            "serial-comm certificate must tighten: {without} !> {with_overlap}"
        );
        // And it remains a true lower bound for the non-overlap kernel.
        let r = simulate(&pr, &part, &sequential(p), &one_f_one_b(p, nmb), false)
            .unwrap();
        assert!(without <= r.total, "{without} > simulated {}", r.total);
    }

    #[test]
    fn bound_is_monotone_in_nmb() {
        let pr = prof(4, 8);
        let part = uniform(pr.n_layers(), 8);
        let pl = interleaved(4, 2);
        let table = StageTable::build(&pr, &part, &pl);
        let caps = MemCaps::unbounded(4);
        let b8 = makespan_lower_bound(&table, &caps, 8, true, true);
        let b16 = makespan_lower_bound(&table, &caps, 16, true, true);
        assert!(b8.is_finite() && b16 > b8);
    }

    #[test]
    fn infeasible_caps_bound_to_infinity() {
        let pr = prof(4, 8);
        let part = uniform(pr.n_layers(), 4);
        let pl = sequential(4);
        let table = StageTable::build(&pr, &part, &pl);
        assert!(fits_lower_bound(&table, &MemCaps::unbounded(4)));
        let tight = MemCaps::uniform(4, 1.0);
        assert!(!fits_lower_bound(&table, &tight));
        assert!(makespan_lower_bound(&table, &tight, 8, false, false).is_infinite());
    }
}
