//! Event-driven simulation kernel — the fast path behind
//! [`crate::perfmodel::simulate`].
//!
//! The retained reference loop (`simulate_reference`) re-scans all `P`
//! devices per executed slot: O(slots · P) candidate scans.  This
//! engine maintains per-device readiness incrementally:
//!
//! - each device has at most one pending slot; when its dependency is
//!   resolved the slot's start time is final (a device's clock only
//!   moves when *it* executes, and dependency end-times never change
//!   once written), so it sits in a binary heap keyed `(start, device)`;
//! - a device whose dependency is unresolved parks on the producer
//!   cell's waiter list (intrusive, allocation-free) and is re-queued
//!   the moment the producing op completes;
//! - deadlock = the heap drains with slots outstanding.
//!
//! Total: O(slots · log P) heap operations.  All state lives in a
//! caller-owned [`SimArena`] so repeated evaluations (the Pipeline
//! Generator issues thousands) allocate nothing after warm-up.
//! Identical arithmetic to the reference loop ⇒ bit-identical
//! [`PerfReport`]s (enforced by `tests/perfmodel_differential.rs`).

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use super::stagetable::StageTable;
use super::{Deadlock, PerfReport};
use crate::memory::MemCaps;
use crate::schedule::{OpKind, Schedule, Slot};
use crate::util::trace::TraceEvent;

const NONE: u32 = u32::MAX;

/// Heap entry: device `d`'s single pending slot, ready at `start` after
/// an un-overlapped receive of `comm` seconds.  The slot is carried as
/// payload so the execution step needs no extra schedule lookup.
#[derive(Clone, Copy, Debug)]
struct Ev {
    start: f64,
    comm: f64,
    d: u32,
    slot: Slot,
}

impl PartialEq for Ev {
    fn eq(&self, o: &Self) -> bool {
        self.cmp(o) == Ordering::Equal
    }
}

impl Eq for Ev {}

impl PartialOrd for Ev {
    fn partial_cmp(&self, o: &Self) -> Option<Ordering> {
        Some(self.cmp(o))
    }
}

impl Ord for Ev {
    fn cmp(&self, o: &Self) -> Ordering {
        // Reversed so the max-heap pops the (start, device) minimum —
        // ties resolve to the lower device id, matching the reference
        // scan order (deterministic, reproducible reports).
        o.start.total_cmp(&self.start).then_with(|| o.d.cmp(&self.d))
    }
}

/// Reusable simulation state.  Create once, pass to every call of
/// [`simulate_in`] / [`crate::perfmodel::fused::fused_eval`]; buffers
/// are resized (never shrunk) so steady-state evaluations are
/// allocation-free.
#[derive(Default)]
pub struct SimArena {
    // (stage, micro-batch) completion times.
    pub(crate) end_f: Vec<f64>,
    pub(crate) end_b: Vec<f64>,
    // Per-device cursors and accounting.
    pub(crate) ptr: Vec<usize>,
    pub(crate) clock: Vec<f64>,
    pub(crate) busy: Vec<f64>,
    pub(crate) comm_block: Vec<f64>,
    pub(crate) overlap: Vec<f64>,
    pub(crate) stash: Vec<f64>,
    pub(crate) peak_stash: Vec<f64>,
    // Intrusive waiter lists: head per (stage, mb) cell, next per device.
    waiter_f: Vec<u32>,
    waiter_b: Vec<u32>,
    waiter_next: Vec<u32>,
    heap: BinaryHeap<Ev>,
    // Fused-path scheduler cursors (see perfmodel::fused).
    pub(crate) next_f: Vec<usize>,
    pub(crate) next_b: Vec<usize>,
    pub(crate) next_w: Vec<usize>,
    pub(crate) budget: Vec<f64>,
}

fn refill<T: Copy>(v: &mut Vec<T>, n: usize, x: T) {
    v.clear();
    v.resize(n, x);
}

impl SimArena {
    pub fn new() -> SimArena {
        SimArena::default()
    }

    pub(crate) fn reset_common(&mut self, s_n: usize, nmb: usize, p: usize) {
        let cells = s_n * nmb;
        refill(&mut self.end_f, cells, f64::NAN);
        refill(&mut self.end_b, cells, f64::NAN);
        refill(&mut self.clock, p, 0.0);
        refill(&mut self.busy, p, 0.0);
        refill(&mut self.comm_block, p, 0.0);
        refill(&mut self.overlap, p, 0.0);
        refill(&mut self.stash, p, 0.0);
        refill(&mut self.peak_stash, p, 0.0);
    }

    fn reset_sim(&mut self, s_n: usize, nmb: usize, p: usize) {
        self.reset_common(s_n, nmb, p);
        let cells = s_n * nmb;
        refill(&mut self.ptr, p, 0);
        refill(&mut self.waiter_f, cells, NONE);
        refill(&mut self.waiter_b, cells, NONE);
        refill(&mut self.waiter_next, p, NONE);
        self.heap.clear();
    }

    pub(crate) fn reset_fused(&mut self, s_n: usize, nmb: usize, p: usize) {
        self.reset_common(s_n, nmb, p);
        refill(&mut self.next_f, s_n, 0);
        refill(&mut self.next_b, s_n, 0);
        refill(&mut self.next_w, s_n, 0);
        refill(&mut self.budget, p, 0.0);
    }
}

/// Assemble the report from arena accounting (shared by both engines).
pub(crate) fn report_from(
    arena: &SimArena,
    table: &StageTable,
    caps: &MemCaps,
    events: Vec<TraceEvent>,
) -> PerfReport {
    let p = table.p;
    debug_assert_eq!(caps.p(), p);
    let total = arena.clock.iter().cloned().fold(0.0, f64::max);
    let m_d: Vec<f64> = (0..p).map(|d| table.static_d[d] + arena.peak_stash[d]).collect();
    let headroom_d: Vec<f64> = (0..p).map(|d| caps.cap(d) - m_d[d]).collect();
    let oom = (0..p).any(|d| m_d[d] > caps.cap(d));
    let bubble_d: Vec<f64> = (0..p)
        .map(|d| (total - arena.busy[d] - arena.comm_block[d]).max(0.0))
        .collect();
    PerfReport {
        total,
        t_d: arena.clock.clone(),
        busy_d: arena.busy.clone(),
        bubble_d,
        overlap_d: arena.overlap.clone(),
        comm_block_d: arena.comm_block.clone(),
        m_d,
        static_d: table.static_d.clone(),
        headroom_d,
        oom,
        events,
    }
}

/// Compute the earliest feasible start on a device (shared formula —
/// identical expression shapes to the reference loop so results are
/// bit-identical).
#[inline]
pub(crate) fn ready_at(dep: f64, comm: f64, clk: f64, overlap_aware: bool) -> f64 {
    if comm == 0.0 {
        clk.max(dep)
    } else if overlap_aware {
        clk.max(dep + comm)
    } else {
        clk.max(dep) + comm
    }
}

/// Queue device `d`'s next slot: push to the heap if its dependency is
/// resolved, else park on the producer cell's waiter list.
fn queue_next(d: usize, schedule: &Schedule, table: &StageTable, a: &mut SimArena) {
    let slots = &schedule.per_device[d];
    if a.ptr[d] >= slots.len() {
        return;
    }
    let sl = slots[a.ptr[d]];
    let s = sl.stage as usize;
    let mb = sl.mb as usize;
    let nmb = schedule.nmb;
    let s_n = table.n_stages;
    let (dep, comm) = match sl.op {
        OpKind::F => {
            if s == 0 {
                (0.0, 0.0)
            } else {
                let k = (s - 1) * nmb + mb;
                let dep = a.end_f[k];
                if dep.is_nan() {
                    a.waiter_next[d] = a.waiter_f[k];
                    a.waiter_f[k] = d as u32;
                    return;
                }
                (dep, table.comm_f_in[s])
            }
        }
        OpKind::B => {
            if s == s_n - 1 {
                let k = s * nmb + mb;
                let dep = a.end_f[k];
                if dep.is_nan() {
                    a.waiter_next[d] = a.waiter_f[k];
                    a.waiter_f[k] = d as u32;
                    return;
                }
                (dep, 0.0)
            } else {
                let k = (s + 1) * nmb + mb;
                let dep = a.end_b[k];
                if dep.is_nan() {
                    a.waiter_next[d] = a.waiter_b[k];
                    a.waiter_b[k] = d as u32;
                    return;
                }
                (dep, table.comm_b_in[s])
            }
        }
        OpKind::W => {
            let k = s * nmb + mb;
            let dep = a.end_b[k];
            if dep.is_nan() {
                a.waiter_next[d] = a.waiter_b[k];
                a.waiter_b[k] = d as u32;
                return;
            }
            (dep, 0.0)
        }
    };
    let start = ready_at(dep, comm, a.clock[d], schedule.overlap_aware);
    a.heap.push(Ev { start, comm, d: d as u32, slot: sl });
}

/// Event-driven simulation over a prebuilt stage table and arena.
/// Same contract as [`crate::perfmodel::simulate`].
pub fn simulate_in(
    arena: &mut SimArena,
    table: &StageTable,
    caps: &MemCaps,
    schedule: &Schedule,
    collect_trace: bool,
) -> Result<PerfReport, Deadlock> {
    simulate_in_with(arena, table, caps, schedule, collect_trace, true)
}

/// [`simulate_in`] with the peak-memory tracker switchable.
/// `track_memory: false` skips all stash accounting (the report's
/// `m_d` collapses to `static_d`) — benchmarking only, to price the
/// tracker's overhead in the hot kernel (`benches/perfmodel.rs`).
pub fn simulate_in_with(
    arena: &mut SimArena,
    table: &StageTable,
    caps: &MemCaps,
    schedule: &Schedule,
    collect_trace: bool,
    track_memory: bool,
) -> Result<PerfReport, Deadlock> {
    let s_n = table.n_stages;
    let p = schedule.p;
    let nmb = schedule.nmb;
    debug_assert_eq!(s_n, schedule.n_stages);
    debug_assert_eq!(table.static_d.len(), p);
    arena.reset_sim(s_n, nmb, p);
    let total_slots: usize = schedule.per_device.iter().map(|v| v.len()).sum();
    let mut events = Vec::new();
    let split_bw = schedule.split_bw;

    for d in 0..p {
        queue_next(d, schedule, table, arena);
    }

    let mut done = 0usize;
    while let Some(Ev { start, comm, d, slot: sl }) = arena.heap.pop() {
        let d = d as usize;
        let s = sl.stage as usize;
        let mb = sl.mb as usize;
        let dur = match sl.op {
            OpKind::F => table.f[s],
            OpKind::B => {
                if split_bw {
                    table.b[s]
                } else {
                    table.b[s] + table.w[s]
                }
            }
            OpKind::W => table.w[s],
        };
        // Comm accounting (identical to the reference loop).
        if comm > 0.0 {
            if schedule.overlap_aware {
                let hidden = (arena.clock[d] - (start - comm)).clamp(0.0, comm);
                arena.overlap[d] += hidden;
                if collect_trace {
                    events.push(TraceEvent {
                        name: format!("recv{}@s{}", mb, s),
                        cat: "comm".into(),
                        ts_us: (start - comm) * 1e6,
                        dur_us: comm * 1e6,
                        pid: d,
                        tid: 1,
                    });
                }
            } else {
                arena.comm_block[d] += comm;
                if collect_trace {
                    events.push(TraceEvent {
                        name: format!("recv{}@s{}", mb, s),
                        cat: "comm".into(),
                        ts_us: (start - comm) * 1e6,
                        dur_us: comm * 1e6,
                        pid: d,
                        tid: 0,
                    });
                }
            }
        }
        let end = start + dur;
        arena.clock[d] = end;
        arena.busy[d] += dur;
        let k = s * nmb + mb;
        match sl.op {
            OpKind::F => {
                arena.end_f[k] = end;
                if track_memory {
                    arena.stash[d] += table.act[s];
                    arena.peak_stash[d] = arena.peak_stash[d].max(arena.stash[d]);
                }
                // Wake consumers parked on F(s, mb).
                let mut w = arena.waiter_f[k];
                arena.waiter_f[k] = NONE;
                while w != NONE {
                    let next = arena.waiter_next[w as usize];
                    arena.waiter_next[w as usize] = NONE;
                    queue_next(w as usize, schedule, table, arena);
                    w = next;
                }
            }
            OpKind::B => {
                arena.end_b[k] = end;
                if track_memory {
                    if split_bw {
                        // B consumed the intermediates; only the
                        // W-retained slice stays stashed (memory/).
                        arena.stash[d] -= table.act[s] - table.act_w[s];
                    } else {
                        arena.stash[d] -= table.act[s];
                    }
                }
                let mut w = arena.waiter_b[k];
                arena.waiter_b[k] = NONE;
                while w != NONE {
                    let next = arena.waiter_next[w as usize];
                    arena.waiter_next[w as usize] = NONE;
                    queue_next(w as usize, schedule, table, arena);
                    w = next;
                }
            }
            OpKind::W => {
                if track_memory {
                    arena.stash[d] -= table.act_w[s];
                }
            }
        }
        if collect_trace {
            events.push(TraceEvent {
                name: format!("{}{}@s{}", sl.op.name(), mb, s),
                cat: sl.op.name().into(),
                ts_us: start * 1e6,
                dur_us: dur * 1e6,
                pid: d,
                tid: 0,
            });
        }
        arena.ptr[d] += 1;
        done += 1;
        queue_next(d, schedule, table, arena);
    }

    if done < total_slots {
        // Heap drained with work outstanding: every remaining device is
        // parked on an unresolvable dependency.  Report the first, like
        // the reference loop.
        let d = (0..p)
            .find(|&d| arena.ptr[d] < schedule.per_device[d].len())
            .expect("outstanding slots imply a blocked device");
        return Err(Deadlock {
            device: d,
            at_slot: arena.ptr[d],
            slot: schedule.per_device[d][arena.ptr[d]],
        });
    }
    Ok(report_from(arena, table, caps, events))
}
