//! Event-driven simulation kernel — the fast path behind
//! [`crate::perfmodel::simulate`].
//!
//! The retained reference loop (`simulate_reference`) re-scans all `P`
//! devices per executed slot: O(slots · P) candidate scans.  This
//! engine maintains per-device readiness incrementally:
//!
//! - each device has at most one pending slot; when its dependency is
//!   resolved the slot's start time is final (a device's clock only
//!   moves when *it* executes, and dependency end-times never change
//!   once written), so it sits in a binary heap keyed `(start, device)`;
//! - a device whose dependency is unresolved parks on the producer
//!   cell's waiter list (intrusive, allocation-free) and is re-queued
//!   the moment the producing op completes;
//! - deadlock = the heap drains with slots outstanding.
//!
//! Total: O(slots · log P) heap operations.  All state lives in a
//! caller-owned [`SimArena`] so repeated evaluations (the Pipeline
//! Generator issues thousands) allocate nothing after warm-up.
//! Identical arithmetic to the reference loop ⇒ bit-identical
//! [`PerfReport`]s (enforced by `tests/perfmodel_differential.rs`).
//!
//! **Steady-state collapse** ([`crate::perfmodel::collapse`], default
//! on): once the executed-op stream locks into a per-micro-batch cycle,
//! the remaining rounds are replayed by a tight per-op loop — no heap,
//! no waiter lists — doing the same f64 operations in the same order,
//! so the report stays bitwise-equal while the per-round cost drops to
//! a handful of flops per op.  The replay is *provably* exact: every
//! simulated value is a pure dataflow function of the schedule (clocks
//! are per-device sequential, dependency cells write-once), the replay
//! follows each device's own slot order (verified against the schedule
//! per op) and never reads an unwritten cell (NaN-guarded); a guard
//! trip just resumes the heap from the exact prefix.  Multi-phase
//! schedules (GPipe's flood/drain) re-lock per phase.  O(slots·log P)
//! becomes O((warmup+drain)·log P + slots) with a near-scalar constant
//! on the second term.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use super::collapse::{CollapseStats, Detector, Lock, MIN_NMB};
use super::stagetable::StageTable;
use super::{Deadlock, PerfReport};
use crate::memory::MemCaps;
use crate::schedule::{OpKind, Schedule, Slot};
use crate::util::trace::TraceEvent;

const NONE: u32 = u32::MAX;

/// Heap entry: device `d`'s single pending slot, ready at `start` after
/// an un-overlapped receive of `comm` seconds.  The slot is carried as
/// payload so the execution step needs no extra schedule lookup.
#[derive(Clone, Copy, Debug)]
struct Ev {
    start: f64,
    comm: f64,
    d: u32,
    slot: Slot,
}

impl PartialEq for Ev {
    fn eq(&self, o: &Self) -> bool {
        self.cmp(o) == Ordering::Equal
    }
}

impl Eq for Ev {}

impl PartialOrd for Ev {
    fn partial_cmp(&self, o: &Self) -> Option<Ordering> {
        Some(self.cmp(o))
    }
}

impl Ord for Ev {
    fn cmp(&self, o: &Self) -> Ordering {
        // Reversed so the max-heap pops the (start, device) minimum —
        // ties resolve to the lower device id, matching the reference
        // scan order (deterministic, reproducible reports).
        o.start.total_cmp(&self.start).then_with(|| o.d.cmp(&self.d))
    }
}

/// One op of the replay cycle, precomputed so the replay loop touches
/// only flat arrays: durations, comm, the dependency cell offset
/// (`s·nmb + off`, to which the running round index is added) and the
/// write cell offset.
#[derive(Clone, Copy)]
struct CycOp {
    d: u32,
    kind: OpKind,
    s: u32,
    off: i32,
    dur: f64,
    comm: f64,
    /// 0 = no dependency, 1 = end_f, 2 = end_b.
    dep_arr: u8,
    dep_cell_off: i64,
    cell_off: i64,
}

/// Reusable simulation state.  Create once, pass to every call of
/// [`simulate_in`] / [`crate::perfmodel::fused::fused_eval`]; buffers
/// are resized (never shrunk) so steady-state evaluations are
/// allocation-free.
#[derive(Default)]
pub struct SimArena {
    // (stage, micro-batch) completion times.
    pub(crate) end_f: Vec<f64>,
    pub(crate) end_b: Vec<f64>,
    // Per-device cursors and accounting.
    pub(crate) ptr: Vec<usize>,
    pub(crate) clock: Vec<f64>,
    pub(crate) busy: Vec<f64>,
    pub(crate) comm_block: Vec<f64>,
    pub(crate) overlap: Vec<f64>,
    pub(crate) stash: Vec<f64>,
    pub(crate) peak_stash: Vec<f64>,
    // Intrusive waiter lists: head per (stage, mb) cell, next per device.
    waiter_f: Vec<u32>,
    waiter_b: Vec<u32>,
    waiter_next: Vec<u32>,
    heap: BinaryHeap<Ev>,
    // Fused-path scheduler cursors (see perfmodel::fused).
    pub(crate) next_f: Vec<usize>,
    pub(crate) next_b: Vec<usize>,
    pub(crate) next_w: Vec<usize>,
    pub(crate) budget: Vec<f64>,
    // Steady-state collapse machinery (engine + fused paths).
    pub(crate) det: Detector,
    cyc: Vec<CycOp>,
}

fn refill<T: Copy>(v: &mut Vec<T>, n: usize, x: T) {
    v.clear();
    v.resize(n, x);
}

impl SimArena {
    pub fn new() -> SimArena {
        SimArena::default()
    }

    pub(crate) fn reset_common(&mut self, s_n: usize, nmb: usize, p: usize) {
        let cells = s_n * nmb;
        refill(&mut self.end_f, cells, f64::NAN);
        refill(&mut self.end_b, cells, f64::NAN);
        refill(&mut self.clock, p, 0.0);
        refill(&mut self.busy, p, 0.0);
        refill(&mut self.comm_block, p, 0.0);
        refill(&mut self.overlap, p, 0.0);
        refill(&mut self.stash, p, 0.0);
        refill(&mut self.peak_stash, p, 0.0);
    }

    fn reset_sim(&mut self, s_n: usize, nmb: usize, p: usize) {
        self.reset_common(s_n, nmb, p);
        let cells = s_n * nmb;
        refill(&mut self.ptr, p, 0);
        refill(&mut self.waiter_f, cells, NONE);
        refill(&mut self.waiter_b, cells, NONE);
        refill(&mut self.waiter_next, p, NONE);
        self.heap.clear();
    }

    /// Re-prime the heap and waiter lists from the current cursor /
    /// end-time state (used when the engine resumes after a replay
    /// session).
    fn reprime(&mut self, schedule: &Schedule, table: &StageTable) {
        let cells = table.n_stages * schedule.nmb;
        let p = schedule.p;
        refill(&mut self.waiter_f, cells, NONE);
        refill(&mut self.waiter_b, cells, NONE);
        refill(&mut self.waiter_next, p, NONE);
        self.heap.clear();
        for d in 0..p {
            queue_next(d, schedule, table, self);
        }
    }

    pub(crate) fn reset_fused(&mut self, s_n: usize, nmb: usize, p: usize) {
        self.reset_common(s_n, nmb, p);
        refill(&mut self.next_f, s_n, 0);
        refill(&mut self.next_b, s_n, 0);
        refill(&mut self.next_w, s_n, 0);
        refill(&mut self.budget, p, 0.0);
    }
}

/// Options for [`simulate_in_opts`].
#[derive(Clone, Copy, Debug)]
pub struct EngineOpts {
    /// Collect per-op trace events (disables collapse: every op must
    /// be materialised).
    pub collect_trace: bool,
    /// Track the activation stash / peak memory (off = bench-only
    /// pricing mode; `m_d` collapses to `static_d`).
    pub track_memory: bool,
    /// Enable steady-state collapse (bit-identical either way; off
    /// retains the pure heap kernel, the differential baseline).
    pub collapse: bool,
}

impl Default for EngineOpts {
    fn default() -> Self {
        EngineOpts { collect_trace: false, track_memory: true, collapse: true }
    }
}

/// Assemble the report from arena accounting (shared by both engines).
pub(crate) fn report_from(
    arena: &SimArena,
    table: &StageTable,
    caps: &MemCaps,
    events: Vec<TraceEvent>,
) -> PerfReport {
    let p = table.p;
    debug_assert_eq!(caps.p(), p);
    let total = arena.clock.iter().cloned().fold(0.0, f64::max);
    let m_d: Vec<f64> = (0..p).map(|d| table.static_d[d] + arena.peak_stash[d]).collect();
    let headroom_d: Vec<f64> = (0..p).map(|d| caps.cap(d) - m_d[d]).collect();
    let oom = (0..p).any(|d| m_d[d] > caps.cap(d));
    let bubble_d: Vec<f64> = (0..p)
        .map(|d| (total - arena.busy[d] - arena.comm_block[d]).max(0.0))
        .collect();
    PerfReport {
        total,
        t_d: arena.clock.clone(),
        busy_d: arena.busy.clone(),
        bubble_d,
        overlap_d: arena.overlap.clone(),
        comm_block_d: arena.comm_block.clone(),
        m_d,
        static_d: table.static_d.clone(),
        headroom_d,
        oom,
        events,
    }
}

/// Compute the earliest feasible start on a device (shared formula —
/// identical expression shapes to the reference loop so results are
/// bit-identical).
#[inline]
pub(crate) fn ready_at(dep: f64, comm: f64, clk: f64, overlap_aware: bool) -> f64 {
    if comm == 0.0 {
        clk.max(dep)
    } else if overlap_aware {
        clk.max(dep + comm)
    } else {
        clk.max(dep) + comm
    }
}

/// Queue device `d`'s next slot: push to the heap if its dependency is
/// resolved, else park on the producer cell's waiter list.
fn queue_next(d: usize, schedule: &Schedule, table: &StageTable, a: &mut SimArena) {
    let slots = &schedule.per_device[d];
    if a.ptr[d] >= slots.len() {
        return;
    }
    let sl = slots[a.ptr[d]];
    let s = sl.stage as usize;
    let mb = sl.mb as usize;
    let nmb = schedule.nmb;
    let s_n = table.n_stages;
    let (dep, comm) = match sl.op {
        OpKind::F => {
            if s == 0 {
                (0.0, 0.0)
            } else {
                let k = (s - 1) * nmb + mb;
                let dep = a.end_f[k];
                if dep.is_nan() {
                    a.waiter_next[d] = a.waiter_f[k];
                    a.waiter_f[k] = d as u32;
                    return;
                }
                (dep, table.comm_f_in[s])
            }
        }
        OpKind::B => {
            if s == s_n - 1 {
                let k = s * nmb + mb;
                let dep = a.end_f[k];
                if dep.is_nan() {
                    a.waiter_next[d] = a.waiter_f[k];
                    a.waiter_f[k] = d as u32;
                    return;
                }
                (dep, 0.0)
            } else {
                let k = (s + 1) * nmb + mb;
                let dep = a.end_b[k];
                if dep.is_nan() {
                    a.waiter_next[d] = a.waiter_b[k];
                    a.waiter_b[k] = d as u32;
                    return;
                }
                (dep, table.comm_b_in[s])
            }
        }
        OpKind::W => {
            let k = s * nmb + mb;
            let dep = a.end_b[k];
            if dep.is_nan() {
                a.waiter_next[d] = a.waiter_b[k];
                a.waiter_b[k] = d as u32;
                return;
            }
            (dep, 0.0)
        }
    };
    let start = ready_at(dep, comm, a.clock[d], schedule.overlap_aware);
    a.heap.push(Ev { start, comm, d: d as u32, slot: sl });
}

/// Event-driven simulation over a prebuilt stage table and arena.
/// Same contract as [`crate::perfmodel::simulate`]; steady-state
/// collapse enabled (bit-identical to the pure heap run).
pub fn simulate_in(
    arena: &mut SimArena,
    table: &StageTable,
    caps: &MemCaps,
    schedule: &Schedule,
    collect_trace: bool,
) -> Result<PerfReport, Deadlock> {
    simulate_in_opts(
        arena,
        table,
        caps,
        schedule,
        EngineOpts { collect_trace, ..EngineOpts::default() },
    )
    .0
}

/// [`simulate_in`] with the peak-memory tracker switchable.
/// `track_memory: false` skips all stash accounting (the report's
/// `m_d` collapses to `static_d`) — benchmarking only, to price the
/// tracker's overhead in the hot kernel (`benches/perfmodel.rs`).
pub fn simulate_in_with(
    arena: &mut SimArena,
    table: &StageTable,
    caps: &MemCaps,
    schedule: &Schedule,
    collect_trace: bool,
    track_memory: bool,
) -> Result<PerfReport, Deadlock> {
    simulate_in_opts(
        arena,
        table,
        caps,
        schedule,
        EngineOpts { collect_trace, track_memory, ..EngineOpts::default() },
    )
    .0
}

/// Full-control entry point: the report plus what the collapse layer
/// did (`benches/perfmodel.rs` sweeps `collapse` on/off and reports
/// rounds replayed per config).
pub fn simulate_in_opts(
    arena: &mut SimArena,
    table: &StageTable,
    caps: &MemCaps,
    schedule: &Schedule,
    opts: EngineOpts,
) -> (Result<PerfReport, Deadlock>, CollapseStats) {
    let s_n = table.n_stages;
    let p = schedule.p;
    let nmb = schedule.nmb;
    debug_assert_eq!(s_n, schedule.n_stages);
    debug_assert_eq!(table.static_d.len(), p);
    arena.reset_sim(s_n, nmb, p);
    let total_slots: usize = schedule.per_device.iter().map(|v| v.len()).sum();
    let mut events = Vec::new();
    let mut stats = CollapseStats::default();
    // Tracing needs every op materialised; collapse skips that.
    let collapse = opts.collapse && !opts.collect_trace && nmb >= MIN_NMB;
    arena.det.reset(collapse, nmb, total_slots);

    for d in 0..p {
        queue_next(d, schedule, table, arena);
    }

    let mut done = 0usize;
    loop {
        // ---- heap phase (with periodicity detection) -------------------
        let mut lock: Option<Lock> = None;
        while let Some(Ev { start, comm, d, slot: sl }) = arena.heap.pop() {
            let d = d as usize;
            let s = sl.stage as usize;
            let mb = sl.mb as usize;
            execute_slot(
                arena, table, schedule, &mut events, opts, start, comm, d, s, mb, sl.op,
            );
            let k = s * nmb + mb;
            // Wake consumers parked on the completed cell.
            match sl.op {
                OpKind::F => {
                    let mut w = arena.waiter_f[k];
                    arena.waiter_f[k] = NONE;
                    while w != NONE {
                        let next = arena.waiter_next[w as usize];
                        arena.waiter_next[w as usize] = NONE;
                        queue_next(w as usize, schedule, table, arena);
                        w = next;
                    }
                }
                OpKind::B => {
                    let mut w = arena.waiter_b[k];
                    arena.waiter_b[k] = NONE;
                    while w != NONE {
                        let next = arena.waiter_next[w as usize];
                        arena.waiter_next[w as usize] = NONE;
                        queue_next(w as usize, schedule, table, arena);
                        w = next;
                    }
                }
                OpKind::W => {}
            }
            arena.ptr[d] += 1;
            done += 1;
            queue_next(d, schedule, table, arena);

            if arena.det.enabled() {
                // The engine locks on window structure alone: the
                // replay is exact by dataflow (module docs), so the
                // fingerprint carries no state bits.
                lock = arena.det.record(d, sl.op, s, mb, |_| ());
                if lock.is_some() {
                    break;
                }
            }
        }

        let Some(lock) = lock else { break };

        // ---- replay session -------------------------------------------
        build_cycle(arena, table, schedule, nmb);
        let track = opts.track_memory;
        let overlap_aware = schedule.overlap_aware;
        let mut r_cur = lock.r + lock.period;
        let mut session_rounds = 0usize;
        let mut bailed = false;
        'replay: while r_cur + lock.max_off <= (nmb - 1) as i64 {
            for i in 0..arena.cyc.len() {
                let op = arena.cyc[i];
                let d = op.d as usize;
                let mb = r_cur + op.off as i64;
                // Per-op guard 1: the schedule really continues the
                // periodic pattern on this device.
                let pd = &schedule.per_device[d];
                let pi = arena.ptr[d];
                if pi >= pd.len() {
                    bailed = true;
                    break 'replay;
                }
                let sl = pd[pi];
                if sl.op != op.kind || sl.stage != op.s || sl.mb as i64 != mb {
                    bailed = true;
                    break 'replay;
                }
                // Per-op guard 2: the dependency cell is written.
                let dep = match op.dep_arr {
                    0 => 0.0,
                    1 => arena.end_f[(op.dep_cell_off + r_cur) as usize],
                    _ => arena.end_b[(op.dep_cell_off + r_cur) as usize],
                };
                if dep.is_nan() {
                    bailed = true;
                    break 'replay;
                }
                let clk = arena.clock[d];
                let start = ready_at(dep, op.comm, clk, overlap_aware);
                if op.comm > 0.0 {
                    if overlap_aware {
                        let hidden = (clk - (start - op.comm)).clamp(0.0, op.comm);
                        arena.overlap[d] += hidden;
                    } else {
                        arena.comm_block[d] += op.comm;
                    }
                }
                let end = start + op.dur;
                arena.clock[d] = end;
                arena.busy[d] += op.dur;
                let cell = (op.cell_off + r_cur) as usize;
                let s = op.s as usize;
                match op.kind {
                    OpKind::F => {
                        arena.end_f[cell] = end;
                        if track {
                            arena.stash[d] += table.act[s];
                            arena.peak_stash[d] =
                                arena.peak_stash[d].max(arena.stash[d]);
                        }
                    }
                    OpKind::B => {
                        arena.end_b[cell] = end;
                        if track {
                            if schedule.split_bw {
                                arena.stash[d] -= table.act[s] - table.act_w[s];
                            } else {
                                arena.stash[d] -= table.act[s];
                            }
                        }
                    }
                    OpKind::W => {
                        if track {
                            arena.stash[d] -= table.act_w[s];
                        }
                    }
                }
                arena.ptr[d] = pi + 1;
                done += 1;
            }
            session_rounds += lock.period as usize;
            r_cur += lock.period;
        }
        // A session only counts if it actually replayed a round — a
        // guard trip on the very first op reports nothing fired (same
        // inert-collapse semantics as the fused kernel).
        if session_rounds > 0 {
            if !stats.fired {
                stats.lock_round = lock.r;
            }
            stats.fired = true;
            stats.sessions += 1;
            stats.rounds_replayed += session_rounds;
        }
        stats.bailed |= bailed;

        // Resume the heap from the exact prefix (drain, or the rest of
        // an aperiodic stretch); detection restarts and may re-lock
        // (multi-phase schedules).
        arena.reprime(schedule, table);
        arena.det.soft_reset();
    }

    if done < total_slots {
        // Heap drained with work outstanding: every remaining device is
        // parked on an unresolvable dependency.  Report the first, like
        // the reference loop.
        let d = (0..p)
            .find(|&d| arena.ptr[d] < schedule.per_device[d].len())
            .expect("outstanding slots imply a blocked device");
        return (
            Err(Deadlock {
                device: d,
                at_slot: arena.ptr[d],
                slot: schedule.per_device[d][arena.ptr[d]],
            }),
            stats,
        );
    }
    (Ok(report_from(arena, table, caps, events)), stats)
}

/// Execute one slot on `d` (accounting identical to the reference
/// loop); shared by the heap phase and trace collection.
#[allow(clippy::too_many_arguments)]
#[inline]
fn execute_slot(
    arena: &mut SimArena,
    table: &StageTable,
    schedule: &Schedule,
    events: &mut Vec<TraceEvent>,
    opts: EngineOpts,
    start: f64,
    comm: f64,
    d: usize,
    s: usize,
    mb: usize,
    kind: OpKind,
) {
    let dur = match kind {
        OpKind::F => table.f[s],
        OpKind::B => {
            if schedule.split_bw {
                table.b[s]
            } else {
                table.bw[s]
            }
        }
        OpKind::W => table.w[s],
    };
    // Comm accounting (identical to the reference loop).
    if comm > 0.0 {
        if schedule.overlap_aware {
            let hidden = (arena.clock[d] - (start - comm)).clamp(0.0, comm);
            arena.overlap[d] += hidden;
            if opts.collect_trace {
                events.push(TraceEvent {
                    name: format!("recv{}@s{}", mb, s),
                    cat: "comm".into(),
                    ts_us: (start - comm) * 1e6,
                    dur_us: comm * 1e6,
                    pid: d,
                    tid: 1,
                });
            }
        } else {
            arena.comm_block[d] += comm;
            if opts.collect_trace {
                events.push(TraceEvent {
                    name: format!("recv{}@s{}", mb, s),
                    cat: "comm".into(),
                    ts_us: (start - comm) * 1e6,
                    dur_us: comm * 1e6,
                    pid: d,
                    tid: 0,
                });
            }
        }
    }
    let end = start + dur;
    arena.clock[d] = end;
    arena.busy[d] += dur;
    let k = s * schedule.nmb + mb;
    match kind {
        OpKind::F => {
            arena.end_f[k] = end;
            if opts.track_memory {
                arena.stash[d] += table.act[s];
                arena.peak_stash[d] = arena.peak_stash[d].max(arena.stash[d]);
            }
        }
        OpKind::B => {
            arena.end_b[k] = end;
            if opts.track_memory {
                if schedule.split_bw {
                    // B consumed the intermediates; only the W-retained
                    // slice stays stashed (memory/).
                    arena.stash[d] -= table.act[s] - table.act_w[s];
                } else {
                    arena.stash[d] -= table.act[s];
                }
            }
        }
        OpKind::W => {
            if opts.track_memory {
                arena.stash[d] -= table.act_w[s];
            }
        }
    }
    if opts.collect_trace {
        events.push(TraceEvent {
            name: format!("{}{}@s{}", kind.name(), mb, s),
            cat: kind.name().into(),
            ts_us: start * 1e6,
            dur_us: dur * 1e6,
            pid: d,
            tid: 0,
        });
    }
}

/// Precompute the replay cycle's per-op durations, comm terms and cell
/// offsets from the detector's window ops.
fn build_cycle(arena: &mut SimArena, table: &StageTable, schedule: &Schedule, nmb: usize) {
    let s_n = table.n_stages;
    arena.cyc.clear();
    for op in &arena.det.cycle {
        let s = op.s as usize;
        let (dur, comm) = match op.kind {
            OpKind::F => (table.f[s], table.comm_f_in[s]),
            OpKind::B => {
                let dur = if schedule.split_bw { table.b[s] } else { table.bw[s] };
                let comm = if s == s_n - 1 { 0.0 } else { table.comm_b_in[s] };
                (dur, comm)
            }
            OpKind::W => (table.w[s], 0.0),
        };
        let (dep_arr, dep_s): (u8, usize) = match op.kind {
            OpKind::F => {
                if s == 0 {
                    (0, 0)
                } else {
                    (1, s - 1)
                }
            }
            OpKind::B => {
                if s == s_n - 1 {
                    (1, s)
                } else {
                    (2, s + 1)
                }
            }
            OpKind::W => (2, s),
        };
        arena.cyc.push(CycOp {
            d: op.d,
            kind: op.kind,
            s: op.s,
            off: op.off,
            dur,
            comm,
            dep_arr,
            dep_cell_off: (dep_s * nmb) as i64 + op.off as i64,
            cell_off: (s * nmb) as i64 + op.off as i64,
        });
    }
}
