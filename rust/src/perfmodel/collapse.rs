//! Steady-state collapse — periodicity detection for the simulation
//! kernels (DESIGN.md §3).
//!
//! 1F1B-family pipelines are *periodic*: after a warmup of O(P·v)
//! micro-batch rounds, every device repeats one steady-state cycle per
//! micro-batch (the structural fact Zero Bubble's scheduling analysis
//! and Controllable-Memory's repeated-building-block formulation rest
//! on).  Simulating the full train of `nmb` micro-batches re-derives
//! that cycle `nmb − O(P)` times through the heap / the greedy scan.
//! This module detects the cycle so the kernels can *replay* it with a
//! tight per-op loop instead — no heap, no waiter lists, no O(S)
//! candidate scans — performing the **same f64 operations in the same
//! order** as the full run, which is what keeps the collapsed path
//! bitwise-equal to the full kernels (`tests/perfmodel_collapse.rs`).
//!
//! **Detection.**  The first executed op names the anchor device `d0`
//! and the anchor `(kind, stage)`.  Every time `d0` re-executes the
//! anchor with micro-batch `r`, one *round* closes; the ops executed
//! since the previous boundary form its *window*, stored with
//! micro-batches relative to `r`.  When the last `k` windows equal the
//! `k` before them element-wise (`k ≤ 4`, so period-2/-3 interleavings
//! lock too) — plus, for callers that require it, a bitwise
//! fingerprint of per-device state (clock deltas to `d0`, absolute
//! stash levels) — the schedule has locked and the concatenated
//! windows become the replay cycle.
//!
//! **Why the two callers need different evidence.**  The heap engine
//! simulates a *fixed* schedule: every value it computes is a pure
//! dataflow function of the schedule (clocks are per-device sequential,
//! dependency cells are write-once), so a replay that (a) follows each
//! device's own slot order — verified against the schedule per op —
//! and (b) never reads an unwritten cell — NaN-guarded per op — is
//! *provably* bitwise-exact however the heap would have interleaved
//! devices.  The engine therefore locks on window structure alone and
//! treats a mid-replay guard trip as "stop replaying here": the prefix
//! is exact, and the heap resumes from it.  The fused scheduler,
//! by contrast, *chooses* each op from data (start-time comparisons,
//! memory-budget `fits` checks), so its replay freezes decisions; it
//! locks only on the full fingerprint (the stash fingerprint makes the
//! budget decisions provably repeat; clock-delta repetition is the
//! evidence the comparisons repeat — stable in practice because FP
//! increments are shift-invariant while the clocks stay within one
//! binade) and a guard trip discards the attempt and re-runs the full
//! scan from scratch.
//!
//! Schedules that never lock step — strongly heterogeneous stages,
//! aperiodic knob combinations, too-few micro-batches — simply never
//! fire and take the existing kernels unchanged.

use std::collections::VecDeque;

use crate::schedule::OpKind;

/// How many consecutive round periods the detector searches (period-k
/// cycles up to this k lock; ZB-style W retirement often alternates
/// with period 2–3).
const KMAX: usize = 4;

/// Collapse is pointless below this many micro-batches (warmup + the
/// two detection rounds already cover the step).
pub(crate) const MIN_NMB: usize = 4;

/// What the collapse layer did during one kernel run.
#[derive(Clone, Copy, Debug, Default)]
pub struct CollapseStats {
    /// A steady-state cycle was detected and replayed.
    pub fired: bool,
    /// Round (micro-batch index at the anchor) of the first lock.
    pub lock_round: i64,
    /// Micro-batch rounds replayed by the collapse loop (across all
    /// replay sessions; multi-phase schedules like GPipe re-lock per
    /// phase, so this can exceed `nmb`).
    pub rounds_replayed: usize,
    /// Replay sessions entered.
    pub sessions: usize,
    /// A replay guard tripped (engine: replay stopped early and the
    /// heap resumed; fused: the attempt was discarded and re-run full).
    pub bailed: bool,
}

/// One op of a detection window / replay cycle: device, op kind,
/// stage, and micro-batch relative to the closing round.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub(crate) struct WinOp {
    pub d: u32,
    pub kind: OpKind,
    pub s: u32,
    pub off: i32,
}

/// Reusable periodicity detector (lives in the caller's
/// [`crate::perfmodel::SimArena`]; all buffers recycle across runs).
#[derive(Default)]
pub(crate) struct Detector {
    enabled: bool,
    nmb: i64,
    win_cap: usize,
    d0: i64,
    anchor: Option<(OpKind, u32)>,
    cur: Vec<WinOp>,
    /// Consecutive closed rounds: (round, window, fingerprint bits).
    hist: VecDeque<(i64, Vec<WinOp>, Vec<u64>)>,
    spare_wins: Vec<Vec<WinOp>>,
    spare_fps: Vec<Vec<u64>>,
    /// Filled on lock: the cycle ops, offs rebased to the lock round.
    pub cycle: Vec<WinOp>,
}

/// A detected lock: replay rounds `r + period, r + 2·period, …` while
/// `round + max_off ≤ nmb − 1`.
#[derive(Clone, Copy, Debug)]
pub(crate) struct Lock {
    pub r: i64,
    pub period: i64,
    pub max_off: i64,
}

impl Detector {
    /// Arm (or disarm) the detector for one kernel run over `nmb`
    /// micro-batches and ~`ops_total` executed ops.
    pub fn reset(&mut self, enabled: bool, nmb: usize, ops_total: usize) {
        self.enabled = enabled && nmb >= MIN_NMB && ops_total > 0;
        self.nmb = nmb as i64;
        // Steady windows hold ~ops_total/nmb ops; anything much longer
        // is an aperiodic stretch not worth tracking.
        self.win_cap = 2 * (ops_total / nmb.max(1)) + 16;
        self.soft_reset();
    }

    /// Clear detection state (after a replay session or an aperiodic
    /// stretch) without touching the run configuration.
    pub fn soft_reset(&mut self) {
        self.d0 = -1;
        self.anchor = None;
        self.recycle_cur();
        while let Some((_, w, f)) = self.hist.pop_front() {
            self.spare_wins.push(w);
            self.spare_fps.push(f);
        }
    }

    fn recycle_cur(&mut self) {
        self.cur.clear();
    }

    pub fn enabled(&self) -> bool {
        self.enabled
    }

    /// Record one executed op.  `fp` fills the caller's state
    /// fingerprint at round boundaries (leave empty for structural-only
    /// locking).  Returns a [`Lock`] when the schedule locks step; the
    /// replay cycle is then in [`Detector::cycle`].
    #[inline]
    pub fn record(
        &mut self,
        d: usize,
        kind: OpKind,
        s: usize,
        mb: usize,
        fp: impl FnOnce(&mut Vec<u64>),
    ) -> Option<Lock> {
        debug_assert!(self.enabled);
        if self.d0 < 0 {
            self.d0 = d as i64;
            self.anchor = Some((kind, s as u32));
        }
        self.cur.push(WinOp { d: d as u32, kind, s: s as u32, off: mb as i32 });
        if self.cur.len() > self.win_cap {
            // Aperiodic stretch: drop everything, re-anchor at d0's
            // next op.
            let keep_d0 = self.d0;
            self.soft_reset();
            self.d0 = keep_d0;
            return None;
        }
        if d as i64 != self.d0 {
            return None;
        }
        let anchored = match self.anchor {
            Some((ak, asg)) => ak == kind && asg == s as u32,
            None => {
                self.anchor = Some((kind, s as u32));
                true
            }
        };
        if !anchored {
            return None;
        }
        self.close_round(mb as i64, fp)
    }

    /// Close round `r`: rebase the window, fingerprint, and search for
    /// a period-k lock.
    fn close_round(&mut self, r: i64, fp: impl FnOnce(&mut Vec<u64>)) -> Option<Lock> {
        let mut win = self.spare_wins.pop().unwrap_or_default();
        win.clear();
        for op in &self.cur {
            win.push(WinOp { off: op.off - r as i32, ..*op });
        }
        self.recycle_cur();
        let mut bits = self.spare_fps.pop().unwrap_or_default();
        bits.clear();
        fp(&mut bits);

        if self.hist.back().is_some_and(|(pr, _, _)| *pr != r - 1) {
            // Non-consecutive rounds (phase change): history restarts.
            while let Some((_, w, f)) = self.hist.pop_front() {
                self.spare_wins.push(w);
                self.spare_fps.push(f);
            }
        }
        self.hist.push_back((r, win, bits));
        if self.hist.len() > 2 * KMAX {
            let (_, w, f) = self.hist.pop_front().expect("non-empty");
            self.spare_wins.push(w);
            self.spare_fps.push(f);
        }

        let n = self.hist.len();
        for k in 1..=KMAX {
            if n < 2 * k {
                break;
            }
            let last = &self.hist[n - 1];
            let prev = &self.hist[n - 1 - k];
            if last.2 != prev.2 {
                continue;
            }
            if (0..k).any(|i| self.hist[n - 1 - i].1 != self.hist[n - 1 - k - i].1) {
                continue;
            }
            // Lock: concatenate the last k windows, offs rebased to r.
            self.cycle.clear();
            let mut max_off = i64::MIN;
            for i in (0..k).rev() {
                let (rj, win, _) = &self.hist[n - 1 - i];
                let shift = *rj - r;
                for op in win {
                    let off = op.off as i64 + shift;
                    max_off = max_off.max(off);
                    self.cycle.push(WinOp { off: off as i32, ..*op });
                }
            }
            let lock = Lock { r, period: k as i64, max_off };
            // Only worth replaying if at least one full period fits.
            if lock.r + lock.period + lock.max_off <= self.nmb - 1 {
                return Some(lock);
            }
            self.cycle.clear();
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn locks_on_period_one_pattern() {
        let mut det = Detector::default();
        det.reset(true, 16, 32);
        let mut lock = None;
        // Device 0 alternates F/B per round; device 1 trails by one mb.
        for r in 0..16usize {
            if lock.is_some() {
                break;
            }
            lock = det.record(0, OpKind::F, 0, r, |_| ());
            if lock.is_some() {
                break;
            }
            if r >= 1 {
                lock = lock.or(det.record(1, OpKind::F, 1, r - 1, |_| ()));
            }
        }
        let lock = lock.expect("periodic pattern must lock");
        assert_eq!(lock.period, 1);
        assert!(det.cycle.len() >= 2);
        assert!(lock.max_off <= 0);
    }

    #[test]
    fn fingerprint_mismatch_blocks_lock() {
        let mut det = Detector::default();
        det.reset(true, 16, 32);
        let mut fired = false;
        for r in 0..16usize {
            // Structurally periodic, but the state fingerprint changes
            // every round: must never lock.
            fired |= det
                .record(0, OpKind::F, 0, r, |bits| bits.push(r as u64))
                .is_some();
        }
        assert!(!fired);
    }

    #[test]
    fn too_few_microbatches_disable_detection() {
        let mut det = Detector::default();
        det.reset(true, MIN_NMB - 1, 6);
        assert!(!det.enabled());
    }

    #[test]
    fn locks_on_period_two_alternation() {
        let mut det = Detector::default();
        det.reset(true, 32, 64);
        let mut lock = None;
        for r in 0..32usize {
            // The anchor op recurs every round; every other round an
            // extra op rides along — a period-2 cycle.
            lock = det.record(0, OpKind::F, 0, r, |_| ());
            if lock.is_some() {
                break;
            }
            if r % 2 == 0 {
                assert!(det.record(1, OpKind::B, 1, r, |_| ()).is_none());
            }
        }
        let lock = lock.expect("period-2 pattern must lock");
        assert_eq!(lock.period, 2);
    }
}
