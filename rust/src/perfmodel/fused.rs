//! Fused schedule+simulate evaluation — the Pipeline Generator's
//! per-candidate hot path.
//!
//! The greedy list scheduler (`schedule::greedy`) already computes every
//! op's start/end time while choosing the emission order; the seed code
//! then *re-simulated* the materialised [`Schedule`] to obtain the
//! [`PerfReport`].  Because the performance model replays ops with the
//! exact same readiness formula, those two passes compute identical
//! numbers — so this module runs the construction loop once and does the
//! Algorithm-1 accounting inline, skipping the intermediate `Schedule`,
//! the second pass, and every per-eval allocation (state lives in the
//! caller's [`SimArena`]).
//!
//! `schedule::greedy::greedy_schedule` is a thin wrapper over this
//! function with slot recording enabled, which is what guarantees the
//! fused report cannot drift from `simulate(greedy_schedule(..))`: they
//! are the same loop (enforced bitwise by
//! `tests/perfmodel_differential.rs`).
//!
//! **Steady-state collapse** ([`crate::perfmodel::collapse`]): the scan
//! loop costs O(S) per emitted op.  Once the emission stream locks into
//! a per-micro-batch cycle *and* the per-device state fingerprint
//! (clock deltas, stash levels) repeats bitwise, the remaining rounds
//! are emitted by a per-op replay loop with no candidate scan at all —
//! O(S²·nmb·v̄) becomes O(S²·warmup + S·nmb).  The replay freezes the
//! scheduler's decisions; the fingerprint is the evidence they repeat
//! (the stash match makes the memory-budget `fits` checks provably
//! repeat; the clock-delta match pins the start-time comparisons, which
//! stay stable while the clocks share a binade since FP increments are
//! shift-invariant there).  Guards verify each replayed op against the
//! scheduler's cursors and start monotonicity; any trip discards the
//! attempt and re-runs the full scan — so a wrong guess costs time,
//! never bits.  In addition, the replay only runs while clocks stay
//! under [`MAX_REPLAY_CLOCK`], the regime where one ULP is below the
//! scan's absolute tie epsilon (beyond it, tie classifications can
//! genuinely drift across binade crossings — observed empirically at
//! 100 s+ scales); on reaching the bound the exact prefix is handed
//! back to the scan.  `fused_score_collapsed == fused_score` is pinned
//! on randomized pipelines by `tests/perfmodel_collapse.rs`, and the
//! generator's Fast-vs-Reference equality pins it end-to-end.

use super::collapse::{CollapseStats, Lock, MIN_NMB};
use super::engine::{ready_at, report_from, SimArena};

/// Largest clock magnitude at which the frozen-decision replay is
/// trusted.  The scan breaks start-time ties with an *absolute*
/// `1e-15` epsilon, so its decisions are only reproducible while
/// rounding noise stays clear of that boundary.  Mathematically-tied
/// candidates computed along different dependency chains differ by a
/// few ULPs; a flip needs such a k-ULP gap to sit within one ULP of
/// the epsilon *and* a binade crossing to drift it across.  Above
/// ~4.5 s one ULP alone exceeds the epsilon and flips are real
/// (observed on homogeneous split-backward pipelines at 100 s+
/// scales: 6/160 probe divergences); at 2–4 s a common 2-ULP gap
/// lands on the boundary; at ≤ 1 s a flip needs an exactly-9-ULP gap
/// — rare enough that 240 adversarial near-bound probe trials showed
/// none.  On trip the replay simply stops — the prefix is exact — and
/// the full scan resumes from it, exactly like the drain.  (The
/// engine replay needs no such bound: it freezes no decisions and is
/// exact by dataflow at any magnitude.)  Residual sub-bound risk is
/// probabilistic, not proven away; it is pinned by the randomized and
/// near-bound homogeneous differential suites in
/// `tests/perfmodel_collapse.rs`.
const MAX_REPLAY_CLOCK: f64 = 1.0;
use super::stagetable::StageTable;
use super::PerfReport;
use crate::memory::MemCaps;
use crate::schedule::greedy::SchedKnobs;
use crate::schedule::{OpKind, Slot};

/// Run the adaptive list scheduler over `table` and return the
/// performance report of the resulting pipeline.  When `record` is
/// given, emitted slots are appended per device (used by
/// `greedy_schedule` to materialise the [`crate::schedule::Schedule`]).
///
/// Over-budget F ops are tracked separately and only taken when nothing
/// else can make progress — the memory constraint is soft here so the
/// builder always terminates; the report flags the resulting pipeline
/// OOM (Eq. 2) and the generator prunes it.
///
/// This entry runs the full scan (no collapse) — it is the oracle the
/// collapsed path is pinned against, and what `greedy_schedule` uses to
/// materialise schedules.
pub fn fused_eval(
    table: &StageTable,
    caps: &MemCaps,
    nmb: usize,
    knobs: SchedKnobs,
    arena: &mut SimArena,
    record: Option<&mut Vec<Vec<Slot>>>,
) -> PerfReport {
    run_loop(table, caps, nmb, knobs, arena, record, false);
    report_from(arena, table, caps, Vec::new())
}

/// [`fused_eval`] with steady-state collapse; returns the (bitwise
/// identical) report plus what the collapse layer did.
pub fn fused_eval_collapsed(
    table: &StageTable,
    caps: &MemCaps,
    nmb: usize,
    knobs: SchedKnobs,
    arena: &mut SimArena,
    record: Option<&mut Vec<Vec<Slot>>>,
) -> (PerfReport, CollapseStats) {
    let stats = run_loop(table, caps, nmb, knobs, arena, record, true);
    (report_from(arena, table, caps, Vec::new()), stats)
}

/// Score-only fused evaluation: identical loop, no report allocation.
/// Returns the step makespan, or `+inf` when the pipeline is OOM
/// (Eq. 2) — exactly `fused_eval(..).total` / `.oom` collapsed to the
/// generator's objective.  Full scan; see [`fused_score_collapsed`].
pub fn fused_score(
    table: &StageTable,
    caps: &MemCaps,
    nmb: usize,
    knobs: SchedKnobs,
    arena: &mut SimArena,
) -> f64 {
    run_loop(table, caps, nmb, knobs, arena, None, false);
    score_from(table, caps, arena)
}

/// [`fused_score`] with steady-state collapse — the Pipeline
/// Generator's default hot path (`GenOptions::collapse`).
pub fn fused_score_collapsed(
    table: &StageTable,
    caps: &MemCaps,
    nmb: usize,
    knobs: SchedKnobs,
    arena: &mut SimArena,
) -> (f64, CollapseStats) {
    let stats = run_loop(table, caps, nmb, knobs, arena, None, true);
    (score_from(table, caps, arena), stats)
}

fn score_from(table: &StageTable, caps: &MemCaps, arena: &SimArena) -> f64 {
    let mut total = 0.0f64;
    for &c in &arena.clock {
        total = total.max(c);
    }
    let oom =
        (0..table.p).any(|d| table.static_d[d] + arena.peak_stash[d] > caps.cap(d));
    if oom {
        f64::INFINITY
    } else {
        total
    }
}

/// One scheduler emission, fully accounted (identical arithmetic to the
/// simulation engines).  Shared by the scan loop and the replay loop.
#[allow(clippy::too_many_arguments)]
#[inline]
fn emit(
    table: &StageTable,
    nmb: usize,
    split_bw: bool,
    overlap_aware: bool,
    arena: &mut SimArena,
    record: &mut Option<&mut Vec<Vec<Slot>>>,
    start: f64,
    s: usize,
    kind: OpKind,
    mb: usize,
    comm: f64,
) {
    let d = table.device[s];
    let dur = match kind {
        OpKind::F => table.f[s],
        OpKind::B => {
            if split_bw {
                table.b[s]
            } else {
                table.bw[s]
            }
        }
        OpKind::W => table.w[s],
    };
    if comm > 0.0 {
        if overlap_aware {
            let hidden = (arena.clock[d] - (start - comm)).clamp(0.0, comm);
            arena.overlap[d] += hidden;
        } else {
            arena.comm_block[d] += comm;
        }
    }
    let end = start + dur;
    arena.clock[d] = end;
    arena.busy[d] += dur;
    let k = s * nmb + mb;
    match kind {
        OpKind::F => {
            arena.end_f[k] = end;
            arena.next_f[s] += 1;
            arena.stash[d] += table.act[s];
            arena.peak_stash[d] = arena.peak_stash[d].max(arena.stash[d]);
        }
        OpKind::B => {
            arena.end_b[k] = end;
            arena.next_b[s] += 1;
            if split_bw {
                // B consumed the intermediates; only the W-retained
                // slice stays stashed (memory/).
                arena.stash[d] -= table.act[s] - table.act_w[s];
            } else {
                arena.stash[d] -= table.act[s];
            }
        }
        OpKind::W => {
            arena.next_w[s] += 1;
            arena.stash[d] -= table.act_w[s];
        }
    }
    if let Some(rec) = record.as_mut() {
        rec[d].push(Slot::new(kind, mb, s));
    }
}

/// One scan candidate: `(start, prio, stage, kind, mb, comm)`.
type Cand = (f64, u8, usize, OpKind, usize, f64);

/// Candidate comparison with the scheduler's epsilon tie-break
/// (prio: B=0 < F=1 < W-when-filling=2; first stage wins exact ties).
#[allow(clippy::too_many_arguments)]
fn consider(
    best: &mut Option<Cand>,
    start: f64,
    prio: u8,
    s: usize,
    kind: OpKind,
    mb: usize,
    comm: f64,
) {
    let better = match best {
        None => true,
        Some((bs, bp, ..)) => {
            start < *bs - 1e-15 || ((start - *bs).abs() <= 1e-15 && prio < *bp)
        }
    };
    if better {
        *best = Some((start, prio, s, kind, mb, comm));
    }
}

/// One full O(S) candidate scan; returns the op to emit.
fn scan(
    table: &StageTable,
    nmb: usize,
    knobs: SchedKnobs,
    arena: &SimArena,
) -> (f64, usize, OpKind, usize, f64) {
    let s_n = table.n_stages;
    let mut best: Option<Cand> = None;
    let mut best_overlimit: Option<Cand> = None;
    for s in 0..s_n {
        let d = table.device[s];
        let clk = arena.clock[d];
        // F candidate.
        let mb = arena.next_f[s];
        if mb < nmb {
            let dep = if s == 0 { 0.0 } else { arena.end_f[(s - 1) * nmb + mb] };
            if !dep.is_nan() {
                let fits = arena.stash[d] + table.act[s] <= arena.budget[d]
                    || arena.stash[d] == 0.0;
                let start = ready_at(dep, table.comm_f_in[s], clk, knobs.overlap_aware);
                let target = if fits { &mut best } else { &mut best_overlimit };
                consider(target, start, 1, s, OpKind::F, mb, table.comm_f_in[s]);
            }
        }
        // B candidate: needs F(mb,s) done and B(mb,s+1) done (or F
        // for the last stage).
        let mb = arena.next_b[s];
        if mb < nmb && !arena.end_f[s * nmb + mb].is_nan() {
            let (dep, comm) = if s == s_n - 1 {
                (arena.end_f[s * nmb + mb], 0.0)
            } else if arena.end_b[(s + 1) * nmb + mb].is_nan() {
                (f64::NAN, 0.0)
            } else {
                (arena.end_b[(s + 1) * nmb + mb], table.comm_b_in[s])
            };
            if !dep.is_nan() {
                consider(
                    &mut best,
                    ready_at(dep, comm, clk, knobs.overlap_aware),
                    0,
                    s,
                    OpKind::B,
                    mb,
                    comm,
                );
            }
        }
        // W candidate (split mode): delayed by default so it only
        // wins when nothing else can start earlier — bubble filling.
        if knobs.split_bw {
            let mb = arena.next_w[s];
            if mb < nmb && mb < arena.next_b[s] {
                let prio = if knobs.w_fill { 2 } else { 0 };
                consider(
                    &mut best,
                    arena.end_b[s * nmb + mb].max(clk),
                    prio,
                    s,
                    OpKind::W,
                    mb,
                    0.0,
                );
            }
        }
    }
    let (start, _, s, kind, mb, comm) = best.or(best_overlimit).unwrap_or_else(|| {
        panic!("scheduler stuck (invalid deps?)")
    });
    (start, s, kind, mb, comm)
}

fn run_loop(
    table: &StageTable,
    caps: &MemCaps,
    nmb: usize,
    knobs: SchedKnobs,
    arena: &mut SimArena,
    mut record: Option<&mut Vec<Vec<Slot>>>,
    collapse: bool,
) -> CollapseStats {
    let s_n = table.n_stages;
    let p = table.p;
    debug_assert_eq!(caps.p(), p);
    let total_ops = s_n * nmb * if knobs.split_bw { 3 } else { 2 };
    let mut stats = CollapseStats::default();
    let mut try_collapse = collapse && nmb >= MIN_NMB;

    'attempt: loop {
        arena.reset_fused(s_n, nmb, p);
        if let Some(rec) = record.as_mut() {
            for v in rec.iter_mut() {
                v.clear();
            }
        }
        for d in 0..p {
            // Unbounded caps give an infinite budget: `fits` always holds.
            arena.budget[d] =
                ((caps.cap(d) - table.static_d[d]) * knobs.mem_cap_factor).max(0.0);
        }
        arena.det.reset(try_collapse, nmb, total_ops);

        let mut emitted = 0usize;
        let mut lock: Option<Lock> = None;
        let mut detect = true;
        while emitted < total_ops {
            let (start, s, kind, mb, comm) = scan(table, nmb, knobs, arena);
            emit(
                table,
                nmb,
                knobs.split_bw,
                knobs.overlap_aware,
                arena,
                &mut record,
                start,
                s,
                kind,
                mb,
                comm,
            );
            emitted += 1;
            if detect && start > MAX_REPLAY_CLOCK {
                // Past the trusted-magnitude bound any lock's replay
                // would stop immediately; skip the bookkeeping.
                detect = false;
            }
            if detect && arena.det.enabled() {
                let d = table.device[s];
                // The scheduler's *decisions* must repeat, so the lock
                // needs the full state fingerprint: clock deltas to the
                // anchor device (start-time comparisons) and absolute
                // stash levels (memory-budget `fits` checks).
                let (clock, stash) = (&arena.clock, &arena.stash);
                let base = clock[table.device[0]];
                lock = arena.det.record(d, kind, s, mb, |bits| {
                    for &c in clock.iter() {
                        bits.push((c - base).to_bits());
                    }
                    for &v in stash.iter() {
                        bits.push(v.to_bits());
                    }
                });
                if lock.is_some() {
                    break;
                }
            }
        }

        if let Some(lock) = lock {
            stats.fired = true;
            stats.sessions += 1;
            stats.lock_round = lock.r;
            let mut r_cur = lock.r + lock.period;
            let mut prev_start = f64::NEG_INFINITY;
            'replay: while r_cur + lock.max_off <= (nmb - 1) as i64 {
                for i in 0..arena.det.cycle.len() {
                    let op = arena.det.cycle[i];
                    let s = op.s as usize;
                    let mb_i = r_cur + op.off as i64;
                    // Guard 1: the frozen decision matches the
                    // scheduler's cursor for this (kind, stage).
                    let next = match op.kind {
                        OpKind::F => arena.next_f[s],
                        OpKind::B => arena.next_b[s],
                        OpKind::W => arena.next_w[s],
                    };
                    if mb_i < 0 || mb_i as usize != next || next >= nmb {
                        stats = CollapseStats { bailed: true, ..CollapseStats::default() };
                        try_collapse = false;
                        continue 'attempt;
                    }
                    let mb = mb_i as usize;
                    // Guard 2: dependency resolved.
                    let (dep, comm) = match op.kind {
                        OpKind::F => {
                            if s == 0 {
                                (0.0, 0.0)
                            } else {
                                (arena.end_f[(s - 1) * nmb + mb], table.comm_f_in[s])
                            }
                        }
                        OpKind::B => {
                            if s == s_n - 1 {
                                (arena.end_f[s * nmb + mb], 0.0)
                            } else {
                                (arena.end_b[(s + 1) * nmb + mb], table.comm_b_in[s])
                            }
                        }
                        OpKind::W => (arena.end_b[s * nmb + mb], 0.0),
                    };
                    let d = table.device[s];
                    let start = if op.kind == OpKind::W {
                        // The scan's W candidate shape: end_b.max(clk).
                        dep.max(arena.clock[d])
                    } else {
                        ready_at(dep, comm, arena.clock[d], knobs.overlap_aware)
                    };
                    if start > MAX_REPLAY_CLOCK {
                        // Leaving the trusted-magnitude regime: the
                        // prefix is exact, hand the rest to the scan
                        // (not a bail — nothing diverged).
                        break 'replay;
                    }
                    // Guard 3: deps resolved, emission order plausible
                    // (scan emissions are monotone in start up to the
                    // 1e-15 tie epsilon).
                    if dep.is_nan() || start < prev_start - 1e-15 {
                        stats = CollapseStats { bailed: true, ..CollapseStats::default() };
                        try_collapse = false;
                        continue 'attempt;
                    }
                    prev_start = start;
                    emit(
                        table,
                        nmb,
                        knobs.split_bw,
                        knobs.overlap_aware,
                        arena,
                        &mut record,
                        start,
                        s,
                        op.kind,
                        mb,
                        comm,
                    );
                    emitted += 1;
                }
                stats.rounds_replayed += lock.period as usize;
                r_cur += lock.period;
            }
            if stats.rounds_replayed == 0 {
                // Nothing actually replayed (e.g. the magnitude bound
                // tripped on the first op): report an inert collapse.
                stats.fired = false;
                stats.sessions = 0;
            }
            // Drain: resume the full scan for the tail ops.
            while emitted < total_ops {
                let (start, s, kind, mb, comm) = scan(table, nmb, knobs, arena);
                emit(
                    table,
                    nmb,
                    knobs.split_bw,
                    knobs.overlap_aware,
                    arena,
                    &mut record,
                    start,
                    s,
                    kind,
                    mb,
                    comm,
                );
                emitted += 1;
            }
        }
        return stats;
    }
}
