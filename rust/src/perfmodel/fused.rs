//! Fused schedule+simulate evaluation — the Pipeline Generator's
//! per-candidate hot path.
//!
//! The greedy list scheduler (`schedule::greedy`) already computes every
//! op's start/end time while choosing the emission order; the seed code
//! then *re-simulated* the materialised [`Schedule`] to obtain the
//! [`PerfReport`].  Because the performance model replays ops with the
//! exact same readiness formula, those two passes compute identical
//! numbers — so this module runs the construction loop once and does the
//! Algorithm-1 accounting inline, skipping the intermediate `Schedule`,
//! the second pass, and every per-eval allocation (state lives in the
//! caller's [`SimArena`]).
//!
//! `schedule::greedy::greedy_schedule` is a thin wrapper over this
//! function with slot recording enabled, which is what guarantees the
//! fused report cannot drift from `simulate(greedy_schedule(..))`: they
//! are the same loop (enforced bitwise by
//! `tests/perfmodel_differential.rs`).

use super::engine::{ready_at, report_from, SimArena};
use super::stagetable::StageTable;
use super::PerfReport;
use crate::memory::MemCaps;
use crate::schedule::greedy::SchedKnobs;
use crate::schedule::{OpKind, Slot};

/// Run the adaptive list scheduler over `table` and return the
/// performance report of the resulting pipeline.  When `record` is
/// given, emitted slots are appended per device (used by
/// `greedy_schedule` to materialise the [`crate::schedule::Schedule`]).
///
/// Over-budget F ops are tracked separately and only taken when nothing
/// else can make progress — the memory constraint is soft here so the
/// builder always terminates; the report flags the resulting pipeline
/// OOM (Eq. 2) and the generator prunes it.
pub fn fused_eval(
    table: &StageTable,
    caps: &MemCaps,
    nmb: usize,
    knobs: SchedKnobs,
    arena: &mut SimArena,
    record: Option<&mut Vec<Vec<Slot>>>,
) -> PerfReport {
    run_loop(table, caps, nmb, knobs, arena, record);
    report_from(arena, table, caps, Vec::new())
}

/// Score-only fused evaluation: identical loop, no report allocation.
/// Returns the step makespan, or `+inf` when the pipeline is OOM
/// (Eq. 2) — exactly `fused_eval(..).total` / `.oom` collapsed to the
/// generator's objective.
pub fn fused_score(
    table: &StageTable,
    caps: &MemCaps,
    nmb: usize,
    knobs: SchedKnobs,
    arena: &mut SimArena,
) -> f64 {
    run_loop(table, caps, nmb, knobs, arena, None);
    let mut total = 0.0f64;
    for &c in &arena.clock {
        total = total.max(c);
    }
    let oom = (0..table.p)
        .any(|d| table.static_d[d] + arena.peak_stash[d] > caps.cap(d));
    if oom {
        f64::INFINITY
    } else {
        total
    }
}

fn run_loop(
    table: &StageTable,
    caps: &MemCaps,
    nmb: usize,
    knobs: SchedKnobs,
    arena: &mut SimArena,
    mut record: Option<&mut Vec<Vec<Slot>>>,
) {
    let s_n = table.n_stages;
    let p = table.p;
    debug_assert_eq!(caps.p(), p);
    arena.reset_fused(s_n, nmb, p);
    for d in 0..p {
        // Unbounded caps give an infinite budget: `fits` always holds.
        arena.budget[d] =
            ((caps.cap(d) - table.static_d[d]) * knobs.mem_cap_factor).max(0.0);
    }

    let total_ops = s_n * nmb * if knobs.split_bw { 3 } else { 2 };
    let mut emitted = 0usize;

    // Candidate comparison with the scheduler's epsilon tie-break
    // (prio: B=0 < F=1 < W-when-filling=2; first stage wins exact ties).
    fn consider(
        best: &mut Option<(f64, u8, usize, Slot)>,
        start: f64,
        prio: u8,
        s: usize,
        slot: Slot,
    ) {
        let better = match best {
            None => true,
            Some((bs, bp, _, _)) => {
                start < *bs - 1e-15 || ((start - *bs).abs() <= 1e-15 && prio < *bp)
            }
        };
        if better {
            *best = Some((start, prio, s, slot));
        }
    }

    while emitted < total_ops {
        let mut best: Option<(f64, u8, usize, Slot)> = None;
        let mut best_overlimit: Option<(f64, u8, usize, Slot)> = None;

        for s in 0..s_n {
            let d = table.device[s];
            let clk = arena.clock[d];
            // F candidate.
            let mb = arena.next_f[s];
            if mb < nmb {
                let dep = if s == 0 { 0.0 } else { arena.end_f[(s - 1) * nmb + mb] };
                if !dep.is_nan() {
                    let fits = arena.stash[d] + table.act[s] <= arena.budget[d]
                        || arena.stash[d] == 0.0;
                    let start = ready_at(dep, table.comm_f_in[s], clk, knobs.overlap_aware);
                    let target = if fits { &mut best } else { &mut best_overlimit };
                    consider(target, start, 1, s, Slot::new(OpKind::F, mb, s));
                }
            }
            // B candidate: needs F(mb,s) done and B(mb,s+1) done (or F
            // for the last stage).
            let mb = arena.next_b[s];
            if mb < nmb && !arena.end_f[s * nmb + mb].is_nan() {
                let (dep, comm) = if s == s_n - 1 {
                    (arena.end_f[s * nmb + mb], 0.0)
                } else if arena.end_b[(s + 1) * nmb + mb].is_nan() {
                    (f64::NAN, 0.0)
                } else {
                    (arena.end_b[(s + 1) * nmb + mb], table.comm_b_in[s])
                };
                if !dep.is_nan() {
                    consider(
                        &mut best,
                        ready_at(dep, comm, clk, knobs.overlap_aware),
                        0,
                        s,
                        Slot::new(OpKind::B, mb, s),
                    );
                }
            }
            // W candidate (split mode): delayed by default so it only
            // wins when nothing else can start earlier — bubble filling.
            if knobs.split_bw {
                let mb = arena.next_w[s];
                if mb < nmb && mb < arena.next_b[s] {
                    let prio = if knobs.w_fill { 2 } else { 0 };
                    consider(
                        &mut best,
                        arena.end_b[s * nmb + mb].max(clk),
                        prio,
                        s,
                        Slot::new(OpKind::W, mb, s),
                    );
                }
            }
        }

        let (start, _, s, slot) = best.or(best_overlimit).unwrap_or_else(|| {
            panic!("scheduler stuck: emitted {emitted}/{total_ops} (invalid deps?)")
        });
        let d = table.device[s];
        let (dur, comm) = match slot.op {
            OpKind::F => (table.f[s], table.comm_f_in[s]),
            OpKind::B => {
                let dur = if knobs.split_bw {
                    table.b[s]
                } else {
                    table.b[s] + table.w[s]
                };
                let comm = if s == s_n - 1 { 0.0 } else { table.comm_b_in[s] };
                (dur, comm)
            }
            OpKind::W => (table.w[s], 0.0),
        };
        // Algorithm-1 accounting, identical to the simulation engines.
        if comm > 0.0 {
            if knobs.overlap_aware {
                let hidden = (arena.clock[d] - (start - comm)).clamp(0.0, comm);
                arena.overlap[d] += hidden;
            } else {
                arena.comm_block[d] += comm;
            }
        }
        let end = start + dur;
        arena.clock[d] = end;
        arena.busy[d] += dur;
        let k = s * nmb + slot.mb as usize;
        match slot.op {
            OpKind::F => {
                arena.end_f[k] = end;
                arena.next_f[s] += 1;
                arena.stash[d] += table.act[s];
                arena.peak_stash[d] = arena.peak_stash[d].max(arena.stash[d]);
            }
            OpKind::B => {
                arena.end_b[k] = end;
                arena.next_b[s] += 1;
                if knobs.split_bw {
                    // B consumed the intermediates; only the W-retained
                    // slice stays stashed (memory/).
                    arena.stash[d] -= table.act[s] - table.act_w[s];
                } else {
                    arena.stash[d] -= table.act[s];
                }
            }
            OpKind::W => {
                arena.next_w[s] += 1;
                arena.stash[d] -= table.act_w[s];
            }
        }
        if let Some(rec) = record.as_mut() {
            rec[d].push(slot);
        }
        emitted += 1;
    }
}
