//! Pipeline Performance Model (paper §4.2, Algorithm 1).
//!
//! Event-driven simulation of a [`Schedule`] over a (partition,
//! placement) with profiled per-layer costs:
//!
//! - **Step 1** layer-level aggregation: [`ProfiledData::stage_cost`]
//!   (O(1) via prefix sums);
//! - **Step 2** stage→device aggregation: [`StageTable`];
//! - **Step 3** runtime & memory estimation: the simulation kernels
//!   yield `T_d = C_d + BubbleTime(d) − OverlapTime(d)` (identity: we
//!   measure busy/bubble/overlap directly), `M_d`, and, optionally,
//!   per-op trace events (Fig 11's simulated traces).
//!
//! Three entry points share identical arithmetic (and bit-identical
//! outputs, enforced by `tests/perfmodel_differential.rs`):
//!
//! - [`simulate`] — the O(slots · log P) event-driven engine
//!   ([`engine::simulate_in`]) behind a convenience wrapper;
//! - [`simulate_reference`] — the retained O(slots · P) scan loop, kept
//!   as the differential-testing oracle and the bench baseline;
//! - [`fused::fused_eval`] — schedule construction + accounting in one
//!   pass, the Pipeline Generator's per-candidate hot path.
//!
//! Deadlock (a schedule whose cross-device waits cycle) is detected and
//! reported rather than hanging — the Pipeline Generator relies on this
//! to prune invalid candidates.
//!
//! [`bounds`] sits *in front of* the kernels: an O(S), allocation-free
//! analytic makespan lower bound from a [`StageTable`] alone, which the
//! Pipeline Generator uses to skip simulating candidates that provably
//! cannot beat its incumbent (DESIGN.md § Search acceleration).
//!
//! [`collapse`] sits *inside* the kernels: once a schedule locks into
//! its per-micro-batch steady state, the remaining rounds are replayed
//! by a tight per-op loop (same f64 operations in the same order ⇒
//! bitwise-identical reports, pinned by `tests/perfmodel_collapse.rs`)
//! instead of re-deriving the cycle through the heap or the greedy
//! scan — candidate-evaluation cost becomes (nearly) independent of
//! `nmb`.

pub mod bounds;
pub mod collapse;
pub mod engine;
pub mod fused;
pub mod stagetable;

pub use bounds::{
    fits_lower_bound, makespan_lower_bound, makespan_lower_bound_in, BoundScratch,
};
pub use collapse::CollapseStats;
pub use engine::{simulate_in, simulate_in_opts, simulate_in_with, EngineOpts, SimArena};
pub use fused::{fused_eval, fused_eval_collapsed, fused_score, fused_score_collapsed};
pub use stagetable::StageTable;

use crate::memory::MemCaps;
use crate::partition::Partition;
use crate::placement::Placement;
use crate::profile::ProfiledData;
use crate::schedule::{OpKind, Schedule, Slot};
use crate::util::trace::TraceEvent;

/// Simulation result (Algorithm 1 outputs).
#[derive(Clone, Debug)]
pub struct PerfReport {
    /// Step makespan (s): `max_d T_d` — the generator's objective.
    pub total: f64,
    /// Per-device last-activity end time.
    pub t_d: Vec<f64>,
    /// Per-device pure compute time (C_d).
    pub busy_d: Vec<f64>,
    /// Per-device idle time within the makespan (BubbleTime(d)).
    pub bubble_d: Vec<f64>,
    /// Per-device comm hidden under compute (OverlapTime(d)).
    pub overlap_d: Vec<f64>,
    /// Per-device time blocked on un-overlapped receives.
    pub comm_block_d: Vec<f64>,
    /// Per-device memory high-water mark (bytes): static + peak stash.
    pub m_d: Vec<f64>,
    /// Per-device static memory (params+grads+optimizer).
    pub static_d: Vec<f64>,
    /// Per-device headroom: capacity − `m_d` (`+inf` on unbounded
    /// devices, negative on OOM devices).
    pub headroom_d: Vec<f64>,
    /// Devices that exceeded capacity.
    pub oom: bool,
    /// Trace events (only when requested).
    pub events: Vec<TraceEvent>,
}

impl PerfReport {
    /// Mean bubble ratio: Σ_d bubble / (P · makespan)  (Fig 1 metric).
    pub fn bubble_ratio(&self) -> f64 {
        let p = self.t_d.len() as f64;
        self.bubble_d.iter().sum::<f64>() / (p * self.total.max(1e-12))
    }

    /// Training throughput in tokens/s for `tokens_per_step`.
    pub fn throughput(&self, tokens_per_step: f64) -> f64 {
        tokens_per_step / self.total.max(1e-12)
    }

    /// Tightest per-device memory headroom (the generator's frontier
    /// metric): `+inf` when unconstrained, negative when OOM.
    pub fn min_headroom(&self) -> f64 {
        self.headroom_d.iter().cloned().fold(f64::INFINITY, f64::min)
    }

    /// Cluster peak memory: the largest per-device high-water mark.
    pub fn peak_mem(&self) -> f64 {
        self.m_d.iter().cloned().fold(0.0, f64::max)
    }
}

/// Simulation error: the schedule deadlocks.
#[derive(Debug)]
pub struct Deadlock {
    pub device: usize,
    pub at_slot: usize,
    pub slot: Slot,
}

impl std::fmt::Display for Deadlock {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "deadlock: device {} blocked at slot index {} ({:?})",
            self.device, self.at_slot, self.slot
        )
    }
}

impl std::error::Error for Deadlock {}

/// Simulate a schedule; see module docs.  Convenience wrapper over the
/// event-driven engine with a fresh [`SimArena`] — hot callers (the
/// generator, the benches) keep an arena and use [`simulate_in`].
pub fn simulate(
    profile: &ProfiledData,
    partition: &Partition,
    placement: &Placement,
    schedule: &Schedule,
    collect_trace: bool,
) -> Result<PerfReport, Deadlock> {
    debug_assert_eq!(placement.n_stages(), partition.n_stages());
    let caps = MemCaps::uniform(placement.p, profile.mem_capacity);
    let table = StageTable::build(profile, partition, placement);
    let mut arena = SimArena::new();
    simulate_in(&mut arena, &table, &caps, schedule, collect_trace)
}

/// The retained reference simulator: the original per-event all-device
/// scan, O(slots · P).  Kept verbatim (plus an explicit `(start,
/// device)` tie-break) as the differential-testing oracle for the fast
/// engines and as the baseline for `benches/perfmodel.rs`.  Uniform
/// capacity from the profile; [`simulate_reference_in`] takes
/// heterogeneous caps.
pub fn simulate_reference(
    profile: &ProfiledData,
    partition: &Partition,
    placement: &Placement,
    schedule: &Schedule,
    collect_trace: bool,
) -> Result<PerfReport, Deadlock> {
    let caps = MemCaps::uniform(placement.p, profile.mem_capacity);
    simulate_reference_in(profile, &caps, partition, placement, schedule, collect_trace)
}

/// [`simulate_reference`] against per-device memory capacities.
pub fn simulate_reference_in(
    profile: &ProfiledData,
    caps: &MemCaps,
    partition: &Partition,
    placement: &Placement,
    schedule: &Schedule,
    collect_trace: bool,
) -> Result<PerfReport, Deadlock> {
    let s_n = partition.n_stages();
    let p = schedule.p;
    let nmb = schedule.nmb;
    debug_assert_eq!(placement.n_stages(), s_n);
    debug_assert_eq!(caps.p(), p);

    // Stage costs (Alg. 1 Steps 1–2).
    struct St {
        f: f64,
        b: f64,
        w: f64,
        act: f64,
        act_w: f64,
        comm_f_in: f64, // p2p time for F input (from stage-1)
        comm_b_in: f64, // p2p time for B input (from stage+1)
    }
    let costs: Vec<_> =
        (0..s_n).map(|s| profile.stage_cost(partition.stage_range(s))).collect();
    let stages: Vec<St> = (0..s_n)
        .map(|s| {
            let comm_f_in = if s > 0 && placement.device_of[s - 1] != placement.device_of[s]
            {
                profile.p2p(costs[s - 1].comm_bytes)
            } else {
                0.0
            };
            let comm_b_in = if s + 1 < s_n
                && placement.device_of[s + 1] != placement.device_of[s]
            {
                // Gradient w.r.t. this stage's output: same size as the
                // forward boundary message.
                profile.p2p(costs[s].comm_bytes)
            } else {
                0.0
            };
            St {
                f: costs[s].f,
                b: if schedule.split_bw { costs[s].b } else { costs[s].b + costs[s].w },
                w: costs[s].w,
                act: costs[s].mem_act,
                act_w: costs[s].mem_act_w,
                comm_f_in,
                comm_b_in,
            }
        })
        .collect();

    let static_d: Vec<f64> = (0..p)
        .map(|d| {
            (0..s_n)
                .filter(|&s| placement.device_of[s] == d)
                .map(|s| costs[s].mem_static)
                .sum()
        })
        .collect();

    // Simulation state.
    let mut end_f = vec![f64::NAN; s_n * nmb];
    let mut end_b = vec![f64::NAN; s_n * nmb];
    let idx = |s: usize, mb: usize| s * nmb + mb;
    let mut ptr = vec![0usize; p];
    let mut clock = vec![0.0f64; p];
    let mut busy = vec![0.0f64; p];
    let mut comm_block = vec![0.0f64; p];
    let mut overlap = vec![0.0f64; p];
    let mut stash = vec![0.0f64; p];
    let mut peak_stash = vec![0.0f64; p];
    let mut events = Vec::new();
    let total_slots: usize = schedule.per_device.iter().map(|v| v.len()).sum();
    let mut done = 0usize;

    while done < total_slots {
        // Pick, among devices whose next slot is dependency-ready, the
        // one that can start earliest (event-driven order); ties break
        // on the lower device id so reports are reproducible across
        // refactors (and match the heap engine's `(start, d)` key).
        let mut best: Option<(f64, f64, usize)> = None; // (start, comm, device)
        for d in 0..p {
            if ptr[d] >= schedule.per_device[d].len() {
                continue;
            }
            let sl = schedule.per_device[d][ptr[d]];
            let s = sl.stage as usize;
            let mb = sl.mb as usize;
            let (dep, comm) = match sl.op {
                OpKind::F => {
                    if s == 0 {
                        (0.0, 0.0)
                    } else {
                        (end_f[idx(s - 1, mb)], stages[s].comm_f_in)
                    }
                }
                OpKind::B => {
                    if s == s_n - 1 {
                        (end_f[idx(s, mb)], 0.0)
                    } else {
                        (end_b[idx(s + 1, mb)], stages[s].comm_b_in)
                    }
                }
                OpKind::W => (end_b[idx(s, mb)], 0.0),
            };
            if dep.is_nan() {
                continue; // blocked on a cross-device dependency
            }
            let start = if comm == 0.0 {
                clock[d].max(dep)
            } else if schedule.overlap_aware {
                clock[d].max(dep + comm)
            } else {
                clock[d].max(dep) + comm
            };
            if best.is_none_or(|(bs, _, bd)| start < bs || (start == bs && d < bd)) {
                best = Some((start, comm, d));
            }
        }

        let (start, comm, d) = match best {
            Some(x) => x,
            None => {
                // All remaining devices blocked: deadlock.
                let d = (0..p).find(|&d| ptr[d] < schedule.per_device[d].len()).unwrap();
                return Err(Deadlock {
                    device: d,
                    at_slot: ptr[d],
                    slot: schedule.per_device[d][ptr[d]],
                });
            }
        };

        let sl = schedule.per_device[d][ptr[d]];
        let s = sl.stage as usize;
        let mb = sl.mb as usize;
        let dur = match sl.op {
            OpKind::F => stages[s].f,
            OpKind::B => stages[s].b,
            OpKind::W => stages[s].w,
        };
        // Comm accounting.
        if comm > 0.0 {
            if schedule.overlap_aware {
                // Hidden fraction: transfer window [start-comm, start]
                // vs device busy-until clock[d].
                let hidden = (clock[d] - (start - comm)).clamp(0.0, comm);
                overlap[d] += hidden;
                if collect_trace {
                    events.push(TraceEvent {
                        name: format!("recv{}@s{}", mb, s),
                        cat: "comm".into(),
                        ts_us: (start - comm) * 1e6,
                        dur_us: comm * 1e6,
                        pid: d,
                        tid: 1,
                    });
                }
            } else {
                comm_block[d] += comm;
                if collect_trace {
                    events.push(TraceEvent {
                        name: format!("recv{}@s{}", mb, s),
                        cat: "comm".into(),
                        ts_us: (start - comm) * 1e6,
                        dur_us: comm * 1e6,
                        pid: d,
                        tid: 0,
                    });
                }
            }
        }
        let end = start + dur;
        clock[d] = end;
        busy[d] += dur;
        match sl.op {
            OpKind::F => {
                end_f[idx(s, mb)] = end;
                stash[d] += stages[s].act;
                peak_stash[d] = peak_stash[d].max(stash[d]);
            }
            OpKind::B => {
                end_b[idx(s, mb)] = end;
                if schedule.split_bw {
                    // B consumed the intermediates; only the W-retained
                    // slice stays stashed (memory/).
                    stash[d] -= stages[s].act - stages[s].act_w;
                } else {
                    stash[d] -= stages[s].act;
                }
            }
            OpKind::W => {
                stash[d] -= stages[s].act_w;
            }
        }
        if collect_trace {
            events.push(TraceEvent {
                name: format!("{}{}@s{}", sl.op.name(), mb, s),
                cat: sl.op.name().into(),
                ts_us: start * 1e6,
                dur_us: dur * 1e6,
                pid: d,
                tid: 0,
            });
        }
        ptr[d] += 1;
        done += 1;
    }

    let total = clock.iter().cloned().fold(0.0, f64::max);
    let m_d: Vec<f64> =
        (0..p).map(|d| static_d[d] + peak_stash[d]).collect();
    let headroom_d: Vec<f64> = (0..p).map(|d| caps.cap(d) - m_d[d]).collect();
    let oom = (0..p).any(|d| m_d[d] > caps.cap(d));
    let bubble_d: Vec<f64> =
        (0..p).map(|d| (total - busy[d] - comm_block[d]).max(0.0)).collect();
    Ok(PerfReport {
        total,
        t_d: clock,
        busy_d: busy,
        bubble_d,
        overlap_d: overlap,
        comm_block_d: comm_block,
        m_d,
        static_d,
        headroom_d,
        oom,
        events,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Family, HardwareCfg, ModelCfg, ParallelCfg, Size};
    use crate::model::build_model;
    use crate::partition::uniform;
    use crate::placement::sequential;
    use crate::schedule::builders::{gpipe, one_f_one_b, zb_h1};

    fn setup(fam: Family, p: usize, nmb: usize) -> (ProfiledData, Partition, Placement) {
        let spec = build_model(&ModelCfg::table5(fam, Size::Small));
        let par = ParallelCfg::new(p, 2, nmb, 1, 4096);
        let prof = ProfiledData::analytical(&spec, &HardwareCfg::default(), &par);
        let part = uniform(prof.n_layers(), p);
        (prof, part, sequential(p))
    }

    #[test]
    fn gpipe_bubble_exceeds_1f1b_memory() {
        // GPipe and 1F1B have the same bubble but GPipe stashes all nmb
        // activations: its memory must be higher.
        let (prof, part, pl) = setup(Family::Llama2, 4, 8);
        let g = simulate(&prof, &part, &pl, &gpipe(4, 8), false).unwrap();
        let o = simulate(&prof, &part, &pl, &one_f_one_b(4, 8), false).unwrap();
        assert!(g.m_d[0] > o.m_d[0], "gpipe {} !> 1f1b {}", g.m_d[0], o.m_d[0]);
    }

    #[test]
    fn more_microbatches_reduce_bubble_ratio() {
        let (prof, part, pl) = setup(Family::Llama2, 4, 4);
        let r4 = simulate(&prof, &part, &pl, &one_f_one_b(4, 4), false).unwrap();
        let r32 = simulate(&prof, &part, &pl, &one_f_one_b(4, 32), false).unwrap();
        assert!(r32.bubble_ratio() < r4.bubble_ratio());
    }

    #[test]
    fn zb_beats_1f1b_on_homogeneous() {
        let (prof, part, pl) = setup(Family::Llama2, 4, 8);
        let zb = simulate(&prof, &part, &pl, &zb_h1(4, 8), false).unwrap();
        let ofob = simulate(&prof, &part, &pl, &one_f_one_b(4, 8), false).unwrap();
        assert!(
            zb.total < ofob.total,
            "zb {:.4} !< 1f1b {:.4}",
            zb.total,
            ofob.total
        );
    }

    #[test]
    fn makespan_at_least_critical_path() {
        let (prof, part, pl) = setup(Family::Gemma, 4, 8);
        let r = simulate(&prof, &part, &pl, &one_f_one_b(4, 8), false).unwrap();
        // Lower bound: the busiest device's compute.
        let max_busy = r.busy_d.iter().cloned().fold(0.0, f64::max);
        assert!(r.total >= max_busy);
        // Identity T_d = C_d + bubble + comm_block (within fp tolerance).
        for d in 0..4 {
            let lhs = r.total;
            let rhs = r.busy_d[d] + r.bubble_d[d] + r.comm_block_d[d];
            assert!((lhs - rhs).abs() / lhs < 1e-9, "dev {d}: {lhs} vs {rhs}");
        }
    }

    #[test]
    fn trace_events_collected() {
        let (prof, part, pl) = setup(Family::Llama2, 2, 2);
        let r = simulate(&prof, &part, &pl, &one_f_one_b(2, 2), true).unwrap();
        // 2 devices × (2F + 2B) compute events + comm events.
        let computes = r.events.iter().filter(|e| e.cat != "comm").count();
        assert_eq!(computes, 8);
    }

    #[test]
    fn deadlock_detected() {
        let (prof, part, pl) = setup(Family::Llama2, 2, 1);
        // Device 0 waits for B(0,0)'s dep B(0,1) before running F(0,0):
        // cross-device cycle with device 1 needing F(0,0) first.
        let bad = Schedule {
            p: 2,
            nmb: 1,
            n_stages: 2,
            split_bw: false,
            overlap_aware: false,
            per_device: vec![
                vec![Slot::new(OpKind::B, 0, 0), Slot::new(OpKind::F, 0, 0)],
                vec![Slot::new(OpKind::F, 0, 1), Slot::new(OpKind::B, 0, 1)],
            ],
        };
        let fast = simulate(&prof, &part, &pl, &bad, false);
        let refr = simulate_reference(&prof, &part, &pl, &bad, false);
        let (f, r) = (fast.unwrap_err(), refr.unwrap_err());
        assert_eq!((f.device, f.at_slot, f.slot), (r.device, r.at_slot, r.slot));
    }

    #[test]
    fn equal_start_ties_break_on_lower_device() {
        // Regression for the tie-break contract: two dependency-free ops
        // with identical start times must execute lowest-device-first in
        // both engines, so trace order (and any order-sensitive derived
        // report) is reproducible across refactors.
        let (prof, _, _) = setup(Family::Llama2, 2, 2);
        let part = Partition::from_sizes(&[prof.n_layers()]);
        let pl = Placement { p: 2, device_of: vec![0] };
        let sch = Schedule {
            p: 2,
            nmb: 2,
            n_stages: 1,
            split_bw: false,
            overlap_aware: false,
            // Both devices open with a dependency-free F at t=0: a tie.
            per_device: vec![
                vec![Slot::new(OpKind::F, 0, 0)],
                vec![Slot::new(OpKind::F, 1, 0)],
            ],
        };
        let fast = simulate(&prof, &part, &pl, &sch, true).unwrap();
        let refr = simulate_reference(&prof, &part, &pl, &sch, true).unwrap();
        for r in [&fast, &refr] {
            assert_eq!(r.events.len(), 2);
            assert_eq!(r.events[0].pid, 0, "device 0 must win the t=0 tie");
            assert_eq!(r.events[1].pid, 1);
        }
        assert_eq!(fast.t_d, refr.t_d);
    }

    #[test]
    fn fast_engine_matches_reference_on_builders() {
        for (fam, p, nmb) in
            [(Family::Gemma, 4, 8), (Family::NemotronH, 4, 16), (Family::Llama2, 2, 4)]
        {
            let (prof, part, pl) = setup(fam, p, nmb);
            for sch in [one_f_one_b(p, nmb), gpipe(p, nmb), zb_h1(p, nmb)] {
                let a = simulate(&prof, &part, &pl, &sch, false).unwrap();
                let b = simulate_reference(&prof, &part, &pl, &sch, false).unwrap();
                assert_eq!(a.total, b.total);
                assert_eq!(a.t_d, b.t_d);
                assert_eq!(a.busy_d, b.busy_d);
                assert_eq!(a.bubble_d, b.bubble_d);
                assert_eq!(a.overlap_d, b.overlap_d);
                assert_eq!(a.comm_block_d, b.comm_block_d);
                assert_eq!(a.m_d, b.m_d);
                assert_eq!(a.static_d, b.static_d);
                assert_eq!(a.headroom_d, b.headroom_d);
                assert_eq!(a.oom, b.oom);
            }
        }
    }
}
