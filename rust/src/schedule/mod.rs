//! Workload scheduling (paper §2.4): the schedule IR — per-device
//! ordered lists of F/B/W slots — plus structural validity checking.
//!
//! Sub-modules: [`block`] (the schedule-synthesis block IR every
//! family compiles through), [`builders`] (GPipe, S-1F1B, I-1F1B,
//! ZB-H1 seeds — thin [`block::BlockIr`] instances) and [`greedy`]
//! (the adaptive event-driven list scheduler that AdaPtis
//! workload-scheduling tuning drives).

pub mod block;
pub mod builders;
pub mod greedy;

use crate::placement::Placement;

/// Computation kinds (paper Table 1): forward, input-grad backward,
/// param-grad backward.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum OpKind {
    F,
    B,
    W,
}

impl OpKind {
    pub fn name(&self) -> &'static str {
        match self {
            OpKind::F => "F",
            OpKind::B => "B",
            OpKind::W => "W",
        }
    }
}

/// One scheduled computation: op of micro-batch `mb` at stage `stage`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct Slot {
    pub op: OpKind,
    pub mb: u32,
    pub stage: u32,
}

impl Slot {
    pub fn new(op: OpKind, mb: usize, stage: usize) -> Slot {
        Slot { op, mb: mb as u32, stage: stage as u32 }
    }
}

/// A complete workload schedule for one training step.
#[derive(Clone, Debug)]
pub struct Schedule {
    /// Pipeline devices.
    pub p: usize,
    /// Micro-batches per step.
    pub nmb: usize,
    /// Total stages (= placement.n_stages()).
    pub n_stages: usize,
    /// If false, `B` slots carry the fused B+W cost and no `W` slots
    /// exist (classic 1F1B); if true, B and W are scheduled separately
    /// (ZB-style backward splitting).
    pub split_bw: bool,
    /// Executor hint: hoist receives for comm/compute overlap (§4.4).
    pub overlap_aware: bool,
    /// Per-device slot order.
    pub per_device: Vec<Vec<Slot>>,
}

impl Schedule {
    pub fn total_slots(&self) -> usize {
        self.per_device.iter().map(|v| v.len()).sum()
    }

    /// Structural validity w.r.t. a placement:
    /// 1. every required (op, mb, stage) appears exactly once, on the
    ///    owning device;
    /// 2. same-device dependency edges are order-respecting:
    ///    F(mb,s-1) < F(mb,s), B(mb,s+1) < B(mb,s) when colocated,
    ///    F(mb,s) < B(mb,s) < W(mb,s).
    /// Cross-device readiness is runtime behaviour — deadlock-freedom
    /// of the whole schedule is checked by simulation (perfmodel).
    pub fn validate(&self, placement: &Placement) -> Result<(), String> {
        if placement.n_stages() != self.n_stages {
            return Err(format!(
                "placement has {} stages, schedule {}",
                placement.n_stages(),
                self.n_stages
            ));
        }
        let s_last = self.n_stages - 1;
        // Position lookup: (op, mb, stage) -> (device, index).
        let mut pos = std::collections::HashMap::new();
        for (d, slots) in self.per_device.iter().enumerate() {
            for (i, sl) in slots.iter().enumerate() {
                if sl.stage as usize > s_last || sl.mb as usize >= self.nmb {
                    return Err(format!("slot {sl:?} out of range on dev {d}"));
                }
                if placement.device_of[sl.stage as usize] != d {
                    return Err(format!(
                        "slot {sl:?} on dev {d} but stage {} owned by dev {}",
                        sl.stage, placement.device_of[sl.stage as usize]
                    ));
                }
                if pos.insert(*sl, (d, i)).is_some() {
                    return Err(format!("duplicate slot {sl:?}"));
                }
            }
        }
        // Completeness.
        for mb in 0..self.nmb {
            for s in 0..=s_last {
                for op in [OpKind::F, OpKind::B] {
                    if !pos.contains_key(&Slot::new(op, mb, s)) {
                        return Err(format!("missing {op:?}(mb={mb}, s={s})"));
                    }
                }
                let w = Slot::new(OpKind::W, mb, s);
                match (self.split_bw, pos.contains_key(&w)) {
                    (true, false) => return Err(format!("missing W(mb={mb}, s={s})")),
                    (false, true) => return Err(format!("unexpected W slot {w:?}")),
                    _ => {}
                }
            }
        }
        // Same-device ordering.
        let order_ok = |a: Slot, b: Slot| -> bool {
            match (pos.get(&a), pos.get(&b)) {
                (Some((da, ia)), Some((db, ib))) if da == db => ia < ib,
                _ => true,
            }
        };
        for mb in 0..self.nmb {
            for s in 0..=s_last {
                let f = Slot::new(OpKind::F, mb, s);
                let b = Slot::new(OpKind::B, mb, s);
                if !order_ok(f, b) {
                    return Err(format!("B before F (mb={mb}, s={s})"));
                }
                if self.split_bw && !order_ok(b, Slot::new(OpKind::W, mb, s)) {
                    return Err(format!("W before B (mb={mb}, s={s})"));
                }
                if s > 0 && !order_ok(Slot::new(OpKind::F, mb, s - 1), f) {
                    return Err(format!("F order violated (mb={mb}, s={s})"));
                }
                if s < s_last && !order_ok(Slot::new(OpKind::B, mb, s + 1), b) {
                    return Err(format!("B order violated (mb={mb}, s={s})"));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::builders::one_f_one_b;
    use super::*;
    use crate::placement::sequential;

    #[test]
    fn validate_catches_missing() {
        let pl = sequential(2);
        let mut sch = one_f_one_b(2, 4);
        assert!(sch.validate(&pl).is_ok());
        sch.per_device[0].pop();
        assert!(sch.validate(&pl).is_err());
    }

    #[test]
    fn validate_catches_misplaced() {
        let pl = sequential(2);
        let mut sch = one_f_one_b(2, 2);
        // Move a stage-1 slot onto device 0.
        let sl = sch.per_device[1][0];
        sch.per_device[1].remove(0);
        sch.per_device[0].push(sl);
        assert!(sch.validate(&pl).is_err());
    }

    #[test]
    fn validate_catches_order_violation() {
        let pl = sequential(1);
        let sch = Schedule {
            p: 1,
            nmb: 1,
            n_stages: 1,
            split_bw: false,
            overlap_aware: false,
            per_device: vec![vec![
                Slot::new(OpKind::B, 0, 0),
                Slot::new(OpKind::F, 0, 0),
            ]],
        };
        assert!(sch.validate(&pl).is_err());
    }
}
