//! Fixed schedule builders — the baselines from the paper's evaluation
//! (§5.1): GPipe, S-1F1B, interleaved I-1F1B, and ZB-H1.  These also
//! seed the Pipeline Generator's search (§4.3).
//!
//! Since the schedule-synthesis refactor each builder is a thin
//! [`super::block::BlockIr`] instance compiled through the one emission
//! machine; the hand-written slot orders are reproduced **bitwise**
//! (pinned by the differential suite in `tests/schedule_block.rs`,
//! which retains the legacy constructors).

use super::block::{gpipe_block, i1f1b_block, s1f1b_block, zb_h1_block};
use super::Schedule;

/// Sequential stage→device map (stage s on device s).
fn seq_device_of(p: usize) -> Vec<usize> {
    (0..p).collect()
}

/// GPipe: all forwards, then all backwards (fused B+W).
/// Sequential placement, S == P.
pub fn gpipe(p: usize, nmb: usize) -> Schedule {
    gpipe_block(p, nmb)
        .compile_on(&seq_device_of(p), p, nmb)
        .expect("gpipe block is well-formed")
        .0
}

/// S-1F1B (Megatron / DAPPLE): warmup `P-1-rank` forwards, then strict
/// 1F1B steady state, then drain.  Fused backward, sequential
/// placement, S == P.
pub fn one_f_one_b(p: usize, nmb: usize) -> Schedule {
    s1f1b_block(p, nmb)
        .compile_on(&seq_device_of(p), p, nmb)
        .expect("s1f1b block is well-formed")
        .0
}

/// I-1F1B (Megatron interleaved virtual-pipeline schedule) over an
/// interleaved placement with `v` chunks per device.  Requires
/// `nmb % p == 0` (the Megatron constraint); panics otherwise.
///
/// Virtual micro-batch `k` on device `rank` maps to:
/// `chunk = (k % (p·v)) / p`, `mb = (k / (p·v))·p + k % p`, and the
/// stage is `chunk·p + rank` — the block IR's group-`P` unit order.
/// The general warmup depth `2(P-1-rank) + (v-1)P` holds for every
/// `nmb % p == 0` (no Megatron `nmb == p` all-warmup special case;
/// pinned by `interleaved_nmb_eq_p_interleaves_instead_of_all_warmup`).
pub fn interleaved_1f1b(p: usize, v: usize, nmb: usize) -> Schedule {
    assert!(nmb % p == 0, "interleaved 1F1B requires nmb % p == 0");
    let device_of = crate::placement::interleaved(p, v).device_of;
    i1f1b_block(p, v, nmb)
        .compile_on(&device_of, p, nmb)
        .expect("i1f1b block is well-formed")
        .0
}

/// ZB-H1 (Qi et al. 2024): 1F1B with the backward split into B and W;
/// W is delayed to fill the drain bubble while keeping 1F1B-level
/// activation memory (the block's warmup stash rule).  Sequential
/// placement, S == P.
pub fn zb_h1(p: usize, nmb: usize) -> Schedule {
    zb_h1_block(p, nmb)
        .compile_on(&seq_device_of(p), p, nmb)
        .expect("zb-h1 block is well-formed")
        .0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::LayerCost;
    use crate::partition::uniform;
    use crate::perfmodel::simulate;
    use crate::placement::{interleaved, sequential};
    use crate::profile::ProfiledData;
    use crate::schedule::OpKind;

    /// One synthetic layer per stage — builder grids test *structure*
    /// (validity, deadlock-freedom), not magnitudes.
    fn unit_profile(n_layers: usize) -> ProfiledData {
        let layers = vec![
            LayerCost {
                f: 1.0,
                b: 2.0,
                w: 1.0,
                mem_act: 1.0,
                mem_act_w: 0.5,
                comm_bytes: 0.5,
                ..LayerCost::default()
            };
            n_layers
        ];
        ProfiledData::from_measured(layers, 1e-3, 1.0, f64::INFINITY)
    }

    #[test]
    fn gpipe_valid() {
        let sch = gpipe(4, 8);
        assert!(sch.validate(&sequential(4)).is_ok());
        assert_eq!(sch.total_slots(), 4 * 16);
    }

    #[test]
    fn one_f_one_b_valid() {
        for p in [1, 2, 4, 8] {
            for nmb in [1, 2, 4, 16, 17] {
                let sch = one_f_one_b(p, nmb);
                sch.validate(&sequential(p))
                    .unwrap_or_else(|e| panic!("p={p} nmb={nmb}: {e}"));
            }
        }
    }

    #[test]
    fn one_f_one_b_warmup_depth() {
        let sch = one_f_one_b(4, 8);
        // Device 0 has 3 warmup F's before its first B.
        let first_b = sch.per_device[0]
            .iter()
            .position(|s| s.op == OpKind::B)
            .unwrap();
        assert_eq!(first_b, 4); // 3 warmup + 1 steady F
        // Last device alternates immediately.
        assert_eq!(sch.per_device[3][0].op, OpKind::F);
        assert_eq!(sch.per_device[3][1].op, OpKind::B);
    }

    #[test]
    fn interleaved_valid() {
        for (p, v, nmb) in [(2, 2, 4), (4, 2, 8), (4, 4, 8), (2, 3, 2)] {
            let sch = interleaved_1f1b(p, v, nmb);
            sch.validate(&interleaved(p, v))
                .unwrap_or_else(|e| panic!("p={p} v={v} nmb={nmb}: {e}"));
        }
    }

    #[test]
    fn builders_valid_and_deadlock_free_on_grid() {
        // Every fixed builder, over a wide (p, nmb) grid: structurally
        // valid AND executable (the perf model's event-driven run is
        // the deadlock oracle — validate() only checks per-device
        // order, not cross-device feasibility).
        for p in [1usize, 2, 3, 4, 6, 8] {
            for nmb in [1usize, 2, 3, 4, 7, 8, 16] {
                let prof = unit_profile(p);
                let part = uniform(p, p);
                let pl = sequential(p);
                for (name, sch) in [
                    ("gpipe", gpipe(p, nmb)),
                    ("1f1b", one_f_one_b(p, nmb)),
                    ("zb-h1", zb_h1(p, nmb)),
                ] {
                    sch.validate(&pl)
                        .unwrap_or_else(|e| panic!("{name} p={p} nmb={nmb}: {e}"));
                    simulate(&prof, &part, &pl, &sch, false).unwrap_or_else(|e| {
                        panic!("{name} p={p} nmb={nmb}: deadlock: {e}")
                    });
                }
            }
        }
    }

    #[test]
    fn interleaved_general_warmup_valid_and_deadlock_free_on_grid() {
        // The general warmup depth — no `nmb == p` special case — over
        // every (p, v, nmb % p == 0) combination in the grid.
        for p in [1usize, 2, 3, 4, 6, 8] {
            for v in 1usize..=4 {
                for mult in 1usize..=3 {
                    let nmb = p * mult;
                    let sch = interleaved_1f1b(p, v, nmb);
                    let pl = interleaved(p, v);
                    sch.validate(&pl)
                        .unwrap_or_else(|e| panic!("p={p} v={v} nmb={nmb}: {e}"));
                    let prof = unit_profile(p * v);
                    let part = uniform(p * v, p * v);
                    simulate(&prof, &part, &pl, &sch, false).unwrap_or_else(|e| {
                        panic!("p={p} v={v} nmb={nmb}: deadlock: {e}")
                    });
                }
            }
        }
    }

    #[test]
    fn interleaved_nmb_eq_p_interleaves_instead_of_all_warmup() {
        // The removed Megatron special case degraded nmb == p to a
        // GPipe-like all-warmup run; the general depth starts B's in
        // the steady state on late ranks and stashes less.
        let (p, v, nmb) = (4usize, 2usize, 4usize);
        let sch = interleaved_1f1b(p, v, nmb);
        // Rank p-1's warmup is (v-1)·p = 4 of 8 virtual mbs: after the
        // fifth F (the first steady-state one) comes its first B —
        // index 5, where all-warmup would still be forwarding.
        let first_b = sch.per_device[p - 1]
            .iter()
            .position(|s| s.op == OpKind::B)
            .unwrap();
        assert_eq!(first_b, 5);
        // Under all-warmup every device stashes all nmb·v activations
        // (8.0 with unit act); the last rank must now peak below that.
        let prof = unit_profile(p * v);
        let part = uniform(p * v, p * v);
        let pl = interleaved(p, v);
        let r = simulate(&prof, &part, &pl, &sch, false).unwrap();
        assert!(
            r.m_d[p - 1] < (nmb * v) as f64,
            "rank {} stash {} not below all-warmup {}",
            p - 1,
            r.m_d[p - 1],
            nmb * v
        );
    }

    #[test]
    fn zb_h1_valid_and_split() {
        for p in [2, 4, 8] {
            for nmb in [2, 4, 16, 19] {
                let sch = zb_h1(p, nmb);
                assert!(sch.split_bw);
                sch.validate(&sequential(p))
                    .unwrap_or_else(|e| panic!("p={p} nmb={nmb}: {e}"));
                // W count equals B count.
                let ws = sch.per_device.iter().flatten().filter(|s| s.op == OpKind::W);
                assert_eq!(ws.count(), p * nmb);
            }
        }
    }
}
