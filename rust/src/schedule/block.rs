//! Schedule-synthesis block IR (ROADMAP item 1; "Pipeline Parallelism
//! with Controllable Memory").
//!
//! A schedule is no longer four hand-written builders: it is one
//! **repeated per-device building block** — an (F/B/W interleaving
//! pattern, per-device offset, per-device chunk lag, lifespan/stash
//! budget) parameterization — plus warmup/drain closures *derived*
//! from the block.  A single [`BlockIr::compile`] lowers any instance
//! to the existing [`Schedule`] type, so every downstream consumer
//! (perfmodel kernels, collapse detector, memory tracker, executor
//! lowering, service fingerprinting) is untouched at the type level
//! but newly reachable by synthesized families.
//!
//! # IR grammar
//!
//! ```text
//! block     := (pattern, split_bw, group, offsets[P], lag[P], stash)
//! pattern   := FThenB               -- steady state emits F then B
//!            | BThenF               -- steady state emits B then F (ZB)
//! group     := g ≥ 1                -- consecutive micro-batches per
//!                                      chunk visit (Megatron uses g=P)
//! offsets   := per-device warmup depth (virtual micro-batch units)
//! lag       := per-device chunk phase lag, in micro-batch rounds:
//!              chunk c's F stream is delayed lag·c rounds, its B
//!              stream lag·(v-1-c) rounds (the V-schedule lifespan)
//! stash     := Warmup               -- W retired to hold in-flight ≤ offset
//!            | Fixed(k)             -- W retired to hold in-flight ≤ k
//! ```
//!
//! # Compile semantics
//!
//! Per device `d` owning chunks `c₀ < c₁ < … < c_{v-1}` (its stages in
//! ascending order), the **unit streams** enumerate `total = nmb·v`
//! virtual micro-batches: F-units walk micro-batch rounds in groups of
//! `group` through chunks ascending, B-units through chunks
//! *descending* (backward passes retire the deepest chunk first), with
//! chunk `c`'s stream shifted by the device's `lag` as above — `lag =
//! 0` reproduces the uniform interleave of the classic builders, while
//! a positive lag phase-separates the chunks the way a V-schedule's
//! up-and-down chains require.  The emission machine then derives
//! warmup and drain from the block:
//!
//! 1. emit `eff[d]` warmup F-units (the *warmup closure*);
//! 2. steady state: one B-unit per iteration, interleaved with the next
//!    F-unit per `pattern`, retiring W-units per `stash` when
//!    `split_bw`;
//! 3. drain: leftover B-units (F exhausted) and all pending W-units
//!    (the *drain closure*);
//! 4. a **dependency-order repair pass** re-emits every device's
//!    sequence in executable order (hoisting the earliest ready op of
//!    the lowest device on a global stall), so *every* IR instance
//!    compiles to a deadlock-free schedule.  The pass is a no-op
//!    reorder for any already-feasible emission — in particular for
//!    all four legacy builders, which stay bitwise — and is exactly
//!    how the warmup closure of a V-schedule (chunk-0 F's first, the
//!    lagged chunk staggered in) falls out of the block.
//!
//! `eff` is the **feasibility-clamped** offset vector: raised to the
//! pattern's floor (a B-unit's colocated F-unit must precede it — the
//! pull-forward invariant), capped at `total`, and made non-increasing
//! along pipeline order (device of stage 0 first).  A downstream
//! device that warms up *deeper* than its upstream neighbour starves
//! it — the classic cross-device deadlock — so the clamp plus the
//! repair pass is what makes every IR instance executable (pinned by
//! the property grids in `tests/schedule_block.rs`).  A pull-forward
//! guard in the steady loop additionally emits any not-yet-emitted
//! colocated F before its B, so `Schedule::validate` holds for *every*
//! compile.
//!
//! # The four legacy builders as IR instances
//!
//! | builder        | pattern | group | offsets[d]            | lag | stash  |
//! |----------------|---------|-------|-----------------------|-----|--------|
//! | GPipe          | FThenB  | 1     | `nmb`                 | 0   | Warmup |
//! | S-1F1B         | FThenB  | 1     | `P-1-d`               | 0   | Warmup |
//! | I-1F1B         | FThenB  | P     | `2(P-1-d) + (v-1)P`   | 0   | Warmup |
//! | ZB-H1          | BThenF  | 1     | `P-d`                 | 0   | Warmup |
//!
//! Each reproduces the hand-written slot order **bitwise** (pinned by
//! the differential suite against the retained legacy constructors in
//! `tests/schedule_block.rs`).  [`zb_v`] and [`v_mem`] are the first
//! *new* instances: V-shaped blocks over the wave placement, with
//! [`v_mem`]'s lifespan knob trading bubbles for activation memory.

use std::collections::VecDeque;

use crate::placement::Placement;

use super::{OpKind, Schedule, Slot};

/// Steady-state interleaving pattern of the building block.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Pattern {
    /// Emit the next F-unit, then the B-unit (1F1B-family blocks).
    FThenB,
    /// Emit the B-unit, then the next F-unit (ZB-family blocks).
    BThenF,
}

/// W-retirement rule (the stash side of the parameterization; only
/// meaningful when `split_bw`).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum StashRule {
    /// Retire the oldest W once in-flight stashes exceed the device's
    /// effective warmup offset (ZB-H1's rule: 1F1B-level memory).
    Warmup,
    /// Retire once in-flight stashes exceed a fixed budget of `k`
    /// virtual micro-batches (the controllable-memory knob).
    Fixed(u32),
}

/// A schedule as a repeated per-device building block; see module docs.
#[derive(Clone, Debug, PartialEq)]
pub struct BlockIr {
    pub pattern: Pattern,
    /// Lower B and W separately (ZB-style backward splitting).
    pub split_bw: bool,
    /// Consecutive micro-batches per chunk visit (≥ 1).
    pub group: usize,
    /// Requested per-device warmup depth, in virtual micro-batch
    /// units.  [`BlockIr::compile`] clamps to a feasible `eff` vector.
    pub offsets: Vec<usize>,
    /// Per-device chunk phase lag in micro-batch rounds (0 for the
    /// classic uniform interleave; ~`P-1-d` for V-schedules).
    pub lag: Vec<usize>,
    pub stash: StashRule,
    /// Executor overlap hint, copied into the compiled [`Schedule`].
    pub overlap_aware: bool,
}

/// What [`BlockIr::compile_with_stats`] actually emitted — the declared
/// budgets the property tests hold the memory tracker against.
#[derive(Clone, Debug, Default)]
pub struct CompileStats {
    /// Feasibility-clamped warmup offsets (per device).
    pub eff_offsets: Vec<usize>,
    /// Peak F-emitted − B-emitted per device: virtual micro-batches
    /// whose activation stash is live simultaneously.
    pub max_inflight: Vec<usize>,
    /// Peak B-emitted − W-emitted per device (0 unless `split_bw`):
    /// W-retained slices held simultaneously.
    pub max_pending_w: Vec<usize>,
}

impl BlockIr {
    /// Compile against a placement (chunks per device must be uniform).
    pub fn compile(&self, placement: &Placement, nmb: usize) -> Result<Schedule, String> {
        self.compile_with_stats(placement, nmb).map(|(s, _)| s)
    }

    /// [`BlockIr::compile`] plus the emission statistics.
    pub fn compile_with_stats(
        &self,
        placement: &Placement,
        nmb: usize,
    ) -> Result<(Schedule, CompileStats), String> {
        self.compile_on(&placement.device_of, placement.p, nmb)
    }

    /// Compile from a raw stage→device map (what a pool worker holds:
    /// the [`crate::perfmodel::StageTable`] carries `device`, not a
    /// [`Placement`]).
    pub fn compile_on(
        &self,
        device_of: &[usize],
        p: usize,
        nmb: usize,
    ) -> Result<(Schedule, CompileStats), String> {
        if nmb == 0 || p == 0 {
            return Err("empty pipeline".into());
        }
        if self.group == 0 {
            return Err("group must be ≥ 1".into());
        }
        if self.offsets.len() != p {
            return Err(format!("{} offsets for {} devices", self.offsets.len(), p));
        }
        if self.lag.len() != p {
            return Err(format!("{} lags for {} devices", self.lag.len(), p));
        }
        // Chunks per device, ascending stage order.
        let mut chunks: Vec<Vec<usize>> = vec![Vec::new(); p];
        for (s, &d) in device_of.iter().enumerate() {
            if d >= p {
                return Err(format!("stage {s} on device {d} ≥ p={p}"));
            }
            chunks[d].push(s);
        }
        let v = chunks[0].len();
        if v == 0 || chunks.iter().any(|c| c.len() != v) {
            return Err("block IR needs a uniform chunk count per device".into());
        }
        let total = nmb * v;

        // Feasibility clamp: floor (pull-forward invariant for B-unit
        // 0), cap at total, then non-increasing along pipeline order.
        let g0 = self.group.min(nmb);
        let floor = ((v - 1) * g0
            + match self.pattern {
                Pattern::FThenB => 0,
                Pattern::BThenF => 1,
            })
        .min(total);
        let mut order: Vec<usize> = (0..p).collect();
        order.sort_by_key(|&d| chunks[d][0]);
        let mut eff = vec![0usize; p];
        let mut prev = total;
        for &d in &order {
            let e = self.offsets[d].max(floor).min(total).min(prev);
            eff[d] = e;
            prev = e;
        }

        // Emission machine (warmup / steady / drain closures).
        // Emit the next F-unit on `chunk`.
        fn emit_f(f_units: &[(usize, usize)], chunk: &[usize], fi: &mut usize, out: &mut Vec<Slot>) {
            let (m, c) = f_units[*fi];
            out.push(Slot::new(OpKind::F, m, chunk[c]));
            *fi += 1;
        }
        let mut per_device: Vec<Vec<Slot>> = Vec::with_capacity(p);
        for d in 0..p {
            let chunk = &chunks[d];
            let lag = self.lag[d];
            // Per-device unit streams: micro-batch rounds in groups of
            // `group`, F through chunks ascending (chunk c delayed
            // lag·c rounds), B through chunks descending (chunk c
            // delayed lag·(v-1-c) rounds).  lag = 0 is the shared
            // uniform interleave of the classic builders; with group =
            // P and nmb % P == 0 it is exactly Megatron's virtual
            // micro-batch enumeration.
            let rmax = nmb + lag * (v - 1);
            let mut f_units: Vec<(usize, usize)> = Vec::with_capacity(total);
            let mut b_units: Vec<(usize, usize)> = Vec::with_capacity(total);
            let mut base = 0usize;
            while base < rmax {
                let hi = (base + self.group).min(rmax);
                for c in 0..v {
                    for r in base..hi {
                        let delay = lag * c;
                        if r >= delay && r - delay < nmb {
                            f_units.push((r - delay, c));
                        }
                    }
                }
                for c in (0..v).rev() {
                    for r in base..hi {
                        let delay = lag * (v - 1 - c);
                        if r >= delay && r - delay < nmb {
                            b_units.push((r - delay, c));
                        }
                    }
                }
                base = hi;
            }
            debug_assert_eq!(f_units.len(), total);
            debug_assert_eq!(b_units.len(), total);
            // F-unit index of each (mb, chunk) — the pull-forward table.
            let mut fpos = vec![0usize; total];
            for (i, &(m, c)) in f_units.iter().enumerate() {
                fpos[m * v + c] = i;
            }

            let cap = if self.split_bw { 3 * total } else { 2 * total };
            let mut out: Vec<Slot> = Vec::with_capacity(cap);
            let budget = match self.stash {
                StashRule::Warmup => eff[d],
                StashRule::Fixed(k) => k as usize,
            };
            let mut fi = 0usize;
            let mut wq: VecDeque<(usize, usize)> = VecDeque::new();
            for _ in 0..eff[d] {
                emit_f(&f_units, chunk, &mut fi, &mut out);
            }
            for (bi, &(bm, bc)) in b_units.iter().enumerate() {
                let need = fpos[bm * v + bc];
                match self.pattern {
                    Pattern::FThenB => {
                        if fi < total {
                            emit_f(&f_units, chunk, &mut fi, &mut out);
                        }
                        // Pull-forward guard: keeps F(mb,s) ahead of
                        // B(mb,s) on-device whatever the clamp and lag
                        // produced.
                        while fi <= need {
                            emit_f(&f_units, chunk, &mut fi, &mut out);
                        }
                        out.push(Slot::new(OpKind::B, bm, chunk[bc]));
                        if self.split_bw {
                            wq.push_back((bm, bc));
                            if fi >= total || fi - bi - 1 >= budget {
                                let (wm, wc) = wq.pop_front().expect("just pushed");
                                out.push(Slot::new(OpKind::W, wm, chunk[wc]));
                            }
                        }
                    }
                    Pattern::BThenF => {
                        while fi <= need {
                            emit_f(&f_units, chunk, &mut fi, &mut out);
                        }
                        out.push(Slot::new(OpKind::B, bm, chunk[bc]));
                        if self.split_bw {
                            wq.push_back((bm, bc));
                        }
                        if fi < total {
                            emit_f(&f_units, chunk, &mut fi, &mut out);
                            // Steady state: keep in-flight stashes ≤
                            // budget by retiring the oldest W before
                            // admitting more F's (ZB-H1's rule when
                            // budget = warmup).
                            if self.split_bw && fi - bi - 1 >= budget {
                                let (wm, wc) = wq.pop_front().expect("pending W");
                                out.push(Slot::new(OpKind::W, wm, chunk[wc]));
                            }
                        } else if self.split_bw {
                            // Drain: one W between consecutive B's
                            // fills the bubble ZB targets.
                            if let Some((wm, wc)) = wq.pop_front() {
                                out.push(Slot::new(OpKind::W, wm, chunk[wc]));
                            }
                        }
                    }
                }
            }
            for (wm, wc) in wq {
                out.push(Slot::new(OpKind::W, wm, chunk[wc]));
            }
            per_device.push(out);
        }

        let per_device = repair(per_device, device_of, nmb)?;

        // Emission statistics from the final (repaired) order.
        let mut stats = CompileStats {
            eff_offsets: eff,
            max_inflight: vec![0; p],
            max_pending_w: vec![0; p],
        };
        for d in 0..p {
            let (mut fc, mut bc, mut wc) = (0usize, 0usize, 0usize);
            for sl in &per_device[d] {
                match sl.op {
                    OpKind::F => {
                        fc += 1;
                        stats.max_inflight[d] = stats.max_inflight[d].max(fc - bc);
                    }
                    OpKind::B => {
                        bc += 1;
                        stats.max_pending_w[d] = stats.max_pending_w[d].max(bc - wc);
                    }
                    OpKind::W => wc += 1,
                }
            }
        }
        let schedule = Schedule {
            p,
            nmb,
            n_stages: p * v,
            split_bw: self.split_bw,
            overlap_aware: self.overlap_aware,
            per_device,
        };
        Ok((schedule, stats))
    }

    /// Compact human-readable family label (bench/service reporting).
    pub fn family(&self) -> String {
        let lmax = self.lag.iter().copied().max().unwrap_or(0);
        format!(
            "{}{}g{}{}{}",
            match self.pattern {
                Pattern::FThenB => "fb",
                Pattern::BThenF => "bf",
            },
            if self.split_bw { "+w" } else { "" },
            self.group,
            if lmax > 0 { format!("v{lmax}") } else { String::new() },
            match self.stash {
                StashRule::Warmup => String::new(),
                StashRule::Fixed(k) => format!("s{k}"),
            }
        )
    }

    /// Structural identity bits for `CandKey`/fingerprints: everything
    /// [`BlockIr::compile`] reads, packed into `u32`s.  Injective: the
    /// stash rule gets a discriminant word of its own, so no `Fixed`
    /// budget (not even `u32::MAX`) can alias `Warmup`.
    pub fn key_bits(&self) -> Vec<u32> {
        let mut bits = Vec::with_capacity(5 + 2 * self.offsets.len());
        bits.push(match self.pattern {
            Pattern::FThenB => 0,
            Pattern::BThenF => 1,
        });
        bits.push(u32::from(self.split_bw) | u32::from(self.overlap_aware) << 1);
        bits.push(self.group as u32);
        match self.stash {
            StashRule::Warmup => bits.extend([0, 0]),
            StashRule::Fixed(k) => bits.extend([1, k]),
        }
        bits.extend(self.offsets.iter().map(|&o| o as u32));
        bits.extend(self.lag.iter().map(|&l| l as u32));
        bits
    }
}

/// Dependency-order re-emission: execute each device's queue head
/// whenever its dependencies are met; on a global stall, hoist the
/// earliest ready op of the lowest-indexed device.  A no-op reorder
/// for feasible inputs (head execution never stalls), and guaranteed
/// to terminate otherwise: a dependency-minimal unexecuted op is
/// always ready wherever it sits.
fn repair(
    per_device: Vec<Vec<Slot>>,
    device_of: &[usize],
    nmb: usize,
) -> Result<Vec<Vec<Slot>>, String> {
    let s_n = device_of.len();
    let p = per_device.len();
    let idx_of = |op: OpKind, mb: u32, s: u32| -> usize {
        let kind = match op {
            OpKind::F => 0usize,
            OpKind::B => 1,
            OpKind::W => 2,
        };
        (kind * s_n + s as usize) * nmb + mb as usize
    };
    let mut done = vec![false; 3 * s_n * nmb];
    let ready = |done: &[bool], sl: &Slot| -> bool {
        match sl.op {
            OpKind::F => sl.stage == 0 || done[idx_of(OpKind::F, sl.mb, sl.stage - 1)],
            OpKind::B => {
                done[idx_of(OpKind::F, sl.mb, sl.stage)]
                    && (sl.stage as usize == s_n - 1
                        || done[idx_of(OpKind::B, sl.mb, sl.stage + 1)])
            }
            OpKind::W => done[idx_of(OpKind::B, sl.mb, sl.stage)],
        }
    };
    let mut remaining: usize = per_device.iter().map(Vec::len).sum();
    let mut queues: Vec<VecDeque<Slot>> = per_device.into_iter().map(VecDeque::from).collect();
    let mut out: Vec<Vec<Slot>> = queues.iter().map(|q| Vec::with_capacity(q.len())).collect();
    while remaining > 0 {
        let mut progress = false;
        for d in 0..p {
            while let Some(sl) = queues[d].front().copied() {
                if !ready(&done, &sl) {
                    break;
                }
                queues[d].pop_front();
                done[idx_of(sl.op, sl.mb, sl.stage)] = true;
                out[d].push(sl);
                remaining -= 1;
                progress = true;
            }
        }
        if !progress {
            let mut hoisted = false;
            'hoist: for d in 0..p {
                for i in 0..queues[d].len() {
                    let sl = queues[d][i];
                    if ready(&done, &sl) {
                        queues[d].remove(i);
                        done[idx_of(sl.op, sl.mb, sl.stage)] = true;
                        out[d].push(sl);
                        remaining -= 1;
                        hoisted = true;
                        break 'hoist;
                    }
                }
            }
            if !hoisted {
                return Err("block IR repair: dependency cycle across devices".into());
            }
        }
    }
    Ok(out)
}

// ---- The four legacy builders as IR instances --------------------------

/// GPipe as a block: all-warmup FThenB.
pub fn gpipe_block(p: usize, nmb: usize) -> BlockIr {
    BlockIr {
        pattern: Pattern::FThenB,
        split_bw: false,
        group: 1,
        offsets: vec![nmb; p],
        lag: vec![0; p],
        stash: StashRule::Warmup,
        overlap_aware: false,
    }
}

/// S-1F1B as a block: warmup `P-1-d`, strict 1F1B steady state.
pub fn s1f1b_block(p: usize, nmb: usize) -> BlockIr {
    let _ = nmb;
    BlockIr {
        pattern: Pattern::FThenB,
        split_bw: false,
        group: 1,
        offsets: (0..p).map(|d| p - 1 - d).collect(),
        lag: vec![0; p],
        stash: StashRule::Warmup,
        overlap_aware: false,
    }
}

/// I-1F1B as a block: Megatron's interleaved schedule over
/// `interleaved(p, v)` — group `P`, warmup `2(P-1-d) + (v-1)P`.
pub fn i1f1b_block(p: usize, v: usize, nmb: usize) -> BlockIr {
    let _ = nmb;
    BlockIr {
        pattern: Pattern::FThenB,
        split_bw: false,
        group: p,
        offsets: (0..p).map(|d| (p - 1 - d) * 2 + (v - 1) * p).collect(),
        lag: vec![0; p],
        stash: StashRule::Warmup,
        overlap_aware: false,
    }
}

/// ZB-H1 as a block: BThenF with split backward, warmup `P-d`, W
/// retired by the warmup rule (1F1B-level activation memory).
pub fn zb_h1_block(p: usize, nmb: usize) -> BlockIr {
    let _ = nmb;
    BlockIr {
        pattern: Pattern::BThenF,
        split_bw: true,
        group: 1,
        offsets: (0..p).map(|d| p - d).collect(),
        lag: vec![0; p],
        stash: StashRule::Warmup,
        overlap_aware: false,
    }
}

// ---- New families (first instances beyond the legacy four) -------------

/// ZB-V (controllable-memory paper): a V-shaped block over the
/// [`crate::placement::wave`]`(p, 2)` placement — device `d` owns
/// stages `d` and `2p-1-d`, so the deepest stage's F→B turnaround is
/// device-local on the middle device.  A flat `2P-1` warmup with a
/// `P-1-d` chunk lag phase-separates the down-going F chain from the
/// up-coming one; split backward fills the ramp with W's.  Beats
/// S-1F1B across the unit-cost grid (pinned in
/// `tests/schedule_block.rs`).
pub fn zb_v(p: usize, nmb: usize) -> BlockIr {
    let _ = nmb;
    BlockIr {
        pattern: Pattern::FThenB,
        split_bw: true,
        group: 1,
        offsets: vec![2 * p - 1; p],
        lag: (0..p).map(|d| p - 1 - d).collect(),
        stash: StashRule::Warmup,
        overlap_aware: false,
    }
}

/// Memory-controllable V-schedule: [`zb_v`] with warmup depth and
/// chunk lag capped at `lifespan` virtual micro-batches — the paper's
/// lifespan knob, trading bubbles for activation memory.  `lifespan ≥
/// 2P-1` recovers [`zb_v`].
pub fn v_mem(p: usize, nmb: usize, lifespan: usize) -> BlockIr {
    let _ = nmb;
    BlockIr {
        pattern: Pattern::FThenB,
        split_bw: true,
        group: 1,
        offsets: vec![(2 * p - 1).min(lifespan.max(1)); p],
        lag: (0..p).map(|d| (p - 1 - d).min(lifespan)).collect(),
        stash: StashRule::Warmup,
        overlap_aware: false,
    }
}

/// The placement the V-shaped families compile against.
pub fn v_placement(p: usize) -> Placement {
    crate::placement::wave(p, 2)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::placement::{interleaved, sequential, wave};

    #[test]
    fn rejects_malformed_inputs() {
        let ir = s1f1b_block(4, 8);
        assert!(ir.compile(&sequential(4), 0).is_err());
        let mut bad = ir.clone();
        bad.group = 0;
        assert!(bad.compile(&sequential(4), 8).is_err());
        let mut bad = ir.clone();
        bad.offsets.pop();
        assert!(bad.compile(&sequential(4), 8).is_err());
        let mut bad = ir.clone();
        bad.lag.pop();
        assert!(bad.compile(&sequential(4), 8).is_err());
        // Irregular chunk counts (2 stages on device 0, 1 on device 1).
        let plac = Placement { p: 2, device_of: vec![0, 0, 1] };
        assert!(s1f1b_block(2, 4).compile(&plac, 4).is_err());
    }

    #[test]
    fn compile_is_always_structurally_valid() {
        // Even absurd offsets (huge, zero, increasing) and lags compile
        // to a Schedule that passes validate() — clamp + pull-forward +
        // repair.
        for p in [1usize, 2, 4] {
            for nmb in [1usize, 3, 8] {
                for offs in [vec![0; p], vec![1000; p], (0..p).collect::<Vec<_>>()] {
                    for lag in [vec![0; p], vec![3; p], (0..p).rev().collect::<Vec<_>>()] {
                        for (pattern, split) in [(Pattern::FThenB, false), (Pattern::BThenF, true)]
                        {
                            let ir = BlockIr {
                                pattern,
                                split_bw: split,
                                group: 1,
                                offsets: offs.clone(),
                                lag: lag.clone(),
                                stash: StashRule::Warmup,
                                overlap_aware: false,
                            };
                            let pl = sequential(p);
                            let sch = ir.compile(&pl, nmb).unwrap();
                            sch.validate(&pl).unwrap_or_else(|e| {
                                panic!("p={p} nmb={nmb} offs={offs:?} lag={lag:?}: {e}")
                            });
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn clamp_is_non_increasing_along_pipeline_order() {
        let ir = BlockIr {
            pattern: Pattern::FThenB,
            split_bw: false,
            group: 1,
            offsets: vec![0, 5, 2, 7],
            lag: vec![0; 4],
            stash: StashRule::Warmup,
            overlap_aware: false,
        };
        let (_, stats) = ir.compile_with_stats(&sequential(4), 8).unwrap();
        for w in stats.eff_offsets.windows(2) {
            assert!(w[1] <= w[0], "clamped offsets must not increase: {stats:?}");
        }
    }

    #[test]
    fn interleaved_and_wave_chunking_compiles() {
        for p in [2usize, 4] {
            for v in [2usize, 3] {
                let ir = i1f1b_block(p, v, p);
                for pl in [interleaved(p, v), wave(p, v)] {
                    let sch = ir.compile(&pl, p).unwrap();
                    sch.validate(&pl).unwrap();
                    assert_eq!(sch.n_stages, p * v);
                }
            }
        }
    }

    #[test]
    fn v_family_shapes() {
        let (p, nmb) = (4usize, 12usize);
        let pl = v_placement(p);
        let sch = zb_v(p, nmb).compile(&pl, nmb).unwrap();
        sch.validate(&pl).unwrap();
        assert!(sch.split_bw);
        assert_eq!(sch.n_stages, 2 * p);
        // Lifespan knob: a tighter budget keeps fewer virtual
        // micro-batches in flight on the first device.
        let (_, tight) = v_mem(p, nmb, 1).compile_with_stats(&pl, nmb).unwrap();
        let (_, loose) = v_mem(p, nmb, 2 * p).compile_with_stats(&pl, nmb).unwrap();
        assert!(
            tight.max_inflight[0] < loose.max_inflight[0],
            "tight={tight:?} loose={loose:?}"
        );
    }

    #[test]
    fn family_labels_are_distinct() {
        let a = s1f1b_block(4, 8).family();
        let b = zb_h1_block(4, 8).family();
        let c = zb_v(4, 8).family();
        assert!(a != b && b != c && a != c, "{a} {b} {c}");
    }

    #[test]
    fn key_bits_distinguish_every_parameter() {
        let base = s1f1b_block(4, 8);
        let bits = base.key_bits();
        for other in [
            BlockIr { pattern: Pattern::BThenF, ..base.clone() },
            BlockIr { split_bw: true, ..base.clone() },
            BlockIr { group: 4, ..base.clone() },
            BlockIr { stash: StashRule::Fixed(3), ..base.clone() },
            BlockIr { stash: StashRule::Fixed(u32::MAX), ..base.clone() },
            BlockIr { offsets: vec![3, 2, 1, 1], ..base.clone() },
            BlockIr { lag: vec![1, 1, 0, 0], ..base.clone() },
            BlockIr { overlap_aware: true, ..base.clone() },
        ] {
            assert_ne!(bits, other.key_bits(), "{other:?}");
        }
    }
}
