//! Adaptive event-driven list scheduler — the engine behind AdaPtis
//! workload-scheduling tuning (§4.3):
//!
//! - **advance F and B**: ops are emitted at their earliest feasible
//!   start, subject to the memory constraint (Eq. 2);
//! - **delay W**: W has no inter-device dependency, so it is held back
//!   and emitted only to fill idle time (or to release memory under
//!   pressure) — the ZB insight, generalised to any placement;
//! - **overlap-aware** (§4.4): with `overlap_aware`, receives are
//!   assumed hoisted so a consumer waits for `producer_end + p2p`
//!   rather than serialising the transfer on its own timeline;
//! - **OOM avoidance**: `mem_cap_factor` scales the activation budget —
//!   the generator lowers it to advance B/W and repair OOM.
//!
//! Unlike the fixed builders this works for *arbitrary* partitions and
//! placements, which is what makes the co-optimization loop possible.
//!
//! The construction loop itself lives in
//! [`crate::perfmodel::fused::fused_eval`]: the scheduler computes every
//! op's timing while choosing the emission order, so the Pipeline
//! Generator evaluates candidates in that single fused pass.  This
//! function is the wrapper that records the emitted slots and
//! materialises the [`Schedule`] IR for the executor and the baselines.

use super::{Schedule, Slot};
use crate::memory::MemCaps;
use crate::partition::Partition;
use crate::placement::Placement;
use crate::perfmodel::{fused_eval, SimArena, StageTable};
use crate::profile::ProfiledData;

/// Tuning knobs for the adaptive scheduler.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SchedKnobs {
    /// Split backward into B and W (ZB-style).
    pub split_bw: bool,
    /// Delay W to fill bubbles (false = W immediately after B).
    pub w_fill: bool,
    /// Fraction of the activation-memory budget usable (≤ 1.0).
    pub mem_cap_factor: f64,
    /// Executor overlap hint (affects both timing model and executor).
    pub overlap_aware: bool,
}

impl Default for SchedKnobs {
    fn default() -> Self {
        SchedKnobs {
            split_bw: true,
            w_fill: true,
            mem_cap_factor: 1.0,
            overlap_aware: true,
        }
    }
}

/// Build an adaptive schedule for any (partition, placement), with the
/// profile's uniform memory capacity as the activation budget.
pub fn greedy_schedule(
    profile: &ProfiledData,
    partition: &Partition,
    placement: &Placement,
    nmb: usize,
    knobs: SchedKnobs,
) -> Schedule {
    let caps = MemCaps::uniform(placement.p, profile.mem_capacity);
    greedy_schedule_caps(profile, &caps, partition, placement, nmb, knobs)
}

/// [`greedy_schedule`] against per-device (possibly heterogeneous)
/// memory capacities — the budget each device's F-admission respects.
pub fn greedy_schedule_caps(
    profile: &ProfiledData,
    caps: &MemCaps,
    partition: &Partition,
    placement: &Placement,
    nmb: usize,
    knobs: SchedKnobs,
) -> Schedule {
    let table = StageTable::build(profile, partition, placement);
    let mut arena = SimArena::new();
    greedy_schedule_in(&mut arena, &table, caps, nmb, knobs)
}

/// [`greedy_schedule_caps`] over a prebuilt [`StageTable`] and a
/// caller-owned [`SimArena`] — the Pipeline Generator's Reference
/// engine materialises a schedule per candidate, and the candidate's
/// table is already built, so this variant skips the rebuild and the
/// per-call arena allocation.  Identical output (the table build is
/// deterministic).
pub fn greedy_schedule_in(
    arena: &mut SimArena,
    table: &StageTable,
    caps: &MemCaps,
    nmb: usize,
    knobs: SchedKnobs,
) -> Schedule {
    let mut slots: Vec<Vec<Slot>> = vec![Vec::new(); table.p];
    let _ = fused_eval(table, caps, nmb, knobs, arena, Some(&mut slots));
    Schedule {
        p: table.p,
        nmb,
        n_stages: table.n_stages,
        split_bw: knobs.split_bw,
        overlap_aware: knobs.overlap_aware,
        per_device: slots,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Family, HardwareCfg, ModelCfg, ParallelCfg, Size};
    use crate::model::build_model;
    use crate::partition::uniform;
    use crate::placement::{interleaved, sequential, wave};
    use crate::schedule::OpKind;

    fn profile(fam: Family) -> ProfiledData {
        let spec = build_model(&ModelCfg::table5(fam, Size::Small));
        ProfiledData::analytical(
            &spec,
            &HardwareCfg::default(),
            &ParallelCfg::new(4, 2, 8, 1, 4096),
        )
    }

    #[test]
    fn valid_on_sequential() {
        let prof = profile(Family::Gemma);
        let part = uniform(prof.n_layers(), 4);
        let pl = sequential(4);
        for knobs in [
            SchedKnobs::default(),
            SchedKnobs { split_bw: false, ..SchedKnobs::default() },
            SchedKnobs { w_fill: false, ..SchedKnobs::default() },
        ] {
            let sch = greedy_schedule(&prof, &part, &pl, 8, knobs);
            sch.validate(&pl).unwrap_or_else(|e| panic!("{knobs:?}: {e}"));
        }
    }

    #[test]
    fn valid_on_interleaved_and_wave() {
        let prof = profile(Family::NemotronH);
        for pl in [interleaved(4, 2), wave(4, 2)] {
            let part = uniform(prof.n_layers(), pl.n_stages());
            let sch = greedy_schedule(&prof, &part, &pl, 8, SchedKnobs::default());
            sch.validate(&pl).unwrap_or_else(|e| panic!("{e}"));
        }
    }

    #[test]
    fn w_is_delayed_when_filling() {
        let prof = profile(Family::Gemma);
        let part = uniform(prof.n_layers(), 4);
        let pl = sequential(4);
        let sch = greedy_schedule(&prof, &part, &pl, 8, SchedKnobs::default());
        // On device 0 the first W should come after several B's (W's
        // pushed into the drain phase), unlike immediate-W scheduling.
        let d0 = &sch.per_device[0];
        let first_w = d0.iter().position(|s| s.op == OpKind::W).unwrap();
        let bs_before = d0[..first_w].iter().filter(|s| s.op == OpKind::B).count();
        assert!(bs_before >= 1, "W at {first_w}, B's before: {bs_before}");
    }

    #[test]
    fn tight_memory_still_schedules() {
        let mut prof = profile(Family::Gemma);
        prof.mem_capacity = 1.0; // pathological: forces stash==0 path
        let part = uniform(prof.n_layers(), 4);
        let pl = sequential(4);
        let sch = greedy_schedule(&prof, &part, &pl, 4, SchedKnobs::default());
        sch.validate(&pl).unwrap();
    }

    #[test]
    fn fused_report_matches_rebuilt_schedule() {
        // The wrapper and the fused evaluation are the same loop: the
        // report returned while recording must equal a fresh simulation
        // of the recorded schedule, bitwise.
        let prof = profile(Family::NemotronH);
        let part = uniform(prof.n_layers(), 4);
        let pl = sequential(4);
        let knobs = SchedKnobs::default();
        let table = StageTable::build(&prof, &part, &pl);
        let caps = MemCaps::uniform(4, prof.mem_capacity);
        let mut arena = SimArena::new();
        let mut slots = vec![Vec::new(); 4];
        let fused = fused_eval(&table, &caps, 8, knobs, &mut arena, Some(&mut slots));
        let sch = Schedule {
            p: 4,
            nmb: 8,
            n_stages: 4,
            split_bw: knobs.split_bw,
            overlap_aware: knobs.overlap_aware,
            per_device: slots,
        };
        let sim = crate::perfmodel::simulate_reference(&prof, &part, &pl, &sch, false)
            .unwrap();
        assert_eq!(fused.total, sim.total);
        assert_eq!(fused.t_d, sim.t_d);
        assert_eq!(fused.busy_d, sim.busy_d);
        assert_eq!(fused.m_d, sim.m_d);
    }
}
