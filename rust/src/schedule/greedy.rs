//! Adaptive event-driven list scheduler — the engine behind AdaPtis
//! workload-scheduling tuning (§4.3):
//!
//! - **advance F and B**: ops are emitted at their earliest feasible
//!   start, subject to the memory constraint (Eq. 2);
//! - **delay W**: W has no inter-device dependency, so it is held back
//!   and emitted only to fill idle time (or to release memory under
//!   pressure) — the ZB insight, generalised to any placement;
//! - **overlap-aware** (§4.4): with `overlap_aware`, receives are
//!   assumed hoisted so a consumer waits for `producer_end + p2p`
//!   rather than serialising the transfer on its own timeline;
//! - **OOM avoidance**: `mem_cap_factor` scales the activation budget —
//!   the generator lowers it to advance B/W and repair OOM.
//!
//! Unlike the fixed builders this works for *arbitrary* partitions and
//! placements, which is what makes the co-optimization loop possible.

use super::{OpKind, Schedule, Slot};
use crate::partition::Partition;
use crate::placement::Placement;
use crate::profile::ProfiledData;

/// Tuning knobs for the adaptive scheduler.
#[derive(Clone, Copy, Debug)]
pub struct SchedKnobs {
    /// Split backward into B and W (ZB-style).
    pub split_bw: bool,
    /// Delay W to fill bubbles (false = W immediately after B).
    pub w_fill: bool,
    /// Fraction of the activation-memory budget usable (≤ 1.0).
    pub mem_cap_factor: f64,
    /// Executor overlap hint (affects both timing model and executor).
    pub overlap_aware: bool,
}

impl Default for SchedKnobs {
    fn default() -> Self {
        SchedKnobs {
            split_bw: true,
            w_fill: true,
            mem_cap_factor: 1.0,
            overlap_aware: true,
        }
    }
}

struct StageInfo {
    device: usize,
    f: f64,
    b: f64,
    w: f64,
    act_bytes: f64,
    comm_in: f64,   // p2p seconds for the activation arriving from prev stage
    comm_b_in: f64, // p2p seconds for the gradient arriving from next stage
}

/// Build an adaptive schedule for any (partition, placement).
pub fn greedy_schedule(
    profile: &ProfiledData,
    partition: &Partition,
    placement: &Placement,
    nmb: usize,
    knobs: SchedKnobs,
) -> Schedule {
    let s_n = partition.n_stages();
    assert_eq!(s_n, placement.n_stages());
    let p = placement.p;

    let costs: Vec<_> =
        (0..s_n).map(|s| profile.stage_cost(partition.stage_range(s))).collect();
    let stages: Vec<StageInfo> = (0..s_n)
        .map(|s| {
            let c = &costs[s];
            let comm_in = if s == 0 || placement.device_of[s - 1] == placement.device_of[s]
            {
                0.0
            } else {
                profile.p2p(costs[s - 1].comm_bytes)
            };
            let comm_b_in = if s + 1 == s_n
                || placement.device_of[s + 1] == placement.device_of[s]
            {
                0.0
            } else {
                // Gradient message = this stage's output size.
                profile.p2p(c.comm_bytes)
            };
            StageInfo {
                device: placement.device_of[s],
                f: c.f,
                b: if knobs.split_bw { c.b } else { c.b + c.w },
                w: c.w,
                act_bytes: c.mem_act,
                comm_in,
                comm_b_in,
            }
        })
        .collect();

    // Per-device memory budget for activation stashes.
    let budget: Vec<f64> = (0..p)
        .map(|d| {
            let static_mem: f64 = (0..s_n)
                .filter(|&s| stages[s].device == d)
                .map(|s| costs[s].mem_static)
                .sum();
            ((profile.mem_capacity - static_mem) * knobs.mem_cap_factor).max(0.0)
        })
        .collect();

    // Progress counters: next micro-batch per (op, stage).
    let mut next_f = vec![0usize; s_n];
    let mut next_b = vec![0usize; s_n];
    let mut next_w = vec![0usize; s_n];
    // End times of completed ops.
    let mut end_f = vec![vec![f64::NAN; nmb]; s_n];
    let mut end_b = vec![vec![f64::NAN; nmb]; s_n];
    let mut clock = vec![0.0f64; p];
    let mut stash = vec![0.0f64; p]; // live activation bytes per device
    let mut out: Vec<Vec<Slot>> = vec![Vec::new(); p];

    let total_ops = s_n * nmb * if knobs.split_bw { 3 } else { 2 };
    let mut emitted = 0usize;

    // Earliest feasible start of a candidate on its device.
    let ready = |dep_end: f64, comm: f64, clk: f64, overlap: bool| -> f64 {
        if comm == 0.0 {
            clk.max(dep_end)
        } else if overlap {
            clk.max(dep_end + comm)
        } else {
            clk.max(dep_end) + comm
        }
    };

    while emitted < total_ops {
        // Gather the globally earliest-start candidate; ties prefer
        // B > F > W (B frees downstream deps and, fused, memory).
        // Over-budget F's are tracked separately: they are only taken
        // when nothing else can make progress — the memory constraint
        // is soft here so the builder always terminates; the
        // performance model flags the resulting pipeline OOM (Eq. 2)
        // and the generator prunes it.
        fn consider(
            best: &mut Option<(f64, u8, usize, Slot)>,
            start: f64,
            prio: u8,
            s: usize,
            slot: Slot,
        ) {
            let better = match best {
                None => true,
                Some((bs, bp, _, _)) => {
                    start < *bs - 1e-15 || ((start - *bs).abs() <= 1e-15 && prio < *bp)
                }
            };
            if better {
                *best = Some((start, prio, s, slot));
            }
        }
        let mut best: Option<(f64, u8, usize, Slot)> = None; // (start, prio, stage, slot)
        let mut best_overlimit: Option<(f64, u8, usize, Slot)> = None;

        for s in 0..s_n {
            let d = stages[s].device;
            let clk = clock[d];
            // F candidate.
            let mb = next_f[s];
            if mb < nmb {
                let dep = if s == 0 { 0.0 } else { end_f[s - 1][mb] };
                if !dep.is_nan() {
                    let fits = stash[d] + stages[s].act_bytes <= budget[d]
                        || stash[d] == 0.0;
                    let start = ready(dep, stages[s].comm_in, clk, knobs.overlap_aware);
                    let target = if fits { &mut best } else { &mut best_overlimit };
                    consider(target, start, 1, s, Slot::new(OpKind::F, mb, s));
                }
            }
            // B candidate: needs F(mb,s) done and B(mb,s+1) done (or F
            // for the last stage).
            let mb = next_b[s];
            if mb < nmb && !end_f[s][mb].is_nan() {
                let (dep, comm) = if s == s_n - 1 {
                    (end_f[s][mb], 0.0)
                } else if end_b[s + 1][mb].is_nan() {
                    (f64::NAN, 0.0)
                } else {
                    (end_b[s + 1][mb], stages[s].comm_b_in)
                };
                if !dep.is_nan() {
                    consider(
                        &mut best,
                        ready(dep, comm, clk, knobs.overlap_aware),
                        0,
                        s,
                        Slot::new(OpKind::B, mb, s),
                    );
                }
            }
            // W candidate (split mode): needs B done; delayed by
            // default (prio 2) so it only wins when nothing else can
            // start earlier — i.e. it fills bubbles.
            if knobs.split_bw {
                let mb = next_w[s];
                if mb < nmb && mb < next_b[s] {
                    let prio = if knobs.w_fill { 2 } else { 0 };
                    consider(
                        &mut best,
                        end_b[s][mb].max(clk),
                        prio,
                        s,
                        Slot::new(OpKind::W, mb, s),
                    );
                }
            }
        }

        let (start, _, s, slot) = best.or(best_overlimit).unwrap_or_else(|| {
            panic!("scheduler stuck: emitted {emitted}/{total_ops} (invalid deps?)")
        });
        let d = stages[s].device;
        let dur = match slot.op {
            OpKind::F => stages[s].f,
            OpKind::B => stages[s].b,
            OpKind::W => stages[s].w,
        };
        let end = start + dur;
        clock[d] = end;
        match slot.op {
            OpKind::F => {
                end_f[s][slot.mb as usize] = end;
                next_f[s] += 1;
                stash[d] += stages[s].act_bytes;
            }
            OpKind::B => {
                end_b[s][slot.mb as usize] = end;
                next_b[s] += 1;
                if !knobs.split_bw {
                    stash[d] -= stages[s].act_bytes;
                }
            }
            OpKind::W => {
                next_w[s] += 1;
                stash[d] -= stages[s].act_bytes;
            }
        }
        out[d].push(slot);
        emitted += 1;
    }

    Schedule {
        p,
        nmb,
        n_stages: s_n,
        split_bw: knobs.split_bw,
        overlap_aware: knobs.overlap_aware,
        per_device: out,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Family, HardwareCfg, ModelCfg, ParallelCfg, Size};
    use crate::model::build_model;
    use crate::partition::uniform;
    use crate::placement::{interleaved, sequential, wave};

    fn profile(fam: Family) -> ProfiledData {
        let spec = build_model(&ModelCfg::table5(fam, Size::Small));
        ProfiledData::analytical(
            &spec,
            &HardwareCfg::default(),
            &ParallelCfg::new(4, 2, 8, 1, 4096),
        )
    }

    #[test]
    fn valid_on_sequential() {
        let prof = profile(Family::Gemma);
        let part = uniform(prof.n_layers(), 4);
        let pl = sequential(4);
        for knobs in [
            SchedKnobs::default(),
            SchedKnobs { split_bw: false, ..SchedKnobs::default() },
            SchedKnobs { w_fill: false, ..SchedKnobs::default() },
        ] {
            let sch = greedy_schedule(&prof, &part, &pl, 8, knobs);
            sch.validate(&pl).unwrap_or_else(|e| panic!("{knobs:?}: {e}"));
        }
    }

    #[test]
    fn valid_on_interleaved_and_wave() {
        let prof = profile(Family::NemotronH);
        for pl in [interleaved(4, 2), wave(4, 2)] {
            let part = uniform(prof.n_layers(), pl.n_stages());
            let sch = greedy_schedule(&prof, &part, &pl, 8, SchedKnobs::default());
            sch.validate(&pl).unwrap_or_else(|e| panic!("{e}"));
        }
    }

    #[test]
    fn w_is_delayed_when_filling() {
        let prof = profile(Family::Gemma);
        let part = uniform(prof.n_layers(), 4);
        let pl = sequential(4);
        let sch = greedy_schedule(&prof, &part, &pl, 8, SchedKnobs::default());
        // On device 0 the first W should come after several B's (W's
        // pushed into the drain phase), unlike immediate-W scheduling.
        let d0 = &sch.per_device[0];
        let first_w = d0.iter().position(|s| s.op == OpKind::W).unwrap();
        let bs_before = d0[..first_w].iter().filter(|s| s.op == OpKind::B).count();
        assert!(bs_before >= 1, "W at {first_w}, B's before: {bs_before}");
    }

    #[test]
    fn tight_memory_still_schedules() {
        let mut prof = profile(Family::Gemma);
        prof.mem_capacity = 1.0; // pathological: forces stash==0 path
        let part = uniform(prof.n_layers(), 4);
        let pl = sequential(4);
        let sch = greedy_schedule(&prof, &part, &pl, 4, SchedKnobs::default());
        sch.validate(&pl).unwrap();
    }
}
