//! Model partition: splitting the flat layer list into contiguous
//! pipeline stages (paper §2.2), plus the partition policies used as
//! Pipeline Generator seeds and the tuning move (§4.3 "Model Partition
//! Tuning").

use crate::profile::ProfiledData;

/// A partition of `n_layers` into `S` contiguous stages, stored as
/// stage start offsets: stage `s` covers `bounds[s]..bounds[s+1]`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Partition {
    pub bounds: Vec<usize>,
}

impl Partition {
    pub fn from_sizes(sizes: &[usize]) -> Partition {
        let mut bounds = vec![0];
        for &s in sizes {
            assert!(s > 0, "empty stage");
            bounds.push(bounds.last().unwrap() + s);
        }
        Partition { bounds }
    }

    pub fn n_stages(&self) -> usize {
        self.bounds.len() - 1
    }

    pub fn n_layers(&self) -> usize {
        *self.bounds.last().unwrap()
    }

    pub fn stage_range(&self, s: usize) -> std::ops::Range<usize> {
        self.bounds[s]..self.bounds[s + 1]
    }

    pub fn stage_len(&self, s: usize) -> usize {
        self.bounds[s + 1] - self.bounds[s]
    }

    /// Which stage owns layer `l`.
    pub fn stage_of(&self, l: usize) -> usize {
        match self.bounds.binary_search(&l) {
            Ok(i) => i.min(self.n_stages() - 1),
            Err(i) => i - 1,
        }
    }

    /// Move one layer across the boundary between stages `s` and `s+1`.
    /// `toward_earlier`: shift boundary right→left (stage s gives its
    /// last layer to s+1) when false, or s+1 gives its first layer to s
    /// when true.  Returns false (no-op) if a stage would become empty.
    pub fn shift_boundary(&mut self, s: usize, toward_earlier: bool) -> bool {
        assert!(s + 1 < self.bounds.len() - 0 && s + 1 <= self.n_stages());
        let b = self.bounds[s + 1];
        if toward_earlier {
            // s absorbs first layer of s+1.
            if self.stage_len(s + 1) <= 1 {
                return false;
            }
            self.bounds[s + 1] = b + 1;
        } else {
            // s+1 absorbs last layer of s.
            if self.stage_len(s) <= 1 {
                return false;
            }
            self.bounds[s + 1] = b - 1;
        }
        true
    }

    /// Validity: monotone bounds, no empty stage, covers all layers.
    pub fn is_valid(&self) -> bool {
        self.bounds.len() >= 2
            && self.bounds[0] == 0
            && self.bounds.windows(2).all(|w| w[0] < w[1])
    }
}

/// Uniform layer split (the S-1F1B / Megatron default, §2.2): each
/// stage gets `⌈n/S⌉` or `⌊n/S⌋` layers, remainder spread from the
/// front.
pub fn uniform(n_layers: usize, n_stages: usize) -> Partition {
    assert!(n_stages >= 1 && n_layers >= n_stages);
    let base = n_layers / n_stages;
    let rem = n_layers % n_stages;
    let sizes: Vec<usize> =
        (0..n_stages).map(|s| base + usize::from(s < rem)).collect();
    Partition::from_sizes(&sizes)
}

/// Compute-balanced partition (the Mist-style seed, §2.2): dynamic
/// programming that minimises the maximum per-stage fused compute
/// (F+B+W).  O(S · n²) — exact, not a heuristic.
pub fn balanced(profile: &ProfiledData, n_stages: usize) -> Partition {
    let w: Vec<f64> = profile.layers.iter().map(|l| l.f + l.b + l.w).collect();
    balanced_by(&w, n_stages)
}

/// Memory-balanced partition: the same exact DP over per-layer memory
/// (static + one micro-batch of stash) instead of compute.  Used as an
/// extra Pipeline Generator seed when per-device memory caps bind —
/// compute-balanced splits concentrate the vocab head's huge embedding
/// on one device, which is exactly what a tight cap rejects.
pub fn memory_balanced(profile: &ProfiledData, n_stages: usize) -> Partition {
    let w: Vec<f64> = profile.layers.iter().map(|l| l.mem_static + l.mem_act).collect();
    balanced_by(&w, n_stages)
}

/// Min-max DP over arbitrary non-negative per-layer weights.
fn balanced_by(w: &[f64], n_stages: usize) -> Partition {
    let n = w.len();
    assert!(n >= n_stages);
    let mut prefix = vec![0.0; n + 1];
    for i in 0..n {
        prefix[i + 1] = prefix[i] + w[i];
    }
    let seg = |a: usize, b: usize| prefix[b] - prefix[a]; // layers a..b
    // dp[s][i] = min over partitions of first i layers into s stages of
    // the max stage weight.
    let inf = f64::INFINITY;
    let mut dp = vec![vec![inf; n + 1]; n_stages + 1];
    let mut cut = vec![vec![0usize; n + 1]; n_stages + 1];
    dp[0][0] = 0.0;
    for s in 1..=n_stages {
        for i in s..=n {
            // last stage covers j..i
            for j in (s - 1)..i {
                let cand = dp[s - 1][j].max(seg(j, i));
                if cand < dp[s][i] {
                    dp[s][i] = cand;
                    cut[s][i] = j;
                }
            }
        }
    }
    // Recover bounds.
    let mut bounds = vec![n];
    let mut i = n;
    for s in (1..=n_stages).rev() {
        i = cut[s][i];
        bounds.push(i);
    }
    bounds.reverse();
    assert_eq!(bounds[0], 0);
    Partition { bounds }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Family, HardwareCfg, ModelCfg, ParallelCfg, Size};
    use crate::model::build_model;
    use crate::profile::ProfiledData;

    fn gemma_profile() -> ProfiledData {
        let spec = build_model(&ModelCfg::table5(Family::Gemma, Size::Small));
        ProfiledData::analytical(
            &spec,
            &HardwareCfg::default(),
            &ParallelCfg::new(4, 2, 16, 1, 4096),
        )
    }

    #[test]
    fn uniform_covers() {
        let p = uniform(10, 4);
        assert!(p.is_valid());
        assert_eq!(p.n_stages(), 4);
        assert_eq!(p.n_layers(), 10);
        let sizes: Vec<usize> = (0..4).map(|s| p.stage_len(s)).collect();
        assert_eq!(sizes, vec![3, 3, 2, 2]);
    }

    #[test]
    fn stage_of_consistent() {
        let p = uniform(66, 4);
        for l in 0..66 {
            let s = p.stage_of(l);
            assert!(p.stage_range(s).contains(&l), "layer {l} stage {s}");
        }
    }

    #[test]
    fn balanced_beats_uniform_on_gemma() {
        // The head is worth many blocks: the balanced split must give the
        // last stage far fewer layers and achieve lower max stage cost.
        let prof = gemma_profile();
        let uni = uniform(prof.n_layers(), 4);
        let bal = balanced(&prof, 4);
        let maxcost = |p: &Partition| {
            (0..p.n_stages())
                .map(|s| {
                    let c = prof.stage_cost(p.stage_range(s));
                    c.f + c.b + c.w
                })
                .fold(0.0f64, f64::max)
        };
        assert!(bal.is_valid());
        assert!(
            maxcost(&bal) < 0.8 * maxcost(&uni),
            "balanced {:.3e} should beat uniform {:.3e}",
            maxcost(&bal),
            maxcost(&uni)
        );
        assert!(bal.stage_len(3) < uni.stage_len(3));
    }

    #[test]
    fn memory_balanced_spreads_static_memory() {
        // Gemma's embedding + head dominate static memory; the
        // memory-balanced split must achieve a lower max per-stage
        // footprint than the uniform split.
        let prof = gemma_profile();
        let uni = uniform(prof.n_layers(), 4);
        let mem = memory_balanced(&prof, 4);
        let maxmem = |p: &Partition| {
            (0..p.n_stages())
                .map(|s| {
                    let c = prof.stage_cost(p.stage_range(s));
                    c.mem_static + c.mem_act
                })
                .fold(0.0f64, f64::max)
        };
        assert!(mem.is_valid());
        assert_eq!(mem.n_layers(), prof.n_layers());
        assert!(
            maxmem(&mem) < maxmem(&uni),
            "memory-balanced {:.3e} should beat uniform {:.3e}",
            maxmem(&mem),
            maxmem(&uni)
        );
    }

    #[test]
    fn shift_boundary_moves_one_layer() {
        let mut p = uniform(8, 4);
        assert!(p.shift_boundary(1, true));
        assert_eq!(p.stage_len(1), 3);
        assert_eq!(p.stage_len(2), 1);
        // Shrinking an 1-layer stage must refuse.
        assert!(!p.shift_boundary(2, false));
        assert!(p.is_valid());
    }
}
