//! Cluster backends for executing lowered [`crate::executor::Program`]s:
//!
//! - [`spec`]: static per-device resource description (memory
//!   capacities, heterogeneous allowed) consumed by the planning stack;
//! - [`sim`]: discrete-event simulator with rendezvous send semantics —
//!   instruction-level timing (validates the executor's comm passes and
//!   quantifies overlap/deadlock-repair effects);
//! - [`fault`]: deterministic fault & drift injection for [`sim`] —
//!   the scenario generator the elastic re-planning loop
//!   ([`crate::adapt`]) is exercised against;
//! - [`real`]: the message fabric for the thread-per-device RealCluster
//!   (used by [`crate::trainer`] to run actual PJRT compute).

pub mod fault;
pub mod real;
pub mod sim;
pub mod spec;

pub use fault::{Drift, FaultEvent, FaultPlan, FaultView, LinkWindow, RetryPolicy, StepFaults};
pub use spec::{ClusterSpec, DeviceSpec};
