//! Message fabric for the RealCluster: one OS thread per pipeline
//! device, mpsc channels as P2P links, tagged messages with per-device
//! mailboxes so out-of-order arrivals (hoisted receives, W-filled
//! schedules) never block the transport.
//!
//! The driver (trainer main thread) participates as pseudo-device
//! `p` — it injects micro-batch inputs/targets and collects losses.

use std::collections::HashMap;
use std::sync::mpsc::{channel, Receiver, Sender};

use crate::runtime::Tensor;

/// Logical channel id: (micro-batch, producer stage, consumer stage,
/// kind) — the executor's [`crate::executor::Chan`], one key space
/// across the abstract passes, the SimCluster and this fabric.  Driver
/// I/O uses reserved stage ids (see [`Tag`]).
pub type ChannelKey = crate::executor::Chan;

/// Message tag distinguishing payload streams.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Tag {
    /// Pipeline activation / gradient traffic.
    Chan(ChannelKey),
    /// Driver → first-stage device: token ids for `mb`.
    Ids(u32),
    /// Driver → head device: target ids for `mb`.
    Targets(u32),
    /// Head device → driver: scalar loss for `mb`.
    Loss(u32),
    /// Driver → device: control (step barrier release).
    Step(u64),
    /// Device → driver: step finished (device id in payload shape[0]).
    Done(u64),
}

/// A tagged tensor message.
pub struct Msg {
    pub tag: Tag,
    pub tensor: Tensor,
}

/// Per-endpoint mailbox: a receiver plus a buffer for out-of-order
/// messages.
pub struct Mailbox {
    rx: Receiver<Msg>,
    buf: HashMap<Tag, Vec<Tensor>>,
}

impl Mailbox {
    /// Blocking receive of a specific tag.
    pub fn recv(&mut self, tag: Tag) -> Tensor {
        if let Some(v) = self.buf.get_mut(&tag) {
            if let Some(t) = v.pop() {
                return t;
            }
        }
        loop {
            let m = self.rx.recv().expect("fabric closed while waiting");
            if m.tag == tag {
                return m.tensor;
            }
            self.buf.entry(m.tag).or_default().push(m.tensor);
        }
    }

    /// Non-blocking check whether a tag is available (buffered or
    /// immediately drainable).
    pub fn try_recv(&mut self, tag: Tag) -> Option<Tensor> {
        if let Some(v) = self.buf.get_mut(&tag) {
            if let Some(t) = v.pop() {
                return Some(t);
            }
        }
        while let Ok(m) = self.rx.try_recv() {
            if m.tag == tag {
                return Some(m.tensor);
            }
            self.buf.entry(m.tag).or_default().push(m.tensor);
        }
        None
    }
}

/// The full fabric: `p` device endpoints + 1 driver endpoint.
pub struct Fabric {
    /// senders[i] = handle for sending *to* endpoint i.
    pub senders: Vec<Sender<Msg>>,
}

impl Fabric {
    /// Build a fabric with `p` devices (+driver).  Returns the fabric
    /// (clonable senders) and the per-endpoint mailboxes in id order
    /// (devices 0..p, driver at index p).
    pub fn new(p: usize) -> (Fabric, Vec<Mailbox>) {
        let mut senders = Vec::with_capacity(p + 1);
        let mut boxes = Vec::with_capacity(p + 1);
        for _ in 0..=p {
            let (tx, rx) = channel();
            senders.push(tx);
            boxes.push(Mailbox { rx, buf: HashMap::new() });
        }
        (Fabric { senders }, boxes)
    }

    pub fn send(&self, to: usize, tag: Tag, tensor: Tensor) {
        self.senders[to]
            .send(Msg { tag, tensor })
            .expect("fabric endpoint dropped");
    }

    pub fn clone_senders(&self) -> Fabric {
        Fabric { senders: self.senders.clone() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::OpKind;

    #[test]
    fn out_of_order_delivery() {
        let (fab, mut boxes) = Fabric::new(1);
        let key_a = Tag::Chan((0, 0, 1, OpKind::F));
        let key_b = Tag::Chan((1, 0, 1, OpKind::F));
        fab.send(0, key_b, Tensor::ones(&[2]));
        fab.send(0, key_a, Tensor::zeros(&[2]));
        // Ask for A first even though B arrived first.
        let a = boxes[0].recv(key_a);
        assert_eq!(a.f32s(), &[0.0, 0.0]);
        let b = boxes[0].recv(key_b);
        assert_eq!(b.f32s(), &[1.0, 1.0]);
    }

    #[test]
    fn cross_thread_roundtrip() {
        let (fab, mut boxes) = Fabric::new(2);
        let driver_box = boxes.pop().unwrap();
        let mut b1 = boxes.pop().unwrap();
        let mut b0 = boxes.pop().unwrap();
        let f2 = fab.clone_senders();
        let h0 = std::thread::spawn(move || {
            let x = b0.recv(Tag::Ids(0));
            f2.send(1, Tag::Chan((0, 0, 1, OpKind::F)), x);
        });
        let f3 = fab.clone_senders();
        let h1 = std::thread::spawn(move || {
            let x = b1.recv(Tag::Chan((0, 0, 1, OpKind::F)));
            f3.send(2, Tag::Loss(0), x);
        });
        fab.send(0, Tag::Ids(0), Tensor::iota(&[4], 1.0));
        let mut driver_box = driver_box;
        let out = driver_box.recv(Tag::Loss(0));
        assert_eq!(out.f32s(), &[0.0, 1.0, 2.0, 3.0]);
        h0.join().unwrap();
        h1.join().unwrap();
    }

    #[test]
    fn try_recv_buffers() {
        let (fab, mut boxes) = Fabric::new(1);
        assert!(boxes[0].try_recv(Tag::Step(1)).is_none());
        fab.send(0, Tag::Done(7), Tensor::zeros(&[1]));
        fab.send(0, Tag::Step(1), Tensor::zeros(&[1]));
        assert!(boxes[0].try_recv(Tag::Step(1)).is_some());
        assert!(boxes[0].try_recv(Tag::Done(7)).is_some());
    }
}
