//! Fault & drift injection for the SimCluster — the adversary the
//! elastic re-planning loop ([`crate::adapt`]) trains against.
//!
//! A [`FaultPlan`] describes how a cluster degrades over a run: smooth
//! per-device drift (thermal throttling modeled as a slow sinusoid),
//! small per-step jitter, step changes (stragglers appearing and
//! disappearing), per-link slowdowns, and device deaths.  The view at
//! any step — [`FaultPlan::view`] — is a *pure function* of
//! `(plan, step)`: drift is closed-form, jitter is a counter-hash of
//! `(seed, step, device)`, and events are explicit step ranges.  No
//! sequential state means any step can be recomputed independently, so
//! every scenario replays **bitwise** from its seed regardless of which
//! steps a harness samples — the determinism the recovery tests in
//! `tests/adapt_replan.rs` pin.
//!
//! Scales multiply *time*: `compute_scale = 2.0` means ops take twice
//! as long (the device runs at half rate); `link_scale` likewise for
//! transfer seconds on a directed device pair.  A dead device freezes —
//! [`crate::cluster::sim::run_timed_faulted`] reports the resulting
//! stall with the blocked peer identified.

/// One discrete fault event.  Step ranges are `[from, until)`;
/// `usize::MAX` means "forever".
#[derive(Clone, Copy, Debug)]
pub enum FaultEvent {
    /// `device` computes `factor`× slower over the step range.
    Straggler { device: usize, factor: f64, from: usize, until: usize },
    /// Transfers on the directed link `src → dst` take `factor`× longer
    /// over the step range.
    LinkDelay { src: usize, dst: usize, factor: f64, from: usize, until: usize },
    /// `device` dies at `step` (permanently).
    Kill { device: usize, step: usize },
}

/// Smooth per-device drift: compute slows by up to `amplitude`
/// (relative), following half a cosine hump per `period` steps, offset
/// by `phase` (radians).  At `phase = 0` the drift is zero at step 0,
/// so an initial plan starts accurate.
#[derive(Clone, Copy, Debug)]
pub struct Drift {
    pub device: usize,
    pub amplitude: f64,
    pub period: f64,
    pub phase: f64,
}

/// A deterministic fault schedule over `p` physical devices.
#[derive(Clone, Debug)]
pub struct FaultPlan {
    pub seed: u64,
    /// Physical devices covered by the plan.
    pub p: usize,
    /// Relative amplitude of per-step compute jitter (0 disables).
    pub jitter: f64,
    pub drift: Vec<Drift>,
    pub events: Vec<FaultEvent>,
}

/// The materialized fault state at one step, in whatever device index
/// space the caller built the plan for (the adapt harness remaps
/// physical → logical before handing a view to the simulator).
#[derive(Clone, Debug)]
pub struct FaultView {
    pub step: usize,
    /// Per-device multiplier on op durations (≥ some small floor).
    pub compute_scale: Vec<f64>,
    /// Row-major `p×p` multiplier on transfer seconds for the directed
    /// link `src·p + dst`.
    pub link_scale: Vec<f64>,
    pub alive: Vec<bool>,
}

impl FaultView {
    /// The no-fault view (all scales 1, everyone alive).
    pub fn healthy(p: usize) -> FaultView {
        FaultView {
            step: 0,
            compute_scale: vec![1.0; p],
            link_scale: vec![1.0; p * p],
            alive: vec![true; p],
        }
    }

    pub fn link(&self, src: usize, dst: usize) -> f64 {
        self.link_scale[src * self.alive.len() + dst]
    }

    /// True when every scale is exactly 1 and everyone is alive — lets
    /// the simulator take its unfaulted (bitwise-pinned) path.
    pub fn is_healthy(&self) -> bool {
        self.compute_scale.iter().all(|&s| s == 1.0)
            && self.link_scale.iter().all(|&s| s == 1.0)
            && self.alive.iter().all(|&a| a)
    }
}

/// Deterministic timeout + capped-exponential-backoff policy for the
/// mid-step transport in [`crate::cluster::sim::run_timed_midstep`].
///
/// All randomness (per-attempt jitter) is a pure function of
/// `(seed, device, attempt)` via the seeded [`crate::util::rng`] — never
/// wall clock — so a faulted run replays bitwise from its seed.  The
/// same policy prices *failure detection*: a peer that stops responding
/// is declared dead only after the full timeout/retry ladder has been
/// exhausted, which is exactly [`RetryPolicy::detect_latency`].
#[derive(Clone, Copy, Debug)]
pub struct RetryPolicy {
    /// Seconds before one send/recv attempt is abandoned.
    pub timeout_s: f64,
    /// First backoff interval; attempt `k` waits `base · 2^k`.
    pub backoff_base_s: f64,
    /// Cap on any single backoff interval.
    pub backoff_cap_s: f64,
    /// Attempts after the first before giving up (declaring the peer
    /// dead, or — for transient link windows — forcing the transfer
    /// through at its degraded duration).
    pub max_retries: usize,
    /// Relative jitter on each backoff interval, in `[0, 1)`.
    pub jitter: f64,
    /// Seed for the jitter stream (independent of the fault-plan seed).
    pub seed: u64,
}

impl Default for RetryPolicy {
    fn default() -> RetryPolicy {
        RetryPolicy {
            timeout_s: 10e-3,
            backoff_base_s: 5e-3,
            backoff_cap_s: 40e-3,
            max_retries: 3,
            jitter: 0.2,
            seed: 0x5e7_2e7_12,
        }
    }
}

impl RetryPolicy {
    /// Backoff slept after abandoned attempt `attempt` (0-based) on
    /// `device` — capped exponential with seeded multiplicative jitter.
    /// Pure in `(self, device, attempt)`.
    pub fn backoff_s(&self, device: usize, attempt: usize) -> f64 {
        let exp = self.backoff_base_s * (1u64 << attempt.min(40)) as f64;
        let base = exp.min(self.backoff_cap_s);
        if self.jitter == 0.0 {
            return base;
        }
        let h = mix64(self.seed ^ mix64(device as u64) ^ (attempt as u64).wrapping_mul(0x9e37));
        base * (1.0 + self.jitter * (2.0 * unit(h) - 1.0))
    }

    /// Virtual seconds from a peer's death until `device` declares it
    /// dead: the initial timeout plus every backoff + re-timeout in the
    /// retry ladder.  Deterministic, so detection cost replays bitwise.
    pub fn detect_latency(&self, device: usize) -> f64 {
        let mut t = self.timeout_s;
        for k in 0..self.max_retries {
            t += self.backoff_s(device, k) + self.timeout_s;
        }
        t
    }
}

/// A transient slowdown window on the directed link `src → dst`,
/// expressed in *virtual seconds within one step* (as opposed to
/// [`FaultEvent::LinkDelay`]'s whole-step granularity).
#[derive(Clone, Copy, Debug)]
pub struct LinkWindow {
    pub src: usize,
    pub dst: usize,
    /// Transfer-duration multiplier while the window is active (> 1).
    pub factor: f64,
    /// Window `[from_s, until_s)` relative to step start.
    pub from_s: f64,
    pub until_s: f64,
}

/// Intra-step fault events consumed by
/// [`crate::cluster::sim::run_timed_midstep`]: at most one device kill
/// (at a virtual time within the step) plus transient link windows.
/// [`StepFaults::none`] is the identity — the runner is then bitwise
/// equal to [`crate::cluster::sim::run_timed_faulted`].
#[derive(Clone, Debug, Default)]
pub struct StepFaults {
    /// `(device, kill_at_s)`: the device freezes at that virtual time;
    /// any op that would complete after it is lost.
    pub kill: Option<(usize, f64)>,
    pub links: Vec<LinkWindow>,
}

impl StepFaults {
    pub fn none() -> StepFaults {
        StepFaults::default()
    }

    /// Duration multiplier for a transfer starting at `t` on `src→dst`
    /// (product of active windows; exactly 1.0 when none apply, so the
    /// unfaulted arithmetic is untouched).
    pub fn link_factor(&self, src: usize, dst: usize, t: f64) -> f64 {
        let mut f = 1.0;
        for w in &self.links {
            if w.src == src && w.dst == dst && t >= w.from_s && t < w.until_s {
                f *= w.factor;
            }
        }
        f
    }
}

/// SplitMix64 finalizer — the same mixer [`crate::util::rng`] seeds
/// with, used here as a counter hash so jitter at `(seed, step, device)`
/// is stateless.
fn mix64(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9e3779b97f4a7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
    z ^ (z >> 31)
}

/// Uniform in [0, 1) from a hash (53-bit mantissa fill).
fn unit(h: u64) -> f64 {
    (h >> 11) as f64 / (1u64 << 53) as f64
}

impl FaultPlan {
    /// A fault-free plan (the control scenario).
    pub fn healthy(p: usize) -> FaultPlan {
        FaultPlan { seed: 0, p, jitter: 0.0, drift: Vec::new(), events: Vec::new() }
    }

    pub fn with_jitter(mut self, jitter: f64) -> FaultPlan {
        self.jitter = jitter;
        self
    }

    pub fn with_drift(mut self, d: Drift) -> FaultPlan {
        self.drift.push(d);
        self
    }

    pub fn with_event(mut self, e: FaultEvent) -> FaultPlan {
        self.events.push(e);
        self
    }

    /// First step at which any device is dead, if the plan kills one.
    pub fn first_kill(&self) -> Option<usize> {
        self.events
            .iter()
            .filter_map(|e| match e {
                FaultEvent::Kill { step, .. } => Some(*step),
                _ => None,
            })
            .min()
    }

    /// First step at which any fault (drift aside) is active — used by
    /// harnesses to anchor steps-to-recover.
    pub fn first_onset(&self) -> Option<usize> {
        self.events
            .iter()
            .map(|e| match e {
                FaultEvent::Straggler { from, .. } => *from,
                FaultEvent::LinkDelay { from, .. } => *from,
                FaultEvent::Kill { step, .. } => *step,
            })
            .min()
    }

    /// Where inside its kill step a device's death lands, as a fraction
    /// of the step's predicted makespan in `(0.05, 0.95)` — a pure
    /// counter-hash of `(seed, device)`, so mid-step kill times replay
    /// bitwise from the seed.  Harnesses multiply this by the active
    /// plan's predicted step time to get `kill_at_s`.
    pub fn kill_frac(&self, device: usize) -> f64 {
        let h = mix64(self.seed ^ 0x6b11_1_f2ac ^ mix64(device as u64 ^ 0x9e37));
        0.05 + 0.9 * unit(h)
    }

    /// Structural sanity: indices in range, ranges non-empty, and — the
    /// part last-writer-wins used to paper over — at most one `Kill`
    /// per device.  Two kills on one device always meant a scenario
    /// author error; the earlier one silently won in `view()`.
    pub fn validate(&self) -> Result<(), String> {
        let mut kills: Vec<usize> = Vec::new();
        for e in &self.events {
            match *e {
                FaultEvent::Straggler { device, factor, from, until } => {
                    if device >= self.p {
                        return Err(format!("straggler device {device} out of range (p={})", self.p));
                    }
                    if !(factor > 0.0) || from >= until {
                        return Err(format!("straggler on {device}: bad factor/range"));
                    }
                }
                FaultEvent::LinkDelay { src, dst, factor, from, until } => {
                    if src >= self.p || dst >= self.p {
                        return Err(format!("link delay {src}->{dst} out of range (p={})", self.p));
                    }
                    if !(factor > 0.0) || from >= until {
                        return Err(format!("link delay {src}->{dst}: bad factor/range"));
                    }
                }
                FaultEvent::Kill { device, .. } => {
                    if device >= self.p {
                        return Err(format!("kill device {device} out of range (p={})", self.p));
                    }
                    if kills.contains(&device) {
                        return Err(format!(
                            "overlapping Kill events for device {device}: a device dies once; \
                             merge or drop the duplicate"
                        ));
                    }
                    kills.push(device);
                }
            }
        }
        for d in &self.drift {
            if d.device >= self.p {
                return Err(format!("drift device {} out of range (p={})", d.device, self.p));
            }
        }
        Ok(())
    }

    /// Human-readable dump of the whole schedule — what a scenario
    /// author reads to sanity-check a plan before a long run.
    pub fn describe(&self) -> String {
        use std::fmt::Write as _;
        let mut s = String::new();
        let _ = writeln!(s, "FaultPlan: p={} seed={:#x} jitter={}", self.p, self.seed, self.jitter);
        for d in &self.drift {
            let _ = writeln!(
                s,
                "  drift    dev {}: up to {:.0}% slower, period {} steps, phase {:.2}",
                d.device,
                d.amplitude * 100.0,
                d.period,
                d.phase
            );
        }
        for e in &self.events {
            match *e {
                FaultEvent::Straggler { device, factor, from, until } => {
                    let _ = writeln!(
                        s,
                        "  straggler dev {device}: {factor}x slower, steps [{from}, {})",
                        RangeEnd(until)
                    );
                }
                FaultEvent::LinkDelay { src, dst, factor, from, until } => {
                    let _ = writeln!(
                        s,
                        "  link      {src} -> {dst}: {factor}x slower, steps [{from}, {})",
                        RangeEnd(until)
                    );
                }
                FaultEvent::Kill { device, step } => {
                    let _ = writeln!(
                        s,
                        "  kill      dev {device}: dies at step {step} ({:.0}% into the step)",
                        self.kill_frac(device) * 100.0
                    );
                }
            }
        }
        if self.drift.is_empty() && self.events.is_empty() {
            let _ = writeln!(s, "  (healthy: no events)");
        }
        s
    }

    /// Materialize the fault state at `step` — pure in `(self, step)`.
    pub fn view(&self, step: usize) -> FaultView {
        let mut v = FaultView::healthy(self.p);
        v.step = step;
        for d in &self.drift {
            debug_assert!(d.device < self.p);
            // Half-cosine hump: 0 at phase 0, peaks at `amplitude`.
            let x = 2.0 * std::f64::consts::PI * (step as f64 / d.period) + d.phase;
            let hump = 0.5 * (1.0 - x.cos());
            v.compute_scale[d.device] *= 1.0 + d.amplitude * hump;
        }
        if self.jitter > 0.0 {
            for dev in 0..self.p {
                let h = mix64(
                    self.seed ^ (step as u64).wrapping_mul(0x9e3779b97f4a7c15) ^ dev as u64,
                );
                // Symmetric multiplicative jitter in [1-j, 1+j).
                v.compute_scale[dev] *= 1.0 + self.jitter * (2.0 * unit(h) - 1.0);
            }
        }
        for e in &self.events {
            match *e {
                FaultEvent::Straggler { device, factor, from, until } => {
                    if step >= from && step < until {
                        v.compute_scale[device] *= factor;
                    }
                }
                FaultEvent::LinkDelay { src, dst, factor, from, until } => {
                    if step >= from && step < until {
                        v.link_scale[src * self.p + dst] *= factor;
                    }
                }
                FaultEvent::Kill { device, step: at } => {
                    if step >= at {
                        v.alive[device] = false;
                    }
                }
            }
        }
        for s in &mut v.compute_scale {
            *s = s.max(1e-3);
        }
        v
    }
}

/// Displays `usize::MAX` step-range ends as `inf` in [`FaultPlan::describe`].
struct RangeEnd(usize);

impl std::fmt::Display for RangeEnd {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        if self.0 == usize::MAX {
            write!(f, "inf")
        } else {
            write!(f, "{}", self.0)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn plan() -> FaultPlan {
        FaultPlan { seed: 7, p: 4, jitter: 0.01, drift: Vec::new(), events: Vec::new() }
            .with_drift(Drift { device: 1, amplitude: 0.3, period: 64.0, phase: 0.0 })
            .with_event(FaultEvent::Straggler { device: 2, factor: 2.0, from: 10, until: 20 })
            .with_event(FaultEvent::LinkDelay {
                src: 0,
                dst: 1,
                factor: 3.0,
                from: 5,
                until: usize::MAX,
            })
            .with_event(FaultEvent::Kill { device: 3, step: 30 })
    }

    #[test]
    fn views_replay_bitwise_and_statelessly() {
        let p = plan();
        // Same step twice, and out of order: bitwise identical.
        let a = p.view(17);
        let b = p.view(17);
        assert_eq!(a.compute_scale, b.compute_scale);
        assert_eq!(a.link_scale, b.link_scale);
        assert_eq!(a.alive, b.alive);
        let later = p.view(40);
        let again = p.view(17);
        assert_eq!(a.compute_scale, again.compute_scale);
        assert!(!later.alive[3]);
    }

    #[test]
    fn events_respect_their_ranges() {
        let p = plan();
        assert!(p.view(9).compute_scale[2] < 1.5, "straggler not yet active");
        assert!(p.view(10).compute_scale[2] >= 2.0 * 0.99);
        assert!(p.view(20).compute_scale[2] < 1.5, "straggler expired");
        assert_eq!(p.view(4).link(0, 1), 1.0);
        assert_eq!(p.view(5).link(0, 1), 3.0);
        assert!(p.view(29).alive[3] && !p.view(30).alive[3]);
        assert_eq!(p.first_kill(), Some(30));
        assert_eq!(p.first_onset(), Some(5));
    }

    #[test]
    fn drift_starts_at_zero_and_seeds_differ() {
        let p = FaultPlan::healthy(2)
            .with_drift(Drift { device: 0, amplitude: 0.5, period: 100.0, phase: 0.0 });
        assert_eq!(p.view(0).compute_scale[0], 1.0, "phase-0 drift is 0 at step 0");
        assert!(p.view(50).compute_scale[0] > 1.4, "hump peaks mid-period");
        let a = FaultPlan { seed: 1, ..FaultPlan::healthy(2) }.with_jitter(0.05);
        let b = FaultPlan { seed: 2, ..FaultPlan::healthy(2) }.with_jitter(0.05);
        assert_ne!(a.view(3).compute_scale, b.view(3).compute_scale);
        assert!(FaultPlan::healthy(3).view(12).is_healthy());
    }

    #[test]
    fn validate_rejects_overlapping_kills_and_bad_indices() {
        assert!(plan().validate().is_ok());
        let dup = plan()
            .with_event(FaultEvent::Kill { device: 3, step: 50 });
        let err = dup.validate().unwrap_err();
        assert!(err.contains("overlapping Kill"), "got: {err}");
        let oob = FaultPlan::healthy(2).with_event(FaultEvent::Kill { device: 5, step: 1 });
        assert!(oob.validate().is_err());
        let bad = FaultPlan::healthy(2).with_event(FaultEvent::Straggler {
            device: 0,
            factor: 2.0,
            from: 9,
            until: 9,
        });
        assert!(bad.validate().is_err());
    }

    #[test]
    fn describe_is_human_readable_and_complete() {
        let s = plan().describe();
        assert!(s.contains("p=4"), "{s}");
        assert!(s.contains("straggler dev 2"), "{s}");
        assert!(s.contains("0 -> 1"), "{s}");
        assert!(s.contains("inf"), "open-ended range prints as inf: {s}");
        assert!(s.contains("kill"), "{s}");
        assert!(FaultPlan::healthy(2).describe().contains("healthy"));
    }

    #[test]
    fn kill_frac_is_seeded_and_interior() {
        let p = plan();
        for d in 0..4 {
            let f = p.kill_frac(d);
            assert!(f > 0.05 - 1e-12 && f < 0.95, "{f}");
            assert_eq!(f.to_bits(), p.kill_frac(d).to_bits(), "pure counter-hash");
        }
        let other = FaultPlan { seed: 99, ..plan() };
        assert_ne!(p.kill_frac(1).to_bits(), other.kill_frac(1).to_bits());
    }

    #[test]
    fn retry_policy_is_deterministic_and_monotone() {
        let r = RetryPolicy::default();
        let d0 = r.detect_latency(0);
        assert_eq!(d0.to_bits(), r.detect_latency(0).to_bits(), "bitwise replay");
        assert!(d0 > r.timeout_s, "ladder adds to the base timeout");
        // Backoffs grow (up to the cap) and jitter stays bounded.
        let b0 = r.backoff_s(0, 0);
        let b2 = r.backoff_s(0, 2);
        assert!(b0 > 0.0 && b2 > b0 * 1.5, "b0={b0} b2={b2}");
        assert!(r.backoff_s(0, 20) <= r.backoff_cap_s * (1.0 + r.jitter));
        let none = RetryPolicy { max_retries: 0, ..r };
        assert_eq!(none.detect_latency(3).to_bits(), none.timeout_s.to_bits());
    }

    #[test]
    fn step_faults_link_factor_windows() {
        let sf = StepFaults {
            kill: None,
            links: vec![LinkWindow { src: 0, dst: 1, factor: 4.0, from_s: 1.0, until_s: 2.0 }],
        };
        assert_eq!(sf.link_factor(0, 1, 0.5), 1.0);
        assert_eq!(sf.link_factor(0, 1, 1.5), 4.0);
        assert_eq!(sf.link_factor(0, 1, 2.0), 1.0, "half-open window");
        assert_eq!(sf.link_factor(1, 0, 1.5), 1.0, "directed");
        assert!(StepFaults::none().kill.is_none());
    }
}
