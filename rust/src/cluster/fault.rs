//! Fault & drift injection for the SimCluster — the adversary the
//! elastic re-planning loop ([`crate::adapt`]) trains against.
//!
//! A [`FaultPlan`] describes how a cluster degrades over a run: smooth
//! per-device drift (thermal throttling modeled as a slow sinusoid),
//! small per-step jitter, step changes (stragglers appearing and
//! disappearing), per-link slowdowns, and device deaths.  The view at
//! any step — [`FaultPlan::view`] — is a *pure function* of
//! `(plan, step)`: drift is closed-form, jitter is a counter-hash of
//! `(seed, step, device)`, and events are explicit step ranges.  No
//! sequential state means any step can be recomputed independently, so
//! every scenario replays **bitwise** from its seed regardless of which
//! steps a harness samples — the determinism the recovery tests in
//! `tests/adapt_replan.rs` pin.
//!
//! Scales multiply *time*: `compute_scale = 2.0` means ops take twice
//! as long (the device runs at half rate); `link_scale` likewise for
//! transfer seconds on a directed device pair.  A dead device freezes —
//! [`crate::cluster::sim::run_timed_faulted`] reports the resulting
//! stall with the blocked peer identified.

/// One discrete fault event.  Step ranges are `[from, until)`;
/// `usize::MAX` means "forever".
#[derive(Clone, Copy, Debug)]
pub enum FaultEvent {
    /// `device` computes `factor`× slower over the step range.
    Straggler { device: usize, factor: f64, from: usize, until: usize },
    /// Transfers on the directed link `src → dst` take `factor`× longer
    /// over the step range.
    LinkDelay { src: usize, dst: usize, factor: f64, from: usize, until: usize },
    /// `device` dies at `step` (permanently).
    Kill { device: usize, step: usize },
}

/// Smooth per-device drift: compute slows by up to `amplitude`
/// (relative), following half a cosine hump per `period` steps, offset
/// by `phase` (radians).  At `phase = 0` the drift is zero at step 0,
/// so an initial plan starts accurate.
#[derive(Clone, Copy, Debug)]
pub struct Drift {
    pub device: usize,
    pub amplitude: f64,
    pub period: f64,
    pub phase: f64,
}

/// A deterministic fault schedule over `p` physical devices.
#[derive(Clone, Debug)]
pub struct FaultPlan {
    pub seed: u64,
    /// Physical devices covered by the plan.
    pub p: usize,
    /// Relative amplitude of per-step compute jitter (0 disables).
    pub jitter: f64,
    pub drift: Vec<Drift>,
    pub events: Vec<FaultEvent>,
}

/// The materialized fault state at one step, in whatever device index
/// space the caller built the plan for (the adapt harness remaps
/// physical → logical before handing a view to the simulator).
#[derive(Clone, Debug)]
pub struct FaultView {
    pub step: usize,
    /// Per-device multiplier on op durations (≥ some small floor).
    pub compute_scale: Vec<f64>,
    /// Row-major `p×p` multiplier on transfer seconds for the directed
    /// link `src·p + dst`.
    pub link_scale: Vec<f64>,
    pub alive: Vec<bool>,
}

impl FaultView {
    /// The no-fault view (all scales 1, everyone alive).
    pub fn healthy(p: usize) -> FaultView {
        FaultView {
            step: 0,
            compute_scale: vec![1.0; p],
            link_scale: vec![1.0; p * p],
            alive: vec![true; p],
        }
    }

    pub fn link(&self, src: usize, dst: usize) -> f64 {
        self.link_scale[src * self.alive.len() + dst]
    }

    /// True when every scale is exactly 1 and everyone is alive — lets
    /// the simulator take its unfaulted (bitwise-pinned) path.
    pub fn is_healthy(&self) -> bool {
        self.compute_scale.iter().all(|&s| s == 1.0)
            && self.link_scale.iter().all(|&s| s == 1.0)
            && self.alive.iter().all(|&a| a)
    }
}

/// SplitMix64 finalizer — the same mixer [`crate::util::rng`] seeds
/// with, used here as a counter hash so jitter at `(seed, step, device)`
/// is stateless.
fn mix64(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9e3779b97f4a7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
    z ^ (z >> 31)
}

/// Uniform in [0, 1) from a hash (53-bit mantissa fill).
fn unit(h: u64) -> f64 {
    (h >> 11) as f64 / (1u64 << 53) as f64
}

impl FaultPlan {
    /// A fault-free plan (the control scenario).
    pub fn healthy(p: usize) -> FaultPlan {
        FaultPlan { seed: 0, p, jitter: 0.0, drift: Vec::new(), events: Vec::new() }
    }

    pub fn with_jitter(mut self, jitter: f64) -> FaultPlan {
        self.jitter = jitter;
        self
    }

    pub fn with_drift(mut self, d: Drift) -> FaultPlan {
        self.drift.push(d);
        self
    }

    pub fn with_event(mut self, e: FaultEvent) -> FaultPlan {
        self.events.push(e);
        self
    }

    /// First step at which any device is dead, if the plan kills one.
    pub fn first_kill(&self) -> Option<usize> {
        self.events
            .iter()
            .filter_map(|e| match e {
                FaultEvent::Kill { step, .. } => Some(*step),
                _ => None,
            })
            .min()
    }

    /// First step at which any fault (drift aside) is active — used by
    /// harnesses to anchor steps-to-recover.
    pub fn first_onset(&self) -> Option<usize> {
        self.events
            .iter()
            .map(|e| match e {
                FaultEvent::Straggler { from, .. } => *from,
                FaultEvent::LinkDelay { from, .. } => *from,
                FaultEvent::Kill { step, .. } => *step,
            })
            .min()
    }

    /// Materialize the fault state at `step` — pure in `(self, step)`.
    pub fn view(&self, step: usize) -> FaultView {
        let mut v = FaultView::healthy(self.p);
        v.step = step;
        for d in &self.drift {
            debug_assert!(d.device < self.p);
            // Half-cosine hump: 0 at phase 0, peaks at `amplitude`.
            let x = 2.0 * std::f64::consts::PI * (step as f64 / d.period) + d.phase;
            let hump = 0.5 * (1.0 - x.cos());
            v.compute_scale[d.device] *= 1.0 + d.amplitude * hump;
        }
        if self.jitter > 0.0 {
            for dev in 0..self.p {
                let h = mix64(
                    self.seed ^ (step as u64).wrapping_mul(0x9e3779b97f4a7c15) ^ dev as u64,
                );
                // Symmetric multiplicative jitter in [1-j, 1+j).
                v.compute_scale[dev] *= 1.0 + self.jitter * (2.0 * unit(h) - 1.0);
            }
        }
        for e in &self.events {
            match *e {
                FaultEvent::Straggler { device, factor, from, until } => {
                    if step >= from && step < until {
                        v.compute_scale[device] *= factor;
                    }
                }
                FaultEvent::LinkDelay { src, dst, factor, from, until } => {
                    if step >= from && step < until {
                        v.link_scale[src * self.p + dst] *= factor;
                    }
                }
                FaultEvent::Kill { device, step: at } => {
                    if step >= at {
                        v.alive[device] = false;
                    }
                }
            }
        }
        for s in &mut v.compute_scale {
            *s = s.max(1e-3);
        }
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn plan() -> FaultPlan {
        FaultPlan { seed: 7, p: 4, jitter: 0.01, drift: Vec::new(), events: Vec::new() }
            .with_drift(Drift { device: 1, amplitude: 0.3, period: 64.0, phase: 0.0 })
            .with_event(FaultEvent::Straggler { device: 2, factor: 2.0, from: 10, until: 20 })
            .with_event(FaultEvent::LinkDelay {
                src: 0,
                dst: 1,
                factor: 3.0,
                from: 5,
                until: usize::MAX,
            })
            .with_event(FaultEvent::Kill { device: 3, step: 30 })
    }

    #[test]
    fn views_replay_bitwise_and_statelessly() {
        let p = plan();
        // Same step twice, and out of order: bitwise identical.
        let a = p.view(17);
        let b = p.view(17);
        assert_eq!(a.compute_scale, b.compute_scale);
        assert_eq!(a.link_scale, b.link_scale);
        assert_eq!(a.alive, b.alive);
        let later = p.view(40);
        let again = p.view(17);
        assert_eq!(a.compute_scale, again.compute_scale);
        assert!(!later.alive[3]);
    }

    #[test]
    fn events_respect_their_ranges() {
        let p = plan();
        assert!(p.view(9).compute_scale[2] < 1.5, "straggler not yet active");
        assert!(p.view(10).compute_scale[2] >= 2.0 * 0.99);
        assert!(p.view(20).compute_scale[2] < 1.5, "straggler expired");
        assert_eq!(p.view(4).link(0, 1), 1.0);
        assert_eq!(p.view(5).link(0, 1), 3.0);
        assert!(p.view(29).alive[3] && !p.view(30).alive[3]);
        assert_eq!(p.first_kill(), Some(30));
        assert_eq!(p.first_onset(), Some(5));
    }

    #[test]
    fn drift_starts_at_zero_and_seeds_differ() {
        let p = FaultPlan::healthy(2)
            .with_drift(Drift { device: 0, amplitude: 0.5, period: 100.0, phase: 0.0 });
        assert_eq!(p.view(0).compute_scale[0], 1.0, "phase-0 drift is 0 at step 0");
        assert!(p.view(50).compute_scale[0] > 1.4, "hump peaks mid-period");
        let a = FaultPlan { seed: 1, ..FaultPlan::healthy(2) }.with_jitter(0.05);
        let b = FaultPlan { seed: 2, ..FaultPlan::healthy(2) }.with_jitter(0.05);
        assert_ne!(a.view(3).compute_scale, b.view(3).compute_scale);
        assert!(FaultPlan::healthy(3).view(12).is_healthy());
    }
}
